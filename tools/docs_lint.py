"""Docs lint: resolve README/docs cross-links and doctest README snippets.

Checks, for README.md and every docs/*.md file:
  * relative markdown links point at files that exist in the repo;
  * fragment links (``file.md#anchor`` / ``#anchor``) match a heading in
    the target file (GitHub slugification);
then runs ``doctest`` over README.md's ``>>>`` examples with ``src`` on
the path.

Run:  python tools/docs_lint.py       (CI fast lane runs this)
Exit code: number of broken links (+1 if doctests fail).
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style heading anchor."""
    s = re.sub(r"[`*_]", "", heading.strip()).lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    return {slugify(h) for h in HEADING_RE.findall(path.read_text())}


def check_file(path: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, frag = target.partition("#")
        dest = (path.parent / ref).resolve() if ref else path
        if ref and not dest.exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
            continue
        if frag and dest.suffix == ".md" and slugify(frag) not in anchors_of(dest):
            errors.append(
                f"{path.relative_to(REPO)}: missing anchor -> {target}"
            )
    return errors


def main() -> int:
    files = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
    errors: list[str] = []
    for f in files:
        if not f.exists():
            errors.append(f"missing doc file: {f.relative_to(REPO)}")
            continue
        errors.extend(check_file(f))
    for e in errors:
        print(f"LINT: {e}")

    sys.path.insert(0, str(REPO / "src"))
    fails, tried = doctest.testfile(
        str(REPO / "README.md"), module_relative=False, verbose=False
    )
    print(f"docs lint: {len(files)} files, {len(errors)} broken links; "
          f"README doctests: {tried - fails}/{tried} pass")
    return len(errors) + (1 if fails else 0)


if __name__ == "__main__":
    sys.exit(main())
