"""Shared benchmark plumbing: synthetic model profiles + CR measurement.

The paper evaluates six checkpoints on six QA benchmarks; this container
has no trained weights or eval sets, so each paper model is emulated by a
synthetic-KV PROFILE (channel spread / token smoothness / outlier rate
chosen to span the entropy regimes the paper's Figs 3-4 show). Absolute
CRs therefore differ from the paper's; the REPRODUCED quantities are the
relative effects: CR vs pack size (Fig 13), repacking gains (Table I),
PackKV-vs-KIVI at matched distortion (Tables II-V). See EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.block_format import CompressedKVStream
from repro.core.quantization import QuantConfig
from repro.data import synthetic_kv

# six synthetic profiles standing in for the paper's six models (token
# spikes = attention-sink/delimiter outliers — they produce Fig 13's
# falling tail at large pack sizes)
MODEL_PROFILES = {
    "llama2-7b-like": dict(channel_scale=2.0, smooth=0.88, noise=0.20,
                           outlier_frac=0.05, spike_frac=0.10, spike_mag=4.0),
    "llama31-8b-like": dict(channel_scale=1.5, smooth=0.82, noise=0.30,
                            outlier_frac=0.08, spike_frac=0.12, spike_mag=4.5),
    "llama2-13b-like": dict(channel_scale=2.2, smooth=0.90, noise=0.18,
                            outlier_frac=0.04, spike_frac=0.08, spike_mag=4.0),
    "r1-llama-8b-like": dict(channel_scale=1.4, smooth=0.78, noise=0.35,
                             outlier_frac=0.10, spike_frac=0.14, spike_mag=5.0),
    "ministral-8b-like": dict(channel_scale=1.8, smooth=0.85, noise=0.25,
                              outlier_frac=0.06, spike_frac=0.11, spike_mag=4.5),
    "phi4-like": dict(channel_scale=2.0, smooth=0.84, noise=0.22,
                      outlier_frac=0.05, spike_frac=0.10, spike_mag=4.2),
}

HEAD_DIM = 128
N_TOKENS = 512  # 8 blocks of 64
N_HEADS = 4

# (pack_size, repack_mode) sweeps at the turning point (paper §IV-D)
K_PACK_SWEEP = [(4, "greedy_joint"), (8, "greedy_joint"), (16, "greedy_joint"),
                (8, "none")]
V_PACK_SWEEP = [(4, "greedy_joint"), (8, "greedy_joint"), (16, "greedy_joint"),
                (8, "median_v")]


def model_kv(name: str, seed: int = 0, part: str = "k") -> np.ndarray:
    prof = dict(MODEL_PROFILES[name])
    if part == "v":
        # V caches carry token-CATEGORY structure (the groupable pattern
        # repacking exploits — Table I's V gains) and fewer channel outliers
        prof.update(n_patterns=4, pattern_scale=1.2,
                    outlier_frac=prof["outlier_frac"] / 2)
    # deterministic per (model, part)
    seed_v = (abs(hash((name, part))) + seed) % 2**31
    rng = np.random.default_rng(seed_v)
    x = synthetic_kv(rng, 1, N_HEADS, N_TOKENS, HEAD_DIM, **prof)
    return x[0]  # [H, L, D]


def stream_cr(
    k: np.ndarray,
    v: np.ndarray,
    *,
    pack_size: int = 8,
    repack: str = "greedy_joint",
    k_rel: float = 0.1,
    v_rel: float = 0.2,
    part: str = "both",
) -> float:
    """Storage-tier compression ratio over all heads/blocks (paper format)."""
    s = CompressedKVStream(
        pack_size=pack_size,
        repack_mode=repack,
        k_quant=QuantConfig(rel_scale=k_rel),
        v_quant=QuantConfig(rel_scale=v_rel),
    )
    H, L, D = k.shape
    nb = L // 64
    for h in range(H):
        for b in range(nb):
            s.append(k[h, b * 64 : (b + 1) * 64], v[h, b * 64 : (b + 1) * 64],
                     head=h, token_start=b * 64)
    if part == "both":
        return s.compression_ratio()
    # single-part accounting (K or V only)
    sm = s.entries[0].k_block  # noqa: F841 (structure reference)
    bits = 0
    vals = 0
    for e in s.entries:
        blk = e.k_block if part == "k" else e.v_block
        bits += blk.total_bits() + e.n_tokens * 32
        vals += e.n_tokens * blk.shape[1]
    return vals * 16 / bits


def attn_distortion(k: np.ndarray, v: np.ndarray, k_deq: np.ndarray,
                    v_deq: np.ndarray, seed: int = 0) -> float:
    """Decode-attention output relative error — the accuracy proxy.

    Mean over random queries of ||Att(q,K',V') - Att(q,K,V)|| / ||Att||.
    """
    rng = np.random.default_rng(seed)
    H, L, D = k.shape
    q = rng.normal(size=(16, H, D)).astype(np.float32)
    sm = 1.0 / np.sqrt(D)

    def att(K, V):
        s = np.einsum("qhd,hld->qhl", q, K) * sm
        s = s - s.max(-1, keepdims=True)
        p = np.exp(s)
        p /= p.sum(-1, keepdims=True)
        return np.einsum("qhl,hld->qhd", p, V)

    base = att(k, v)
    out = att(k_deq, v_deq)
    return float(np.linalg.norm(out - base) / np.linalg.norm(base))


def quant_roundtrip(x: np.ndarray, rel: float, granularity: str = "token",
                    group: int = 64, bits: int | None = None) -> np.ndarray:
    """Host-side quantize+dequantize for distortion sweeps. x: [H, L, D]."""
    if granularity == "token":
        lo = x.min(-1, keepdims=True)
        hi = x.max(-1, keepdims=True)
        rngs = hi - lo
        scale = rngs / (2**bits - 1) if bits else rel * rngs
        scale = np.where(scale > 0, scale, 1.0)
        maxq = (2**bits - 1) if bits else int(round(1.0 / rel))
        q = np.clip(np.round((x - lo) / scale), 0, maxq)
        return (q * scale + lo).astype(np.float32)
    # channel-wise (KIVI-K): stats along context inside groups
    H, L, D = x.shape
    Lb = (L // group) * group
    xg = x[:, :Lb].reshape(H, Lb // group, group, D)
    lo = xg.min(2, keepdims=True)
    hi = xg.max(2, keepdims=True)
    rngs = hi - lo
    scale = rngs / (2**bits - 1) if bits else rel * rngs
    scale = np.where(scale > 0, scale, 1.0)
    maxq = (2**bits - 1) if bits else int(round(1.0 / rel))
    q = np.clip(np.round((xg - lo) / scale), 0, maxq)
    out = (q * scale + lo).reshape(H, Lb, D)
    return np.concatenate([out, x[:, Lb:]], axis=1).astype(np.float32)


def find_turning_point(k: np.ndarray, v: np.ndarray, mode: str,
                       threshold: float = 0.05, scales=None) -> float:
    """Largest rel scale with distortion <= threshold — the paper's
    'acceptable accuracy turning point' (Tables III/IV), with attention-
    output distortion standing in for task accuracy.

    mode: 'k_channel' (KIVI-K), 'k_token' (PackKV-K), 'v_token'.
    """
    best = 0.0
    for rel in scales if scales is not None else np.geomspace(0.01, 0.8, 14):
        if mode == "k_channel":
            d = attn_distortion(k, v, quant_roundtrip(k, rel, "channel"), v)
        elif mode == "k_token":
            d = attn_distortion(k, v, quant_roundtrip(k, rel, "token"), v)
        else:  # v_token
            d = attn_distortion(k, v, k, quant_roundtrip(v, rel, "token"))
        if d <= threshold:
            best = max(best, rel)
    return best
