"""Shared-prefix page cache on a shared-system-prompt workload (ISSUE 5).

The workload every production deployment sees: N requests whose prompts all
open with the same long system prompt (few-shot template / chat preamble)
followed by a short unique user suffix. Served twice over identical
weights, both on the PAGED pool:

  * BASELINE (PR-4): ``--paged`` only — every admission re-runs prefill
    over the full prompt and pops private pages for all of it.
  * PREFIX CACHE: ``--prefix-cache`` — the first admission registers the
    system prompt's compressed pages; every later admission maps them by
    reference and prefills only its suffix.

Reported per policy: prefix-index hit rate, PREFILL throughput (prompt
tokens / admission wall time; the acceptance bar is >= 2x — the shared
pages cost zero FLOPs and zero compression work), peak pool residency
(pages with ref > 0; the bar is a measurable reduction, since N shared
copies collapse into one), and the hit-vs-cold bit-identity check (each
repeated-prefix request must reproduce the engine's own cold output
exactly). NOTE the two modes are different numerical regimes (chunked vs
whole-prompt prefill), so exactness is asserted WITHIN the prefix-cache
engine, not across modes. Results land in BENCH_prefix.json (CI uploads
it as an artifact).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

CAPACITY = 1024
PAGE = 128
MAX_BATCH = 4
SYS_TOKENS = 768  # 6 full pages shared by every request
SUFFIX_LENS = (24, 40, 56, 32)
MAX_NEW = 6
N_REQUESTS = 8


def make_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, SYS_TOKENS)
    return [
        Request(rid=rid, max_new=MAX_NEW,
                tokens=np.concatenate([
                    sys_prompt,
                    rng.integers(0, vocab, SUFFIX_LENS[rid % len(SUFFIX_LENS)]),
                ]))
        for rid in range(N_REQUESTS)
    ]


def serve(eng: Engine, reqs: list[Request]) -> dict:
    """Serve concurrent traffic, timing admissions (prefill) separately from
    the decode launches and sampling peak pool residency (pages with
    ``ref > 0``) after every admission round."""
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    prompt_tokens = sum(len(r.tokens) for r in reqs)
    peak_pages = 0
    t_prefill = 0.0
    t0 = time.perf_counter()
    while srv.queue or srv.n_occupied:
        ta = time.perf_counter()
        srv._admit()  # admissions isolated so prefill tok/s is clean
        t_prefill += time.perf_counter() - ta
        if srv.queue and not srv.n_occupied:
            # mirror of SlotServer.run()'s progress guarantee: a retire
            # always precedes the next admit attempt, so a stall with all
            # slots empty means the pool cannot fit this workload at all
            raise RuntimeError("admission stalled with every slot empty — "
                               "pool too small for the bench workload")
        peak_pages = max(
            peak_pages, int((np.asarray(srv.cache.pages.ref[0]) > 0).sum()))
        if srv.n_occupied:
            n_steps, n_bucket = srv._chunk_plan()
            srv._decode_chunk(n_steps, n_bucket, [])
    wall = time.perf_counter() - t0
    s = srv.stats
    return {
        "prompt_tokens": prompt_tokens,
        "prefill_s": t_prefill,
        "prefill_tok_s": prompt_tokens / t_prefill,
        "wall_s": wall,
        "peak_pages_resident": peak_pages,
        "hit_rate": s.prefix_hit_rate,
        "pages_shared": s.prefix_pages_shared,
        "prefix_evictions": s.prefix_evictions,
        "admission_blocks": s.admission_blocks,
        "outputs": {rid: r.output for rid, r in srv.done.items()},
    }


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print(f"\n[ISSUE 5] prefix cache: {N_REQUESTS} requests sharing a "
          f"{SYS_TOKENS}-token system prompt ({SYS_TOKENS // PAGE} pages), "
          f"unique suffixes {SUFFIX_LENS}")
    results = {"capacity": CAPACITY, "page_size": PAGE,
               "sys_tokens": SYS_TOKENS, "n_requests": N_REQUESTS}
    ok = True
    for policy in ("packkv", "none"):
        mk = lambda prefix: Engine(
            cfg, params, PackKVConfig(policy=policy),
            EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                         calib_tokens=128, bucketed=True, bucket_unit=PAGE,
                         decode_chunk=8, paged=True, page_size=PAGE,
                         prefix_cache=prefix),
        )
        base_eng, pfx_eng = mk(False), mk(True)
        # warmup: compile every admission/decode variant off the clock
        serve(base_eng, make_requests(cfg.vocab, seed=1))
        serve(pfx_eng, make_requests(cfg.vocab, seed=1))

        base = serve(base_eng, make_requests(cfg.vocab))
        warm = serve(pfx_eng, make_requests(cfg.vocab))
        # hit == cold bit-identity within the prefix-cache engine: replay
        # each request alone on a fresh (cold-index) server
        exact = all(
            np.array_equal(
                warm["outputs"][r.rid],
                serve(pfx_eng, [r])["outputs"][r.rid],
            )
            for r in make_requests(cfg.vocab)
        )
        speedup = warm["prefill_tok_s"] / base["prefill_tok_s"]
        residency = base["peak_pages_resident"] / warm["peak_pages_resident"]
        print(f"  {policy:7s} baseline: {base['prefill_tok_s']:8.1f} prefill "
              f"tok/s, {base['peak_pages_resident']:3d} peak pages   "
              f"prefix-cache: {warm['prefill_tok_s']:8.1f} tok/s, "
              f"{warm['peak_pages_resident']:3d} pages -> {speedup:.2f}x "
              f"prefill, {residency:.2f}x residency (hit rate "
              f"{warm['hit_rate']:.2f}, {warm['pages_shared']} pages "
              f"shared); hit==cold exact: {exact}")
        results[policy] = {
            "baseline": {k: v for k, v in base.items() if k != "outputs"},
            "prefix_cache": {k: v for k, v in warm.items() if k != "outputs"},
            "prefill_speedup": speedup,
            "residency_reduction": residency,
            "hit_eq_cold_exact": exact,
        }
        ok = ok and exact and speedup >= 2.0 and residency > 1.0
    with open("BENCH_prefix.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"prefix cache >=2x prefill tok/s, reduced residency, hit==cold "
          f"exact: {ok}")
    print("wrote BENCH_prefix.json")
    return bool(ok)


if __name__ == "__main__":
    main()
