"""Paper Figs 15/16: decode matvec throughput, fused-decompression vs dense.

No TPU in this container, so three complementary measurements:

1. BYTES-MOVED MODEL (the paper's own argument): decode attention is
   bandwidth-bound, so throughput ratio = bytes ratio. We build real
   calibrated TieredCaches and count exact compressed bytes (payload +
   pack metadata + token metadata) vs raw bf16 — per K phase (q·Kᵀ) and
   V phase (w·V), per model profile. Modeled TPU v5e tok/s = 819 GB/s /
   bytes-per-token.

2. MEASURED CPU WALL-CLOCK of the jitted XLA paths (packed vs dense) —
   a sanity signal that reading fewer bytes helps even on CPU.

3. Kernel-path equivalence is covered by tests/test_kernels.py (pallas
   interpret == xla oracle); interpret-mode timing is not meaningful.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import PackKVConfig, alloc_layer_cache, calibrate_specs, prefill_cache
from repro.core.tiered import tiered_bits_per_value
from repro.kernels import ops
from repro.utils import tree_bytes

from .common import MODEL_PROFILES, model_kv

HBM_BW = 819e9  # TPU v5e


def cache_bytes_per_token(cache_part) -> float:
    """Exact compressed bytes per (token, head) of one TieredCache."""
    L = cache_part.capacity
    H = cache_part.scale.shape[-2]
    B = cache_part.scale.shape[0]
    n = 0
    for t in cache_part.tiers:
        n += t.payload.size * 4 + t.mins.size + t.shifts.size
    n += cache_part.scale.size * 2 + cache_part.zero.size * 2  # fp16-counted
    return n / (L * H * B)


def run_model(name: str) -> dict:
    k = model_kv(name, part="k")[None]  # [1, H, L, D]
    v = model_kv(name, part="v")[None]
    B, H, L, D = k.shape
    kj, vj = jnp.asarray(k), jnp.asarray(v)
    cfg = calibrate_specs(kj, vj, PackKVConfig())
    cache = alloc_layer_cache(cfg, B, H, D, L)
    cache = prefill_cache(cache, kj, vj)

    raw_bpt = D * 2  # bf16 per (token, head)
    k_bpt = cache_bytes_per_token(cache.k)
    v_bpt = cache_bytes_per_token(cache.v)
    return {
        "k_speedup": raw_bpt / k_bpt,
        "v_speedup": raw_bpt / v_bpt,
        "k_bpt": k_bpt,
        "v_bpt": v_bpt,
        "raw_bpt": raw_bpt,
        # modeled v5e decode-attention throughput per head (tokens/s)
        "tok_s_dense": HBM_BW / (2 * raw_bpt * L * H),
        "tok_s_packed": HBM_BW / ((k_bpt + v_bpt) * L * H),
    }


def measure_cpu(L=4096, H=8, D=128, B=2, iters=5) -> dict:
    rng = np.random.default_rng(0)
    from repro.data import synthetic_kv

    k = jnp.asarray(synthetic_kv(rng, B, H, L, D))
    v = jnp.asarray(synthetic_kv(rng, B, H, L, D))
    cfg = calibrate_specs(k, v, PackKVConfig())
    cache = prefill_cache(alloc_layer_cache(cfg, B, H, D, L), k, v)
    cfg_n = PackKVConfig(policy="none")
    cache_n = prefill_cache(alloc_layer_cache(cfg_n, B, H, D, L), k, v)
    q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
    sm = 1.0 / np.sqrt(D)

    packed = jax.jit(lambda q, c: ops.packed_decode_attention(
        q, c.k, c.v, c.resid_k, c.resid_v, c.n_comp, c.n_resid, sm))
    dense = jax.jit(lambda q, c: ops.dense_decode_attention(
        q, c.raw_k, c.raw_v, c.resid_k, c.resid_v, c.n_comp, c.n_resid, sm))

    def bench(f, c):
        f(q, c).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(q, c).block_until_ready()
        return (time.perf_counter() - t0) / iters

    tp = bench(packed, cache)
    td = bench(dense, cache_n)
    return {"packed_s": tp, "dense_s": td, "cpu_speedup": td / tp,
            "packed_bytes": tree_bytes(cache), "dense_bytes": tree_bytes(cache_n)}


def main() -> bool:
    print("\n[Figs 15/16] fused decompress+matvec vs dense matvec "
          "(bytes-moved model, TPU v5e constants)")
    print(f"{'model':22s} {'K speedup':>10s} {'V speedup':>10s} "
          f"{'K B/tok':>9s} {'V B/tok':>9s} {'raw':>6s}")
    ks, vs = [], []
    for name in MODEL_PROFILES:
        r = run_model(name)
        ks.append(r["k_speedup"])
        vs.append(r["v_speedup"])
        print(f"{name:22s} {r['k_speedup']:9.2f}x {r['v_speedup']:9.2f}x "
              f"{r['k_bpt']:9.1f} {r['v_bpt']:9.1f} {r['raw_bpt']:6.0f}")
    print(f"{'avg':22s} {np.mean(ks):9.2f}x {np.mean(vs):9.2f}x   "
          f"(paper GPU: K +75.6%, V +171.6%; bandwidth-bound model bounds "
          f"the TPU gain by the byte ratio)")

    cpu = measure_cpu()
    print(f"\nCPU wall-clock sanity (L=4096): packed {cpu['packed_s']*1e3:.1f} ms "
          f"vs dense {cpu['dense_s']*1e3:.1f} ms -> {cpu['cpu_speedup']:.2f}x "
          f"(cache bytes {cpu['packed_bytes']/1e6:.1f} vs "
          f"{cpu['dense_bytes']/1e6:.1f} MB)")
    ok = np.mean(ks) > 1.756 and np.mean(vs) > 2.716
    print(f"\nFigs 15/16 reproduced (modeled gain exceeds paper's GPU gain): {ok}")
    return bool(ok)


if __name__ == "__main__":
    main()
