"""Paper Table II: K-cache CR — KIVI (channel quant, integer bits) vs
PackKV (token quant + repack + bit-pack) at MATCHED distortion.

Procedure (paper §IV-D1): find each method's 5%-distortion turning point,
then take the best CR at or below it. KIVI CRs are the analytic
bits+metadata formula (the paper quotes 4.57/6.40 from the same formula);
PackKV CRs come from the actual storage-tier bitstream.
"""
from __future__ import annotations

import numpy as np

from repro.core.kivi import kivi_cr_from_rel_scale

from .common import (
    K_PACK_SWEEP,
    MODEL_PROFILES,
    find_turning_point,
    model_kv,
    stream_cr,
)


def run() -> dict:
    out: dict = {}
    for name in MODEL_PROFILES:
        k = model_kv(name, part="k")
        v = model_kv(name, part="v")
        tp_ch = find_turning_point(k, v, "k_channel",
                                   scales=np.geomspace(0.01, 0.8, 12))
        tp_tok = find_turning_point(k, v, "k_token",
                                    scales=np.geomspace(0.01, 0.24, 12))
        kivi = kivi_cr_from_rel_scale(max(tp_ch, 1e-3))
        # PackKV: best CR over pack sizes / repacking at the token turning pt
        pack = max(
            stream_cr(k, v, pack_size=p, repack=m, k_rel=max(tp_tok, 1e-3),
                      part="k")
            for p, m in K_PACK_SWEEP
        )
        out[name] = {"kivi": kivi, "packkv": pack,
                     "gain_pct": (pack / kivi - 1) * 100}
    return out


def main() -> bool:
    res = run()
    print("\n[Table II] K cache CR at matched (5%) distortion")
    print(f"{'model':22s} {'KIVI':>8s} {'PackKV':>8s} {'gain':>9s}")
    gains = []
    for name, r in res.items():
        gains.append(r["gain_pct"])
        print(f"{name:22s} {r['kivi']:8.2f} {r['packkv']:8.2f} "
              f"{r['gain_pct']:+8.1f}%")
    avg = float(np.mean(gains))
    print(f"{'avg':22s} {'':8s} {'':8s} {avg:+8.1f}%   (paper: +153.2%)")
    ok = avg > 25  # direction + material margin (absolute value is data-dependent)
    print(f"\nTable II direction reproduced (PackKV >> KIVI at matched "
          f"distortion): {ok}")
    return ok


if __name__ == "__main__":
    main()
