"""Beyond-paper: the PackKV codec applied to DP gradient exchange.

Measures (a) on-wire compression ratio vs bit width, (b) convergence
penalty with/without error feedback on a real tiny-LM training run —
the distributed-optimization trick recorded in DESIGN.md §5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKES
from repro.data import ShardedTokenStream
from repro.distributed.grad_compress import (
    GradCompressConfig,
    compression_ratio,
    init_residuals,
    roundtrip_grads,
)
from repro.models import get_model
from repro.training import OptConfig, init_opt_state
from repro.training.optimizer import adamw_update


def train_losses(bits: int | None, error_feedback: bool, steps: int = 12):
    cfg = SMOKES["smollm-135m"]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    gc = GradCompressConfig(bits=bits or 8, error_feedback=error_feedback)
    resid = init_residuals(params, gc) if error_feedback else None
    stream = ShardedTokenStream(vocab=cfg.vocab, batch_per_host=8, seq=64)
    losses = []

    @jax.jit
    def grads_fn(p, b):
        return jax.value_and_grad(lambda pp: api.loss_fn(pp, cfg, b))(p)

    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        loss, g = grads_fn(params, b)
        if bits is not None:
            g, new_resid = roundtrip_grads(g, gc, resid)
            if error_feedback:
                resid = new_resid
        params, opt, _ = adamw_update(g, opt, params, oc)
        losses.append(float(loss))
    return losses


def main() -> bool:
    cfg = SMOKES["smollm-135m"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print("\n[beyond-paper] PackKV-style gradient compression for DP all-reduce")
    for bits in (2, 4, 8):
        cr = compression_ratio(params, GradCompressConfig(bits=bits))
        print(f"  {bits}-bit wire format: {cr:.1f}x less DP traffic")

    base = train_losses(None, False)
    ef = train_losses(4, True)
    nf = train_losses(4, False)
    print(f"\n  final loss after 12 steps: fp32 {base[-1]:.4f} | "
          f"4-bit+EF {ef[-1]:.4f} | 4-bit no-EF {nf[-1]:.4f}")
    ok = ef[-1] < base[-1] + 0.15 and base[-1] < base[0]
    print(f"  4-bit + error feedback tracks fp32 within 0.15 nats: {ok}")
    return ok


if __name__ == "__main__":
    main()
