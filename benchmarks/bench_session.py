"""Multi-turn session cache: returning-session TTFT (ISSUE 9).

The traffic shape the session cache exists for: a user sends a long first
turn, reads the answer, and comes back with a short follow-up. Served
twice over identical weights:

  * COLD: ``session_cache`` off — every follow-up turn re-prefills the
    WHOLE conversation (first prompt + first answer + extension), paying
    a full prefill for context the server already computed once.
  * SESSION: ``--session-cache`` — the retiring first turn parks its
    compressed pages host-side; the follow-up restores them with one
    scatter and only the short extension streams through (teacher-forced)
    decode launches. No forward pass touches the restored context.

Reported per policy: median returning-turn TTFT (the acceptance bar is
>= 2x better than cold), aggregate delivered tok/s (bar: >= 0.95x of the
cold run — parking traffic must not tax throughput), and the session hit
rate. For the lossless policy the returning outputs must also equal the
cold run's bit-for-bit (for packkv the cold re-prefill calibrates over
the longer turn-2 prompt, so equality is against the uninterrupted chain
instead — that matrix lives in tests/test_session_cache.py). Results
land in BENCH_session.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

CAPACITY = 1152
PAGE = 128
MAX_BATCH = 2
T1_LENS = (960, 976, 992, 1000)  # long first turns: the prefill the cache
#                                  saves grows with these, while the hit
#                                  path stays O(extension) — one restore
#                                  scatter + EXT+1 decode launches (kept
#                                  under the 1024-token flash-attention
#                                  q-chunk so cold prefill stays one chunk)
MAX_NEW1 = 8
EXT = 2             # short follow-up extensions: the returning turn's only
MAX_NEW2 = 12       # uncached tokens
TRIALS = 3          # timed trials, medians reported (shared runners drift)


def serve(eng: Engine, seed: int) -> dict:
    """One full conversation sweep: each session's turn 1 runs to
    retirement, then its follow-up (turn-1 trace + extension) arrives.
    Identical arrival order for both engines — only the cache differs."""
    srv = SlotServer(eng)
    rng = np.random.default_rng(seed)
    t2_ttft = []
    outputs = {}
    t0 = time.perf_counter()
    for s, n1 in enumerate(T1_LENS):
        prompt = rng.integers(0, eng.cfg.vocab, n1)
        r1 = Request(rid=2 * s, max_new=MAX_NEW1, tokens=prompt)
        srv.submit(r1)
        srv.run()
        ext = rng.integers(0, eng.cfg.vocab, EXT)
        r2 = Request(rid=2 * s + 1, max_new=MAX_NEW2, tokens=np.concatenate(
            [prompt, np.asarray(r1.output), ext]))
        srv.submit(r2)
        srv.run()
        t2_ttft.append((r2.t_first - r2.t_submit) * 1e3)
        outputs[r1.rid], outputs[r2.rid] = r1.output, r2.output
    wall = time.perf_counter() - t0
    s = srv.stats
    return {
        "t2_ttft_ms": t2_ttft,
        "t2_ttft_med_ms": float(np.median(t2_ttft)),
        "tok_s": s.tokens_out / wall,
        "wall_s": wall,
        "session_parks": s.session_parks,
        "session_hits": s.session_hits,
        "session_hit_rate": s.session_hit_rate,
        "session_restored_pages": s.session_restored_pages,
        "outputs": outputs,
    }


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print(f"\n[ISSUE 9] session cache: {len(T1_LENS)} two-turn sessions "
          f"({min(T1_LENS)}-{max(T1_LENS)}-token first turns, {EXT}-token "
          f"follow-ups) on {MAX_BATCH} slots")
    results = {"capacity": CAPACITY, "page_size": PAGE,
               "max_batch": MAX_BATCH, "t1_lens": list(T1_LENS),
               "ext": EXT, "trials": TRIALS}
    ok = True
    for policy in ("packkv", "none"):
        mk = lambda session: Engine(
            cfg, params, PackKVConfig(policy=policy),
            EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                         calib_tokens=128, bucketed=True, bucket_unit=PAGE,
                         decode_chunk=4, paged=True, page_size=PAGE,
                         prefill_chunk_pages=0, session_cache=session),
        )
        cold_eng, sess_eng = mk(False), mk(True)
        # warmup: compile every admission/decode/restore variant off the
        # clock (same prompt lengths, different content)
        serve(cold_eng, seed=1)
        serve(sess_eng, seed=1)

        cold_runs = [serve(cold_eng, seed=0) for _ in range(TRIALS)]
        sess_runs = [serve(sess_eng, seed=0) for _ in range(TRIALS)]
        med = lambda runs, k: float(np.median([r[k] for r in runs]))
        cold_ttft = med(cold_runs, "t2_ttft_med_ms")
        sess_ttft = med(sess_runs, "t2_ttft_med_ms")
        speedup = cold_ttft / sess_ttft
        tok_ratio = med(sess_runs, "tok_s") / med(cold_runs, "tok_s")
        hits = int(np.median([r["session_hits"] for r in sess_runs]))
        hit_rate = float(np.median([r["session_hit_rate"]
                                    for r in sess_runs]))
        # lossless policy: a served-from-park follow-up equals the cold
        # re-prefill bit-for-bit (packkv's cold run re-calibrates, see
        # module docstring — its exactness bar is the uninterrupted chain)
        exact = policy != "none" or all(
            np.array_equal(sess_runs[0]["outputs"][rid], out)
            for rid, out in cold_runs[0]["outputs"].items()
        )
        print(f"  {policy:7s} returning-turn TTFT: cold {cold_ttft:8.1f} ms"
              f"   session {sess_ttft:8.1f} ms -> {speedup:.2f}x "
              f"({hits} hits, rate {hit_rate:.2f}, tok/s ratio "
              f"{tok_ratio:.2f})"
              + ("" if policy != "none" else f"; hit==cold exact: {exact}"))
        results[policy] = {
            "cold": {k: v for k, v in cold_runs[0].items() if k != "outputs"}
            | {"t2_ttft_med_ms": cold_ttft},
            "session": {k: v for k, v in sess_runs[0].items()
                        if k != "outputs"}
            | {"t2_ttft_med_ms": sess_ttft, "session_hits": hits},
            "ttft_speedup": speedup,
            "tok_s_ratio": tok_ratio,
            "session_hit_rate": hit_rate,
            "hit_eq_cold": exact,
        }
        ok = ok and exact and hits == len(T1_LENS) and speedup >= 2.0 \
            and tok_ratio >= 0.95
    with open("BENCH_session.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"session cache >=2x returning-turn TTFT, tok/s within 5%, "
          f"every follow-up a hit: {ok}")
    print("wrote BENCH_session.json")
    return bool(ok)


if __name__ == "__main__":
    main()
