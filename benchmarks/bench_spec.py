"""Speculative multi-token decode on the compressed paged cache (ISSUE 7).

The same request mix served by the PR-6 engine (chunked prefill/decode
interleaving, bucketed launches) with speculation OFF vs ON, in two
acceptance regimes:

  * FRIENDLY: an oracle ``ReplayDrafter`` replays the baseline run's own
    outputs, emulating the templated/repetitive continuations where
    prompt-lookup drafting hits nearly always (acceptance ~ 1). This is a
    legitimate stand-in because the verify pass guarantees greedy outputs
    are exact for ARBITRARY draft content — the drafter only ever changes
    speed, never tokens (see ``NGramDrafter`` docstring).
  * ADVERSARIAL: the default suffix n-gram drafter on uniform-random
    traffic, where almost every draft dies (acceptance ~ 0). The per-slot
    acceptance backoff (``EngineConfig.spec_backoff``) must degrade the
    engine to the plain chunked-decode path so throughput stays at
    baseline.

Acceptance bars: friendly >= 1.5x decode tok/s, adversarial >= 0.95x,
outputs bit-identical to the non-speculative engine in BOTH regimes.
Results land in BENCH_spec.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

CAPACITY = 1024
BUCKET_UNIT = 128
DECODE_CHUNK = 8
MAX_BATCH = 4
PAGE = 128
SPEC_K = 4
PROMPT_LEN = 144
MAX_NEW = 192
N_REQUESTS = 8


class ReplayDrafter:
    """Oracle drafter replaying a reference run's outputs (acceptance ~ 1).

    Keyed by prompt content: ``seed`` receives prompt + first generated
    token, so the matching reference output stream resumes at position 1.
    """

    def __init__(self, ref_outputs: dict[tuple, list[int]]):
        self._ref = ref_outputs  # {tuple(prompt): generated tokens}
        self._pos: dict[int, list] = {}  # slot -> [stream, cursor]

    def seed(self, slot: int, tokens) -> None:
        toks = [int(t) for t in tokens]
        self._pos[slot] = [self._ref.get(tuple(toks[:-1]), []), 1]

    def extend(self, slot: int, tokens) -> None:
        self._pos[slot][1] += len(tuple(tokens))

    def drop(self, slot: int) -> None:
        self._pos.pop(slot, None)

    def draft(self, slot: int, k: int) -> list[int]:
        stream, cur = self._pos.get(slot, ([], 0))
        return [int(t) for t in stream[cur:cur + k]]


def make_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(rid=rid, max_new=MAX_NEW,
                tokens=rng.integers(0, vocab, PROMPT_LEN))
        for rid in range(N_REQUESTS)
    ]


def serve(eng: Engine, reqs: list[Request], drafter=None) -> dict:
    srv = SlotServer(eng, drafter=drafter)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    s = srv.stats
    return {
        "tok_s": s.tokens_out / dt,
        "wall_s": dt,
        "decode_steps": s.decode_steps,
        "spec_launches": s.spec_launches,
        "spec_drafted": s.spec_drafted,
        "spec_accepted": s.spec_accepted,
        "acceptance": s.spec_accepted / max(1, s.spec_drafted),
        "outputs": {rid: list(r.output) for rid, r in srv.done.items()},
    }


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print(f"\n[ISSUE 7] speculative decode: {N_REQUESTS} requests, "
          f"prompt {PROMPT_LEN}, max_new {MAX_NEW}, spec_k {SPEC_K}")
    ecfg = EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                        calib_tokens=128, bucketed=True,
                        bucket_unit=BUCKET_UNIT, decode_chunk=DECODE_CHUNK,
                        page_size=PAGE)
    base_eng = Engine(cfg, params, PackKVConfig(policy="packkv"), ecfg)
    spec_eng = Engine(cfg, params, base_eng.pack_cfg,
                      dataclasses.replace(ecfg, calibrate=False,
                                          spec_decode=True, spec_k=SPEC_K))

    # warmup both engines (compile amortization off the clock); the spec
    # warmup uses a replay drafter so the verify window path compiles too
    warm = serve(base_eng, make_requests(cfg.vocab, seed=1))
    warm_ref = {tuple(int(t) for t in r.tokens): warm["outputs"][r.rid]
                for r in make_requests(cfg.vocab, seed=1)}
    serve(spec_eng, make_requests(cfg.vocab, seed=1), ReplayDrafter(warm_ref))
    serve(spec_eng, make_requests(cfg.vocab, seed=1))

    base = serve(base_eng, make_requests(cfg.vocab))
    ref = {tuple(int(t) for t in r.tokens): base["outputs"][r.rid]
           for r in make_requests(cfg.vocab)}
    friendly = serve(spec_eng, make_requests(cfg.vocab), ReplayDrafter(ref))
    adversarial = serve(spec_eng, make_requests(cfg.vocab))

    results = {"capacity": CAPACITY, "bucket_unit": BUCKET_UNIT,
               "decode_chunk": DECODE_CHUNK, "spec_k": SPEC_K,
               "baseline": {k: v for k, v in base.items() if k != "outputs"}}
    ok = True
    for name, run, bar in (("friendly", friendly, 1.5),
                           ("adversarial", adversarial, 0.95)):
        exact = all(np.array_equal(base["outputs"][rid], run["outputs"][rid])
                    for rid in base["outputs"])
        speedup = run["tok_s"] / base["tok_s"]
        print(f"  {name:11s} base: {base['tok_s']:7.2f} tok/s   "
              f"spec: {run['tok_s']:7.2f} tok/s -> {speedup:.2f}x "
              f"(bar {bar}x); acceptance {run['acceptance']:.3f} "
              f"({run['spec_accepted']}/{run['spec_drafted']}); "
              f"exact: {exact}")
        results[name] = {
            **{k: v for k, v in run.items() if k != "outputs"},
            "speedup": speedup, "outputs_exact": exact, "bar": bar,
        }
        ok = ok and exact and speedup >= bar
    with open("BENCH_spec.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"speculative decode >=1.5x friendly / >=0.95x adversarial, "
          f"outputs exact: {ok}")
    print("wrote BENCH_spec.json")
    return bool(ok)


if __name__ == "__main__":
    main()
