"""Continuous (slot) batching vs lock-step wave batching on mixed traffic.

Beyond-paper serving benchmark: the same workload — short chat-style
requests interleaved with long generations — served two ways over the same
engine and weights:

  * WAVE (legacy lock-step): requests grouped into max_batch waves,
    left-padded batched prefill, shared decode loop of max(max_new) steps.
    Finished rows burn decode compute until the wave drains.
  * SLOT (continuous): per-row cache state; each request prefills into a
    free slot at its true length, slots retire and refill independently.

Reported: aggregate decode tokens/sec (useful tokens only), slot-step
occupancy, and the per-request greedy-equivalence check against
batch-size-1 decoding (for both the packkv and none policies).

CPU wall-clock numbers (smoke llama2-7b config) are indicative, not TPU
projections — but the occupancy gap is structural: wave occupancy equals
mean(tokens)/max(tokens) per wave, the slot scheduler's approaches 1.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

# mixed workload: prompt lengths drawn from a small set (bounds prefill
# compile count), max_new split short/long
PROMPT_LENS = (40, 72, 120)
MAX_NEWS = (4, 8, 24)
N_REQUESTS = 12
MAX_BATCH = 4


def make_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(N_REQUESTS):
        plen = int(PROMPT_LENS[rid % len(PROMPT_LENS)])
        mnew = int(MAX_NEWS[rid % len(MAX_NEWS)])
        reqs.append(Request(rid=rid, max_new=mnew,
                            tokens=rng.integers(0, vocab, plen)))
    return reqs


def run_wave_lockstep(eng: Engine, reqs: list[Request], pad_id: int = 0):
    """The pre-refactor wave algorithm (left-pad + shared decode loop)."""
    useful = 0
    decode_steps = 0
    slot_steps = 0
    t0 = time.perf_counter()
    queue = list(reqs)
    while queue:
        wave, queue = queue[:MAX_BATCH], queue[MAX_BATCH:]
        S = max(len(r.tokens) for r in wave)
        S = -(-S // 64) * 64
        toks = np.full((len(wave), S), pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.tokens):] = r.tokens
        max_new = max(r.max_new for r in wave)
        out, _ = eng.generate({"tokens": jnp.asarray(toks)}, max_new)
        useful += sum(r.max_new for r in wave)
        decode_steps += max_new
        slot_steps += max_new * len(wave)
    dt = time.perf_counter() - t0
    occ = useful / slot_steps if slot_steps else 0.0
    return {"tok_s": useful / dt, "wall_s": dt, "occupancy": occ,
            "useful": useful}


def run_slot(eng: Engine, reqs: list[Request]):
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    s = srv.stats
    return {"tok_s": s.tokens_out / dt, "wall_s": dt,
            "occupancy": s.occupancy, "useful": s.tokens_out,
            "slot_reuses": s.slot_reuses, "outputs": srv.done}


def check_equivalence(eng: Engine, reqs: list[Request], outputs) -> bool:
    ok = True
    for r in reqs:
        want, _ = eng.generate(
            {"tokens": jnp.asarray(r.tokens[None], jnp.int32)}, r.max_new
        )
        ok &= bool(np.array_equal(outputs[r.rid].output, want[0]))
    return ok


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print("\n[beyond-paper] continuous slot batching vs lock-step waves "
          f"({N_REQUESTS} mixed requests, prompts {PROMPT_LENS}, "
          f"max_new {MAX_NEWS}, {MAX_BATCH} slots)")
    ok = True
    for policy in ("none", "packkv"):
        eng = Engine(cfg, params, PackKVConfig(policy=policy),
                     EngineConfig(capacity=256, max_batch=MAX_BATCH,
                                  calib_tokens=128))
        reqs = make_requests(cfg.vocab)
        # warmup both paths (compile amortization off the clock)
        run_wave_lockstep(eng, make_requests(cfg.vocab, seed=1))
        run_slot(eng, make_requests(cfg.vocab, seed=1))

        wave = run_wave_lockstep(eng, reqs)
        slot = run_slot(eng, make_requests(cfg.vocab))
        eq = check_equivalence(eng, reqs, slot["outputs"])
        speedup = slot["tok_s"] / wave["tok_s"] if wave["tok_s"] else float("inf")
        print(f"  {policy:7s} wave: {wave['tok_s']:7.2f} tok/s "
              f"(occ {wave['occupancy']:.2f})   "
              f"slot: {slot['tok_s']:7.2f} tok/s "
              f"(occ {slot['occupancy']:.2f}, reuses {slot['slot_reuses']}) "
              f"-> {speedup:.2f}x; per-request outputs exact: {eq}")
        ok = ok and eq and slot["tok_s"] > wave["tok_s"]
    print(f"continuous batching beats lock-step waves on mixed traffic: {ok}")
    return bool(ok)


if __name__ == "__main__":
    main()
