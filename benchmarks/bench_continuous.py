"""Continuous (slot) batching vs lock-step wave batching on mixed traffic.

Beyond-paper serving benchmark: the same workload — short chat-style
requests interleaved with long generations — served two ways over the same
engine and weights:

  * WAVE (legacy lock-step): requests grouped into max_batch waves,
    left-padded batched prefill, shared decode loop of max(max_new) steps.
    Finished rows burn decode compute until the wave drains.
  * SLOT (continuous): per-row cache state; each request prefills into a
    free slot at its true length, slots retire and refill independently.

Reported: aggregate decode tokens/sec (useful tokens only), slot-step
occupancy, and the per-request greedy-equivalence check against
batch-size-1 decoding (for both the packkv and none policies).

A second section (``main_mixed_latency``, BENCH_mixed.json) measures TAIL
LATENCY under bursty mixed traffic: p50/p95/p99 time-to-first-token and
inter-token latency for monolithic admission (``prefill_chunk_pages=0``,
every occupied slot stalls for each whole admitted prompt) vs the
chunk-interleaved scheduler (decode between bounded chunks). Decode runs
per-token (``decode_chunk=1``) so each inter-token interval is a real
launch, not a share of a multi-step chunk's timestamp.

CPU wall-clock numbers (smoke llama2-7b config) are indicative, not TPU
projections — but the occupancy gap is structural: wave occupancy equals
mean(tokens)/max(tokens) per wave, the slot scheduler's approaches 1 —
and so is the stall bound: monolithic p99 ITL contains whole-prompt
prefills, chunked p99 ITL at most one chunk.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

# mixed workload: prompt lengths drawn from a small set (bounds prefill
# compile count), max_new split short/long
PROMPT_LENS = (40, 72, 120)
MAX_NEWS = (4, 8, 24)
N_REQUESTS = 12
MAX_BATCH = 4


def make_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(N_REQUESTS):
        plen = int(PROMPT_LENS[rid % len(PROMPT_LENS)])
        mnew = int(MAX_NEWS[rid % len(MAX_NEWS)])
        reqs.append(Request(rid=rid, max_new=mnew,
                            tokens=rng.integers(0, vocab, plen)))
    return reqs


def run_wave_lockstep(eng: Engine, reqs: list[Request], pad_id: int = 0):
    """The pre-refactor wave algorithm (left-pad + shared decode loop)."""
    useful = 0
    decode_steps = 0
    slot_steps = 0
    t0 = time.perf_counter()
    queue = list(reqs)
    while queue:
        wave, queue = queue[:MAX_BATCH], queue[MAX_BATCH:]
        S = max(len(r.tokens) for r in wave)
        S = -(-S // 64) * 64
        toks = np.full((len(wave), S), pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.tokens):] = r.tokens
        max_new = max(r.max_new for r in wave)
        out, _ = eng.generate({"tokens": jnp.asarray(toks)}, max_new)
        useful += sum(r.max_new for r in wave)
        decode_steps += max_new
        slot_steps += max_new * len(wave)
    dt = time.perf_counter() - t0
    occ = useful / slot_steps if slot_steps else 0.0
    return {"tok_s": useful / dt, "wall_s": dt, "occupancy": occ,
            "useful": useful}


def run_slot(eng: Engine, reqs: list[Request]):
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    s = srv.stats
    return {"tok_s": s.tokens_out / dt, "wall_s": dt,
            "occupancy": s.occupancy, "useful": s.tokens_out,
            "slot_reuses": s.slot_reuses, "outputs": srv.done}


def check_equivalence(eng: Engine, reqs: list[Request], outputs) -> bool:
    ok = True
    for r in reqs:
        want, _ = eng.generate(
            {"tokens": jnp.asarray(r.tokens[None], jnp.int32)}, r.max_new
        )
        ok &= bool(np.array_equal(outputs[r.rid].output, want[0]))
    return ok


# -- bursty mixed-traffic tail latency (BENCH_mixed.json) -------------------
# decode-heavy mixed traffic (most prompts fit one admission chunk, every
# third is a long 1024-token prompt whose monolithic prefill stalls the
# whole table) under WALL-CLOCK burst arrivals: a burst lands every
# LAT_BURST_GAP_S seconds whether or not the scheduler has caught up, so
# queue wait — and through it p99 TTFT — reflects the true service rate,
# exactly like an arrival-rate-driven serving benchmark (not a
# submit-per-step loop, which would let a slow scheduler slow its own
# arrival process down). Arrivals outpace service, so the tail TTFT is
# backlog drain: the scheduler with the higher delivered throughput wins
# it honestly.
LAT_PROMPT_LENS = (256, 384, 1024)
LAT_MAX_NEWS = (48, 64, 96)
LAT_N_REQUESTS = 24
LAT_BURST = 8          # requests per arrival burst
LAT_BURST_GAP_S = 0.4  # wall-clock seconds between bursts
LAT_TRIALS = 3         # timed trials per engine, interleaved; medians
#                        reported (shared-runner wall clocks drift)


def make_latency_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=rid, max_new=int(LAT_MAX_NEWS[rid % len(LAT_MAX_NEWS)]),
                    tokens=rng.integers(
                        0, vocab, int(LAT_PROMPT_LENS[rid % len(LAT_PROMPT_LENS)])))
            for rid in range(LAT_N_REQUESTS)]


def run_bursty(eng: Engine, reqs: list[Request]) -> dict:
    """Run the server against a wall-clock arrival schedule; collect
    per-request TTFT (t_first - t_submit, queue wait included) and
    inter-token intervals from the launch timestamps the scheduler
    records."""
    srv = SlotServer(eng)
    pending = list(reqs)
    t0 = time.perf_counter()
    arrivals = {r.rid: t0 + (i // LAT_BURST) * LAT_BURST_GAP_S
                for i, r in enumerate(reqs)}
    while pending or srv.queue or srv.n_occupied or srv._task is not None:
        now = time.perf_counter()
        while pending and arrivals[pending[0].rid] <= now:
            srv.submit(pending.pop(0))
        if not (srv.queue or srv.n_occupied or srv._task is not None):
            time.sleep(max(0.0, arrivals[pending[0].rid] - now))
            continue
        srv.step()
    wall = time.perf_counter() - t0
    done = [srv.done[r.rid] for r in reqs]
    ttft = [r.t_first - r.t_submit for r in done]
    itl = [float(d) for r in done for d in np.diff(r.token_times)]
    pct = lambda xs: {f"p{q}": float(np.percentile(xs, q)) * 1e3
                      for q in (50, 95, 99)}  # milliseconds
    return {"ttft_ms": pct(ttft), "itl_ms": pct(itl),
            "tok_s": srv.stats.tokens_out / wall, "wall_s": wall,
            "prefill_chunks": srv.stats.prefill_chunks,
            "outputs": {r.rid: r.output for r in done}}


def _median_run(runs: list[dict]) -> dict:
    """Per-metric medians over interleaved trials (latency percentiles and
    throughput are medianed independently — each is noisy on a different
    part of the run)."""
    med = lambda f: float(np.median([f(r) for r in runs]))
    return {
        "ttft_ms": {q: med(lambda r: r["ttft_ms"][q])
                    for q in ("p50", "p95", "p99")},
        "itl_ms": {q: med(lambda r: r["itl_ms"][q])
                   for q in ("p50", "p95", "p99")},
        "tok_s": med(lambda r: r["tok_s"]),
        "wall_s": med(lambda r: r["wall_s"]),
        "prefill_chunks": runs[0]["prefill_chunks"],
    }


def main_mixed_latency() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print("\n[beyond-paper] tail latency under bursty mixed traffic: "
          f"monolithic vs chunk-interleaved admission ({LAT_N_REQUESTS} "
          f"requests, prompts {LAT_PROMPT_LENS}, bursts of {LAT_BURST}, "
          f"median of {LAT_TRIALS} interleaved trials)")
    ecfg = EngineConfig(capacity=2048, max_batch=8, calib_tokens=128,
                        decode_chunk=1, page_size=128,
                        prefill_chunk_pages=4)
    chunked = Engine(cfg, params, PackKVConfig(), ecfg)
    import dataclasses

    mono = Engine(cfg, params, chunked.pack_cfg,
                  dataclasses.replace(ecfg, prefill_chunk_pages=0,
                                      calibrate=False))
    results = {"config": {"prompts": LAT_PROMPT_LENS, "max_new": LAT_MAX_NEWS,
                          "n_requests": LAT_N_REQUESTS, "burst": LAT_BURST,
                          "burst_gap_s": LAT_BURST_GAP_S, "slots": 8,
                          "decode_chunk": 1, "page_size": 128,
                          "prefill_chunk_pages": 4, "trials": LAT_TRIALS}}
    # warmup: same prompt lengths + chunk offsets -> compiles off the clock
    for eng in (mono, chunked):
        run_bursty(eng, make_latency_requests(cfg.vocab, seed=1))
    # interleave trials (alternating order) so machine-speed drift on a
    # shared runner lands on both engines, then compare medians
    m_runs, c_runs = [], []
    for trial in range(LAT_TRIALS):
        pairs = [(mono, m_runs), (chunked, c_runs)]
        for eng, acc in (pairs if trial % 2 == 0 else pairs[::-1]):
            acc.append(run_bursty(eng, make_latency_requests(cfg.vocab)))
    exact = all(np.array_equal(mr["outputs"][rid], cr["outputs"][rid])
                for mr, cr in zip(m_runs, c_runs) for rid in mr["outputs"])
    m, c = _median_run(m_runs), _median_run(c_runs)
    for name, r in (("monolithic", m), ("chunked", c)):
        print(f"  {name:10s} TTFT p50/p95/p99 "
              f"{r['ttft_ms']['p50']:7.1f}/{r['ttft_ms']['p95']:7.1f}/"
              f"{r['ttft_ms']['p99']:7.1f} ms   ITL p50/p95/p99 "
              f"{r['itl_ms']['p50']:6.1f}/{r['itl_ms']['p95']:6.1f}/"
              f"{r['itl_ms']['p99']:6.1f} ms   {r['tok_s']:6.1f} tok/s "
              f"({r['prefill_chunks']} prefill chunks)")
    ok_ttft = c["ttft_ms"]["p99"] < m["ttft_ms"]["p99"]
    ok_itl = c["itl_ms"]["p99"] < m["itl_ms"]["p99"]
    ok_tok = c["tok_s"] >= 0.95 * m["tok_s"]  # 5% CPU-timer noise floor
    ok = bool(exact and ok_ttft and ok_itl and ok_tok)
    print(f"  outputs exact: {exact}; p99 TTFT improved: {ok_ttft}; "
          f"p99 ITL improved: {ok_itl}; no tok/s regression: {ok_tok}")
    results.update(monolithic=m, chunked=c, ok=ok)
    with open("BENCH_mixed.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print("wrote BENCH_mixed.json")
    return ok


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print("\n[beyond-paper] continuous slot batching vs lock-step waves "
          f"({N_REQUESTS} mixed requests, prompts {PROMPT_LENS}, "
          f"max_new {MAX_NEWS}, {MAX_BATCH} slots)")
    ok = True
    for policy in ("none", "packkv"):
        eng = Engine(cfg, params, PackKVConfig(policy=policy),
                     EngineConfig(capacity=256, max_batch=MAX_BATCH,
                                  calib_tokens=128))
        reqs = make_requests(cfg.vocab)
        # warmup both paths (compile amortization off the clock)
        run_wave_lockstep(eng, make_requests(cfg.vocab, seed=1))
        run_slot(eng, make_requests(cfg.vocab, seed=1))

        wave = run_wave_lockstep(eng, reqs)
        slot = run_slot(eng, make_requests(cfg.vocab))
        eq = check_equivalence(eng, reqs, slot["outputs"])
        speedup = slot["tok_s"] / wave["tok_s"] if wave["tok_s"] else float("inf")
        print(f"  {policy:7s} wave: {wave['tok_s']:7.2f} tok/s "
              f"(occ {wave['occupancy']:.2f})   "
              f"slot: {slot['tok_s']:7.2f} tok/s "
              f"(occ {slot['occupancy']:.2f}, reuses {slot['slot_reuses']}) "
              f"-> {speedup:.2f}x; per-request outputs exact: {eq}")
        ok = ok and eq and slot["tok_s"] > wave["tok_s"]
    print(f"continuous batching beats lock-step waves on mixed traffic: {ok}")
    return bool(ok)


if __name__ == "__main__":
    main()
