"""Paper Tables III/IV (structure): acceptable-accuracy turning points.

For each synthetic model profile, sweep relative quantization scales and
record the largest scale whose attention-output distortion stays <= 5%
(the paper's acceptable-drop criterion, with distortion standing in for
task accuracy — no trained checkpoints in this container).

Reproduced claim: token-wise K quantization hits the 5% wall at a much
SMALLER rel scale than channel-wise (paper Table III: token ranges top
out ~0.12-0.24 vs channel ~0.27-0.80) — this is exactly why KIVI chose
channel-wise K, and why PackKV's lossless stage must (and does) win the
CR back (Table II / bench_k_compression).
"""
from __future__ import annotations

import numpy as np

from .common import MODEL_PROFILES, find_turning_point, model_kv

K_CHANNEL_SCALES = np.geomspace(0.01, 0.8, 12)
K_TOKEN_SCALES = np.geomspace(0.01, 0.24, 12)
V_TOKEN_SCALES = np.geomspace(0.01, 0.68, 12)


def run() -> dict:
    out: dict = {}
    for name in MODEL_PROFILES:
        k = model_kv(name, part="k")
        v = model_kv(name, part="v")
        out[name] = {
            "k_channel": find_turning_point(k, v, "k_channel",
                                            scales=K_CHANNEL_SCALES),
            "k_token": find_turning_point(k, v, "k_token", scales=K_TOKEN_SCALES),
            "v_token": find_turning_point(k, v, "v_token", scales=V_TOKEN_SCALES),
        }
    return out


def main() -> bool:
    res = run()
    print("\n[Tables III/IV] 5%-distortion turning points (rel quant scale)")
    print(f"{'model':22s} {'K channel':>10s} {'K token':>10s} {'V token':>10s}")
    ok = True
    for name, r in res.items():
        print(f"{name:22s} {r['k_channel']:10.4f} {r['k_token']:10.4f} "
              f"{r['v_token']:10.4f}")
        if not (r["k_channel"] >= r["k_token"] > 0):
            ok = False
    print(f"\nTable III pattern reproduced (channel turning point >= token): {ok}")
    return ok


if __name__ == "__main__":
    main()
