"""Length-aware kernel launches on ragged mixed-length traffic (ISSUE 3).

The same short/long request mix served twice over identical weights:

  * BASELINE (PR-2): per-token jitted dispatch, every launch iterates the
    full ``capacity`` grid however few tokens are live.
  * LENGTH-AWARE: bucketed prefix slicing (compressed reads cover the
    smallest power-of-two bucket >= max live length), in-kernel tile
    skipping inside the last bucket, and donated multi-step decode chunks
    (one dispatch per ``decode_chunk`` tokens, cache updated in place).

The workload keeps mean live length <= capacity/4, the regime the paper's
throughput claim (§IV-E) lives in: a 4096-token allocation serving ~256
live tokens should pay for 256, not 4096. Reported: decode tokens/sec,
speedup, dead-tile fraction (fraction of launched context tiles that hold
no live token) for both launch strategies, compile count, and the
bit-identical greedy equivalence check. Results land in BENCH_ragged.json
(CI uploads it as an artifact).
"""
from __future__ import annotations

import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig, bucket_set
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

CAPACITY = 2048
BUCKET_UNIT = 128
DECODE_CHUNK = 8
MAX_BATCH = 4
# short chat turns interleaved with long generations; prompts well under
# capacity so live length stays <= capacity/4 throughout
PROMPT_LENS = (60, 100, 180, 140)
MAX_NEWS = (8, 24, 8, 40)
N_REQUESTS = 8


def make_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(rid=rid, max_new=int(MAX_NEWS[rid % len(MAX_NEWS)]),
                tokens=rng.integers(0, vocab, int(PROMPT_LENS[rid % len(PROMPT_LENS)])))
        for rid in range(N_REQUESTS)
    ]


def dead_tile_fraction(launches, unit: int) -> dict:
    """Fraction of launched context tiles holding no live token.

    ``launches``: SlotStats.launches — (steps, bucket tokens, live token
    counts per occupied row). "full" recomputes the same trace as if every
    launch had covered the full capacity grid (the PR-2 strategy).
    """
    live = launched = launched_full = 0
    for steps, bucket, rows in launches:
        for n in rows:
            live += steps * math.ceil(n / unit)
            launched += steps * (bucket // unit)
            launched_full += steps * (CAPACITY // unit)
    if not launched:
        return {"full_launch": 0.0, "bucketed": 0.0}
    return {
        "full_launch": 1.0 - live / launched_full,
        "bucketed": 1.0 - live / launched,
    }


def serve(eng: Engine, reqs: list[Request]) -> dict:
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    s = srv.stats
    return {
        "tok_s": s.tokens_out / dt,
        "wall_s": dt,
        "decode_steps": s.decode_steps,
        "dispatches": s.chunk_launches,
        "occupancy": s.occupancy,
        "dead_tiles": dead_tile_fraction(s.launches, BUCKET_UNIT),
        "outputs": {rid: r.output for rid, r in srv.done.items()},
    }


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    mean_live = float(np.mean([len(r.tokens) + r.max_new
                               for r in make_requests(cfg.vocab)]))
    print(f"\n[ISSUE 3] length-aware launches: {N_REQUESTS} mixed requests, "
          f"capacity {CAPACITY}, mean live length {mean_live:.0f} "
          f"(<= capacity/4: {mean_live <= CAPACITY / 4})")
    results = {"capacity": CAPACITY, "bucket_unit": BUCKET_UNIT,
               "decode_chunk": DECODE_CHUNK, "mean_live_tokens": mean_live,
               "buckets": list(bucket_set(CAPACITY, BUCKET_UNIT))}
    ok = True
    for policy in ("packkv", "none"):
        base_eng = Engine(cfg, params, PackKVConfig(policy=policy),
                          EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                                       calib_tokens=128, bucketed=False,
                                       decode_chunk=1, log_launches=True))
        fast_eng = Engine(cfg, params, PackKVConfig(policy=policy),
                          EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                                       calib_tokens=128, bucketed=True,
                                       bucket_unit=BUCKET_UNIT,
                                       decode_chunk=DECODE_CHUNK,
                                       log_launches=True))
        # warmup (compile amortization off the clock)
        serve(base_eng, make_requests(cfg.vocab, seed=1))
        serve(fast_eng, make_requests(cfg.vocab, seed=1))

        base = serve(base_eng, make_requests(cfg.vocab))
        fast = serve(fast_eng, make_requests(cfg.vocab))
        exact = all(np.array_equal(base["outputs"][rid], fast["outputs"][rid])
                    for rid in base["outputs"])
        speedup = fast["tok_s"] / base["tok_s"]
        compiles = fast_eng._decode_multi._cache_size()
        print(f"  {policy:7s} PR-2: {base['tok_s']:7.2f} tok/s "
              f"({base['dispatches']} dispatches, dead tiles "
              f"{base['dead_tiles']['full_launch']:.2f})   "
              f"length-aware: {fast['tok_s']:7.2f} tok/s "
              f"({fast['dispatches']} dispatches, dead tiles "
              f"{fast['dead_tiles']['bucketed']:.2f}) "
              f"-> {speedup:.2f}x; exact: {exact}; "
              f"decode compiles: {compiles}/{len(results['buckets'])}")
        results[policy] = {
            "baseline": {k: v for k, v in base.items() if k != "outputs"},
            "length_aware": {k: v for k, v in fast.items() if k != "outputs"},
            "speedup": speedup,
            "outputs_exact": exact,
            "decode_compiles": compiles,
        }
        # acceptance bar: >=2x on the compressed (paper) path; the 'none'
        # policy is reported for context (its baseline attention is a plain
        # einsum, so MLP/dispatch dominate and the ratio is structurally
        # smaller)
        ok = ok and exact and (speedup >= 2.0 or policy == "none")
        ok = ok and compiles <= len(results["buckets"])
    with open("BENCH_ragged.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"length-aware launches >=2x on ragged traffic, outputs exact: {ok}")
    print("wrote BENCH_ragged.json")
    return bool(ok)


if __name__ == "__main__":
    main()
