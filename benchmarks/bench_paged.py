"""Paged tiered KV pool on mixed-length traffic (ISSUE 4).

The same short/long request mix served twice over identical weights:

  * DENSE (PR-3): per-slot contiguous compressed buffers sized to
    ``capacity`` — resident memory = max_batch * capacity tokens however
    short the live sequences are.
  * PAGED: shared page pool + per-slot page tables, the pool
    OVERSUBSCRIBED down to the workload's peak page reservation (plus a
    one-page watermark) — resident memory tracks live tokens.

The workload keeps mean live length <= capacity/4 (the fragmentation
regime KV-Compress targets). Reported: resident compressed-region bytes
for both storage modes and the reduction ratio (acceptance bar: >=2x at
this live length), decode tokens/sec for both (bar: paged within 5% of
dense), admission telemetry, and the per-request bit-identity check.
Results land in BENCH_paged.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer
from repro.utils import cdiv, tree_bytes

CAPACITY = 2048
PAGE = 256
BUCKET_UNIT = 256
DECODE_CHUNK = 8
MAX_BATCH = 4
PROMPT_LENS = (60, 100, 180, 140)
MAX_NEWS = (8, 24, 8, 40)
N_REQUESTS = 8


def make_requests(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(rid=rid, max_new=int(MAX_NEWS[rid % len(MAX_NEWS)]),
                tokens=rng.integers(0, vocab,
                                    int(PROMPT_LENS[rid % len(PROMPT_LENS)])))
        for rid in range(N_REQUESTS)
    ]


def workload_pool_pages(reqs: list[Request]) -> int:
    """Smallest safe pool: the peak reservation is bounded by the
    ``MAX_BATCH`` largest per-request worst cases (+1 watermark page)."""
    needs = sorted(
        (cdiv(min(CAPACITY, len(r.tokens) + r.max_new), PAGE) for r in reqs),
        reverse=True,
    )
    return sum(needs[:MAX_BATCH]) + 1


def resident_compressed_bytes(cache) -> int:
    """Bytes held by the compressed region (+ page tables), excluding the
    residual buffers and counters (identical across storage modes)."""
    return (tree_bytes(cache.k) + tree_bytes(cache.v)
            + tree_bytes(cache.raw_k) + tree_bytes(cache.raw_v)
            + tree_bytes(cache.pages))


def serve(eng: Engine, reqs: list[Request]) -> dict:
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    s = srv.stats
    return {
        "tok_s": s.tokens_out / dt,
        "wall_s": dt,
        "decode_steps": s.decode_steps,
        "occupancy": s.occupancy,
        "admission_blocks": s.admission_blocks,
        "pages_reserved_peak": s.pages_reserved_peak,
        "resident_bytes": resident_compressed_bytes(srv.cache),
        "outputs": {rid: r.output for rid, r in srv.done.items()},
    }


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = make_requests(cfg.vocab)
    mean_live = float(np.mean([len(r.tokens) + r.max_new for r in reqs]))
    pool_pages = workload_pool_pages(reqs)
    dense_pages_equiv = MAX_BATCH * CAPACITY // PAGE
    print(f"\n[ISSUE 4] paged pool: {N_REQUESTS} mixed requests, capacity "
          f"{CAPACITY}, mean live {mean_live:.0f} (<= capacity/4: "
          f"{mean_live <= CAPACITY / 4}); pool {pool_pages} pages vs dense-"
          f"equivalent {dense_pages_equiv}")
    results = {"capacity": CAPACITY, "page_size": PAGE,
               "mean_live_tokens": mean_live, "pool_pages": pool_pages,
               "dense_pages_equivalent": dense_pages_equiv}
    ok = True
    for policy in ("packkv", "none"):
        mk = lambda paged: Engine(
            cfg, params, PackKVConfig(policy=policy),
            EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                         calib_tokens=128, bucketed=True,
                         bucket_unit=BUCKET_UNIT, decode_chunk=DECODE_CHUNK,
                         paged=paged, page_size=PAGE,
                         pool_pages=pool_pages if paged else None,
                         page_watermark=1 if paged else 0),
        )
        dense_eng, paged_eng = mk(False), mk(True)
        # warmup (compile amortization off the clock)
        serve(dense_eng, make_requests(cfg.vocab, seed=1))
        serve(paged_eng, make_requests(cfg.vocab, seed=1))

        dense = serve(dense_eng, make_requests(cfg.vocab))
        paged = serve(paged_eng, make_requests(cfg.vocab))
        exact = all(np.array_equal(dense["outputs"][rid], paged["outputs"][rid])
                    for rid in dense["outputs"])
        reduction = dense["resident_bytes"] / paged["resident_bytes"]
        tok_ratio = paged["tok_s"] / dense["tok_s"]
        print(f"  {policy:7s} dense: {dense['resident_bytes'] / 2**20:6.1f} MiB "
              f"{dense['tok_s']:7.2f} tok/s   paged: "
              f"{paged['resident_bytes'] / 2**20:6.1f} MiB "
              f"{paged['tok_s']:7.2f} tok/s -> {reduction:.2f}x smaller, "
              f"{tok_ratio:.2f}x tok/s (blocks "
              f"{paged['admission_blocks']}, peak pages "
              f"{paged['pages_reserved_peak']}); exact: {exact}")
        results[policy] = {
            "dense": {k: v for k, v in dense.items() if k != "outputs"},
            "paged": {k: v for k, v in paged.items() if k != "outputs"},
            "resident_reduction": reduction,
            "tok_s_ratio": tok_ratio,
            "outputs_exact": exact,
        }
        ok = ok and exact and reduction >= 2.0 and tok_ratio >= 0.95
    with open("BENCH_paged.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"paged pool >=2x resident reduction, <=5% tok/s cost, exact: {ok}")
    print("wrote BENCH_paged.json")
    return bool(ok)


if __name__ == "__main__":
    main()
