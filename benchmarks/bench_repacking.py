"""Paper Table I: CR by repacking mode (None / Greedy / Median), K and V.

Reproduced claims: Greedy gives the largest gains; Median helps mainly V;
both are lossless transforms (verified by tests/test_block_format.py).
"""
from __future__ import annotations

import numpy as np

from .common import MODEL_PROFILES, model_kv, stream_cr

MODES = {"None": "none", "Greedy": "greedy_joint", "Median": "median_v"}


def run() -> dict:
    out: dict = {"K": {}, "V": {}}
    for name in MODEL_PROFILES:
        k = model_kv(name, part="k")
        v = model_kv(name, part="v")
        for part in ("K", "V"):
            out[part][name] = {
                label: stream_cr(k, v, repack=mode, part=part.lower())
                for label, mode in MODES.items()
            }
    return out


def main() -> bool:
    res = run()
    gains = {}
    for part in ("K", "V"):
        print(f"\n[Table I] {part} cache CR by repacking mode")
        print(f"{'model':22s} {'None':>8s} {'Greedy':>14s} {'Median':>14s}")
        g_g, g_m = [], []
        for name, r in res[part].items():
            dg = (r["Greedy"] / r["None"] - 1) * 100
            dm = (r["Median"] / r["None"] - 1) * 100
            g_g.append(dg)
            g_m.append(dm)
            print(f"{name:22s} {r['None']:8.2f} {r['Greedy']:8.2f} ({dg:+5.1f}%)"
                  f" {r['Median']:8.2f} ({dm:+5.1f}%)")
        gains[part] = (float(np.mean(g_g)), float(np.mean(g_m)))
        print(f"{'avg':22s} {'':8s} {gains[part][0]:+14.1f}% {gains[part][1]:+14.1f}%")
    # paper: greedy K +4.5%, V +19.7%; median helps V (+17.7%), ~neutral K
    ok = (
        gains["K"][0] >= 0
        and gains["V"][0] > 5
        and gains["V"][1] > 3
        and gains["V"][0] >= gains["K"][0]
    )
    print(f"\nTable I pattern reproduced (greedy>0, V gains >> K gains): {ok}")
    return ok


if __name__ == "__main__":
    main()
