"""Paper Fig. 17: multi-GPU scaling — per-instance throughput is flat
because instances are independent.

TPU translation: under a DATA-PARALLEL-ONLY mesh, the decode step must
contain ZERO cross-device collectives — then per-chip throughput is
independent of chip count by construction (the paper's 'near-perfect
scaling'). We verify by compiling the decode step on a (8, 1) mesh in a
subprocess (8 fake host devices) and counting collectives in the SPMD HLO.
"""
from __future__ import annotations

import json
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import ARCHS, SHAPES
from repro.launch.specs import build_cell
from repro.launch.dryrun import collective_bytes
from repro.distributed.sharding import set_active_mesh

mesh = jax.make_mesh((8, 1), ("data", "model"))
cell = build_cell(ARCHS["smollm-135m"], SHAPES["decode_32k"], mesh)
with mesh:
    set_active_mesh(mesh)
    comp = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                   out_shardings=cell.out_shardings,
                   donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
    set_active_mesh(None)
coll = collective_bytes(comp.as_text())
print("RESULT " + json.dumps(coll))
"""


def main() -> bool:
    r = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, cwd=".",
    )
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    if not line:
        print("[Fig 17] compile failed:", r.stderr[-500:])
        return False
    coll = json.loads(line[0][7:])
    total = coll.get("total", -1)
    print("\n[Fig 17] DP-only (8×1 mesh) decode-step collectives:", coll)
    # smollm decode_32k per-step cache traffic ≈ 86 MB/device; anything
    # below 0.5% of that is launch-time bookkeeping, not a scaling term
    cache_bytes = 86e6
    eff = 1.0 - total / cache_bytes
    ok = total < 0.005 * cache_bytes
    print(f"cross-instance bytes/step: {total:,} "
          f"({total / cache_bytes:.2%} of per-step cache traffic) -> "
          f"scaling efficiency ≈ {eff:.2%} (paper Fig 17: 'near-perfect'): {ok}")
    return ok


if __name__ == "__main__":
    main()
