"""Sharded paged serving: per-device cache residency + exactness (ISSUE 10).

The point of sharding the page pool by KV head over the ``kv`` mesh axis
is MEMORY: each device holds ``1/kv_shards`` of every pool payload
(packed blocks, residuals, scales, permutations) while only the small
page ledger (tables, free-list, counters) is replicated. This bench
builds the same engine at mesh shapes (1,1), (1,2), (1,4) and (2,2),
serves identical traffic through each, and reports:

  * exactness — sharded outputs must equal the single-device outputs
    bit-for-bit (the engine's merge is a disjoint head scatter + one
    psum, so this is an equality bar, not a tolerance);
  * residency — device-0 resident cache bytes, split into sharded
    payload vs replicated ledger by inspecting each leaf's addressable
    shard: payload must scale ~1/kv_shards;
  * throughput — delivered tok/s per mesh, RECORDED HONESTLY but not
    gated: on 8 fake host-platform devices of one CPU the lanes add
    collective overhead without adding silicon, so the ratio is
    informational (on real multi-chip topologies the payload bandwidth
    is what scales).

PASS gates on exactness + residency. Runs in a subprocess so the forced
8-device host platform never leaks into the parent's jax. Results land
in BENCH_sharded.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

MESHES = [(1, 1), (1, 2), (1, 4), (2, 2)]

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import time
import jax
import numpy as np
from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

MESHES = json.loads(os.environ["BENCH_SHARDED_MESHES"])
cfg = SMOKES["llama2-7b"]
params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
PAGE, CAP = 128, 512


def dev0_bytes(cache):
    # device-0 resident bytes, split sharded-payload vs replicated-ledger
    # by each leaf's addressable shard size (a replicated leaf's device-0
    # shard is the whole array)
    payload = ledger = 0
    for leaf in jax.tree_util.tree_leaves(cache):
        shards = [s for s in leaf.addressable_shards
                  if s.device == jax.devices()[0]]
        n = sum(s.data.nbytes for s in shards)
        if n < leaf.nbytes:
            payload += n
        else:
            ledger += n
    return payload, ledger


def serve(eng, seed=0):
    srv = SlotServer(eng)
    rng = np.random.default_rng(seed)
    for rid in range(4):
        toks = rng.integers(0, cfg.vocab, int(rng.integers(100, 200)))
        srv.submit(Request(rid=rid, max_new=8, tokens=toks))
    t0 = time.perf_counter()
    srv.run()
    wall = time.perf_counter() - t0
    outs = [list(map(int, srv.done[i].output)) for i in sorted(srv.done)]
    return outs, srv.stats.tokens_out / wall


res = {}
for dp, kv in MESHES:
    eng = Engine(cfg, params, PackKVConfig(policy="packkv"),
                 EngineConfig(capacity=CAP, max_batch=2, calib_tokens=128,
                              bucketed=True, bucket_unit=64, paged=True,
                              page_size=PAGE, mesh_shape=(dp, kv)))
    serve(eng, seed=1)  # warmup: compile off the clock
    outs, tok_s = serve(eng, seed=0)
    payload, ledger = dev0_bytes(eng.alloc_slot_cache())
    res[f"{dp}x{kv}"] = {"dp": dp, "kv": kv, "outputs": outs,
                         "tok_s": tok_s, "payload_bytes_dev0": payload,
                         "ledger_bytes_dev0": ledger}
print("RESULT " + json.dumps(res))
"""


def main() -> bool:
    print(f"\n[ISSUE 10] sharded paged serving: packkv paged engine at "
          f"{MESHES} on 8 host-platform devices")
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "BENCH_SHARDED_MESHES": json.dumps(MESHES)}
    r = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=env, cwd=".", timeout=1800)
    lines = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    if not lines:
        print(f"child failed:\n{r.stderr[-2000:]}")
        return False
    res = json.loads(lines[0][7:])

    base = res["1x1"]
    base_resident = base["payload_bytes_dev0"] + base["ledger_bytes_dev0"]
    ok = True
    results = {"meshes": [list(m) for m in MESHES], "page_size": 128,
               "capacity": 512, "per_mesh": {}}
    for key, row in res.items():
        kv = row["kv"]
        exact = row["outputs"] == base["outputs"]
        resident = row["payload_bytes_dev0"] + row["ledger_bytes_dev0"]
        ratio = resident / base_resident
        tok_ratio = row["tok_s"] / base["tok_s"]
        # the sharded payload must carry ~1/kv of the single-device cache;
        # the replicated ledger is the small additive floor on top. At
        # (1,1) there is no mesh, so every byte counts as "payload" there.
        want = (base_resident / kv + row["ledger_bytes_dev0"]) / base_resident
        residency_ok = kv == 1 or ratio <= want + 0.02
        ok = ok and exact and residency_ok
        tgt = f", ~1/kv target {want:.3f}" if kv > 1 else ""
        print(f"  {key}: exact={exact}  dev0 resident {resident:>12,} B "
              f"({ratio:.3f}x of 1x1{tgt})  "
              f"tok/s {row['tok_s']:.1f} ({tok_ratio:.2f}x, informational)")
        results["per_mesh"][key] = {
            "exact": exact, "resident_bytes_dev0": resident,
            "payload_bytes_dev0": row["payload_bytes_dev0"],
            "ledger_bytes_dev0": row["ledger_bytes_dev0"],
            "residency_ratio": ratio, "residency_target": want,
            "residency_ok": bool(residency_ok),
            "tok_s": row["tok_s"], "tok_s_ratio": tok_ratio,
        }
    with open("BENCH_sharded.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"sharded == single-device bit-exact, dev0 residency ~1/kv: {ok}")
    print("wrote BENCH_sharded.json")
    return bool(ok)


if __name__ == "__main__":
    main()
