"""Benchmark runner: one harness per paper table/figure + roofline summary.

  PYTHONPATH=src python -m benchmarks.run            # all paper benchmarks
  PYTHONPATH=src python -m benchmarks.run --only fig13
  PYTHONPATH=src python -m benchmarks.run --list     # suite names
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (
    bench_continuous,
    bench_grad_compress,
    bench_k_compression,
    bench_pack_size,
    bench_paged,
    bench_preempt,
    bench_prefix,
    bench_ragged,
    bench_repacking,
    bench_scaling,
    bench_session,
    bench_sharded,
    bench_spec,
    bench_throughput,
    bench_turning_points,
    bench_v_compression,
)

BENCHES = {
    "fig13_pack_size": bench_pack_size.main,
    "table1_repacking": bench_repacking.main,
    "table34_turning_points": bench_turning_points.main,
    "table2_k_compression": bench_k_compression.main,
    "table5_v_compression": bench_v_compression.main,
    "fig1516_throughput": bench_throughput.main,
    "fig17_scaling": bench_scaling.main,
    "beyond_grad_compress": bench_grad_compress.main,
    "beyond_continuous_batching": bench_continuous.main,
    "beyond_mixed_latency": bench_continuous.main_mixed_latency,
    "beyond_ragged_length_aware": bench_ragged.main,
    "beyond_paged_pool": bench_paged.main,
    "beyond_prefix_cache": bench_prefix.main,
    "beyond_spec_decode": bench_spec.main,
    "beyond_preemption": bench_preempt.main,
    "beyond_session_cache": bench_session.main,
    "beyond_sharded_serving": bench_sharded.main,
}


def _print_suites(stream, indent: str = "") -> None:
    """The ONE rendering of the suite registry: ``--list`` and the
    unknown-``--only`` error both call this, so they cannot drift when a
    suite is added."""
    for name in BENCHES:
        print(f"{indent}{name}", file=stream)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over suite names (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print registered suite names and exit")
    args = ap.parse_args()
    if args.list:
        _print_suites(sys.stdout)
        return 0
    if args.only and not any(args.only in name for name in BENCHES):
        print(f"--only {args.only!r} matches no registered suite; "
              f"known suites:", file=sys.stderr)
        _print_suites(sys.stderr, indent="  ")
        return 2
    results = {}
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            results[name] = bool(fn())
        except Exception:  # noqa: BLE001 — report, don't abort the suite
            import traceback

            traceback.print_exc()
            results[name] = False
        print(f"[{name}] {'PASS' if results[name] else 'FAIL'} "
              f"({time.time() - t0:.1f}s)")

    print(f"\n{'=' * 72}\nSUMMARY\n{'=' * 72}")
    for name, ok in results.items():
        print(f"{'PASS' if ok else 'FAIL'}  {name}")
    n_fail = sum(not ok for ok in results.values())
    print(f"\n{len(results) - n_fail}/{len(results)} benchmarks reproduce "
          f"the paper's claims")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
