"""§Roofline: three-term roofline per (arch × shape × mesh) from dry-run JSONs.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s      (197 TF bf16, v5e)
  memory term     = HLO_bytes_per_device / HBM_bw           (819 GB/s)
  collective term = collective_bytes_per_device / link_bw   (50 GB/s)

HLO numbers come from the loop-aware analyzer (benchmarks/hlo_cost.py) run
on the post-SPMD per-partition module at dry-run time, so per-device is the
natural unit. MODEL_FLOPS uses 6·N·D (train), 2·N·D (prefill), 2·N_active·B
(decode) with N from the analytic param counts.

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--mesh single]
Writes experiments/roofline.md (+ returns rows for benchmarks.run).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "../experiments/dryrun")
OUT_DIR = os.path.join(os.path.dirname(__file__), "../experiments")


def model_flops(arch_name: str, shape_name: str) -> float:
    a = ARCHS[arch_name]
    s = SHAPES[shape_name]
    n = a.active_param_count() if a.family == "moe" else a.param_count()
    if s.kind == "train":
        return 6.0 * n * s.global_batch * s.seq_len
    if s.kind == "prefill":
        return 2.0 * n * s.global_batch * s.seq_len
    # decode: one token per sequence + attention read ≈ 2·N·B (+2·L·D·H per
    # head handled inside N-dominated regimes; the cache read shows up in the
    # MEMORY term, which is the point of the paper)
    return 2.0 * n * s.global_batch


def load_rows(mesh: str = "single", policy: str = "packkv") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}_{policy}.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok" or "loop_cost" not in r:
            continue
        lc = r["loop_cost"]
        if "error" in lc:
            continue
        n_dev = r["n_devices"]
        t_c = lc["flops"] / PEAK_FLOPS
        t_m = lc["bytes"] / HBM_BW
        t_x = lc["collectives"]["total"] / LINK_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(r["arch"], r["shape"])
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "policy": policy,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_total": lc["flops"] * n_dev,
            "useful_ratio": mf / (lc["flops"] * n_dev) if lc["flops"] else 0.0,
            "roofline_frac": (
                max(t_c, 1e-30) / max(t_c, t_m, t_x)
            ),
            "temp_gb": r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        })
    return rows


def render(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful (6ND/HLO) | roofline frac | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} | "
            f"{r['temp_gb']:.1f} |\n"
        )
    return hdr + body


def main() -> bool:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--policy", default="packkv")
    args = ap.parse_args()
    rows = load_rows(args.mesh, args.policy)
    if not rows:
        print("no dry-run records found — run repro.launch.dryrun first")
        return False
    md = render(rows)
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = "" if args.mesh == "single" else f"_{args.mesh}"
    if args.policy != "packkv":
        tag += f"_{args.policy}"
    out = os.path.join(OUT_DIR, f"roofline{tag}.md")
    with open(out, "w") as f:
        f.write(f"# Roofline ({args.mesh} pod, {args.policy})\n\n" + md)
    print(md)
    print(f"{len(rows)} rows -> {out}")
    return True


if __name__ == "__main__":
    main()
