"""Paper Fig. 13: compression ratio vs pack size (K and V).

Reproduced claim: pack size 8/16 is optimal — small packs pay metadata
(min+width per pack), large packs pay range growth.
"""
from __future__ import annotations

from .common import MODEL_PROFILES, model_kv, stream_cr

PACK_SIZES = (2, 4, 8, 16, 32)


def run() -> dict:
    out = {"K": {}, "V": {}}
    for name in MODEL_PROFILES:
        k = model_kv(name, part="k")
        v = model_kv(name, part="v")
        out["K"][name] = {
            p: round(stream_cr(k, v, pack_size=p, part="k"), 2) for p in PACK_SIZES
        }
        out["V"][name] = {
            p: round(stream_cr(k, v, pack_size=p, part="v"), 2) for p in PACK_SIZES
        }
    return out


def main() -> bool:
    res = run()
    ok = True
    for part in ("K", "V"):
        print(f"\n[Fig 13{'a' if part == 'K' else 'b'}] {part} cache CR vs pack size")
        print(f"{'model':22s} " + " ".join(f"p={p:<6d}" for p in PACK_SIZES))
        for name, crs in res[part].items():
            print(f"{name:22s} " + " ".join(f"{crs[p]:<8.2f}" for p in PACK_SIZES))
            best = max(crs.values())
            # reproduced claim: p=8/16 captures (nearly) all of the CR —
            # diminishing returns beyond 16, which together with u32/u64
            # word alignment is the paper's case for 8/16. (On our
            # synthetic KV the curve plateaus rather than peaks; absolute
            # optimum can sit at 32 within a few %. EXPERIMENTS.md §CR.)
            if crs[16] < 0.93 * best:
                ok = False
                print(f"  !! CR(16)={crs[16]} < 93% of best {best}")
            if crs[2] > 0.8 * best:
                ok = False
                print("  !! small packs should pay metadata")
    print(f"\nFig13 reproduced (p=8/16 near-optimal, small packs pay "
          f"metadata): {ok}")
    return ok


if __name__ == "__main__":
    main()
