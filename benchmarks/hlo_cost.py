"""Mini HLO cost analyzer with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers / chunked-attention / token-recurrence graph is
undercounted by its trip count (verified: smollm L=2/4/8 all report the
same FLOPs). This analyzer parses the post-optimization HLO text and
computes:

  * flops       — dot/convolution ops (2·|out|·K), multiplied through
                  nested while trip counts
  * bytes       — per-op operand+output buffer traffic (fusion = its
                  operands + outputs, matching XLA's fusion accounting)
  * collectives — per-kind bytes (all-reduce / all-gather / reduce-scatter
                  / all-to-all / collective-permute), trip-multiplied

Trip counts are extracted from each while's condition computation
(largest integer literal in a compare — the lax.scan pattern). Unknown
conditions fall back to 1 and are reported in ``warnings``.

``conditional`` branches contribute their MAX-cost branch (conservative:
the decode flush branch runs once per 64 tokens but is counted every
step; see EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.+)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)(?:\.clone)?\s*\(.*\)\s*->.*{\s*$")
_CALLS = ("calls=", "body=", "condition=", "to_apply=")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    n = 0
    for dt, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dt, 4)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        n += size * b
    return n


def _shape_elems(text: str) -> int:
    n = 0
    for _, dims in _SHAPE_RE.findall(text):
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        n += size
    return n


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_text: str  # output shape text
    operands: list
    attrs: str
    operands_text: str = ""


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.bytes * k,
            {kk: vv * k for kk, vv in self.coll.items()},
        )


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Op]] = {}
        self.warnings: list[str] = []
        self._parse(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: dict[str, Cost] = {}

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: list[Op] | None = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            # op lines have " = " with spaces; header /*index=N*/ comments don't
            if mc and " = " not in line.split("->")[0]:
                cur = []
                self.comps[mc.group("name")] = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            mo = _OP_RE.match(line)
            if not mo:
                continue
            rest = mo.group("rest")
            # split "SHAPES opcode(operands), attrs"
            m2 = re.match(r"(?P<shape>\(.*?\)|\S+)\s+(?P<opcode>[\w\-]+)\((?P<tail>.*)$", rest)
            if not m2:
                continue
            tail = m2.group("tail")
            # operands end at the matching close paren
            depth = 1
            for i, ch in enumerate(tail):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operands_text = tail[:i] if depth == 0 else tail
            attrs = tail[i + 1 :] if depth == 0 else ""
            ops = re.findall(r"%([\w.\-]+)", operands_text)
            cur.append(
                Op(
                    name=mo.group("name"),
                    opcode=m2.group("opcode"),
                    out_text=m2.group("shape"),
                    operands=ops,
                    attrs=attrs,
                    operands_text=operands_text,
                )
            )

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if m:
            return m.group(1)
        return next(iter(self.comps))

    # -- trip count --------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        """Largest integer literal in the condition computation — the
        lax.scan pattern compares the induction var against the length."""
        ops = self.comps.get(cond_name, [])
        best = 0
        for op in ops:
            if op.opcode == "constant":
                m = re.match(r"\s*(\d+)\s*$", op.operands_text)
                if m:
                    best = max(best, int(m.group(1)))
            for m in re.finditer(r"constant\((\d+)\)", op.attrs + op.operands_text):
                best = max(best, int(m.group(1)))
            # fused conditions inline the bound into a fusion's computation
            called = self._attr_comp(op, "calls=")
            if called:
                for iop in self.comps.get(called, []):
                    if iop.opcode == "constant":
                        m = re.match(r"\s*(\d+)\s*$", iop.operands_text)
                        if m:
                            best = max(best, int(m.group(1)))
        if best == 0:
            self.warnings.append(f"trip count not found for {cond_name}; using 1")
            best = 1
        return best

    # -- cost --------------------------------------------------------------
    def _symtab(self, comp: str) -> dict[str, str]:
        return {op.name: op.out_text for op in self.comps.get(comp, [])}

    def _dot_flops(self, op: Op, sym: dict) -> float:
        out_elems = _shape_elems(op.out_text)
        lhs_text = sym.get(op.operands[0], "") if op.operands else ""
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        k = 1
        if m and lhs_text:
            dims_txt = _SHAPE_RE.findall(lhs_text)
            if dims_txt:
                dims = [int(d) for d in dims_txt[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * out_elems * k

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        sym = self._symtab(comp_name)
        for op in self.comps.get(comp_name, []):
            oc = op.opcode
            out_bytes = _shape_bytes(op.out_text)
            if oc == "while":
                body = self._attr_comp(op, "body=")
                cond = self._attr_comp(op, "condition=")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    total += self.cost_of(body).scaled(trips)
                if cond:
                    total += self.cost_of(cond).scaled(trips)
            elif oc == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.attrs)
                branch_comps = [b for b in branches if b in self.comps]
                if branch_comps:
                    costs = [self.cost_of(b) for b in branch_comps]
                    best = max(costs, key=lambda c: c.flops + c.bytes)
                    total += best
            elif oc in ("fusion", "call", "custom-call", "map"):
                called = self._attr_comp(op, "calls=") or self._attr_comp(
                    op, "to_apply="
                )
                inner = self.cost_of(called) if called else Cost()
                # fusion buffer traffic: operands + output (inner bytes are
                # register/loop traffic, not HBM)
                opnd_bytes = sum(
                    _shape_bytes(sym.get(o, "")) for o in op.operands
                )
                total += Cost(flops=inner.flops, bytes=opnd_bytes + out_bytes,
                              coll=dict(inner.coll))
            elif oc == "dot":
                f = self._dot_flops(op, sym)
                opnd_bytes = sum(_shape_bytes(sym.get(o, "")) for o in op.operands)
                total += Cost(flops=f, bytes=opnd_bytes + out_bytes)
            elif oc == "convolution":
                # rough: 2 * out_elems * (kernel elems)
                k_bytes = (
                    _shape_elems(sym.get(op.operands[1], "")) if len(op.operands) > 1 else 1
                )
                total += Cost(flops=2.0 * _shape_elems(op.out_text) * k_bytes,
                              bytes=out_bytes * 2)
            elif any(oc.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                total += Cost(bytes=out_bytes * 2, coll={kind: float(out_bytes)})
            elif oc in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "after-all", "partition-id"):
                pass
            else:  # standalone elementwise / copy / reduce etc.
                opnd_bytes = sum(_shape_bytes(sym.get(o, "")) for o in op.operands)
                total += Cost(bytes=opnd_bytes + out_bytes)
        self._memo[comp_name] = total
        return total

    def _attr_comp(self, op: Op, key: str) -> str | None:
        m = re.search(re.escape(key) + r"%?([\w.\-]+)", op.attrs)
        if m and m.group(1) in self.comps:
            return m.group(1)
        return None

    def total(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> dict:
    a = HloAnalyzer(hlo_text)
    c = a.total()
    coll = dict(c.coll)
    coll["total"] = sum(coll.values())
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": coll,
        "warnings": a.warnings[:20],
    }
