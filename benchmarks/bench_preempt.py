"""Priority preemption under a low-priority flood (ISSUE 8).

The hostile-traffic shape preemption exists for: a steady flood of long
class-1 requests keeps every slot busy, then a handful of short class-0
requests arrive mid-flood. Served twice over identical weights on the
paged pool:

  * FIFO BASELINE: ``preempt`` off, every request the same class — the
    late class-0 arrivals wait for a flood request to drain before they
    see a slot, so their TTFT is a whole low-priority decode tail.
  * PREEMPT: ``--preempt`` — the blocked class-0 admission swaps a
    class-1 victim's compressed pages out to host RAM, serves, and the
    victim resumes from its evacuated bytes.

Reported per policy: p99 TTFT of the class-0 arrivals (the acceptance
bar is >= 2x better than FIFO), the preemption count (must be > 0 or the
run measured nothing), aggregate delivered tok/s (the bar is within 10%
of the non-preemptive run — swap traffic must not tank throughput), and
per-request output equality across the two runs (a resumed victim must
reproduce its uninterrupted output bit-for-bit; the per-config matrix
lives in tests/test_preempt.py). Results land in BENCH_preempt.json (CI
uploads it as an artifact).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

CAPACITY = 512
PAGE = 128
MAX_BATCH = 4
FLOOD_PROMPT = 120  # single-page: one fused admission per step, so the
#                     flood saturates every slot before the class-0 burst
FLOOD_MAX_NEW = 80  # long enough that the handful of swap round-trips
#                     amortize: the tok/s parity bar is a 10% band
N_FLOOD = 10
HI_PROMPT = 100
HI_MAX_NEW = 8
N_HI = 3
HI_AFTER_STEPS = 6
TRIALS = 3          # timed trials, medians reported (shared runners drift)


def make_requests(vocab: int, classes: bool, seed: int = 0):
    """(flood, high-priority burst). With ``classes`` off, everything is
    class 0 — arrival-order FIFO, the baseline."""
    rng = np.random.default_rng(seed)
    flood = [Request(rid=rid, max_new=FLOOD_MAX_NEW,
                     priority=1 if classes else 0,
                     tokens=rng.integers(0, vocab, FLOOD_PROMPT - 16 * (rid % 3)))
             for rid in range(N_FLOOD)]
    his = [Request(rid=N_FLOOD + i, max_new=HI_MAX_NEW, priority=0,
                   tokens=rng.integers(0, vocab, HI_PROMPT + 8 * i))
           for i in range(N_HI)]
    return flood, his


def serve(eng: Engine, flood: list[Request], his: list[Request]) -> dict:
    """Drive the scheduler step-by-step: the flood is queued up front, the
    class-0 burst lands after ``HI_AFTER_STEPS`` steps (deterministic in
    scheduler steps, not wall clock, so both engines see one arrival
    order)."""
    srv = SlotServer(eng)
    for r in flood:
        srv.submit(r)
    burst = list(his)
    n = 0
    t0 = time.perf_counter()
    while srv.queue or srv.n_occupied or srv._task is not None or burst:
        if n == HI_AFTER_STEPS and burst:
            for r in burst:
                srv.submit(r)
            burst = []
        srv.step()
        n += 1
    wall = time.perf_counter() - t0
    s = srv.stats
    hi_ttft = [(srv.done[r.rid].t_first - srv.done[r.rid].t_submit) * 1e3
               for r in his]
    return {
        "hi_ttft_p99_ms": float(np.percentile(hi_ttft, 99)),
        "hi_ttft_ms": hi_ttft,
        "tok_s": s.tokens_out / wall,
        "wall_s": wall,
        "preemptions": s.preemptions,
        "swapped_pages": s.swapped_pages,
        "restored_pages": s.restored_pages,
        "outputs": {rid: r.output for rid, r in srv.done.items()},
    }


def main() -> bool:
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    print(f"\n[ISSUE 8] preemption: {N_FLOOD} class-1 requests "
          f"(~{FLOOD_PROMPT}-token prompts, {FLOOD_MAX_NEW} new) flooding "
          f"{MAX_BATCH} slots; {N_HI} class-0 arrivals after "
          f"{HI_AFTER_STEPS} steps")
    results = {"capacity": CAPACITY, "page_size": PAGE,
               "max_batch": MAX_BATCH, "n_flood": N_FLOOD, "n_hi": N_HI}
    ok = True
    for policy in ("packkv", "none"):
        mk = lambda preempt: Engine(
            cfg, params, PackKVConfig(policy=policy),
            EngineConfig(capacity=CAPACITY, max_batch=MAX_BATCH,
                         calib_tokens=128, bucketed=True, bucket_unit=PAGE,
                         decode_chunk=4, paged=True, page_size=PAGE,
                         preempt=preempt),
        )
        fifo_eng, pre_eng = mk(False), mk(True)
        # warmup: compile every admission/decode/evacuate variant off the clock
        serve(fifo_eng, *make_requests(cfg.vocab, classes=False, seed=1))
        serve(pre_eng, *make_requests(cfg.vocab, classes=True, seed=1))

        fifo_runs = [serve(fifo_eng, *make_requests(cfg.vocab, classes=False))
                     for _ in range(TRIALS)]
        pre_runs = [serve(pre_eng, *make_requests(cfg.vocab, classes=True))
                    for _ in range(TRIALS)]
        med = lambda runs, k: float(np.median([r[k] for r in runs]))
        fifo_p99 = med(fifo_runs, "hi_ttft_p99_ms")
        pre_p99 = med(pre_runs, "hi_ttft_p99_ms")
        speedup = fifo_p99 / pre_p99
        tok_ratio = med(pre_runs, "tok_s") / med(fifo_runs, "tok_s")
        n_preempt = int(np.median([r["preemptions"] for r in pre_runs]))
        # resumed == uninterrupted: every request's output must be
        # bit-identical whether or not it was swapped out along the way
        exact = all(
            np.array_equal(pre_runs[0]["outputs"][rid], out)
            for rid, out in fifo_runs[0]["outputs"].items()
        )
        print(f"  {policy:7s} class-0 p99 TTFT: FIFO {fifo_p99:8.1f} ms   "
              f"preempt {pre_p99:8.1f} ms -> {speedup:.2f}x "
              f"({n_preempt} preemptions, tok/s ratio {tok_ratio:.2f}); "
              f"resumed==uninterrupted exact: {exact}")
        results[policy] = {
            "fifo": {k: v for k, v in fifo_runs[0].items() if k != "outputs"}
            | {"hi_ttft_p99_ms": fifo_p99},
            "preempt": {k: v for k, v in pre_runs[0].items() if k != "outputs"}
            | {"hi_ttft_p99_ms": pre_p99, "preemptions": n_preempt},
            "ttft_speedup": speedup,
            "tok_s_ratio": tok_ratio,
            "resumed_eq_uninterrupted": exact,
        }
        ok = ok and exact and n_preempt > 0 and speedup >= 2.0 \
            and tok_ratio >= 0.9
    with open("BENCH_preempt.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
    print(f"preemption >=2x class-0 p99 TTFT, tok/s within 10%, "
          f"resumed==uninterrupted: {ok}")
    print("wrote BENCH_preempt.json")
    return bool(ok)


if __name__ == "__main__":
    main()
