"""Paper Table V: V-cache CR — KIVI token quant vs PackKV (same token
quant + lossless encoding) at the same quantization settings.

The paper's point (§IV-D2): both use token-wise V quantization, so
accuracy is THEORETICALLY IDENTICAL; PackKV's gain is pure lossless
encoding on top. We therefore compare at the V turning point directly.
"""
from __future__ import annotations

import numpy as np

from repro.core.kivi import kivi_cr_from_rel_scale

from .common import (
    MODEL_PROFILES,
    V_PACK_SWEEP,
    find_turning_point,
    model_kv,
    stream_cr,
)


def run() -> dict:
    out: dict = {}
    for name in MODEL_PROFILES:
        k = model_kv(name, part="k")
        v = model_kv(name, part="v")
        tp = find_turning_point(k, v, "v_token",
                                scales=np.geomspace(0.01, 0.68, 12))
        kivi = kivi_cr_from_rel_scale(max(tp, 1e-3))
        pack = max(
            stream_cr(k, v, pack_size=p, repack=m, v_rel=max(tp, 1e-3), part="v")
            for p, m in V_PACK_SWEEP
        )
        out[name] = {"turning_point": tp, "kivi": kivi, "packkv": pack,
                     "gain_pct": (pack / kivi - 1) * 100}
    return out


def main() -> bool:
    res = run()
    print("\n[Table V] V cache CR at the token-quant turning point "
          "(identical accuracy by construction)")
    print(f"{'model':22s} {'scale':>7s} {'KIVI':>8s} {'PackKV':>8s} {'gain':>9s}")
    gains = []
    for name, r in res.items():
        gains.append(r["gain_pct"])
        print(f"{name:22s} {r['turning_point']:7.3f} {r['kivi']:8.2f} "
              f"{r['packkv']:8.2f} {r['gain_pct']:+8.1f}%")
    avg = float(np.mean(gains))
    print(f"{'avg':22s} {'':7s} {'':8s} {'':8s} {avg:+8.1f}%   (paper: +179.6%)")
    ok = avg > 25
    print(f"\nTable V direction reproduced: {ok}")
    return ok


if __name__ == "__main__":
    main()
