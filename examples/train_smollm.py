"""End-to-end driver: train a ~100M-param model for a few hundred steps.

Uses the FULL smollm-135m config (30L × 576d, the assigned architecture)
on the synthetic Zipf stream, with WSD schedule, gradient accumulation,
async checkpointing, and straggler monitoring — the complete training
substrate at quickstart scale.

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 300]
(CPU: ~1-2 s/step at batch 8 × seq 256.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_arch
from repro.data import ShardedTokenStream
from repro.distributed import StragglerMonitor
from repro.models import get_model
from repro.training import OptConfig, init_opt_state
from repro.training.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/packkv_smollm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch("smollm-135m")  # FULL 135M config
    api = get_model(cfg)
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.0f}M params")

    opt_cfg = OptConfig(lr=6e-4, schedule="wsd", warmup_steps=20,
                        total_steps=args.steps)
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    stream = ShardedTokenStream(vocab=cfg.vocab, batch_per_host=args.batch,
                                seq=args.seq)
    start = 0
    if args.resume and (last := latest_step(args.ckpt_dir)) is not None:
        (params, opt), extra = restore(args.ckpt_dir, last, (params, opt))
        stream.restore(extra["stream"])
        start = last
        print(f"resumed from step {last}")

    step_fn = jax.jit(make_train_step(api, cfg, opt_cfg, args.grad_accum),
                      donate_argnums=(0, 1))
    ckpt = AsyncCheckpointer(args.ckpt_dir)
    monitor = StragglerMonitor()
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        monitor.start()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        verdict = monitor.stop()
        if step % 20 == 0:
            tok_s = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"lr-phase {'warmup' if step < 20 else 'stable/decay'}  "
                  f"{tok_s:,.0f} tok/s  [{verdict}]")
        if (step + 1) % 100 == 0:
            ckpt.submit(step + 1, (params, opt), {"stream": stream.state()})
    ckpt.close()
    print(f"done: final loss {loss:.4f} in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
