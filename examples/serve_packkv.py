"""Serve a small model with batched requests through the PackKV engine.

Builds two engines over the same weights — uncompressed and PackKV —
serves the same requests through both via the continuous slot scheduler,
and reports the agreement rate and scheduler stats. This is the paper's
deployment story end-to-end: calibration -> compile -> slot-scheduled
serving with compressed decode (see docs/serving.md).

Run:  PYTHONPATH=src python examples/serve_packkv.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.cache import PackKVConfig
from repro.core.tiered import tiered_bits_per_value
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer


def main():
    cfg = get_arch("llama2-7b", smoke=True)  # reduced config for CPU
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(capacity=512, max_batch=4, calib_tokens=192)

    print("building engines (calibration + jit)...")
    e_none = Engine(cfg, params, PackKVConfig(policy="none"), ecfg)
    e_pack = Engine(cfg, params,
                    PackKVConfig(k_rel_scale=0.02, v_rel_scale=0.02), ecfg)
    ks = e_pack.pack_cfg.k_spec_static
    print(f"calibrated K tiers {ks.widths} × {ks.counts} -> "
          f"{tiered_bits_per_value(ks):.2f} bits/value "
          f"({16 / tiered_bits_per_value(ks):.1f}x vs bf16)")

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, max_new=12,
                tokens=rng.integers(0, cfg.vocab, int(rng.integers(40, 120))))
        for i in range(8)
    ]

    outs = {}
    for name, eng in (("uncompressed", e_none), ("packkv", e_pack)):
        srv = SlotServer(eng)
        for r in reqs:
            srv.submit(dataclasses.replace(r))
        srv.run()
        outs[name] = {r.rid: r.output for r in srv.done.values()}
        print(f"{name}: served {len(srv.done)} requests "
              f"(occupancy {srv.stats.occupancy:.2f}, "
              f"{srv.stats.slot_reuses} slot reuses)")

    agree = np.mean([
        (outs["uncompressed"][rid] == outs["packkv"][rid]).mean()
        for rid in outs["uncompressed"]
    ])
    print(f"greedy-token agreement (rel_scale=0.02): {agree:.1%}")
    print("(tighten/loosen rel scales to trade cache memory vs fidelity — "
          "paper Tables III/IV)")


if __name__ == "__main__":
    main()
