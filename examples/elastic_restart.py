"""Fault-tolerance drill: preemption + elastic restart + straggler exclusion.

Simulates the fleet-controller loop: train, get preempted mid-run (we just
stop), restart from the latest COMMITted checkpoint with a DIFFERENT mesh
shape (elastic downscale after a straggler exclusion), and verify the loss
trajectory continues bit-exactly for the data stream.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_arch
from repro.data import ShardedTokenStream
from repro.distributed import StragglerMonitor, downscale_plan
from repro.models import get_model
from repro.training import OptConfig, init_opt_state
from repro.training.train import make_train_step

CKPT = "/tmp/packkv_elastic"


def run_segment(start: int, stop: int, params, opt, stream, step_fn, ckpt):
    losses = {}
    for step in range(start, stop):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses[step] = float(metrics["loss"])
        if (step + 1) % 5 == 0:
            ckpt.submit(step + 1, (params, opt), {"stream": stream.state()})
    return params, opt, losses


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_arch("smollm-135m", smoke=True)
    api = get_model(cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    stream = ShardedTokenStream(vocab=cfg.vocab, batch_per_host=4, seq=128)
    step_fn = jax.jit(make_train_step(api, cfg, opt_cfg), donate_argnums=(0, 1))

    # ---- run 1: train 12 steps, checkpointing every 5; then "preempted"
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    ckpt = AsyncCheckpointer(CKPT)
    params, opt, l1 = run_segment(0, 12, params, opt, stream, step_fn, ckpt)
    ckpt.close()
    print(f"run 1 preempted at step 12 (latest checkpoint: "
          f"step {latest_step(CKPT)})")

    # ---- straggler detection triggers an elastic downscale decision
    mon = StragglerMonitor(patience=2)
    for dt in (1.0, 1.0, 1.0, 1.0, 9.0, 9.5):
        verdict = mon.observe(dt)
    plan = downscale_plan((2, 16, 16), "exclude-straggler")
    print(f"straggler verdict: {verdict} -> elastic plan "
          f"{plan.old_shape} -> {plan.new_shape}")

    # ---- run 2: restore on the "new mesh" (restore takes target shardings;
    # on 1 CPU device the reshard is trivial, the code path is identical)
    params2 = api.init(jax.random.PRNGKey(0), cfg)
    opt2 = init_opt_state(params2)
    last = latest_step(CKPT)
    (params2, opt2), extra = restore(CKPT, last, (params2, opt2))
    stream2 = ShardedTokenStream(vocab=cfg.vocab, batch_per_host=4, seq=128)
    stream2.restore(extra["stream"])
    ckpt2 = AsyncCheckpointer(CKPT)
    _, _, l2 = run_segment(last, 15, params2, opt2, stream2, step_fn, ckpt2)
    ckpt2.close()

    # the overlapping steps must match the uninterrupted trajectory
    overlap = [s for s in l1 if s in l2]
    drift = max(abs(l1[s] - l2[s]) for s in overlap)
    print(f"steps {overlap} replayed after restart; max loss drift {drift:.2e}")
    assert drift < 1e-4, "restart is not deterministic!"
    print("elastic restart drill PASSED")


if __name__ == "__main__":
    main()
