"""Quickstart: compress a KV cache with PackKV and decode against it.

Shows the paper's full pipeline on one layer of data:
  quantize -> repack -> tier-pack -> seamless append -> fused decode
and reports the compression ratio + attention error vs full precision.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    append_token,
    calibrate_specs,
    prefill_cache,
)
from repro.data import synthetic_kv
from repro.kernels import ops
from repro.kernels.ref import dense_decode_attention_ref
from repro.utils import tree_bytes


def main():
    rng = np.random.default_rng(0)
    B, H_kv, H_q, D, capacity = 1, 4, 8, 128, 1024
    prompt_len = 512

    # "prefill" K/V (stand-ins for a model's attention projections)
    k = jnp.asarray(synthetic_kv(rng, B, H_kv, prompt_len, D))
    v = jnp.asarray(synthetic_kv(rng, B, H_kv, prompt_len, D))

    # 1. calibrate static tier widths from the data (engine-build step)
    cfg = calibrate_specs(k, v, PackKVConfig(k_rel_scale=0.1, v_rel_scale=0.2))
    print("calibrated K tiers:", cfg.k_spec_static.widths, cfg.k_spec_static.counts)
    print("calibrated V tiers:", cfg.v_spec_static.widths, cfg.v_spec_static.counts)

    # 2. prefill: quantize + V-median repack + bit-pack, block by block
    cache = alloc_layer_cache(cfg, B, H_kv, D, capacity)
    cache = prefill_cache(cache, k, v)
    print(f"compressed {int(cache.n_comp[0])} tokens; {int(cache.n_resid[0])} in the "
          f"fp16 residual buffer")

    # 3. seamless appending during decode
    for _ in range(10):
        kt = jnp.asarray(synthetic_kv(rng, B, H_kv, 1, D))
        cache = append_token(cache, kt, kt)

    # 4. computation-aware decompression: fused decode attention
    q = jnp.asarray(rng.normal(size=(B, H_q, D)).astype(np.float32))
    out = ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, sm_scale=D ** -0.5,
    )
    # same op on the Pallas kernel path (interpret mode on CPU)
    out_pl = ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, sm_scale=D ** -0.5, backend="pallas",
    )
    print("pallas kernel max |Δ| vs XLA path:",
          float(jnp.max(jnp.abs(out - out_pl))))

    # 5. accuracy + memory vs the uncompressed baseline
    pad = jnp.zeros((B, H_kv, capacity - prompt_len, D))
    exact = dense_decode_attention_ref(
        q, jnp.concatenate([k, pad], 2), jnp.concatenate([v, pad], 2),
        cache.resid_k, cache.resid_v, jnp.int32(prompt_len), cache.n_resid,
        D ** -0.5,
    )
    rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
    comp = sum(
        t.payload.size * 4 + t.mins.size + t.shifts.size
        for c in (cache.k, cache.v) for t in c.tiers
    ) + cache.k.scale.size * 4 + cache.v.scale.size * 4
    raw = 2 * B * H_kv * capacity * D * 2
    print(f"attention output rel err vs fp32: {rel:.4f}")
    print(f"cache: {comp:,} B compressed vs {raw:,} B raw bf16 "
          f"-> {raw / comp:.1f}x")


if __name__ == "__main__":
    main()
