"""Sharded checkpointing with elastic restore.

Format: one directory per step —
  step_000123/
    manifest.json   (pytree structure, leaf dtypes/shapes, data-stream state)
    arrays.npz      (flat leaves, keyed by index)
    COMMIT          (written LAST; restore ignores dirs without it)

Atomicity: write into ``.tmp-<step>`` then os.rename; the COMMIT marker
makes partially written checkpoints (simulated preemption) invisible to
``latest_step``. Restore takes target shardings, so the same checkpoint
restores onto a DIFFERENT mesh (elastic down/up-scale) — leaves are saved
as full host arrays (per-shard formats would gather here; on a real fleet
each host writes its shard and restore re-slices, same manifest).

``AsyncCheckpointer`` overlaps the host copy + disk write with the next
training step via a single worker thread (bounded queue of 1 — back-
pressure instead of unbounded memory growth).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np

COMMIT = "COMMIT"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_savable(x: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't store ml_dtypes (bfloat16 etc.) — save as a u16/u8 view."""
    dt = str(x.dtype)
    if dt == "bfloat16":
        return x.view(np.uint16), dt
    if dt.startswith("float8"):
        return x.view(np.uint8), dt
    return x, dt


def _from_savable(x: np.ndarray, dt: str) -> np.ndarray:
    if dt == "bfloat16" or dt.startswith("float8"):
        import ml_dtypes

        return x.view(np.dtype(getattr(ml_dtypes, dt)))
    return x


def save(path: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the final checkpoint dir."""
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(path, f".tmp-{step}")
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a, dt = _to_savable(np.asarray(jax.device_get(x)))
        arrays[f"leaf_{i}"] = a
        dtypes.append(dt)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    import hashlib

    manifest = {
        "step": step,
        # structural fingerprint (restore() takes the treedef from like_tree;
        # this guards against restoring into a mismatched structure)
        "tree_hash": hashlib.sha256(
            str(jax.tree_util.tree_structure(tree)).encode()
        ).hexdigest()[:16],
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_mini(path: str, tree, extra: dict | None = None) -> str:
    """Atomic single-entry save of an evacuated mini-cache (or any small
    pytree) into directory ``path`` — the disk spill tier of the session
    cache rides this.

    Same on-disk grammar as ``save()`` (arrays.npz of savable-dtype leaf
    views + manifest.json + COMMIT written last, tmp-dir then rename) but
    keyed by caller-chosen path instead of a step number, so a
    ``SessionStore`` can name entries after session traces. ``extra``
    must be JSON-serializable.
    """
    leaves, treedef = _flatten(tree)
    parent = os.path.dirname(path) or "."
    tmp = os.path.join(parent, f".tmp-{os.path.basename(path)}")
    os.makedirs(tmp, exist_ok=True)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a, dt = _to_savable(np.asarray(jax.device_get(x)))
        arrays[f"leaf_{i}"] = a
        dtypes.append(dt)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    import hashlib

    manifest = {
        "tree_hash": hashlib.sha256(str(treedef).encode()).hexdigest()[:16],
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def load_mini(path: str, treedef=None):
    """Inverse of ``save_mini``. Returns ``(tree, extra)``.

    With ``treedef`` (a ``jax.tree_util`` treedef, e.g. cached by the
    ``SessionStore`` from its first evacuation) the leaves are unflattened
    back into the original structure and the structural fingerprint is
    checked; with ``treedef=None`` the flat leaf list is returned —
    enough for byte-level round-trip checks.
    """
    if not os.path.exists(os.path.join(path, COMMIT)):
        raise FileNotFoundError(f"no committed mini-cache at {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    dtypes = manifest["dtypes"]
    leaves = [
        _from_savable(data[f"leaf_{i}"], dtypes[i])
        for i in range(manifest["n_leaves"])
    ]
    if treedef is None:
        return leaves, manifest["extra"]
    import hashlib

    want = hashlib.sha256(str(treedef).encode()).hexdigest()[:16]
    if manifest.get("tree_hash") not in (None, want):
        raise ValueError("mini-cache structure mismatch (different engine?)")
    return treedef.unflatten(leaves), manifest["extra"]


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and os.path.exists(os.path.join(path, d, COMMIT)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str, step: int, like_tree, shardings=None):
    """Restore onto ``shardings`` (None -> host). ``like_tree`` provides the
    treedef (shapes may differ across meshes only in sharding, not value)."""
    import hashlib

    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    dtypes = manifest.get("dtypes") or [None] * manifest["n_leaves"]
    leaves = [
        _from_savable(data[f"leaf_{i}"], dtypes[i]) if dtypes[i] else data[f"leaf_{i}"]
        for i in range(manifest["n_leaves"])
    ]
    treedef = jax.tree_util.tree_structure(like_tree)
    want = hashlib.sha256(str(treedef).encode()).hexdigest()[:16]
    if manifest.get("tree_hash") not in (None, want):
        raise ValueError("checkpoint structure mismatch (different model?)")
    tree = treedef.unflatten(leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree, manifest["extra"]


def gc_old(path: str, keep: int) -> None:
    if not os.path.isdir(path):
        return
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_") and os.path.exists(os.path.join(path, d, COMMIT))
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d))


class AsyncCheckpointer:
    """Single-worker async saver with back-pressure (queue size 1)."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self.q: queue.Queue = queue.Queue(maxsize=1)
        self.errors: list[Exception] = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save(self.path, step, tree, extra)
                gc_old(self.path, self.keep)
            except Exception as e:  # surfaced on next submit/close
                self.errors.append(e)
            finally:
                self.q.task_done()

    def submit(self, step: int, tree, extra: dict | None = None) -> None:
        if self.errors:
            raise self.errors[0]
        # device_get NOW so the training step can mutate buffers freely
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.q.put((step, host_tree, extra))

    def close(self) -> None:
        self.q.join()
        self.q.put(None)
        self._t.join()
        if self.errors:
            raise self.errors[0]
