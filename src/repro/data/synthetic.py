"""Synthetic data pipeline.

Two generators:

* ``zipf_token_batch`` / ``ShardedTokenStream`` — deterministic Zipf-
  distributed LM token stream with per-host sharding (the training data
  substrate; real deployments swap in a tokenized corpus behind the same
  iterator protocol).

* ``synthetic_kv`` — KV-cache-like tensors with the structure the paper
  measures on real models (Figs 3–4): strong per-channel offsets, smooth
  variation along the context dimension (channel correlation / repeating
  patterns) plus noise. Used by CR benchmarks and accuracy-proxy tests so
  compression ratios are meaningful rather than gaussian-worst-case.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def zipf_token_batch(
    rng: np.random.Generator, batch: int, seq: int, vocab: int, alpha: float = 1.1
) -> np.ndarray:
    """[batch, seq] int32 Zipf(alpha) tokens in [0, vocab)."""
    # inverse-CDF sampling on a truncated Zipf for vectorized speed
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    cdf = np.cumsum(probs)
    u = rng.random((batch, seq))
    toks = np.searchsorted(cdf, u).astype(np.int32)
    return np.minimum(toks, vocab - 1)


@dataclasses.dataclass
class ShardedTokenStream:
    """Deterministic, restartable, host-sharded token stream.

    Each (host, step) pair maps to an independent RNG stream, so restart
    from a checkpointed ``step`` reproduces the exact same batches and
    different hosts never overlap — the property elastic restarts rely on.
    """

    vocab: int
    batch_per_host: int
    seq: int
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0
    step: int = 0
    alpha: float = 1.1

    def next_batch(self) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_id, self.step])
        )
        toks = zipf_token_batch(
            rng, self.batch_per_host, self.seq + 1, self.vocab, self.alpha
        )
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])


def synthetic_kv(
    rng: np.random.Generator,
    batch: int,
    heads: int,
    seq: int,
    head_dim: int,
    *,
    channel_scale: float = 2.0,
    smooth: float = 0.95,
    noise: float = 0.15,
    outlier_frac: float = 0.05,
    spike_frac: float = 0.06,
    spike_mag: float = 3.0,
    n_patterns: int = 0,
    pattern_scale: float = 1.0,
    dtype=np.float32,
) -> np.ndarray:
    """KV-like data [B, H, L, D]: per-channel offsets + AR(1) along context
    + sparse token spikes.

    channel_scale: magnitude spread of per-channel means (paper Fig. 4's
      vertical stripes — a few channels dominate the range).
    smooth: AR(1) coefficient along the context dim (token-to-token
      correlation that repacking exploits).
    noise: white-noise floor.
    outlier_frac: fraction of high-variance channels (KV caches have heavy
      per-channel kurtosis; these land in the wide tiers).
    spike_frac/spike_mag: fraction of TOKENS with outlier activations
      (attention sinks, delimiters) — these widen any bit-pack that
      includes them, which is what makes very large pack sizes pay range
      growth (paper Fig. 13's falling tail).
    n_patterns/pattern_scale: tokens draw one of ``n_patterns`` channel-
      mean templates (token categories: code/prose/numbers...). Interleaved
      categories are exactly what encode-aware REPACKING groups — the
      source of the paper's Table I gains.
    """
    ch_mean = rng.normal(0, channel_scale, size=(1, heads, 1, head_dim))
    ch_std = np.full((1, heads, 1, head_dim), noise)
    n_out = max(1, int(outlier_frac * head_dim))
    out_idx = rng.choice(head_dim, size=n_out, replace=False)
    ch_std[..., out_idx] = 1.0
    e = rng.normal(0, 1, size=(batch, heads, seq, head_dim))
    x = np.empty_like(e)
    x[:, :, 0] = e[:, :, 0]
    for t in range(1, seq):
        x[:, :, t] = smooth * x[:, :, t - 1] + np.sqrt(1 - smooth**2) * e[:, :, t]
    # per-token scale mixture (heteroscedastic tokens): larger packs mix
    # more σ regimes, so per-pack ranges grow with pack size even after
    # repacking — the mechanism behind Fig 13's falling tail
    tok_sigma = np.exp(rng.normal(0, 0.5, size=(batch, heads, seq, 1)))
    out = ch_mean + ch_std * tok_sigma * x
    if n_patterns > 0:
        templates = rng.normal(0, pattern_scale,
                               size=(n_patterns, 1, heads, 1, head_dim))
        tok_type = rng.integers(0, n_patterns, size=(batch, heads, seq))
        out = out + np.take_along_axis(
            np.broadcast_to(templates, (n_patterns, batch, heads, seq, head_dim)),
            tok_type[None, ..., None], axis=0,
        )[0]
    if spike_frac > 0:
        spikes = rng.random((batch, heads, seq, 1)) < spike_frac
        out = out + spikes * rng.normal(0, spike_mag * noise,
                                        size=(batch, heads, seq, head_dim))
    return out.astype(dtype)
