from .synthetic import (  # noqa: F401
    ShardedTokenStream,
    synthetic_kv,
    zipf_token_batch,
)
