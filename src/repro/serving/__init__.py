from .engine import Engine, EngineConfig, Request, WaveServer  # noqa: F401
