from .engine import (  # noqa: F401
    Engine,
    EngineConfig,
    Request,
    SlotServer,
    SlotStats,
)
