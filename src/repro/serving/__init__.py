from .engine import (  # noqa: F401
    Engine,
    EngineConfig,
    NGramDrafter,
    Request,
    SlotServer,
    SlotStats,
)
