"""Serving engine: calibration, jitted prefill/decode, continuous batching.

Build sequence (mirrors a production bring-up):
  1. CALIBRATE — run a short prefill with the uncompressed policy, collect
     raw K/V, pick static TierSpecs (core.cache.calibrate_specs). This is
     the paper's per-model configuration sweep (§IV-B) done once at engine
     build, before compilation.
  2. COMPILE — jit prefill + decode with the calibrated PackKVConfig.
  3. SERVE — ``SlotServer`` runs a continuous-batching scheduler over a
     fixed slot table of ``max_batch`` rows. Every sequence owns one row of
     the decode cache with its own ``n_comp``/``n_resid`` counters: a
     queued request is admitted into any free slot by a jitted single-slot
     prefill-insert (at its TRUE prompt length — no left-padding, so pad
     tokens never pollute the cache), all occupied slots decode together
     each step, and a row is recycled the moment its request finishes
     (EOS / max_new) while the other rows keep decoding.

``WaveServer`` survives as a thin compatibility wrapper over the slot
scheduler (same submit/run_wave surface); model families whose decode
state cannot be row-recycled yet (rwkv6 / hybrid_rglru recurrent state)
fall back to its legacy lock-step wave. See docs/serving.md for the slot
table layout, admission policy and per-row counter plumbing, and
docs/architecture.md for the paged pool.

Invariants the scheduler maintains (and the cache layer relies on):
  * the host-side token counts (``_Active.cached_tokens``) upper-bound the
    device counters — buckets and page reservations are computed without a
    device sync and are always safe over-estimates;
  * in paged mode, reserved pages (sum over active slots of worst-case
    ``ceil(min(capacity, prompt + max_new) / page_size)``) never exceed
    ``pool_pages - page_watermark`` — the in-graph free-list can never
    over-pop, so oversubscribed pools serve mixed traffic exactly;
  * a retired slot's pages are back in the pool (``reset_slot``) before
    the next admission runs, so FIFO admission makes progress whenever any
    slot retires.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.cache import PackKVConfig, calibrate_specs
from ..models import get_model

Array = jax.Array


@dataclasses.dataclass
class EngineConfig:
    capacity: int = 4096  # compressed-region token capacity
    max_batch: int = 8  # slot-table size
    backend: str = "xla"  # xla | pallas
    calibrate: bool = True
    calib_tokens: int = 192  # multiple of the 64-token block
    # length-aware launches (see docs/performance.md):
    bucketed: bool = True  # slice the compressed region to a live-length bucket
    bucket_unit: int = 256  # smallest bucket; power-of-two multiples up to capacity
    decode_chunk: int = 8  # decode steps per donated multi-step launch (1 = per-token)
    log_launches: bool = False  # keep per-launch telemetry (unbounded; bench only)
    # paged compressed region (see docs/architecture.md):
    paged: bool = False  # page-pool storage + page-reservation admission
    page_size: int = 256  # tokens per physical page (power of two, >= block)
    pool_pages: int | None = None  # physical pages; None = max_batch * capacity
    #   / page_size (no oversubscription). Setting it lower oversubscribes:
    #   admission then blocks on page reservations instead of free slots.
    page_watermark: int = 0  # spare pages admission always holds back
    # shared-prefix page cache (requires paged; see docs/serving.md):
    prefix_cache: bool = False  # content-addressed prefix reuse across requests
    prefix_cache_pages: int | None = None  # max pages the index may pin
    #   (None = unbounded; pool-pressure eviction still applies either way)
    debug_invariants: bool = False  # assert refcount conservation after every
    #   admit/retire (device sync per check — tests/bring-up only)


class Engine:
    def __init__(self, cfg: ArchConfig, params, pack_cfg: PackKVConfig,
                 ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.api = get_model(cfg)
        if ecfg.prefix_cache:
            if self.api.prefill_prefix is None:
                raise ValueError(
                    f"family {cfg.family!r} cannot serve --prefix-cache: its "
                    "recurrent decode state has no page-addressable KV pages "
                    "to share (WaveServer-only family) — drop --prefix-cache"
                )
            if not ecfg.paged:
                raise ValueError(
                    "--prefix-cache requires --paged: shared prefixes live "
                    "in the refcounted page pool"
                )
            if cfg.window:
                raise ValueError(
                    "--prefix-cache does not support sliding-window "
                    f"attention (window={cfg.window}): evicted window "
                    "tokens break page-aligned prefix identity"
                )
        if ecfg.paged:
            if not self.api.supports_slots:
                raise ValueError(
                    f"family {cfg.family!r} cannot serve paged (no slot ops; "
                    "its recurrent decode state is not page-addressable)"
                )
            if ecfg.capacity % ecfg.page_size:
                raise ValueError(
                    f"capacity {ecfg.capacity} not a multiple of page_size "
                    f"{ecfg.page_size}"
                )
            pool_pages = (
                ecfg.pool_pages
                if ecfg.pool_pages is not None
                else ecfg.max_batch * ecfg.capacity // ecfg.page_size
            )
            pack_cfg = dataclasses.replace(
                pack_cfg, paged=True, page_size=ecfg.page_size,
                pool_pages=pool_pages,
            )
        self.pack_cfg = (
            self._calibrate(pack_cfg) if (
                ecfg.calibrate
                and pack_cfg.policy == "packkv"
                and cfg.family not in ("rwkv6",)
            ) else pack_cfg
        )
        self._prefill = jax.jit(
            partial(self.api.prefill, cfg=cfg, pack_cfg=self.pack_cfg,
                    capacity=ecfg.capacity)
        )
        # one compile per launch bucket (bounded: core.cache.bucket_set)
        self._decode = jax.jit(
            partial(self.api.decode_step, cfg=cfg, backend=ecfg.backend),
            static_argnames=("n_bucket",),
        )
        if self.api.supports_slots:
            from ..core.cache import mask_free_slots

            # one compile per distinct prompt length; slot index is traced
            self._insert = jax.jit(
                partial(self.api.prefill_into_slot, cfg=cfg,
                        pack_cfg=self.pack_cfg, capacity=ecfg.capacity)
            )
            self._reset = jax.jit(self.api.reset_slot)
            self._mask_free = jax.jit(mask_free_slots)
        if ecfg.prefix_cache:
            from ..core.cache import acquire_pages, release_pages

            # one compile per (prompt length, matched-prefix length) pair
            self._insert_prefix = jax.jit(
                partial(self.api.prefill_prefix, cfg=cfg,
                        pack_cfg=self.pack_cfg, capacity=ecfg.capacity),
                static_argnames=("n_prefix",),
            )
            # index pin/unpin ops take sentinel-padded fixed-length id
            # vectors, so each compiles exactly once
            self._acquire_pages = jax.jit(acquire_pages)
            self._release_pages = jax.jit(release_pages)
            self._dummy_perm = jnp.broadcast_to(
                jnp.arange(cfg.hd, dtype=jnp.int32),
                (cfg.n_layers, cfg.n_kv_heads, cfg.hd),
            )
        if self.api.decode_multi is not None:
            # donated multi-step decode: the chunk loop updates the cache
            # buffers in place (no per-token copy) and one dispatch covers
            # up to ``decode_chunk`` tokens
            self._decode_multi = jax.jit(
                partial(self.api.decode_multi, cfg=cfg, backend=ecfg.backend),
                static_argnames=("t_max", "n_bucket"),
                donate_argnames=("cache",),
            )
        else:
            self._decode_multi = None

    # -- calibration --------------------------------------------------------
    def _calibrate(self, pack_cfg: PackKVConfig) -> PackKVConfig:
        S = self.ecfg.calib_tokens
        rng = np.random.default_rng(0)
        B = 1
        batch = {"tokens": jnp.asarray(rng.integers(0, self.cfg.vocab, (B, S)),
                                       jnp.int32)}
        if self.cfg.input_mode == "tokens_patches":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(B, self.cfg.n_patches, self.cfg.d_model)),
                jnp.float32,
            )
        # calibration reads raw prefill K/V from a dense layout; paged
        # placement is irrelevant to spec choice, so strip it here
        none_cfg = dataclasses.replace(pack_cfg, policy="none", paged=False)
        cap = max(S + self.cfg.n_patches * (self.cfg.input_mode == "tokens_patches"),
                  pack_cfg.block)
        cap = -(-cap // pack_cfg.block) * pack_cfg.block
        if self.cfg.family == "hybrid_rglru":
            _, state = self.api.prefill(self.params, self.cfg, none_cfg, cap, batch)
            cache = state.cache
            n = min(int(jnp.min(cache.n_comp)), self.cfg.window)
        else:
            _, cache = self.api.prefill(self.params, self.cfg, none_cfg, cap, batch)
            n = int(jnp.min(cache.n_comp))
        n = (n // pack_cfg.block) * pack_cfg.block
        if n == 0:
            return pack_cfg
        rk, rv = cache.raw_k, cache.raw_v  # [L?, B, H, cap, D]
        lead = rk.shape[: rk.ndim - 3]
        D = rk.shape[-1]
        k = rk.reshape(-1, *rk.shape[-3:])[:, :, :n, :]  # [L*B, H, n, D]
        v = rv.reshape(-1, *rv.shape[-3:])[:, :, :n, :]
        return calibrate_specs(k, v, pack_cfg)

    # -- serving ------------------------------------------------------------
    def prefill(self, batch: dict):
        return self._prefill(self.params, batch=batch)

    def decode(self, cache, token: Array, n_bucket: int | None = None):
        return self._decode(self.params, cache=cache, token=token,
                            n_bucket=n_bucket)

    def decode_chunk(self, cache, token: Array, active, n_steps: int,
                     eos_id: int | None, n_bucket: int | None = None):
        """Donated multi-step decode (see models/*.decode_steps).

        The ``cache`` argument is DONATED: the caller must drop its
        reference and use the returned cache. Returns
        (tokens np [t_max, B], n_exec int, cache).
        """
        toks, n_exec, cache = self._decode_multi(
            self.params,
            cache=cache,
            token=token,
            active=jnp.asarray(active, bool),
            n_steps=jnp.int32(n_steps),
            eos_id=jnp.int32(-1 if eos_id is None else eos_id),
            t_max=self.ecfg.decode_chunk,
            n_bucket=n_bucket,
        )
        return np.asarray(toks), int(n_exec), cache

    def bucket_for(self, n_max: int) -> int | None:
        """Launch bucket covering ``n_max`` compressed tokens (None = full).

        Paged engines bucket the PAGE COUNT: the unit is raised to the page
        size so every bucket is a whole number of pages and the gather /
        page-indexed kernels see page-aligned launches.
        """
        if not self.ecfg.bucketed:
            return None
        from ..core.cache import bucket_length

        unit = self.ecfg.bucket_unit
        if self.ecfg.paged:
            unit = max(unit, self.ecfg.page_size)
        return bucket_length(n_max, self.ecfg.capacity, unit)

    def alloc_slot_cache(self):
        """Slot-table decode cache: max_batch rows, per-row counters."""
        return self.api.alloc_cache(
            self.cfg, self.pack_cfg, self.ecfg.max_batch, self.ecfg.capacity
        )

    def insert_request(self, cache, slot: int, tokens: np.ndarray):
        """Jitted single-slot prefill-insert; returns (last logits [V], cache)."""
        batch = {"tokens": jnp.asarray(np.asarray(tokens)[None], jnp.int32)}
        logits, cache = self._insert(
            self.params, cache=cache, slot=jnp.int32(slot), batch=batch
        )
        return logits[0], cache

    def insert_request_prefix(self, cache, slot: int, tokens: np.ndarray,
                              pages, perms):
        """Jitted chunked prefill-insert (prefix-cache engines only).

        ``pages``: physical ids of the matched page-aligned prompt prefix
        (mapped into the slot by reference — empty for a cold admission);
        ``perms``: the index entry's (k_perm, v_perm) calibration, or None
        (cold / policy 'none'). Returns (last logits [V], cache)."""
        batch = {"tokens": jnp.asarray(np.asarray(tokens)[None], jnp.int32)}
        phys = jnp.asarray(np.asarray(pages, np.int64), jnp.int32)
        kp, vp = perms if perms is not None else (self._dummy_perm,
                                                  self._dummy_perm)
        logits, cache = self._insert_prefix(
            self.params, cache=cache, slot=jnp.int32(slot), batch=batch,
            prefix_phys=phys, k_perm=kp, v_perm=vp,
            n_prefix=len(pages) * self.ecfg.page_size,
        )
        return logits[0], cache

    def _pad_ids(self, ids) -> Array:
        """Sentinel-pad page ids to the fixed per-slot table width so the
        pin/unpin jits compile once (sentinel entries are dropped)."""
        width = self.ecfg.capacity // self.ecfg.page_size
        out = np.full((width,), self.pack_cfg.pool_pages, np.int64)
        out[: len(ids)] = np.asarray(ids, np.int64)
        return jnp.asarray(out, jnp.int32)

    def index_acquire(self, cache, ids):
        """Pin pages for the prefix index (+1 ref each)."""
        return self._acquire_pages(cache, self._pad_ids(ids))

    def index_release(self, cache, ids):
        """Unpin evicted index pages (-1 ref; freed at zero)."""
        return self._release_pages(cache, self._pad_ids(ids))

    def free_slot(self, cache, slot: int):
        return self._reset(cache, jnp.int32(slot))

    def mask_free(self, cache, active):
        """Re-zero counters of inactive rows (see core.cache.mask_free_slots)."""
        return self._mask_free(cache, active)

    def generate(self, batch: dict, max_new: int, eos_id: int | None = None):
        """Greedy wave decode. Returns tokens [B, max_new] (stops early only
        when every row has emitted ``eos_id``)."""
        logits, cache = self.prefill(batch)
        B = logits.shape[0]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        done = jnp.zeros((B,), bool)
        outs = []
        for _ in range(max_new):
            outs.append(np.asarray(tok[:, 0]))
            if eos_id is not None:
                done = done | (tok[:, 0] == eos_id)
                if bool(done.all()):
                    break
            logits, cache = self.decode(cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return np.stack(outs, axis=1), cache


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # [S] prompt at its true length
    max_new: int
    output: np.ndarray | None = None


@dataclasses.dataclass
class SlotStats:
    """Scheduler telemetry (throughput/occupancy counters)."""

    # per-launch log for length-aware accounting: (steps, bucket tokens
    # launched per row, live token count per occupied row) — the substrate
    # for the dead-tile fraction reported by benchmarks/bench_ragged.py.
    # Grows per launch, so it only fills when EngineConfig.log_launches is on.
    launches: list = dataclasses.field(default_factory=list)
    n_slots: int = 0
    decode_steps: int = 0  # decode steps executed (tokens per occupied row)
    chunk_launches: int = 0  # jitted decode dispatches (== steps when chunk=1)
    occupied_slot_steps: int = 0  # sum over steps of occupied slots
    tokens_out: int = 0  # useful tokens delivered to requests
    admitted: int = 0
    completed: int = 0
    slot_reuses: int = 0  # admissions into a previously-used slot
    wall_s: float = 0.0
    # paged admission telemetry (zeros for dense engines):
    admission_blocks: int = 0  # admissions deferred for lack of free pages
    pages_reserved_peak: int = 0  # max simultaneously-reserved pool pages
    # prefix-cache telemetry (zeros when the feature is off):
    prefix_lookups: int = 0  # admissions that consulted the prefix index
    prefix_hits: int = 0  # admissions that matched >= 1 full page
    prefix_pages_shared: int = 0  # pages mapped by reference (cumulative)
    prefix_evictions: int = 0  # index entries dropped (pressure or cap)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_lookups if self.prefix_lookups \
            else 0.0

    @property
    def occupancy(self) -> float:
        """Fraction of slot-steps that decoded a live request."""
        total = self.decode_steps * max(self.n_slots, 1)
        return self.occupied_slot_steps / total if total else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class _PrefixNode:
    """One full compressed page of a cached prompt prefix (trie node)."""

    __slots__ = ("chunk", "page", "parent", "children", "last_used", "perms")

    def __init__(self, chunk: bytes, page: int, parent):
        self.chunk = chunk  # raw token ids of this page's span (the key)
        self.page = page  # physical pool page id (one index reference held)
        self.parent = parent  # None for depth-0 nodes
        self.children: dict[bytes, "_PrefixNode"] = {}
        self.last_used = 0
        self.perms = None  # depth-0 only: (k_perm, v_perm) device arrays


class PrefixIndex:
    """Host-side content-addressed prefix index over FULL compressed pages.

    A trie keyed by page-aligned chunks of raw prompt token ids; each node
    owns exactly one physical pool page and holds ONE device reference on
    it (``core.cache.acquire_pages``), so cached pages survive their
    originating slot's retirement and are never handed out by the
    allocator. Lookup walks the longest matching chain; eviction removes
    LRU LEAVES only (an interior page is still reachable through its
    children), skipping pages currently mapped into a live slot by
    reference — evicting those would break the scheduler's reservation
    bound (a shared page is reserved by NO slot). Depth-0 nodes carry the
    donor's page-0 channel calibration so a hit compresses its suffix under
    the identical permutation. Pure host state: every device mutation is
    the ``SlotServer``'s, through ``Engine.index_acquire/index_release``.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.roots: dict[bytes, _PrefixNode] = {}
        self.n_held = 0  # pages the index holds a reference on
        self.pages: set[int] = set()  # their ids (each in exactly one node)
        self._clock = 0

    def chunks(self, tokens) -> list[bytes]:
        """Page-aligned raw-token-id chunks (the trie keys)."""
        t = np.ascontiguousarray(np.asarray(tokens, np.int64))
        p = self.page_size
        return [t[i * p:(i + 1) * p].tobytes() for i in range(len(t) // p)]

    def touch(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    def descend(self, parent, chunk: bytes):
        m = self.roots if parent is None else parent.children
        return m.get(chunk)

    def lookup(self, tokens, max_pages: int):
        """Longest-prefix match, LRU-bumping the path.

        Returns (page ids, (k_perm, v_perm) | None)."""
        pages: list[int] = []
        perms = None
        node = None
        for chunk in self.chunks(tokens)[:max_pages]:
            node = self.descend(node, chunk)
            if node is None:
                break
            self.touch(node)
            if node.perms is not None:
                perms = node.perms
            pages.append(node.page)
        return pages, perms

    def insert(self, parent, chunk: bytes, page: int, perms=None):
        node = _PrefixNode(chunk, page, parent)
        self.touch(node)
        node.perms = perms if parent is None else None
        (self.roots if parent is None else parent.children)[chunk] = node
        self.n_held += 1
        self.pages.add(page)
        return node

    def evict_lru(self, protected: set[int]):
        """Drop the least-recently-used unprotected LEAF; returns its page
        id (the caller must release the device reference) or None."""
        best = None
        stack = list(self.roots.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.children or n.page in protected:
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        if best is None:
            return None
        owner = self.roots if best.parent is None else best.parent.children
        del owner[best.chunk]
        self.n_held -= 1
        self.pages.discard(best.page)
        return best.page


class _Active:
    """One occupied slot: the request plus its generation state."""

    __slots__ = ("req", "out", "done")

    def __init__(self, req: Request, first_tok: int, eos_id: int | None):
        self.req = req
        self.out = [first_tok]
        self.done = (eos_id is not None and first_tok == eos_id) or \
            req.max_new <= 1

    @property
    def remaining(self) -> int:
        return self.req.max_new - len(self.out)

    @property
    def cached_tokens(self) -> int:
        """Host-side mirror of this row's cache occupancy (n_comp + n_resid).

        The prompt is inserted at prefill; each decode step appends the
        PREVIOUS token, so the first generated token is not yet cached."""
        return len(self.req.tokens) + len(self.out) - 1


class SlotServer:
    """Continuous-batching scheduler over a fixed slot table.

    Each step: (1) ADMIT — pop queued requests into free slots via the
    jitted single-slot prefill-insert; (2) DECODE — one batched greedy
    decode step over the whole table (free rows ride along masked by their
    zero counters); (3) RETIRE — rows that hit EOS or ``max_new`` record
    their output, their slot counters are reset, and the slot is reusable
    on the very next step. Per-request greedy outputs are bit-identical to
    a batch-size-1 ``Engine.generate`` run (per-row cache state + per-row
    RoPE positions + row-independent attention).

    PAGED engines admit on FREE PAGES, not free slots: each admitted
    request reserves its worst-case page count (``ceil(min(capacity,
    prompt + max_new) / page_size)``) and admission blocks — FIFO order
    preserved — while reservations plus the watermark would overflow the
    pool. Reservations are the host-side guarantee that the in-graph
    free-list never over-pops, which is what makes oversubscription
    (``pool_pages < max_batch * capacity / page_size``) safe under mixed
    traffic.

    PREFIX-CACHE engines additionally keep a host-side ``PrefixIndex``:
    admission looks up the longest page-aligned prompt prefix already
    compressed in the pool, maps those pages into the new slot BY
    REFERENCE (refcounted — they reserve ZERO new pages), runs the chunked
    prefill only over the uncovered suffix, and registers the admitted
    prompt's full pages back into the index. Under pool pressure the
    scheduler EVICTS cold cached prefixes (LRU leaves not mapped into any
    live slot) instead of blocking admission. Cache-hit admissions are
    bit-identical to cold ones: see ``models.transformer.
    prefill_into_slot_prefix`` for why page boundaries are exact resume
    points.
    """

    def __init__(self, engine: Engine, eos_id: int | None = None):
        if not engine.api.supports_slots:
            raise ValueError(
                f"family {engine.cfg.family!r} has no slot ops "
                "(recurrent decode state); use WaveServer's legacy path"
            )
        if engine.cfg.input_mode != "tokens":
            raise ValueError(
                f"input_mode {engine.cfg.input_mode!r} not servable per-slot "
                "(Request carries tokens only); use WaveServer"
            )
        self.engine = engine
        self.eos_id = eos_id
        self.n_slots = engine.ecfg.max_batch
        self.cache = None  # allocated on first admission
        self.slots: list[_Active | None] = [None] * self.n_slots
        self._ever_used = [False] * self.n_slots
        self._last_tok = np.zeros((self.n_slots,), np.int32)
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}
        self.stats = SlotStats(n_slots=self.n_slots)
        self._reserved: dict[int, int] = {}  # slot -> NEWLY-allocatable pages
        self._index = (PrefixIndex(engine.ecfg.page_size)
                       if engine.ecfg.prefix_cache else None)
        self._slot_shared: dict[int, tuple[int, ...]] = {}  # slot -> mapped

    # -- paged admission accounting ----------------------------------------
    @property
    def _pages_avail(self) -> int:
        """Pool pages not spoken for: total minus the watermark, minus every
        slot's reservation of pages it may NEWLY allocate, minus pages the
        prefix index pins. Donor pages counted by both a reservation and
        the index are double-counted — conservative, never unsafe — and
        index pages are reclaimable on demand (``_evict_to_fit``)."""
        ecfg = self.engine.ecfg
        total = self.engine.pack_cfg.pool_pages
        held = self._index.n_held if self._index is not None else 0
        return total - ecfg.page_watermark - sum(self._reserved.values()) - held

    def _pages_needed(self, req: Request) -> int:
        """Worst-case resident pages over the request's lifetime: its
        compressed tokens never exceed min(capacity, prompt + max_new)."""
        from ..utils import cdiv

        ecfg = self.engine.ecfg
        hi = min(ecfg.capacity, len(req.tokens) + req.max_new)
        return cdiv(hi, ecfg.page_size)

    def _match(self, req: Request) -> tuple[list[int], object]:
        """Longest page-aligned prefix the index can serve for ``req``.

        Capped one token short of the prompt so the suffix is never empty
        (admission needs last-token logits to seed decode)."""
        max_m = (len(req.tokens) - 1) // self.engine.ecfg.page_size
        return self._index.lookup(req.tokens, max_m)

    def _live_shared(self) -> set[int]:
        return {p for t in self._slot_shared.values() for p in t}

    def _evict_to_fit(self, need_new: int, protected: set[int]) -> bool:
        """Reclaim index-pinned pages (LRU leaves first) until ``need_new``
        fits, instead of blocking admission. Never evicts pages mapped into
        a live slot by reference (they are covered by NO reservation) or
        the pages just matched for the pending admission."""
        if self._index is None:
            return need_new <= self._pages_avail
        protected = protected | self._live_shared()
        while need_new > self._pages_avail:
            page = self._index.evict_lru(protected)
            if page is None:
                return False
            self.cache = self.engine.index_release(self.cache, [page])
            self.stats.prefix_evictions += 1
        return True

    def _register(self, req: Request, slot: int) -> None:
        """Index every full compressed page of the freshly-admitted prompt.

        Matched pages already have nodes (bumped); new pages get nodes and
        one device reference each. Registration respects
        ``prefix_cache_pages`` by evicting LRU leaves first and simply
        stops when nothing is evictable (a shorter registered chain is
        still a correct trie)."""
        pack = self.engine.pack_cfg
        page = self.engine.ecfg.page_size
        k = (len(req.tokens) // pack.block) * pack.block // page
        if not k:
            return
        cap = self.engine.ecfg.prefix_cache_pages
        row = np.asarray(self.cache.pages.page_table[0, slot, :k])
        perms = None
        if pack.policy != "none":
            perms = (self.cache.k.chan_perm[:, slot],
                     self.cache.v.chan_perm[:, slot])
        protected = self._live_shared() | {int(p) for p in row}
        acquired: list[int] = []
        parent = None
        for d, chunk in enumerate(self._index.chunks(req.tokens)[:k]):
            node = self._index.descend(parent, chunk)
            if node is None:
                if cap is not None and self._index.n_held >= cap:
                    ev = self._index.evict_lru(protected)
                    if ev is None:
                        break
                    self.cache = self.engine.index_release(self.cache, [ev])
                    self.stats.prefix_evictions += 1
                node = self._index.insert(parent, chunk, int(row[d]),
                                          perms if d == 0 else None)
                acquired.append(int(row[d]))
            else:
                self._index.touch(node)
            parent = node
        if acquired:
            self.cache = self.engine.index_acquire(self.cache, acquired)

    def _check_invariants(self) -> None:
        """Debug mode: refcount conservation after every admit/retire.

        ``free ⇔ ref == 0`` in both directions — the number of held pages
        plus the stack height equals the pool size, and every stack entry
        has a zero count. Device sync per call; gate on
        ``EngineConfig.debug_invariants``."""
        if not (self.engine.ecfg.debug_invariants and self.engine.ecfg.paged
                and self.cache is not None):
            return
        pool = self.cache.pages
        ref = np.asarray(pool.ref[0])
        nf = int(pool.n_free[0])
        free = np.asarray(pool.free[0])
        P = ref.shape[0]
        assert int((ref > 0).sum()) + nf == P, (ref, nf)
        assert int((ref == 0).sum()) == nf, (ref, nf)
        assert (ref[free[:nf]] == 0).all(), (ref, free[:nf])
        if self._index is not None:
            assert all(int(ref[p]) >= 1 for p in self._index.pages)

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        if self.engine.ecfg.paged:
            ecfg = self.engine.ecfg
            pack = self.engine.pack_cfg
            # prefill block-flushes the WHOLE prompt, so its block-aligned
            # length must fit the compressed capacity outright (a longer
            # one would pop more pages than a table row holds)
            lb = (len(req.tokens) // pack.block) * pack.block
            if lb > ecfg.capacity:
                raise ValueError(
                    f"request {req.rid}: block-aligned prompt length {lb} "
                    f"exceeds compressed capacity {ecfg.capacity}"
                )
            hi = len(req.tokens) + req.max_new
            if hi > ecfg.capacity + pack.residual:
                # over-contract rows stop flushing at capacity (their page
                # reservation stays a true bound) and would degrade their
                # own residual — enforce the documented upstream rejection
                raise ValueError(
                    f"request {req.rid}: prompt + max_new = {hi} exceeds "
                    f"capacity + residual = {ecfg.capacity + pack.residual}"
                )
            total = self.engine.pack_cfg.pool_pages
            need = self._pages_needed(req)
            if need > total - ecfg.page_watermark:
                raise ValueError(
                    f"request {req.rid} needs {need} pages but the pool "
                    f"admits at most {total - ecfg.page_watermark}"
                )
        self.queue.append(req)

    @property
    def n_occupied(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- scheduler ----------------------------------------------------------
    def _retire(self, i: int) -> Request:
        act = self.slots[i]
        act.req.output = np.asarray(act.out, np.int32)
        self.done[act.req.rid] = act.req
        self.slots[i] = None
        self.cache = self.engine.free_slot(self.cache, i)
        self._reserved.pop(i, None)  # paged: pages return with the reset
        self._slot_shared.pop(i, None)  # shared pages: ref back to the index
        self.stats.completed += 1
        self._check_invariants()
        return act.req

    def _admit(self) -> list[Request]:
        finished: list[Request] = []
        paged = self.engine.ecfg.paged
        for i in range(self.n_slots):
            if not self.queue:
                break
            if self.slots[i] is not None:
                continue
            head = self.queue[0]
            match_pages: list[int] = []
            match_perms = None
            if self._index is not None and self.cache is not None:
                match_pages, match_perms = self._match(head)
            if paged:
                # suffix-only reservation: shared prefix pages reserve 0 —
                # the slot can only ever NEWLY pop pages past the match
                need_new = self._pages_needed(head) - len(match_pages)
                if need_new > self._pages_avail and \
                        not self._evict_to_fit(need_new, set(match_pages)):
                    # page-count admission: keep FIFO order, wait for retire
                    self.stats.admission_blocks += 1
                    break
            req = self.queue.popleft()
            if self.cache is None:
                self.cache = self.engine.alloc_slot_cache()
            if paged:
                self._reserved[i] = self._pages_needed(req) - len(match_pages)
                self.stats.pages_reserved_peak = max(
                    self.stats.pages_reserved_peak, sum(self._reserved.values())
                )
            if self._index is not None:
                self.stats.prefix_lookups += 1
                if match_pages:
                    self.stats.prefix_hits += 1
                    self.stats.prefix_pages_shared += len(match_pages)
                logits, self.cache = self.engine.insert_request_prefix(
                    self.cache, i, req.tokens, match_pages, match_perms
                )
                self._slot_shared[i] = tuple(int(p) for p in match_pages)
                self._register(req, i)
            else:
                logits, self.cache = self.engine.insert_request(
                    self.cache, i, req.tokens
                )
            tok = int(jnp.argmax(logits))
            self.slots[i] = _Active(req, tok, self.eos_id)
            self._last_tok[i] = tok
            self.stats.admitted += 1
            self.stats.tokens_out += 1
            if self._ever_used[i]:
                self.stats.slot_reuses += 1
            self._ever_used[i] = True
            self._check_invariants()
            if self.slots[i].done:  # max_new == 1 or instant EOS
                finished.append(self._retire(i))
        return finished

    def _chunk_plan(self) -> tuple[int, int | None]:
        """(n_steps, n_bucket) for the next decode launch.

        n_steps = min(decode_chunk, min over occupied rows of remaining
        budget) — no row can overshoot its ``max_new`` inside a chunk, so
        retirement stays exact. n_bucket upper-bounds every row's n_comp
        through the WHOLE chunk via the host-side token counts (n_comp <=
        cached tokens <= cached_tokens_now + n_steps)."""
        occupied = [a for a in self.slots if a is not None]
        n_steps = max(1, min(self.engine.ecfg.decode_chunk,
                             min(a.remaining for a in occupied)))
        n_max = max(a.cached_tokens for a in occupied) + n_steps
        return n_steps, self.engine.bucket_for(n_max)

    def _log_launch(self, n_steps: int, n_bucket: int | None):
        if not self.engine.ecfg.log_launches:
            return
        self.stats.launches.append((
            n_steps,
            self.engine.ecfg.capacity if n_bucket is None else n_bucket,
            [a.cached_tokens for a in self.slots if a is not None],
        ))

    def step(self) -> list[Request]:
        """Admit + one decode launch + retire. Returns requests finished now.

        One launch is a donated multi-step chunk (``decode_chunk`` > 1) or a
        single decode step; both mask attention to each row's own length and
        give per-request outputs bit-identical to B=1 ``Engine.generate``.
        """
        t0 = time.perf_counter()
        finished = self._admit()
        if self.n_occupied:
            n_steps, n_bucket = self._chunk_plan()
            if self.engine.ecfg.decode_chunk > 1 and \
                    self.engine._decode_multi is not None:
                self._decode_chunk(n_steps, n_bucket, finished)
            else:
                self._decode_single(n_bucket, finished)
        self.stats.wall_s += time.perf_counter() - t0
        return finished

    def _decode_single(self, n_bucket: int | None, finished: list[Request]):
        """PR-2 style per-token launch (decode_chunk=1), optionally bucketed."""
        tok = jnp.asarray(self._last_tok[:, None])
        logits, self.cache = self.engine.decode(self.cache, tok, n_bucket)
        nxt = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        self.stats.decode_steps += 1
        self.stats.chunk_launches += 1
        self._log_launch(1, n_bucket)
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            self.stats.occupied_slot_steps += 1
            t = int(nxt[i])
            act.out.append(t)
            self._last_tok[i] = t
            self.stats.tokens_out += 1
            if (self.eos_id is not None and t == self.eos_id) or \
                    len(act.out) >= act.req.max_new:
                finished.append(self._retire(i))
        if self.n_occupied < self.n_slots:
            # free rows received a junk append this step; re-zero their
            # counters so free slots stay inert (never flush, never grow)
            active = jnp.asarray([s is not None for s in self.slots], bool)
            self.cache = self.engine.mask_free(self.cache, active)

    def _decode_chunk(self, n_steps: int, n_bucket: int | None,
                      finished: list[Request]):
        """Donated multi-step launch: up to ``n_steps`` tokens per row.

        Rows that emit EOS mid-chunk keep decoding (their later tokens are
        junk, discarded here — rows are independent, so other rows are
        unaffected); the in-graph loop early-exits once ALL rows hit EOS.
        """
        active = [a is not None for a in self.slots]
        toks, n_exec, self.cache = self.engine.decode_chunk(
            self.cache, jnp.asarray(self._last_tok[:, None]), active,
            n_steps, self.eos_id, n_bucket,
        )
        self.stats.chunk_launches += 1
        self.stats.decode_steps += n_exec
        self.stats.occupied_slot_steps += n_exec * self.n_occupied
        self._log_launch(n_exec, n_bucket)
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            for s in range(n_exec):
                t = int(toks[s, i])
                act.out.append(t)
                self._last_tok[i] = t
                self.stats.tokens_out += 1
                if (self.eos_id is not None and t == self.eos_id) or \
                        len(act.out) >= act.req.max_new:
                    act.done = True
                    break  # tokens past EOS are junk
            if act.done:
                finished.append(self._retire(i))
        # no trailing mask_free here: decode_steps re-zeroes free-row
        # counters in-graph every iteration, and _retire resets the rows
        # freed just now, so the cache already satisfies the invariant

    def run(self) -> list[Request]:
        """Drain the queue and all slots; returns every finished request."""
        finished: list[Request] = []
        while self.queue or self.n_occupied:
            finished.extend(self.step())
        return finished


class WaveServer:
    """Compatibility wrapper: groups queued requests into fixed-size waves
    and serves each wave through the continuous ``SlotServer`` (each
    request prefilled at its true length — the old left-pad path and its
    pad-pollution are gone). Families without slot ops (recurrent decode
    state) fall back to the legacy lock-step wave."""

    def __init__(self, engine: Engine, pad_id: int = 0,
                 eos_id: int | None = None):
        self.engine = engine
        self.pad_id = pad_id
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._slots = (
            SlotServer(engine, eos_id=eos_id)
            if engine.api.supports_slots and engine.cfg.input_mode == "tokens"
            else None
        )

    def submit(self, req: Request) -> None:
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        self.queue.append(req)

    def run_wave(self) -> list[Request]:
        if not self.queue:
            return []
        B = self.engine.ecfg.max_batch
        wave, self.queue = self.queue[:B], self.queue[B:]
        if self._slots is not None:
            for r in wave:
                self._slots.submit(r)
            self._slots.run()
            for r in wave:
                self.done[r.rid] = r
            return wave
        return self._legacy_wave(wave)

    def _legacy_wave(self, wave: list[Request]) -> list[Request]:
        """Lock-step wave for recurrent families: batched prefill (left-pad
        to the wave's max prompt length) + shared decode loop. Known
        limitation: left-pad tokens enter the recurrent state."""
        S = max(len(r.tokens) for r in wave)
        S = -(-S // 64) * 64  # block-align prompts
        toks = np.full((len(wave), S), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.tokens):] = r.tokens  # left-pad
        max_new = max(r.max_new for r in wave)
        out, _ = self.engine.generate({"tokens": jnp.asarray(toks)}, max_new)
        for i, r in enumerate(wave):
            r.output = out[i, : r.max_new]
            self.done[r.rid] = r
        return wave
