"""Serving engine: calibration, jitted prefill/decode, continuous batching.

Build sequence (mirrors a production bring-up):
  1. CALIBRATE — run a short prefill with the uncompressed policy, collect
     raw K/V, pick static TierSpecs (core.cache.calibrate_specs). This is
     the paper's per-model configuration sweep (§IV-B) done once at engine
     build, before compilation.
  2. COMPILE — jit prefill + decode with the calibrated PackKVConfig.
  3. SERVE — ``SlotServer`` runs a continuous-batching scheduler over a
     fixed slot table of ``max_batch`` rows. Every sequence owns one row of
     the decode state — per-row ``n_comp``/``n_resid`` counters for the KV
     families, a batch row of the recurrent leaves for rwkv6/hybrid_rglru:
     a queued request is admitted into a free slot at its TRUE prompt
     length (no left-padding, so pad tokens never pollute cache or
     recurrent state), all occupied slots decode together each step, and a
     row is recycled the moment its request finishes (EOS / max_new) while
     the other rows keep decoding.

EVERY family serves through this one engine (the old ``WaveServer``
left-pad wave is gone). Admission is CHUNK-INTERLEAVED by default: instead
of a monolithic prefill dispatch that stalls every occupied slot for the
whole prompt, the scheduler advances the pending admission by at most
``EngineConfig.prefill_chunk_pages`` pages' worth of tokens per step and
runs a decode launch in the same cadence — no occupied slot ever waits
longer than one bounded chunk for its next token. See docs/serving.md for
the slot table layout, admission policy and per-row counter plumbing, and
docs/architecture.md for the paged pool.

Invariants the scheduler maintains (and the cache layer relies on):
  * the host-side token counts (``_Active.cached_tokens``) upper-bound the
    device counters — buckets and page reservations are computed without a
    device sync and are always safe over-estimates;
  * in paged mode, reserved pages (sum over active slots of worst-case
    ``ceil(min(capacity, prompt + max_new) / page_size)``) never exceed
    ``pool_pages - page_watermark`` — the in-graph free-list can never
    over-pop, so oversubscribed pools serve mixed traffic exactly;
  * a retired slot's pages are back in the pool (``reset_slot``) before
    the next admission runs, so FIFO admission makes progress whenever any
    slot retires.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.cache import (
    PackKVConfig,
    SessionStore,
    SwapStore,
    calibrate_specs,
)
from ..distributed.fault import FaultPlan, StragglerMonitor
from ..models import get_model

Array = jax.Array


@dataclasses.dataclass
class EngineConfig:
    capacity: int = 4096  # compressed-region token capacity
    max_batch: int = 8  # slot-table size
    backend: str = "xla"  # xla | pallas
    calibrate: bool = True
    calib_tokens: int = 192  # multiple of the 64-token block
    # multi-device serving (see kernels/sharded.py + docs/architecture.md):
    mesh_shape: tuple = (1, 1)  # (dp, kv): data-parallel row shards x
    #   KV-head shards. (1, 1) = feature off (plain single-device jits,
    #   bit-for-bit the pre-mesh engine). Anything else builds a
    #   jax Mesh over dp*kv devices and routes every cache-touching
    #   dispatch through a shard_map lane: pool payloads shard by KV head
    #   over 'kv', the page ledger + counters stay replicated, attention
    #   work partitions over 'dp' by row masking. Outputs stay
    #   bit-identical to (1, 1); recurrent families reject loudly.
    # length-aware launches (see docs/performance.md):
    bucketed: bool = True  # slice the compressed region to a live-length bucket
    bucket_unit: int = 256  # smallest bucket; power-of-two multiples up to capacity
    decode_chunk: int = 8  # decode steps per donated multi-step launch (1 = per-token)
    log_launches: bool = False  # keep per-launch telemetry (unbounded; bench only)
    # self-speculative decode (see docs/performance.md):
    spec_decode: bool = False  # n-gram drafting + batched k-token verify
    spec_k: int = 4  # max drafted tokens per verify launch (window = k + 1)
    spec_backoff: int = 32  # max per-slot draft cooldown (scheduler steps)
    #   after fully-rejected launches: doubles 1, 2, .. spec_backoff while a
    #   slot's drafts keep dying, so acceptance~0 traffic degrades to the
    #   plain chunked-decode path instead of paying verify windows for one
    #   token each. Any accepted draft resets the slot to eager drafting.
    #   0 disables the backoff (every launch drafts when the table matches).
    # chunked prefill/decode interleaving (see docs/serving.md):
    prefill_chunk_pages: int = 1  # admission chunk budget, in pages of
    #   ``page_size`` tokens per scheduler step (dense engines use the same
    #   token unit). 0 = legacy monolithic prefill-insert: the whole prompt
    #   in one dispatch, stalling every occupied slot for its duration.
    # paged compressed region (see docs/architecture.md):
    paged: bool = False  # page-pool storage + page-reservation admission
    page_size: int = 256  # tokens per physical page (power of two, >= block)
    pool_pages: int | None = None  # physical pages; None = max_batch * capacity
    #   / page_size (no oversubscription). Setting it lower oversubscribes:
    #   admission then blocks on page reservations instead of free slots.
    page_watermark: int = 0  # spare pages admission always holds back
    # shared-prefix page cache (requires paged; see docs/serving.md):
    prefix_cache: bool = False  # content-addressed prefix reuse across requests
    prefix_cache_pages: int | None = None  # max pages the index may pin
    #   (None = unbounded; pool-pressure eviction still applies either way)
    # preemptive serving (see docs/serving.md):
    preempt: bool = False  # compressed-page swap-out of lower-class victims
    #   when a higher-class admission cannot reserve pages (or find a slot);
    #   the victim resumes later bit-identically from a host-RAM SwapStore
    aging_steps: int = 32  # scheduler steps per priority-class promotion of
    #   a queued request (the no-starvation bound: a class-p head competes
    #   as class 0 after p * aging_steps steps). 0 disables aging — strict
    #   priority, a permanent high-class flood then starves lower classes.
    # voluntary multi-turn session cache (ISSUE 9; see docs/serving.md):
    session_cache: bool = False  # park a retiring slot's compressed pages
    #   host-side, keyed by the session's raw token trace; a returning
    #   turn streams them back (no forward pass over restored tokens) and
    #   ingests only its new suffix through teacher-forced decode launches
    session_cache_mb: int = 256  # host-RAM tier budget (LRU by bytes)
    session_ttl_s: float | None = None  # idle expiry for parked sessions
    #   (None = parked entries never age out)
    session_disk_dir: str | None = None  # LRU spill tier: demote host-tier
    #   victims to disk via the checkpoint.sharded mini-cache serializers
    #   instead of dropping them (None = evict outright)
    debug_invariants: bool = False  # assert refcount conservation after every
    #   admit/retire (device sync per check — tests/bring-up only)


class Engine:
    def __init__(self, cfg: ArchConfig, params, pack_cfg: PackKVConfig,
                 ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.api = get_model(cfg)
        mesh_shape = tuple(ecfg.mesh_shape)
        if mesh_shape == (1, 1):
            self.mesh = None  # feature off: plain single-device jits
        else:
            n_dp, n_kv = mesh_shape
            if n_dp < 1 or n_kv < 1:
                raise ValueError(f"mesh_shape must be positive, got "
                                 f"{mesh_shape}")
            if cfg.family in ("rwkv6", "hybrid_rglru"):
                raise ValueError(
                    f"family {cfg.family!r} cannot serve --mesh: its "
                    "recurrent slot state has no KV-head axis to shard "
                    "over the 'kv' mesh axis — drop --mesh (single-device "
                    "serving still applies)")
            if cfg.n_kv_heads % n_kv:
                raise ValueError(
                    f"n_kv_heads {cfg.n_kv_heads} not divisible by "
                    f"kv_shards {n_kv} — pool payloads shard by whole KV "
                    "heads")
            n_dev = len(jax.devices())
            if n_dev < n_dp * n_kv:
                raise ValueError(
                    f"mesh {n_dp}x{n_kv} needs {n_dp * n_kv} devices, have "
                    f"{n_dev} (host-platform testing: set "
                    "XLA_FORCE_HOST_PLATFORM_DEVICE_COUNT / "
                    "--xla_force_host_platform_device_count before jax "
                    "initializes)")
            from jax.sharding import Mesh, NamedSharding, PartitionSpec

            devs = np.array(jax.devices()[: n_dp * n_kv]).reshape(n_dp, n_kv)
            self.mesh = Mesh(devs, ("dp", "kv"))
            # params are replicated once at build; every lane reads them
            # with a replicated in_spec, so no dispatch re-broadcasts
            self.params = jax.device_put(
                self.params, NamedSharding(self.mesh, PartitionSpec()))
        if ecfg.prefix_cache:
            if self.api.prefill_prefix is None:
                raise ValueError(
                    f"family {cfg.family!r} cannot serve --prefix-cache: its "
                    "recurrent decode state has no page-addressable KV pages "
                    "to share — drop --prefix-cache (plain chunked admission "
                    "still applies)"
                )
            if not ecfg.paged:
                raise ValueError(
                    "--prefix-cache requires --paged: shared prefixes live "
                    "in the refcounted page pool"
                )
            if cfg.window:
                raise ValueError(
                    "--prefix-cache does not support sliding-window "
                    f"attention (window={cfg.window}): evicted window "
                    "tokens break page-aligned prefix identity"
                )
        if ecfg.paged:
            if not self.api.supports_paged:
                raise ValueError(
                    f"family {cfg.family!r} cannot serve paged: its "
                    "recurrent decode state is not page-addressable"
                )
            if ecfg.capacity % ecfg.page_size:
                raise ValueError(
                    f"capacity {ecfg.capacity} not a multiple of page_size "
                    f"{ecfg.page_size}"
                )
            pool_pages = (
                ecfg.pool_pages
                if ecfg.pool_pages is not None
                else ecfg.max_batch * ecfg.capacity // ecfg.page_size
            )
            pack_cfg = dataclasses.replace(
                pack_cfg, paged=True, page_size=ecfg.page_size,
                pool_pages=pool_pages,
            )
        self.pack_cfg = (
            self._calibrate(pack_cfg) if (
                ecfg.calibrate
                and pack_cfg.policy == "packkv"
                and cfg.family not in ("rwkv6",)
            ) else pack_cfg
        )
        self._prefill = self._lane_jit(
            partial(self.api.prefill, cfg=cfg, pack_cfg=self.pack_cfg,
                    capacity=ecfg.capacity)
        )
        # one compile per launch bucket (bounded: core.cache.bucket_set)
        self._decode = self._lane_jit(
            partial(self.api.decode_step, cfg=cfg, backend=ecfg.backend),
            static=("n_bucket",),
        )
        # one compile per distinct prompt length; slot index is traced
        self._insert = self._lane_jit(
            partial(self.api.prefill_into_slot, cfg=cfg,
                    pack_cfg=self.pack_cfg, capacity=ecfg.capacity)
        )
        self._reset = self._lane_jit(self.api.reset_slot)
        self._mask_free = self._lane_jit(self.api.mask_free)
        # chunked interleaved admission: one bounded prefill chunk per
        # scheduler step (one compile per distinct (chunk length, offset)).
        # The chunk scratch is raw full-head K/V and carries no cache, so
        # it stays a plain replicated jit even on a mesh.
        self._chunk_step = jax.jit(
            partial(self.api.prefill_chunk, cfg=cfg, pack_cfg=self.pack_cfg),
            static_argnames=("n_ctx",),
        )
        self._chunk_insert = self._lane_jit(
            partial(self.api.prefill_chunk_insert, cfg=cfg,
                    pack_cfg=self.pack_cfg, capacity=ecfg.capacity)
        )

        def _chunk_final_fn(params, cache, slot, scratch, tokens, n_ctx):
            logits, scratch = self.api.prefill_chunk(
                params, scratch=scratch, tokens=tokens, n_ctx=n_ctx,
                cfg=cfg, pack_cfg=self.pack_cfg
            )
            cache = self.api.prefill_chunk_insert(
                cache=cache, slot=slot, scratch=scratch,
                cfg=cfg, pack_cfg=self.pack_cfg, capacity=ecfg.capacity
            )
            return logits, cache

        # final chunk fused with the row insert: one dispatch instead of
        # chunk_step + chunk_insert, and no scratch round-trip, on the last
        # step of every multi-chunk admission
        self._chunk_final = self._lane_jit(_chunk_final_fn,
                                           static=("n_ctx",))
        if ecfg.prefix_cache:
            from ..core.cache import acquire_pages, release_pages

            # one compile per (prompt length, matched-prefix length) pair
            self._insert_prefix = self._lane_jit(
                partial(self.api.prefill_prefix, cfg=cfg,
                        pack_cfg=self.pack_cfg, capacity=ecfg.capacity),
                static=("n_prefix",),
            )
            # interleaved prefix admission: the same per-page segments,
            # one dispatch each (mini-cache round-trips between them)
            self._prefix_chunk_init = self._lane_jit(
                partial(self.api.prefix_chunk_init, cfg=cfg,
                        pack_cfg=self.pack_cfg, capacity=ecfg.capacity),
                static=("n_prefix", "prompt_len"),
            )
            self._prefix_chunk = self._lane_jit(
                partial(self.api.prefix_chunk, cfg=cfg,
                        pack_cfg=self.pack_cfg),
                static=("n_ctx",),
            )
            self._prefix_chunk_insert = self._lane_jit(
                partial(self.api.prefix_chunk_insert, pack_cfg=self.pack_cfg),
                static=("n_prefix", "prompt_len"),
            )
            # index pin/unpin ops take sentinel-padded fixed-length id
            # vectors, so each compiles exactly once
            self._acquire_pages = self._lane_jit(acquire_pages)
            self._release_pages = self._lane_jit(release_pages)
            self._dummy_perm = jnp.broadcast_to(
                jnp.arange(cfg.hd, dtype=jnp.int32),
                (cfg.n_layers, cfg.n_kv_heads, cfg.hd),
            )
        if ecfg.spec_decode:
            if self.api.decode_verify is None:
                raise ValueError(
                    f"family {cfg.family!r} cannot serve --spec-decode: its "
                    "recurrent state update is sequential per token, so "
                    "there is no batched q_len=k verify pass to amortize "
                    "the weights-read over — drop --spec-decode"
                )
            if ecfg.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {ecfg.spec_k}")
            # one batched forward over the q_len = spec_k + 1 draft window;
            # fixed window width -> one compile per launch bucket, ragged
            # per-row draft lengths ride through the ``lens`` mask. The
            # acceptance rule, the counter-only commit of the accepted
            # prefix, and free-row masking all run inside the same program
            # (models/*.verify_steps), so one dispatch per spec step.
            self._verify = self._lane_jit(
                partial(self.api.decode_verify, cfg=cfg, backend=ecfg.backend),
                static=("n_bucket",),
                donate=("cache",),
            )
        if ecfg.session_cache and cfg.window:
            raise ValueError(
                "--session-cache does not support sliding-window attention "
                f"(window={cfg.window}): evicted window tokens break the "
                "parked-trace identity the session key relies on"
            )
        if ecfg.preempt or ecfg.session_cache:
            if self.api.evacuate_slot is None:
                feature = "--preempt" if ecfg.preempt else "--session-cache"
                raise ValueError(
                    f"family {cfg.family!r} cannot serve {feature}: its "
                    "recurrent slot state has no evacuate/restore ops to "
                    f"swap through — drop {feature}"
                )
            # one compile per (live pages, shared-prefix pages) pair — the
            # same specialization granularity as prompt-length admission
            self._evacuate = self._lane_jit(
                self.api.evacuate_slot,
                static=("n_pages", "n_shared"),
            )
            self._restore = self._lane_jit(
                self.api.restore_slot,
                static=("n_pages", "n_shared"),
            )
        if self.api.decode_multi is not None:
            # donated multi-step decode: the chunk loop updates the cache
            # buffers in place (no per-token copy) and one dispatch covers
            # up to ``decode_chunk`` tokens
            self._decode_multi = self._lane_jit(
                partial(self.api.decode_multi, cfg=cfg, backend=ecfg.backend),
                static=("t_max", "n_bucket"),
                donate=("cache",),
            )
        else:
            self._decode_multi = None

    # -- mesh lanes ---------------------------------------------------------
    def _lane_jit(self, fn, *, static=(), donate=()):
        """jit one serving dispatch; on a mesh, the body runs inside a
        shard_map lane (kernels/sharded.py) with cache-spec-derived in/out
        specs. Off-mesh (``mesh_shape == (1, 1)``) this is exactly
        ``jax.jit(fn)`` — the pre-mesh engine, byte for byte.

        Mechanics on a mesh: the wrapper binds the caller's args against
        ``fn``'s signature, closes over the static (python) args, derives
        per-arg PartitionSpecs by name (``LayerKVCache`` args/outputs get
        ``serving_cache_specs`` — payloads by KV head, ledger replicated;
        calibration perms shard their head dim; everything else is
        replicated), gets output specs from ``jax.eval_shape`` over the
        unsharded body (global shapes ARE the out-spec shapes), and
        dispatches through ``sharded_call``, which installs the Lane the
        model code queries via ``active_lane()``.
        """
        if self.mesh is None:
            return jax.jit(fn, static_argnames=static, donate_argnames=donate)
        import inspect

        from ..distributed.sharding import serving_specs
        from ..kernels.sharded import sharded_call

        mesh = self.mesh
        sig = inspect.signature(fn)

        def mesh_fn(*args, **kwargs):
            ba = sig.bind(*args, **kwargs)
            statics = {k: ba.arguments.pop(k) for k in static
                       if k in ba.arguments}
            names = list(ba.arguments)
            vals = [ba.arguments[k] for k in names]
            body = lambda *a: fn(**dict(zip(names, a)), **statics)
            in_specs = tuple(self._arg_specs(n, v)
                             for n, v in zip(names, vals))
            out_specs = serving_specs(jax.eval_shape(body, *vals), mesh)
            return sharded_call(body, mesh, in_specs, out_specs)(*vals)

        mesh_fn.__signature__ = sig  # so jit resolves static/donated names
        return jax.jit(mesh_fn, static_argnames=static, donate_argnames=donate)

    def _arg_specs(self, name, val):
        from ..distributed.sharding import serving_specs, spec_with_fallback

        if name in ("k_perm", "v_perm"):
            # [n_layers, H_kv, D] calibration perms ride head-sharded so
            # the lane's local mini-cache seeds from its own head block
            want = [None] * (val.ndim - 2) + ["kv", None]
            return spec_with_fallback(val.shape, want, self.mesh)
        return serving_specs(val, self.mesh)

    # -- calibration --------------------------------------------------------
    def _calibrate(self, pack_cfg: PackKVConfig) -> PackKVConfig:
        S = self.ecfg.calib_tokens
        rng = np.random.default_rng(0)
        B = 1
        batch = {"tokens": jnp.asarray(rng.integers(0, self.cfg.vocab, (B, S)),
                                       jnp.int32)}
        if self.cfg.input_mode == "tokens_patches":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(B, self.cfg.n_patches, self.cfg.d_model)),
                jnp.float32,
            )
        # calibration reads raw prefill K/V from a dense layout; paged
        # placement is irrelevant to spec choice, so strip it here
        none_cfg = dataclasses.replace(pack_cfg, policy="none", paged=False)
        cap = max(S + self.cfg.n_patches * (self.cfg.input_mode == "tokens_patches"),
                  pack_cfg.block)
        cap = -(-cap // pack_cfg.block) * pack_cfg.block
        if self.cfg.family == "hybrid_rglru":
            _, state = self.api.prefill(self.params, self.cfg, none_cfg, cap, batch)
            cache = state.cache
            n = min(int(jnp.min(cache.n_comp)), self.cfg.window)
        else:
            _, cache = self.api.prefill(self.params, self.cfg, none_cfg, cap, batch)
            n = int(jnp.min(cache.n_comp))
        n = (n // pack_cfg.block) * pack_cfg.block
        if n == 0:
            return pack_cfg
        rk, rv = cache.raw_k, cache.raw_v  # [L?, B, H, cap, D]
        lead = rk.shape[: rk.ndim - 3]
        D = rk.shape[-1]
        k = rk.reshape(-1, *rk.shape[-3:])[:, :, :n, :]  # [L*B, H, n, D]
        v = rv.reshape(-1, *rv.shape[-3:])[:, :, :n, :]
        return calibrate_specs(k, v, pack_cfg)

    # -- serving ------------------------------------------------------------
    def prefill(self, batch: dict):
        return self._prefill(self.params, batch=batch)

    def decode(self, cache, token: Array, n_bucket: int | None = None):
        return self._decode(self.params, cache=cache, token=token,
                            n_bucket=n_bucket)

    def decode_chunk(self, cache, token: Array, active, n_steps: int,
                     eos_id: int | None, n_bucket: int | None = None):
        """Donated multi-step decode (see models/*.decode_steps).

        The ``cache`` argument is DONATED: the caller must drop its
        reference and use the returned cache. Returns
        (tokens np [t_max, B], n_exec int, cache).
        """
        toks, n_exec, cache = self._decode_multi(
            self.params,
            cache=cache,
            token=token,
            active=jnp.asarray(active, bool),
            n_steps=jnp.int32(n_steps),
            eos_id=jnp.int32(-1 if eos_id is None else eos_id),
            t_max=self.ecfg.decode_chunk,
            n_bucket=n_bucket,
        )
        return np.asarray(toks), int(n_exec), cache

    def decode_verify(self, cache, tokens: np.ndarray, lens: np.ndarray,
                      active, n_bucket: int | None = None):
        """One speculative verify launch (see models/*.verify_steps).

        tokens: [B, w] i32 host array (seed + drafts, junk-padded); lens:
        [B] valid window lengths; active: bool [B] occupied rows. The
        ``cache`` argument is DONATED and comes back with the accepted
        prefixes already committed and free rows re-zeroed. Returns
        (hat np [B, w] — per-position greedy argmax, n_accept np [B],
        cache)."""
        hat, n_accept, cache = self._verify(
            self.params,
            cache=cache,
            tokens=jnp.asarray(tokens, jnp.int32),
            lens=jnp.asarray(lens, jnp.int32),
            active=jnp.asarray(active, bool),
            n_bucket=n_bucket,
        )
        return np.asarray(hat), np.asarray(n_accept), cache

    def bucket_for(self, n_max: int) -> int | None:
        """Launch bucket covering ``n_max`` compressed tokens (None = full).

        Paged engines bucket the PAGE COUNT: the unit is raised to the page
        size so every bucket is a whole number of pages and the gather /
        page-indexed kernels see page-aligned launches.
        """
        if not self.ecfg.bucketed or not self.api.supports_paged:
            # recurrent families ignore n_bucket (O(1)/window-bounded
            # state); None avoids one decode recompile per bucket value
            return None
        from ..core.cache import bucket_length

        unit = self.ecfg.bucket_unit
        if self.ecfg.paged:
            unit = max(unit, self.ecfg.page_size)
        return bucket_length(n_max, self.ecfg.capacity, unit)

    def alloc_slot_cache(self):
        """Slot-table decode cache: max_batch rows, per-row counters.

        On a mesh the fresh cache is placed with its serving shardings up
        front (payloads by KV head over 'kv', ledger + counters
        replicated), so every later lane dispatch consumes and produces
        it with zero resharding."""
        cache = self.api.alloc_cache(
            self.cfg, self.pack_cfg, self.ecfg.max_batch, self.ecfg.capacity
        )
        if self.mesh is not None:
            from ..distributed.sharding import serving_cache_specs, to_named

            cache = jax.device_put(
                cache, to_named(serving_cache_specs(cache, self.mesh),
                                self.mesh))
        return cache

    def insert_request(self, cache, slot: int, tokens: np.ndarray):
        """Jitted single-slot prefill-insert; returns (last logits [V], cache)."""
        batch = {"tokens": jnp.asarray(np.asarray(tokens)[None], jnp.int32)}
        logits, cache = self._insert(
            self.params, cache=cache, slot=jnp.int32(slot), batch=batch
        )
        return logits[0], cache

    def insert_request_prefix(self, cache, slot: int, tokens: np.ndarray,
                              pages, perms):
        """Jitted chunked prefill-insert (prefix-cache engines only).

        ``pages``: physical ids of the matched page-aligned prompt prefix
        (mapped into the slot by reference — empty for a cold admission);
        ``perms``: the index entry's (k_perm, v_perm) calibration, or None
        (cold / policy 'none'). Returns (last logits [V], cache)."""
        batch = {"tokens": jnp.asarray(np.asarray(tokens)[None], jnp.int32)}
        phys = jnp.asarray(np.asarray(pages, np.int64), jnp.int32)
        kp, vp = perms if perms is not None else (self._dummy_perm,
                                                  self._dummy_perm)
        logits, cache = self._insert_prefix(
            self.params, cache=cache, slot=jnp.int32(slot), batch=batch,
            prefix_phys=phys, k_perm=kp, v_perm=vp,
            n_prefix=len(pages) * self.ecfg.page_size,
        )
        return logits[0], cache

    # -- chunked interleaved admission --------------------------------------
    def chunk_tokens(self) -> int:
        """Admission chunk budget in tokens (page-aligned)."""
        return self.ecfg.prefill_chunk_pages * self.ecfg.page_size

    def chunk_init(self, prompt_len: int):
        """Fresh admission scratch for a ``prompt_len``-token prompt."""
        return self.api.prefill_chunk_init(
            self.cfg, self.pack_cfg, self.ecfg.capacity, prompt_len=prompt_len
        )

    def chunk_step(self, scratch, tokens: np.ndarray, n_ctx: int):
        """One bounded prefill chunk at absolute offset ``n_ctx`` (STATIC).
        Returns (last-token logits [V], scratch) — only the final chunk's
        logits are meaningful."""
        logits, scratch = self._chunk_step(
            self.params, scratch=scratch,
            tokens=jnp.asarray(np.asarray(tokens)[None], jnp.int32),
            n_ctx=n_ctx,
        )
        return logits[0], scratch

    def chunk_insert(self, cache, slot: int, scratch):
        """Finish a chunked admission: build + scatter row ``slot``."""
        return self._chunk_insert(
            cache=cache, slot=jnp.int32(slot), scratch=scratch
        )

    def chunk_final(self, cache, slot: int, scratch, tokens: np.ndarray,
                    n_ctx: int):
        """Fused last chunk: prefill the final segment AND scatter the
        finished row into slot ``slot``, one dispatch. Returns (last-token
        logits [V], cache)."""
        logits, cache = self._chunk_final(
            self.params, cache, jnp.int32(slot), scratch,
            jnp.asarray(np.asarray(tokens)[None], jnp.int32), n_ctx=n_ctx,
        )
        return logits[0], cache

    def prefix_chunk_bounds(self, prompt_len: int, n_matched_pages: int):
        """Host-side segment bounds for an interleaved prefix admission."""
        return self.api.prefix_chunk_bounds(
            self.pack_cfg, prompt_len, n_matched_pages * self.ecfg.page_size
        )

    def prefix_chunk_start(self, cache, prompt_len: int, pages, perms):
        """Mini-cache seeded with the matched shared pages (prefix engines)."""
        phys = jnp.asarray(np.asarray(pages, np.int64), jnp.int32)
        kp, vp = perms if perms is not None else (self._dummy_perm,
                                                  self._dummy_perm)
        return self._prefix_chunk_init(
            cache=cache, prefix_phys=phys, k_perm=kp, v_perm=vp,
            n_prefix=len(pages) * self.ecfg.page_size, prompt_len=prompt_len,
        )

    def prefix_chunk_step(self, mini, tokens: np.ndarray, n_ctx: int):
        """One page-aligned segment of an interleaved prefix admission."""
        logits, mini = self._prefix_chunk(
            self.params, mini=mini,
            tokens=jnp.asarray(np.asarray(tokens)[None], jnp.int32),
            n_ctx=n_ctx,
        )
        return logits[0], mini

    def prefix_chunk_finish(self, cache, slot: int, mini, pages,
                            prompt_len: int):
        """Scatter the finished mini-cache into pool pages (shared prefix
        pages mapped by reference)."""
        phys = jnp.asarray(np.asarray(pages, np.int64), jnp.int32)
        return self._prefix_chunk_insert(
            cache=cache, slot=jnp.int32(slot), mini=mini, prefix_phys=phys,
            n_prefix=len(pages) * self.ecfg.page_size, prompt_len=prompt_len,
        )

    def _pad_ids(self, ids) -> Array:
        """Sentinel-pad page ids to the fixed per-slot table width so the
        pin/unpin jits compile once (sentinel entries are dropped)."""
        width = self.ecfg.capacity // self.ecfg.page_size
        out = np.full((width,), self.pack_cfg.pool_pages, np.int64)
        out[: len(ids)] = np.asarray(ids, np.int64)
        return jnp.asarray(out, jnp.int32)

    def index_acquire(self, cache, ids):
        """Pin pages for the prefix index (+1 ref each)."""
        return self._acquire_pages(cache, self._pad_ids(ids))

    def index_release(self, cache, ids):
        """Unpin evicted index pages (-1 ref; freed at zero)."""
        return self._release_pages(cache, self._pad_ids(ids))

    def free_slot(self, cache, slot: int):
        return self._reset(cache, jnp.int32(slot))

    def evacuate(self, cache, slot: int, n_pages: int, n_shared: int = 0):
        """Swap row ``slot`` out: returns (cache with the row freed, dense
        B=1 mini-cache holding the row's owned bytes). ``n_pages`` is the
        row's exact live page count (host-mirrored), ``n_shared`` its
        shared-prefix pages (released by reference, not copied)."""
        return self._evacuate(cache, jnp.int32(slot), n_pages=n_pages,
                              n_shared=n_shared)

    def restore(self, cache, slot: int, mini, shared_phys=(),
                n_pages: int = 0, n_shared: int = 0):
        """Stream an evacuated row back into ``slot`` (no forward pass)."""
        phys = jnp.asarray(np.asarray(shared_phys, np.int64), jnp.int32)
        return self._restore(cache, jnp.int32(slot), mini, phys,
                             n_pages=n_pages, n_shared=n_shared)

    def mask_free(self, cache, active):
        """Re-zero counters of inactive rows (see core.cache.mask_free_slots)."""
        return self._mask_free(cache, active)

    def generate(self, batch: dict, max_new: int, eos_id: int | None = None):
        """Greedy wave decode. Returns tokens [B, max_new] (stops early only
        when every row has emitted ``eos_id``)."""
        logits, cache = self.prefill(batch)
        B = logits.shape[0]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        done = jnp.zeros((B,), bool)
        outs = []
        for _ in range(max_new):
            outs.append(np.asarray(tok[:, 0]))
            if eos_id is not None:
                done = done | (tok[:, 0] == eos_id)
                if bool(done.all()):
                    break
            logits, cache = self.decode(cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return np.stack(outs, axis=1), cache


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # [S] prompt at its true length
    max_new: int
    output: np.ndarray | None = None
    # admission class: 0 is the most urgent; FIFO within a class, lower
    # classes delayed (never starved — see EngineConfig.aging_steps)
    priority: int = 0
    # wall-clock budget in ms from submit; a request past its deadline is
    # retired with status 'expired' at the next scheduler step (partial
    # output kept). None = no deadline.
    deadline_ms: float | None = None
    # lifecycle: queued -> active -> done | cancelled | expired | parked
    # (a preempted request goes back to queued and keeps its place;
    # 'parked' is a fault-forced voluntary end-of-turn — partial output
    # kept, cache state parked in the session store when it is on)
    status: str = "queued"
    # latency telemetry (wall-clock seconds; filled by SlotServer):
    t_submit: float = 0.0  # stamped by submit()
    t_first: float | None = None  # first token ready (TTFT = t_first - t_submit)
    token_times: list = dataclasses.field(default_factory=list)  # one per
    #   token; tokens emitted by one multi-step launch share a timestamp
    n_preempts: int = 0  # times this request was swapped out mid-decode
    # scheduler bookkeeping (stamped by submit):
    _seq: int = dataclasses.field(default=0, repr=False)  # global submit order
    _enq_step: int = dataclasses.field(default=0, repr=False)  # step when
    #   (re-)queued — the aging clock

    def cancel(self) -> None:
        """Request cooperative cancellation: honored at the next scheduler
        step — queued, mid-prefill-chunk, swapped-out or decoding alike —
        through the shared retirement path (partial output kept)."""
        self._cancelled = True

    @property
    def cancelled(self) -> bool:
        return getattr(self, "_cancelled", False)


@dataclasses.dataclass
class SlotStats:
    """Scheduler telemetry (throughput/occupancy counters)."""

    # per-launch log for length-aware accounting: (steps, bucket tokens
    # launched per row, live token count per occupied row) — the substrate
    # for the dead-tile fraction reported by benchmarks/bench_ragged.py.
    # Grows per launch, so it only fills when EngineConfig.log_launches is on.
    launches: list = dataclasses.field(default_factory=list)
    n_slots: int = 0
    decode_steps: int = 0  # decode steps executed (tokens per occupied row)
    chunk_launches: int = 0  # jitted decode dispatches (== steps when chunk=1)
    occupied_slot_steps: int = 0  # sum over steps of occupied slots
    tokens_out: int = 0  # useful tokens delivered to requests
    admitted: int = 0
    completed: int = 0
    slot_reuses: int = 0  # admissions into a previously-used slot
    wall_s: float = 0.0
    # paged admission telemetry (zeros for dense engines):
    admission_blocks: int = 0  # admissions deferred for lack of free pages
    pages_reserved_peak: int = 0  # max simultaneously-reserved pool pages
    # chunked admission telemetry (zeros when prefill_chunk_pages == 0):
    prefill_chunks: int = 0  # bounded prefill dispatches (single-chunk
    # plain prompts take the fused monolithic launch and count zero)
    # prefix-cache telemetry (zeros when the feature is off):
    prefix_lookups: int = 0  # admissions that consulted the prefix index
    prefix_hits: int = 0  # admissions that matched >= 1 full page
    prefix_pages_shared: int = 0  # pages mapped by reference (cumulative)
    prefix_evictions: int = 0  # index entries dropped (pressure or cap)
    # speculative-decode telemetry (zeros when spec_decode is off). With
    # speculation on, ``decode_steps`` counts MODEL PASSES (verify launches
    # included), not tokens — ``tokens_out`` stays the token truth:
    spec_launches: int = 0  # verify dispatches (q_len = spec_k + 1)
    spec_drafted: int = 0  # drafted tokens submitted for verification
    spec_accepted: int = 0  # drafted tokens accepted (emitted for free)
    # preemptive-serving telemetry (ISSUE 8; zeros when preempt is off):
    preemptions: int = 0  # slot swap-outs in favor of a higher class
    swapped_pages: int = 0  # pool pages evacuated to the host SwapStore
    restored_pages: int = 0  # pool pages streamed back on re-admission
    cancelled: int = 0  # requests retired via Request.cancel()
    expired: int = 0  # requests retired past their deadline_ms
    # decode-launch watchdog (zeros without spec decode / watchdog):
    degraded_steps: int = 0  # decode steps run with spec decode auto-disabled
    # session-cache telemetry (ISSUE 9; zeros when session_cache is off):
    session_lookups: int = 0  # admissions that consulted the session store
    session_parks: int = 0  # retiring slots parked host-side
    session_hits: int = 0  # admissions served from a parked session
    session_evictions: int = 0  # parked entries lost (capacity/TTL/invalid)
    session_restored_pages: int = 0  # pool pages streamed back on hits

    @property
    def acceptance_rate(self) -> float:
        return self.spec_accepted / self.spec_drafted if self.spec_drafted \
            else 0.0

    @property
    def session_hit_rate(self) -> float:
        return self.session_hits / self.session_lookups if \
            self.session_lookups else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_lookups if self.prefix_lookups \
            else 0.0

    def to_json(self) -> dict:
        """JSON-serializable dump: every counter plus the derived rates
        (the per-launch ``launches`` log is dropped — unbounded)."""
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "launches"}
        d.update(
            occupancy=self.occupancy,
            decode_tok_s=self.decode_tok_s,
            prefix_hit_rate=self.prefix_hit_rate,
            acceptance_rate=self.acceptance_rate,
            session_hit_rate=self.session_hit_rate,
        )
        return d

    @property
    def occupancy(self) -> float:
        """Fraction of slot-steps that decoded a live request."""
        total = self.decode_steps * max(self.n_slots, 1)
        return self.occupied_slot_steps / total if total else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class _PrefixNode:
    """One full compressed page of a cached prompt prefix (trie node)."""

    __slots__ = ("chunk", "page", "parent", "children", "last_used", "perms")

    def __init__(self, chunk: bytes, page: int, parent):
        self.chunk = chunk  # raw token ids of this page's span (the key)
        self.page = page  # physical pool page id (one index reference held)
        self.parent = parent  # None for depth-0 nodes
        self.children: dict[bytes, "_PrefixNode"] = {}
        self.last_used = 0
        self.perms = None  # depth-0 only: (k_perm, v_perm) device arrays


class PrefixIndex:
    """Host-side content-addressed prefix index over FULL compressed pages.

    A trie keyed by page-aligned chunks of raw prompt token ids; each node
    owns exactly one physical pool page and holds ONE device reference on
    it (``core.cache.acquire_pages``), so cached pages survive their
    originating slot's retirement and are never handed out by the
    allocator. Lookup walks the longest matching chain; eviction removes
    LRU LEAVES only (an interior page is still reachable through its
    children), skipping pages currently mapped into a live slot by
    reference — evicting those would break the scheduler's reservation
    bound (a shared page is reserved by NO slot). Depth-0 nodes carry the
    donor's page-0 channel calibration so a hit compresses its suffix under
    the identical permutation. Pure host state: every device mutation is
    the ``SlotServer``'s, through ``Engine.index_acquire/index_release``.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.roots: dict[bytes, _PrefixNode] = {}
        self.n_held = 0  # pages the index holds a reference on
        self.pages: set[int] = set()  # their ids (each in exactly one node)
        self._clock = 0

    def chunks(self, tokens) -> list[bytes]:
        """Page-aligned raw-token-id chunks (the trie keys)."""
        t = np.ascontiguousarray(np.asarray(tokens, np.int64))
        p = self.page_size
        return [t[i * p:(i + 1) * p].tobytes() for i in range(len(t) // p)]

    def touch(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    def descend(self, parent, chunk: bytes):
        m = self.roots if parent is None else parent.children
        return m.get(chunk)

    def lookup(self, tokens, max_pages: int):
        """Longest-prefix match, LRU-bumping the path.

        Returns (page ids, (k_perm, v_perm) | None)."""
        pages: list[int] = []
        perms = None
        node = None
        for chunk in self.chunks(tokens)[:max_pages]:
            node = self.descend(node, chunk)
            if node is None:
                break
            self.touch(node)
            if node.perms is not None:
                perms = node.perms
            pages.append(node.page)
        return pages, perms

    def insert(self, parent, chunk: bytes, page: int, perms=None):
        node = _PrefixNode(chunk, page, parent)
        self.touch(node)
        node.perms = perms if parent is None else None
        (self.roots if parent is None else parent.children)[chunk] = node
        self.n_held += 1
        self.pages.add(page)
        return node

    def evict_lru(self, protected: set[int]):
        """Drop the least-recently-used unprotected LEAF; returns its page
        id (the caller must release the device reference) or None."""
        best = None
        stack = list(self.roots.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.children or n.page in protected:
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        if best is None:
            return None
        owner = self.roots if best.parent is None else best.parent.children
        del owner[best.chunk]
        self.n_held -= 1
        self.pages.discard(best.page)
        return best.page


class NGramDrafter:
    """Host-side per-slot suffix n-gram drafter (self-speculation).

    No separate draft checkpoint: the draft distribution is the sequence
    itself — per slot, keep prompt + emitted tokens and propose the
    continuation of the most recent earlier occurrence of the current
    suffix n-gram ("prompt lookup" drafting). Pure host state, O(n·L) list
    scan per draft (L = slot sequence length, n <= max_ngram) — noise next
    to a model pass. Draft quality only affects SPEED (acceptance rate);
    the verify pass guarantees greedy outputs are exact for arbitrary
    drafts, so a drafter can be swapped freely (benchmarks inject
    adversarial ones).
    """

    def __init__(self, max_ngram: int = 3):
        self.max_ngram = max_ngram
        self._seq: dict[int, list[int]] = {}

    def seed(self, slot: int, tokens) -> None:
        """Start tracking ``slot``: prompt + first generated token."""
        self._seq[slot] = [int(t) for t in tokens]

    def extend(self, slot: int, tokens) -> None:
        """Append the tokens a launch just emitted for ``slot``."""
        self._seq[slot].extend(int(t) for t in tokens)

    def drop(self, slot: int) -> None:
        self._seq.pop(slot, None)

    def draft(self, slot: int, k: int) -> list[int]:
        """Up to ``k`` proposed continuations of ``slot``'s sequence.

        Longest-suffix match first: for n = max_ngram..1, find the most
        recent PRIOR occurrence of the sequence's last n tokens and
        propose what followed it. Empty when nothing matches — the
        scheduler then falls back to a plain decode launch, which is what
        keeps the acceptance≈0 regime at baseline speed."""
        seq = self._seq.get(slot)
        if not seq or k <= 0:
            return []
        L = len(seq)
        for n in range(min(self.max_ngram, L - 1), 0, -1):
            key = seq[L - n:]
            for s in range(L - n - 1, -1, -1):
                if seq[s:s + n] == key:
                    # s + n <= L - 1, so the continuation is never empty
                    return seq[s + n:s + n + k]
        return []


class _Active:
    """One occupied slot: the request plus its generation state.

    ``forced`` is the teacher-forced ingestion queue of a session resume
    (the returning turn's uncached suffix tokens): while it is non-empty,
    decode launches still append to the row's cache, but the launch argmax
    is overridden host-side by the next forced token — suffix ingestion
    rides the shared decode launches (no extra jitted op) and is
    continuation-exact by construction. Forced tokens are prompt, not
    output: nothing is emitted while the queue drains.

    ``base`` re-anchors the host counter mirror (``SlotServer._counters``)
    for rows whose cache state did NOT come from prefilling their own
    prompt (session resume, or preemption of such a row): ``(n_comp0,
    n_resid0, cached0, out0)`` snapshotted at the anchor moment."""

    __slots__ = ("req", "out", "done", "forced", "k0", "base")

    def __init__(self, req: Request, first_tok: int | None,
                 eos_id: int | None, forced=None, base=None):
        self.req = req
        self.forced: list[int] = list(forced) if forced else []
        self.k0 = len(self.forced)  # forced count at the anchor
        self.base = base
        if first_tok is None:  # session resume: nothing emitted yet
            self.out: list[int] = []
            self.done = False
        else:
            self.out = [first_tok]
            self.done = (eos_id is not None and first_tok == eos_id) or \
                req.max_new <= 1

    @property
    def remaining(self) -> int:
        return self.req.max_new - len(self.out)

    @property
    def cached_tokens(self) -> int:
        """Host-side mirror of this row's cache occupancy (n_comp + n_resid).

        The prompt is inserted at prefill; each decode step appends the
        PREVIOUS token, so the first generated token is not yet cached.
        Re-anchored rows count appends SINCE the anchor instead: every
        drained forced token and every emitted token is one append."""
        if self.base is not None:
            _, _, cached0, out0 = self.base
            return cached0 + (self.k0 - len(self.forced)) + \
                (len(self.out) - out0)
        return len(self.req.tokens) + len(self.out) - 1


class _PrefillTask:
    """An in-flight chunked admission: one request advancing through its
    page-aligned prefill segments, interleaved with decode launches.

    The slot is claimed (and its pages reserved) at task start but stays
    ``None`` in the slot table until the final segment inserts the row —
    decode launches in between see it as a free ride-along row."""

    __slots__ = ("req", "slot", "kind", "scratch", "bounds", "idx",
                 "match_pages", "match_perms", "logits")

    def __init__(self, req: Request, slot: int, kind: str, scratch,
                 bounds: list[int], match_pages: tuple[int, ...] = (),
                 match_perms=None):
        self.req = req
        self.slot = slot
        self.kind = kind  # "plain" | "prefix"
        self.scratch = scratch  # raw-K/V scratch | seeded mini-cache
        self.bounds = bounds  # segment offsets; [i, i+1) spans one dispatch
        self.idx = 0  # next segment
        self.match_pages = match_pages
        self.match_perms = match_perms
        self.logits = None  # last segment's logits seed decode

    @property
    def done(self) -> bool:
        return self.idx >= len(self.bounds) - 1


class SlotServer:
    """Continuous-batching scheduler over a fixed slot table — ONE engine
    for every family (KV transformers and recurrent rwkv6/hybrid_rglru).

    Each step: (1) PREFILL CHUNK — advance the pending admission (FIFO
    head) by at most ``prefill_chunk_pages`` pages' worth of prompt, the
    final chunk inserting the finished row into its claimed slot;
    (2) DECODE — one batched greedy decode launch over the whole table
    (free rows ride along masked); (3) RETIRE — rows that hit EOS or
    ``max_new`` record their output, their slot state is reset, and the
    slot is reusable on the very next step. Because every scheduler step
    runs a decode launch, no occupied slot ever stalls for more than one
    bounded prefill chunk (the old monolithic admission stalled decode for
    the WHOLE prompt). ``prefill_chunk_pages=0`` restores the monolithic
    path. Per-request greedy outputs are bit-identical to a batch-size-1
    ``Engine.generate`` run either way (per-row state + per-row positions +
    row-independent attention; chunk boundaries are exact resume points —
    see ``models.layers.resume_attention`` and the per-family
    ``prefill_chunk`` docstrings).

    PAGED engines admit on FREE PAGES, not free slots: each admitted
    request reserves its worst-case page count (``ceil(min(capacity,
    prompt + max_new) / page_size)``) and admission blocks — FIFO order
    preserved — while reservations plus the watermark would overflow the
    pool. Reservations are the host-side guarantee that the in-graph
    free-list never over-pops, which is what makes oversubscription
    (``pool_pages < max_batch * capacity / page_size``) safe under mixed
    traffic.

    PREFIX-CACHE engines additionally keep a host-side ``PrefixIndex``:
    admission looks up the longest page-aligned prompt prefix already
    compressed in the pool, maps those pages into the new slot BY
    REFERENCE (refcounted — they reserve ZERO new pages), runs the chunked
    prefill only over the uncovered suffix, and registers the admitted
    prompt's full pages back into the index. Under pool pressure the
    scheduler EVICTS cold cached prefixes (LRU leaves not mapped into any
    live slot) instead of blocking admission. Cache-hit admissions are
    bit-identical to cold ones: see ``models.transformer.
    prefill_into_slot_prefix`` for why page boundaries are exact resume
    points.

    PREEMPTIVE serving (ISSUE 8; ``EngineConfig.preempt``): requests carry
    a priority class — admission is per-class FIFO with aging (delayed,
    never starved) — and when a higher-class head cannot seat (no free
    slot, or pages short even after index eviction) the scheduler swaps a
    strictly-lower-class victim OUT: its compressed pages, residual and
    counters are evacuated to a host-RAM ``SwapStore``
    (``core.cache.evacuate_row``), shared-prefix pages release their refs
    instead of copying, and the victim requeues with its
    generated-so-far tokens. On re-admission the row streams back
    (``restore_row`` — one scatter, no forward pass) and decoding resumes
    bit-identically to an uninterrupted run. ``Request.deadline_ms`` /
    ``cancel()`` retire work at the next scheduler step — mid-prefill-chunk
    included — through the same ``_retire_slot``/``_finish_dead`` path,
    and a ``distributed.fault.FaultPlan`` can drive all of it
    deterministically (see docs/serving.md).

    SESSION CACHE (ISSUE 9; ``EngineConfig.session_cache``): multi-turn
    traffic parks instead of discarding — when a slot retires, its
    compressed pages, residual, counters and channel calibration are
    evacuated (the same ``evacuate_row`` gather preemption uses) into a
    host-RAM ``SessionStore`` keyed by the session's raw token trace
    (LRU-by-bytes with optional disk spill + TTL; shared prefix pages
    release their refs and are revalidated against the live trie on
    return). A returning turn whose prompt extends a parked trace
    restores the row with ZERO forward passes over the restored tokens
    and ingests only its new suffix, teacher-forced through the ordinary
    decode launches — so a session hit is bit-identical to never having
    parked at all (the continuation-exactness bar of preemption, extended
    across turns; see docs/serving.md).
    """

    def __init__(self, engine: Engine, eos_id: int | None = None,
                 drafter: NGramDrafter | None = None,
                 fault_plan: FaultPlan | None = None,
                 straggler: StragglerMonitor | None = None,
                 session_store: SessionStore | None = None):
        if engine.cfg.input_mode != "tokens":
            raise ValueError(
                f"input_mode {engine.cfg.input_mode!r} not servable per-slot "
                "(Request carries tokens only); batch such inputs through "
                "Engine.generate"
            )
        self.engine = engine
        self.eos_id = eos_id
        # speculative decode: per-slot drafter (injectable — draft quality
        # only moves the acceptance rate, never the outputs)
        self._drafter = (
            (drafter if drafter is not None else NGramDrafter())
            if engine.ecfg.spec_decode else None
        )
        # per-slot acceptance bookkeeping: fully-rejected launches push the
        # slot into an exponentially growing draft cooldown (see
        # EngineConfig.spec_backoff); any accepted draft resets it
        self._spec_backoff = [0] * engine.ecfg.max_batch
        self._spec_cooldown = [0] * engine.ecfg.max_batch
        self.n_slots = engine.ecfg.max_batch
        self.cache = None  # allocated on first admission
        self.slots: list[_Active | None] = [None] * self.n_slots
        self._ever_used = [False] * self.n_slots
        self._last_tok = np.zeros((self.n_slots,), np.int32)
        # per-class FIFO queues (priority 0 = most urgent); the flattened
        # ``queue`` property is the back-compat view
        self.queues: dict[int, deque[Request]] = {}
        self.done: dict[int, Request] = {}
        self.stats = SlotStats(n_slots=self.n_slots)
        self._reserved: dict[int, int] = {}  # slot -> NEWLY-allocatable pages
        self._index = (PrefixIndex(engine.ecfg.page_size)
                       if engine.ecfg.prefix_cache else None)
        self._slot_shared: dict[int, tuple[int, ...]] = {}  # slot -> mapped
        self._task: _PrefillTask | None = None  # in-flight chunked admission
        # preemption: host-RAM store of evacuated rows (ISSUE 8)
        self._swap: SwapStore | None = SwapStore() if engine.ecfg.preempt \
            else None
        # voluntary session cache: parked retiring rows (ISSUE 9; the
        # injectable store lets tests freeze clocks / shrink capacities)
        self._sessions: SessionStore | None = None
        if engine.ecfg.session_cache:
            self._sessions = session_store if session_store is not None \
                else SessionStore(
                    capacity_bytes=engine.ecfg.session_cache_mb << 20,
                    ttl_s=engine.ecfg.session_ttl_s,
                    disk_dir=engine.ecfg.session_disk_dir,
                )
        self._fault_rid = 1_000_000_000  # rid range for fault-fabricated
        #   returning sessions (far above real traffic — never collides)
        self._seq = 0  # global submit stamp (FIFO order within a class)
        self._step_no = 0  # scheduler step counter (aging + fault clock)
        # deterministic fault schedule (tests/bring-up; None in production)
        self._faults = fault_plan
        self._squeeze = 0  # pool pages a pool_squeeze fault holds back
        # decode-launch watchdog: sustained stragglers auto-disable spec
        # decode (exactness-neutral — speculation only changes speed)
        self._watchdog = straggler if straggler is not None else (
            StragglerMonitor() if engine.ecfg.spec_decode else None
        )
        self._spec_degraded = False  # sticky once the watchdog says exclude

    @property
    def queue(self) -> list[Request]:
        """Flattened queue view: classes ascending, FIFO within each — for
        truthiness/len/iteration. Mutate through submit(), never this list."""
        return [r for p in sorted(self.queues) for r in self.queues[p]]

    # -- paged admission accounting ----------------------------------------
    @property
    def _pages_avail(self) -> int:
        """Pool pages not spoken for: total minus the watermark, minus every
        slot's reservation of pages it may NEWLY allocate, minus pages the
        prefix index pins. Donor pages counted by both a reservation and
        the index are double-counted — conservative, never unsafe — and
        index pages are reclaimable on demand (``_evict_to_fit``)."""
        ecfg = self.engine.ecfg
        total = self.engine.pack_cfg.pool_pages
        held = self._index.n_held if self._index is not None else 0
        return (total - ecfg.page_watermark - self._squeeze
                - sum(self._reserved.values()) - held)

    def _pages_needed(self, req: Request) -> int:
        """Worst-case resident pages over the request's lifetime: its
        compressed tokens never exceed min(capacity, prompt + max_new)."""
        from ..utils import cdiv

        ecfg = self.engine.ecfg
        hi = min(ecfg.capacity, len(req.tokens) + req.max_new)
        return cdiv(hi, ecfg.page_size)

    def _match(self, req: Request) -> tuple[list[int], object]:
        """Longest page-aligned prefix the index can serve for ``req``.

        Capped one token short of the prompt so the suffix is never empty
        (admission needs last-token logits to seed decode)."""
        max_m = (len(req.tokens) - 1) // self.engine.ecfg.page_size
        return self._index.lookup(req.tokens, max_m)

    def _live_shared(self) -> set[int]:
        """Shared pages a live slot maps by reference — plus pages a
        SWAPPED-OUT request will re-map on restore (its slot released its
        device refs at evacuation, so only the index still pins them; they
        must survive eviction until the request resumes or dies)."""
        live = {p for t in self._slot_shared.values() for p in t}
        if self._swap is not None:
            for meta in self._swap.metas():
                live.update(meta["shared"])
        return live

    def _evict_to_fit(self, need_new: int, protected: set[int]) -> bool:
        """Reclaim index-pinned pages (LRU leaves first) until ``need_new``
        fits, instead of blocking admission. Never evicts pages mapped into
        a live slot by reference (they are covered by NO reservation) or
        the pages just matched for the pending admission."""
        if self._index is None:
            return need_new <= self._pages_avail
        protected = protected | self._live_shared()
        while need_new > self._pages_avail:
            page = self._index.evict_lru(protected)
            if page is None:
                return False
            self.cache = self.engine.index_release(self.cache, [page])
            self.stats.prefix_evictions += 1
        return True

    def _register(self, req: Request, slot: int) -> None:
        """Index every full compressed page of the freshly-admitted prompt.

        Matched pages already have nodes (bumped); new pages get nodes and
        one device reference each. Registration respects
        ``prefix_cache_pages`` by evicting LRU leaves first and simply
        stops when nothing is evictable (a shorter registered chain is
        still a correct trie)."""
        pack = self.engine.pack_cfg
        page = self.engine.ecfg.page_size
        k = (len(req.tokens) // pack.block) * pack.block // page
        if not k:
            return
        cap = self.engine.ecfg.prefix_cache_pages
        row = np.asarray(self.cache.pages.page_table[0, slot, :k])
        perms = None
        if pack.policy != "none":
            perms = (self.cache.k.chan_perm[:, slot],
                     self.cache.v.chan_perm[:, slot])
        protected = self._live_shared() | {int(p) for p in row}
        acquired: list[int] = []
        parent = None
        for d, chunk in enumerate(self._index.chunks(req.tokens)[:k]):
            node = self._index.descend(parent, chunk)
            if node is None:
                if cap is not None and self._index.n_held >= cap:
                    ev = self._index.evict_lru(protected)
                    if ev is None:
                        break
                    self.cache = self.engine.index_release(self.cache, [ev])
                    self.stats.prefix_evictions += 1
                node = self._index.insert(parent, chunk, int(row[d]),
                                          perms if d == 0 else None)
                acquired.append(int(row[d]))
            else:
                self._index.touch(node)
            parent = node
        if acquired:
            self.cache = self.engine.index_acquire(self.cache, acquired)

    def _check_invariants(self) -> None:
        """Debug mode: refcount conservation after every admit/retire.

        ``free ⇔ ref == 0`` in both directions — the number of held pages
        plus the stack height equals the pool size, and every stack entry
        has a zero count. Device sync per call; gate on
        ``EngineConfig.debug_invariants``."""
        if not (self.engine.ecfg.debug_invariants and self.engine.ecfg.paged
                and self.cache is not None):
            return
        pool = self.cache.pages
        ref = np.asarray(pool.ref[0])
        nf = int(pool.n_free[0])
        free = np.asarray(pool.free[0])
        P = ref.shape[0]
        assert int((ref > 0).sum()) + nf == P, (ref, nf)
        assert int((ref == 0).sum()) == nf, (ref, nf)
        assert (ref[free[:nf]] == 0).all(), (ref, free[:nf])
        if self._index is not None:
            assert all(int(ref[p]) >= 1 for p in self._index.pages)

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        if req.priority < 0:
            raise ValueError(f"request {req.rid}: priority must be >= 0")
        if req.deadline_ms is not None and req.deadline_ms <= 0:
            # an already-expired deadline is a caller bug, not traffic:
            # reject upstream instead of admitting work doomed to reap
            raise ValueError(
                f"request {req.rid}: deadline_ms must be > 0"
            )
        if self.engine.ecfg.paged:
            ecfg = self.engine.ecfg
            pack = self.engine.pack_cfg
            # prefill block-flushes the WHOLE prompt, so its block-aligned
            # length must fit the compressed capacity outright (a longer
            # one would pop more pages than a table row holds)
            lb = (len(req.tokens) // pack.block) * pack.block
            if lb > ecfg.capacity:
                raise ValueError(
                    f"request {req.rid}: block-aligned prompt length {lb} "
                    f"exceeds compressed capacity {ecfg.capacity}"
                )
            hi = len(req.tokens) + req.max_new
            if hi > ecfg.capacity + pack.residual:
                # over-contract rows stop flushing at capacity (their page
                # reservation stays a true bound) and would degrade their
                # own residual — enforce the documented upstream rejection
                raise ValueError(
                    f"request {req.rid}: prompt + max_new = {hi} exceeds "
                    f"capacity + residual = {ecfg.capacity + pack.residual}"
                )
            total = self.engine.pack_cfg.pool_pages
            need = self._pages_needed(req)
            if need > total - ecfg.page_watermark:
                raise ValueError(
                    f"request {req.rid} needs {need} pages but the pool "
                    f"admits at most {total - ecfg.page_watermark}"
                )
        req.t_submit = time.perf_counter()
        req._seq = self._seq
        self._seq += 1
        req._enq_step = self._step_no
        self.queues.setdefault(req.priority, deque()).append(req)

    def _head(self) -> Request | None:
        """The next request to admit: among per-class FIFO heads, the one
        with the smallest (effective class, submit seq). Aging promotes a
        waiting head one class per ``aging_steps`` scheduler steps, so a
        permanent higher-class flood delays lower classes but never starves
        them; ``aging_steps = 0`` is strict priority."""
        ag = self.engine.ecfg.aging_steps
        best, best_key = None, None
        for p, q in self.queues.items():
            if not q:
                continue
            h = q[0]
            eff = max(0, p - (self._step_no - h._enq_step) // ag) if ag > 0 \
                else p
            key = (eff, h._seq)
            if best_key is None or key < best_key:
                best, best_key = h, key
        return best

    def _pop_head(self, head: Request) -> Request:
        got = self.queues[head.priority].popleft()
        assert got is head
        return got

    def _requeue(self, req: Request) -> None:
        """Put a preempted/aborted request back, keeping per-class FIFO by
        ORIGINAL submit order (everything still queued in its class was
        submitted later, so it normally lands at the front)."""
        req.status = "queued"
        req._enq_step = self._step_no
        q = self.queues.setdefault(req.priority, deque())
        pos = 0
        while pos < len(q) and q[pos]._seq < req._seq:
            pos += 1
        q.insert(pos, req)

    @property
    def n_occupied(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- retirement (the ONE path out of a slot) ----------------------------
    def _release_slot(self, i: int, free_pages: bool = True) -> None:
        """Tear down slot ``i``'s scheduler state: drafter row, page
        reservation, shared-prefix mapping, and — unless the pages were
        already returned in-graph by an evacuation — the device row itself.
        Every way out of a slot (EOS, max_new, cancel, deadline, preemption)
        funnels through here, so nothing can leak pages or refcounts."""
        self.slots[i] = None
        if self._drafter is not None:
            self._drafter.drop(i)
        if free_pages:
            self.cache = self.engine.free_slot(self.cache, i)
        self._reserved.pop(i, None)  # paged: pages return with the reset
        self._slot_shared.pop(i, None)  # shared pages: ref back to the index
        self._check_invariants()

    def _retire_slot(self, i: int, reason: str = "done") -> Request:
        """Finish the request in slot ``i`` (reason: done | cancelled |
        expired | parked) and recycle the slot. Natural and voluntary
        ends of turn ("done" / "parked") park the row's cache state in the
        session store when it is on; cancelled/expired work is discarded
        (a killed request is not a conversation that will return)."""
        act = self.slots[i]
        act.req.output = np.asarray(act.out, np.int32)
        act.req.status = reason
        self.done[act.req.rid] = act.req
        parked = reason in ("done", "parked") and self._park_row(i)
        # a parked row was evacuated: its pages are already back in the pool
        self._release_slot(i, free_pages=not parked)
        if reason == "done":
            self.stats.completed += 1
        elif reason == "cancelled":
            self.stats.cancelled += 1
        elif reason == "expired":
            self.stats.expired += 1
        return act.req

    # -- session cache: voluntary park / resume ------------------------------
    def _park_row(self, i: int) -> bool:
        """Park slot ``i``'s cache state in the session store at retirement.

        The parked key is the row's cached token TRACE — the first
        ``cached_tokens + 1`` tokens of prompt + generated (the +1 is the
        pending seed, ``_last_tok``, which the cache has not appended yet).
        That formula is exact whether the row completed, was force-parked
        mid-generation, or was even mid-suffix-ingestion. Shared-prefix
        pages release their refs (never copy); their trie NODE chain is
        remembered so a resume can prove, by object identity, that the
        index still holds the same physical pages. Returns True when the
        row was evacuated (caller must then release with
        ``free_pages=False``)."""
        if self._sessions is None or self.cache is None:
            return False
        act = self.slots[i]
        trace = np.concatenate([
            np.asarray(act.req.tokens, np.int64),
            np.asarray(act.out, np.int64),
        ])[: act.cached_tokens + 1]
        c, r = self._counters(act)
        n_pages = n_shared = 0
        if self.engine.ecfg.paged:
            n_pages = -(-c // self.engine.ecfg.page_size)
            n_shared = len(self._slot_shared.get(i, ()))
        shared = tuple(self._slot_shared.get(i, ()))
        nodes: list = []
        if shared and self._index is not None:
            parent = None
            for chunk in self._index.chunks(trace)[: len(shared)]:
                parent = self._index.descend(parent, chunk)
                assert parent is not None  # live-shared pages can't evict
                nodes.append(parent)
        self.cache, mini = self.engine.evacuate(self.cache, i, n_pages,
                                                n_shared)
        self._sessions.put(trace, mini, dict(
            last_tok=int(self._last_tok[i]), n_pages=n_pages,
            n_shared=n_shared, shared=shared, nodes=tuple(nodes),
            counters=(c, r),
        ))
        self.stats.session_parks += 1
        return True

    def _session_valid(self, meta: dict) -> bool:
        """A parked entry's shared-prefix pages are servable iff the SAME
        trie nodes still hold the SAME physical pages — parking holds no
        device refs, so pool pressure may have evicted (or evicted and
        rebuilt with different calibration) the chain while the session
        was away. Object identity over the remembered node chain is the
        airtight check: nodes die at eviction and are never resurrected."""
        if self._index is None:
            return False
        node = None
        for want, page in zip(meta["nodes"], meta["shared"]):
            node = self._index.descend(node, want.chunk)
            if node is not want or node.page != page:
                return False
        for n in meta["nodes"]:
            self._index.touch(n)
        return True

    def _session_try(self, head: Request, slot: int) -> str:
        """Try to serve ``head`` from a parked session.

        Returns "hit" (popped + admitted into ``slot``), "blocked" (a
        matching entry exists but its pages don't fit yet — the entry is
        kept and the admission retries next step) or "miss" (cold path).
        """
        if self._sessions is None or self.cache is None:
            return "miss"
        while True:
            key = self._sessions.match(head.tokens)
            if key is None:
                return "miss"
            meta = self._sessions.meta(key)
            if meta["n_shared"] and not self._session_valid(meta):
                self._sessions.drop(key)
                continue
            if self.engine.ecfg.paged and not self._fit_pages(
                    head, self._pages_needed(head) - meta["n_shared"],
                    set(meta["shared"])):
                return "blocked"
            req = self._pop_head(head)
            mini, meta = self._sessions.take(key)
            self.stats.session_lookups += 1
            self._session_resume(req, slot, len(key) // 8, mini, meta)
            return "hit"

    def _session_resume(self, req: Request, i: int, trace_len: int,
                        mini, meta: dict) -> None:
        """Re-admit a returning session: stream the parked row back into
        slot ``i`` (shared prefix re-mapped by reference — pure data
        movement, NO forward pass) and queue the prompt's uncached suffix
        for teacher-forced ingestion through the decode launches. The
        counter anchor pins ``_counters`` to the parked row's exact
        (n_comp, n_resid) so later flush arithmetic stays a host mirror."""
        if self.engine.ecfg.paged:
            self._reserved[i] = self._pages_needed(req) - meta["n_shared"]
            self.stats.pages_reserved_peak = max(
                self.stats.pages_reserved_peak, sum(self._reserved.values())
            )
        self.cache = self.engine.restore(
            self.cache, i, mini, meta["shared"],
            n_pages=meta["n_pages"], n_shared=meta["n_shared"],
        )
        if meta["shared"]:
            self._slot_shared[i] = tuple(meta["shared"])
        c0, r0 = meta["counters"]
        forced = [int(t) for t in np.asarray(req.tokens)[trace_len:]]
        act = _Active(req, None, self.eos_id, forced=forced,
                      base=(c0, r0, trace_len - 1, 0))
        self.slots[i] = act
        req.status = "active"
        self._last_tok[i] = meta["last_tok"]
        self._spec_backoff[i] = 0
        self._spec_cooldown[i] = 0
        if self._drafter is not None:
            self._drafter.seed(
                i, [int(t) for t in np.asarray(req.tokens)[:trace_len]]
            )
        if self._ever_used[i]:
            self.stats.slot_reuses += 1
        self._ever_used[i] = True
        self.stats.admitted += 1
        self.stats.session_hits += 1
        self.stats.session_restored_pages += meta["n_pages"] - meta["n_shared"]
        self._check_invariants()

    def _fault_resume(self, n: int) -> None:
        """Fabricate up to ``n`` returning sessions from the oldest parked
        traces (fault injection: deterministic continuations — a short
        fixed suffix, a dedicated rid range far above real traffic).
        Entries whose continuation would not pass admission bounds are
        skipped."""
        if self._sessions is None:
            return
        for trace in self._sessions.traces(n):
            toks = np.concatenate([trace, np.zeros((3,), np.int64)])
            req = Request(rid=self._fault_rid, max_new=2, tokens=toks)
            self._fault_rid += 1
            try:
                self.submit(req)
            except ValueError:
                continue

    # -- preemption: compressed swap-out / swap-in ---------------------------
    def _swap_out_one(self, head: Request) -> bool:
        """Evacuate ONE victim slot to make room for ``head``.

        Victims must be STRICTLY lower class (raw ``priority`` — aging
        promotes a head's admission ORDER, not its preemption rights, so a
        requeued victim can never bounce straight back into its preemptor);
        among them, pick the lowest class with the most remaining work (the
        one that would hold its slot/pages longest), lowest slot index on
        ties. The victim's owned bytes land in the host SwapStore, its
        shared-prefix pages are released by reference, and it requeues at
        its original submit order — resumed outputs are bit-identical to an
        uninterrupted run (cache bytes are placement-independent; counters
        and positions derive from prompt + generated length)."""
        if self._swap is None:
            return False
        cand = [i for i in range(self.n_slots)
                if self.slots[i] is not None
                and self.slots[i].req.priority > head.priority]
        if not cand:
            return False
        i = max(cand, key=lambda j: (self.slots[j].req.priority,
                                     self.slots[j].remaining, -j))
        act = self.slots[i]
        req = act.req
        c, r = self._counters(act)
        n_pages = n_shared = 0
        if self.engine.ecfg.paged:
            n_pages = -(-c // self.engine.ecfg.page_size)
            n_shared = len(self._slot_shared.get(i, ()))
        shared = tuple(self._slot_shared.get(i, ()))
        self.cache, mini = self.engine.evacuate(self.cache, i, n_pages,
                                                n_shared)
        # the counter re-anchor + forced queue make the swap meta exact for
        # ANY row — including a session resume preempted mid-ingestion
        self._swap.put(req.rid, mini, dict(
            out=list(act.out), last_tok=int(self._last_tok[i]),
            n_pages=n_pages, n_shared=n_shared, shared=shared,
            forced=list(act.forced),
            base=(c, r, act.cached_tokens, len(act.out)),
        ))
        req.n_preempts += 1
        self._requeue(req)
        # the evacuation already returned the row's pages in-graph
        self._release_slot(i, free_pages=False)
        self.stats.preemptions += 1
        self.stats.swapped_pages += n_pages - n_shared
        return True

    def _resume(self, req: Request, i: int) -> None:
        """Re-admit a swapped-out request into slot ``i``: stream its pages
        back (shared prefix re-mapped by reference), rebuild the host-side
        generation state, and continue decoding from its saved seed token.
        NO forward pass runs — the seed was never cached, exactly as if the
        preemption never happened."""
        mini, meta = self._swap.pop(req.rid)
        if self.engine.ecfg.paged:
            self._reserved[i] = self._pages_needed(req) - meta["n_shared"]
            self.stats.pages_reserved_peak = max(
                self.stats.pages_reserved_peak, sum(self._reserved.values())
            )
        self.cache = self.engine.restore(
            self.cache, i, mini, meta["shared"],
            n_pages=meta["n_pages"], n_shared=meta["n_shared"],
        )
        if meta["shared"]:
            self._slot_shared[i] = tuple(meta["shared"])
        act = _Active(req, None, self.eos_id, forced=meta["forced"],
                      base=meta["base"])
        act.out = list(meta["out"])
        self.slots[i] = act
        req.status = "active"
        self._last_tok[i] = meta["last_tok"]
        self._spec_backoff[i] = 0
        self._spec_cooldown[i] = 0
        self._ever_used[i] = True
        if self._drafter is not None:
            # the drafter mirrors the CACHED sequence + pending seed: for a
            # row preempted mid-suffix-ingestion that is a prompt prefix,
            # not the whole prompt (the rest drains through ``forced``)
            n_seen = act.cached_tokens + 1 - len(act.out)
            self._drafter.seed(
                i, [int(t) for t in np.asarray(req.tokens)[:n_seen]]
                + list(act.out)
            )
        self.stats.restored_pages += meta["n_pages"] - meta["n_shared"]
        self._check_invariants()

    def _seat(self, head: Request) -> int | None:
        """A free slot for ``head`` — swapping lower-class victims out one
        at a time when preemption is on and the table is full."""
        while True:
            try:
                return self.slots.index(None)
            except ValueError:
                if not self._swap_out_one(head):
                    return None

    def _fit_pages(self, head: Request, need_new: int,
                   protected: set[int]) -> bool:
        """Make ``need_new`` pages reservable: evict cold index prefixes
        first (cheap — recomputable), then swap out lower-class victims."""
        while not self._evict_to_fit(need_new, protected):
            if not self._swap_out_one(head):
                # page-count admission: keep class/FIFO order, wait for a
                # retirement
                self.stats.admission_blocks += 1
                return False
        return True

    def _admit(self) -> list[Request]:
        """Monolithic admission sweep (``prefill_chunk_pages == 0``): seat
        queue heads — swapped-out requests resume, fresh ones prefill in one
        fused dispatch — until the queue drains or admission blocks."""
        finished: list[Request] = []
        paged = self.engine.ecfg.paged
        while True:
            head = self._head()
            if head is None:
                break
            i = self._seat(head)
            if i is None:
                break
            if self._swap is not None and head.rid in self._swap:
                meta = self._swap.meta(head.rid)
                if paged and not self._fit_pages(
                        head, self._pages_needed(head) - meta["n_shared"],
                        set(meta["shared"])):
                    break
                self._resume(self._pop_head(head), i)
                continue
            hit = self._session_try(head, i)
            if hit == "hit":
                continue
            if hit == "blocked":
                break
            match_pages: list[int] = []
            match_perms = None
            if self._index is not None and self.cache is not None:
                match_pages, match_perms = self._match(head)
            if paged:
                # suffix-only reservation: shared prefix pages reserve 0 —
                # the slot can only ever NEWLY pop pages past the match
                need_new = self._pages_needed(head) - len(match_pages)
                if not self._fit_pages(head, need_new, set(match_pages)):
                    break
            req = self._pop_head(head)
            if self._sessions is not None:
                self.stats.session_lookups += 1  # cold admission == miss
            if self.cache is None:
                self.cache = self.engine.alloc_slot_cache()
            if paged:
                self._reserved[i] = self._pages_needed(req) - len(match_pages)
                self.stats.pages_reserved_peak = max(
                    self.stats.pages_reserved_peak, sum(self._reserved.values())
                )
            if self._index is not None:
                self.stats.prefix_lookups += 1
                if match_pages:
                    self.stats.prefix_hits += 1
                    self.stats.prefix_pages_shared += len(match_pages)
                logits, self.cache = self.engine.insert_request_prefix(
                    self.cache, i, req.tokens, match_pages, match_perms
                )
                self._slot_shared[i] = tuple(int(p) for p in match_pages)
                self._register(req, i)
            else:
                logits, self.cache = self.engine.insert_request(
                    self.cache, i, req.tokens
                )
            self._activate(req, i, int(jnp.argmax(logits)))
            self._check_invariants()
            if self.slots[i].done:  # max_new == 1 or instant EOS
                finished.append(self._retire_slot(i))
        return finished

    def _activate(self, req: Request, i: int, tok: int) -> None:
        """Occupy slot ``i`` with ``req`` whose first token is ``tok``."""
        req.status = "active"
        self.slots[i] = _Active(req, tok, self.eos_id)
        self._last_tok[i] = tok
        self._spec_backoff[i] = 0
        self._spec_cooldown[i] = 0
        if self._drafter is not None:
            # the drafter sees prompt + every generated token (the first
            # token included: it is the next launch's seed)
            self._drafter.seed(i, list(np.asarray(req.tokens)) + [tok])
        now = time.perf_counter()
        req.t_first = now
        req.token_times.append(now)
        self.stats.admitted += 1
        self.stats.tokens_out += 1
        if self._ever_used[i]:
            self.stats.slot_reuses += 1
        self._ever_used[i] = True

    # -- chunked interleaved admission --------------------------------------
    def _start_task(self, finished: list[Request]) -> _PrefillTask | None:
        """Claim a slot (and pages) for the FIFO head and build its chunked
        admission task; None while blocked (no free slot / no pages).

        A plain prompt no longer than one chunk budget is admitted here
        directly through the fused monolithic prefill+insert launch: the
        bounded stall is the whole prefill either way, and one dispatch
        beats chunk_step + chunk_insert. Such admissions complete within
        this call (appending to ``finished`` on instant retirement) and
        return None with no task outstanding. Swapped-out requests resume
        here too — a restore is one scatter, not a prefill, so it also
        completes within the call."""
        head = self._head()
        if head is None:
            return None
        slot = self._seat(head)
        if slot is None:
            return None
        if self._swap is not None and head.rid in self._swap:
            meta = self._swap.meta(head.rid)
            if self.engine.ecfg.paged and not self._fit_pages(
                    head, self._pages_needed(head) - meta["n_shared"],
                    set(meta["shared"])):
                return None
            self._resume(self._pop_head(head), slot)
            return None
        if self._session_try(head, slot) != "miss":
            # hit: resumed (a restore is one scatter, not a prefill);
            # blocked: the parked entry waits for pages — either way no task
            return None
        match_pages: list[int] = []
        match_perms = None
        if self._index is not None and self.cache is not None:
            match_pages, match_perms = self._match(head)
        if self.engine.ecfg.paged:
            need_new = self._pages_needed(head) - len(match_pages)
            if not self._fit_pages(head, need_new, set(match_pages)):
                return None
        req = self._pop_head(head)
        if self._sessions is not None:
            self.stats.session_lookups += 1  # cold admission == miss
        if self.cache is None:
            self.cache = self.engine.alloc_slot_cache()
        if self.engine.ecfg.paged:
            self._reserved[slot] = self._pages_needed(req) - len(match_pages)
            self.stats.pages_reserved_peak = max(
                self.stats.pages_reserved_peak, sum(self._reserved.values())
            )
        S = len(req.tokens)
        if self._index is not None:
            self.stats.prefix_lookups += 1
            if match_pages:
                self.stats.prefix_hits += 1
                self.stats.prefix_pages_shared += len(match_pages)
            scratch = self.engine.prefix_chunk_start(
                self.cache, S, match_pages, match_perms
            )
            bounds = self.engine.prefix_chunk_bounds(S, len(match_pages))
            kind = "prefix"
        else:
            c = self.engine.chunk_tokens()
            if S <= c:  # single-chunk prompt: fused fast path
                logits, self.cache = self.engine.insert_request(
                    self.cache, slot, req.tokens
                )
                self._activate(req, slot, int(jnp.argmax(logits)))
                self._check_invariants()
                if self.slots[slot].done:  # max_new == 1 or instant EOS
                    finished.append(self._retire_slot(slot))
                return None
            scratch = self.engine.chunk_init(S)
            bounds = sorted(set(range(0, S, c)) | {S})
            kind = "plain"
        return _PrefillTask(req, slot, kind, scratch, bounds,
                            tuple(int(p) for p in match_pages), match_perms)

    def _advance_task(self, finished: list[Request]) -> None:
        """One scheduler step's worth of admission progress: at most
        ``prefill_chunk_pages`` pages of prefill, inserting + activating
        the row when the last segment completes."""
        if self._task is None:
            self._task = self._start_task(finished)
        t = self._task
        if t is None:
            return
        # plain segments already span the full chunk budget; prefix
        # segments are single pages (PR-5 trace), so batch them up to it
        budget = 1 if t.kind == "plain" \
            else max(1, self.engine.ecfg.prefill_chunk_pages)
        for _ in range(budget):
            if t.done:
                break
            s0, s1 = t.bounds[t.idx], t.bounds[t.idx + 1]
            seg = t.req.tokens[s0:s1]
            if t.kind == "plain":
                if t.idx == len(t.bounds) - 2:  # last segment: fused insert
                    t.logits, self.cache = self.engine.chunk_final(
                        self.cache, t.slot, t.scratch, seg, s0
                    )
                    t.scratch = None
                else:
                    t.logits, t.scratch = self.engine.chunk_step(
                        t.scratch, seg, s0
                    )
            else:
                t.logits, t.scratch = self.engine.prefix_chunk_step(
                    t.scratch, seg, s0
                )
            t.idx += 1
            self.stats.prefill_chunks += 1
        if t.done:
            self._finish_task(t, finished)
            self._task = None

    def _finish_task(self, t: _PrefillTask, finished: list[Request]) -> None:
        i = t.slot
        if t.kind == "prefix":
            self.cache = self.engine.prefix_chunk_finish(
                self.cache, i, t.scratch, t.match_pages, len(t.req.tokens)
            )
            self._slot_shared[i] = t.match_pages
            self._register(t.req, i)
        # plain rows were already scattered by the fused final chunk
        self._activate(t.req, i, int(jnp.argmax(t.logits)))
        self._check_invariants()
        if self.slots[i].done:  # max_new == 1 or instant EOS
            finished.append(self._retire_slot(i))

    def _chunk_plan(self) -> tuple[int, int | None]:
        """(n_steps, n_bucket) for the next decode launch.

        n_steps = min(decode_chunk, min over occupied rows of remaining
        budget) — no row can overshoot its ``max_new`` inside a chunk, so
        retirement stays exact. n_bucket upper-bounds every row's n_comp
        through the WHOLE chunk via the host-side token counts (n_comp <=
        cached tokens <= cached_tokens_now + n_steps)."""
        occupied = [a for a in self.slots if a is not None]
        n_steps = max(1, min(self.engine.ecfg.decode_chunk,
                             min(a.remaining for a in occupied)))
        if any(a.forced for a in occupied):
            # teacher-forced suffix ingestion overrides the launch argmax
            # from the HOST — a multi-step in-graph chunk would feed the
            # model its own (wrong) token, so ingesting steps go one at a
            # time (the other rows still decode usefully in the launch)
            n_steps = 1
        n_max = max(a.cached_tokens for a in occupied) + n_steps
        return n_steps, self.engine.bucket_for(n_max)

    def _log_launch(self, n_steps: int, n_bucket: int | None):
        if not self.engine.ecfg.log_launches:
            return
        self.stats.launches.append((
            n_steps,
            self.engine.ecfg.capacity if n_bucket is None else n_bucket,
            [a.cached_tokens for a in self.slots if a is not None],
        ))

    # -- cancellation / deadlines / faults -----------------------------------
    def _finish_dead(self, req: Request, why: str,
                     finished: list[Request]) -> None:
        """Retire a request that never (re-)reached a slot: queued, swapped
        out, or mid-prefill-chunk. Output is whatever was generated before
        it was swapped out (empty otherwise)."""
        out: list[int] = []
        if self._swap is not None and req.rid in self._swap:
            out = self._swap.meta(req.rid)["out"]
            self._swap.drop(req.rid)
        req.output = np.asarray(out, np.int32)
        req.status = why
        self.done[req.rid] = req
        if why == "cancelled":
            self.stats.cancelled += 1
        else:
            self.stats.expired += 1
        finished.append(req)

    def _abort_task(self) -> None:
        """Drop the in-flight chunked admission at its current chunk
        boundary. Mid-task state is leak-free by construction: plain tasks
        hold no device pages before their fused final chunk, and prefix
        tasks take shared-page references only at ``prefix_chunk_finish`` —
        the only thing to hand back is the host-side reservation."""
        t, self._task = self._task, None
        self._reserved.pop(t.slot, None)
        self._check_invariants()

    def _reap(self, finished: list[Request]) -> None:
        """Honor ``cancel()`` and ``deadline_ms`` at the top of the step —
        before any new work launches — for queued, swapped-out,
        mid-prefill-chunk and decoding requests alike. All three ends meet
        the same retirement path (``_retire_slot`` / ``_finish_dead``)."""
        now = time.perf_counter()

        def dead(req: Request) -> str | None:
            if req.cancelled:
                return "cancelled"
            if req.deadline_ms is not None and \
                    (now - req.t_submit) * 1e3 > req.deadline_ms:
                return "expired"
            return None

        for q in self.queues.values():
            for req in [r for r in q if dead(r)]:
                q.remove(req)
                self._finish_dead(req, dead(req), finished)
        if self._task is not None and dead(self._task.req):
            req = self._task.req
            self._abort_task()
            self._finish_dead(req, dead(req), finished)
        for i in range(self.n_slots):
            act = self.slots[i]
            if act is not None:
                why = dead(act.req)
                if why is not None:
                    finished.append(self._retire_slot(i, why))

    def _fault_victims(self, n: int) -> list[Request]:
        """Deterministic victim order for cancel/deadline storms: occupied
        slots ascending, then queued requests in submit order, then the
        in-flight prefill task."""
        out: list[Request] = []
        for i in range(self.n_slots):
            if len(out) >= n:
                return out
            if self.slots[i] is not None:
                out.append(self.slots[i].req)
        for req in sorted((r for q in self.queues.values() for r in q),
                          key=lambda r: r._seq):
            if len(out) >= n:
                return out
            out.append(req)
        if len(out) < n and self._task is not None:
            out.append(self._task.req)
        return out

    def _apply_faults(self, finished: list[Request]) -> None:
        """Fire this step's scheduled faults (see ``distributed.fault.
        FaultPlan`` for kind semantics). Faults act through the same seams
        real traffic does — cancel flags, deadline rewrites, requeues,
        forced end-of-turn parks — so every invariant the scheduler
        maintains must survive them."""
        if self._faults is None:
            return
        for ev in self._faults.at(self._step_no):
            self._faults.fired.append(ev)
            if ev.kind == "pool_squeeze":
                self._squeeze = max(0, int(ev.arg))
            elif ev.kind in ("cancel", "deadline"):
                for req in self._fault_victims(max(1, int(ev.arg))):
                    if ev.kind == "cancel":
                        req.cancel()
                    else:
                        req.deadline_ms = 1e-9  # expired at the next reap
            elif ev.kind == "chunk_abort":
                if self._task is not None:
                    req = self._task.req
                    self._abort_task()
                    self._requeue(req)  # prefill restarts from scratch
            elif ev.kind == "straggler":
                self._observe_launch(float(ev.arg))
            elif ev.kind == "park":
                # voluntary end-of-turn mid-generation: the user stopped
                # typing — retire with the partial output, park the cache
                n = max(1, int(ev.arg))
                for i, act in enumerate(self.slots):
                    if n == 0:
                        break
                    if act is not None:
                        finished.append(self._retire_slot(i, "parked"))
                        n -= 1
            elif ev.kind == "resume":
                self._fault_resume(max(1, int(ev.arg)))
            elif ev.kind == "session_expire":
                if self._sessions is not None:
                    self._sessions.expire_now(max(1, int(ev.arg)))

    def _observe_launch(self, dt: float) -> None:
        """Feed one decode-launch wall time to the straggler watchdog; a
        sustained-straggler verdict permanently degrades this server to
        plain decode (speculation off — graceful, exactness-neutral)."""
        if self._watchdog is None or self._spec_degraded:
            return
        if self._watchdog.observe(dt) == "exclude":
            self._spec_degraded = True

    def step(self) -> list[Request]:
        """Reap cancellations/deadlines, fire scheduled faults, then one
        bounded prefill chunk (or a monolithic admission sweep when
        ``prefill_chunk_pages == 0``) + one decode launch + retire. Returns
        requests finished now.

        One launch is a donated multi-step chunk (``decode_chunk`` > 1) or a
        single decode step; both mask attention to each row's own length and
        give per-request outputs bit-identical to B=1 ``Engine.generate``.
        """
        t0 = time.perf_counter()
        self._step_no += 1
        finished: list[Request] = []
        self._apply_faults(finished)
        self._reap(finished)
        if self.engine.ecfg.prefill_chunk_pages > 0:
            self._advance_task(finished)
        else:
            finished.extend(self._admit())
        if self.n_occupied:
            t_dec = time.perf_counter()
            if self.engine.ecfg.spec_decode and not self._spec_degraded:
                self._decode_spec(finished)
            else:
                if self.engine.ecfg.spec_decode:
                    self.stats.degraded_steps += 1
                self._decode_plain(finished)
            self._observe_launch(time.perf_counter() - t_dec)
        if self._sessions is not None:  # mirror store-side eviction counts
            self.stats.session_evictions = (
                self._sessions.evictions + self._sessions.expired
            )
        self.stats.wall_s += time.perf_counter() - t0
        return finished

    def _decode_plain(self, finished: list[Request]) -> None:
        """The non-speculative launch: donated multi-step chunk or a
        single bucketed decode step."""
        n_steps, n_bucket = self._chunk_plan()
        if self.engine.ecfg.decode_chunk > 1 and \
                self.engine._decode_multi is not None:
            self._decode_chunk(n_steps, n_bucket, finished)
        else:
            self._decode_single(n_bucket, finished)

    def _decode_single(self, n_bucket: int | None, finished: list[Request]):
        """PR-2 style per-token launch (decode_chunk=1), optionally bucketed."""
        tok = jnp.asarray(self._last_tok[:, None])
        logits, self.cache = self.engine.decode(self.cache, tok, n_bucket)
        nxt = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        now = time.perf_counter()
        self.stats.decode_steps += 1
        self.stats.chunk_launches += 1
        self._log_launch(1, n_bucket)
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            self.stats.occupied_slot_steps += 1
            if act.forced:
                # teacher-forced suffix ingestion (session resume): the
                # launch cached the previous token; the argmax is
                # overridden by the next already-known prompt token —
                # nothing is emitted, no EOS/max_new bookkeeping applies
                t = act.forced.pop(0)
                self._last_tok[i] = t
                if self._drafter is not None:
                    self._drafter.extend(i, (t,))
                continue
            t = int(nxt[i])
            act.out.append(t)
            if act.req.t_first is None:  # first real token of a session hit
                act.req.t_first = now
            act.req.token_times.append(now)
            self._last_tok[i] = t
            self.stats.tokens_out += 1
            if self._drafter is not None:
                self._drafter.extend(i, (t,))
            if (self.eos_id is not None and t == self.eos_id) or \
                    len(act.out) >= act.req.max_new:
                finished.append(self._retire_slot(i))
        if self.n_occupied < self.n_slots:
            # free rows received a junk append this step; re-zero their
            # counters so free slots stay inert (never flush, never grow)
            active = jnp.asarray([s is not None for s in self.slots], bool)
            self.cache = self.engine.mask_free(self.cache, active)

    def _decode_chunk(self, n_steps: int, n_bucket: int | None,
                      finished: list[Request]):
        """Donated multi-step launch: up to ``n_steps`` tokens per row.

        Rows that emit EOS mid-chunk keep decoding (their later tokens are
        junk, discarded here — rows are independent, so other rows are
        unaffected); the in-graph loop early-exits once ALL rows hit EOS.
        """
        active = [a is not None for a in self.slots]
        toks, n_exec, self.cache = self.engine.decode_chunk(
            self.cache, jnp.asarray(self._last_tok[:, None]), active,
            n_steps, self.eos_id, n_bucket,
        )
        now = time.perf_counter()
        self.stats.chunk_launches += 1
        self.stats.decode_steps += n_exec
        self.stats.occupied_slot_steps += n_exec * self.n_occupied
        self._log_launch(n_exec, n_bucket)
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            if act.forced:
                # teacher-forced suffix ingestion (session resume): the
                # chunk plan pins n_steps to 1 while any row has forced
                # tokens pending, so exactly one append landed — override
                # the argmax with the already-known prompt token
                if n_exec:
                    t = act.forced.pop(0)
                    self._last_tok[i] = t
                    if self._drafter is not None:
                        self._drafter.extend(i, (t,))
                continue
            emitted = []
            for s in range(n_exec):
                t = int(toks[s, i])
                emitted.append(t)
                act.out.append(t)
                if act.req.t_first is None:  # first real token of a hit
                    act.req.t_first = now
                act.req.token_times.append(now)
                self._last_tok[i] = t
                self.stats.tokens_out += 1
                if (self.eos_id is not None and t == self.eos_id) or \
                        len(act.out) >= act.req.max_new:
                    act.done = True
                    break  # tokens past EOS are junk
            if self._drafter is not None:
                self._drafter.extend(i, emitted)
            if act.done:
                finished.append(self._retire_slot(i))
        # no trailing mask_free here: decode_steps re-zeroes free-row
        # counters in-graph every iteration, and _retire resets the rows
        # freed just now, so the cache already satisfies the invariant

    # -- speculative decode --------------------------------------------------
    def _counters(self, act: _Active) -> tuple[int, int]:
        """Host-mirrored (n_comp, n_resid) for an occupied slot — exact,
        zero device syncs. The device counters are a deterministic function
        of prompt length and cached-token count: prefill flushes every full
        block (``n_comp = Lb``), then each cached decode token appends one
        residual slot with a block flush whenever the residual hits R at
        append start (paged rows stop flushing once the compressed region
        is at capacity, exactly ``core.cache.append_token``'s guard).

        Re-anchored rows (session resume, or a preemption of one) start
        from the anchor's exact ``(n_comp, n_resid)`` snapshot and apply
        the same append recurrence to the tokens cached since — the
        closed-form flush count is anchor-independent."""
        pack = self.engine.pack_cfg
        if act.base is not None:
            lb, r0, cached0, _ = act.base
            r = r0 + (act.cached_tokens - cached0)
        else:
            S = len(act.req.tokens)
            lb = (S // pack.block) * pack.block
            r = S - lb + len(act.out) - 1  # residual had no flush ever fired
        f = 0
        if r > pack.residual:  # flushes fire as soon as r crosses R
            f = -(-(r - pack.residual) // pack.block)
        if self.engine.ecfg.paged:
            f = min(f, (self.engine.ecfg.capacity - lb) // pack.block)
        return lb + f * pack.block, r - f * pack.block

    def _plan_spec(self) -> tuple[np.ndarray, np.ndarray] | None:
        """Per-row draft plan for one verify launch.

        The window width is FIXED at ``spec_k + 1`` (one compiled program
        per launch bucket); ragged per-row draft lengths ride through the
        ``lens`` mask, junk-padded. Each row's draft is capped by (a) its
        post-seed residual headroom — the verify window must never cross a
        compression flush or page pop (``core.cache.append_window``) — and
        (b) ``remaining - 1``, so accepted-prefix emission can never
        overshoot ``max_new``. Rows in acceptance backoff (every draft of
        their recent launches died) sit the launch out and their cooldown
        ticks down. Returns None when no active row has a proposal: a
        verify window would then be pure overhead, and the caller falls
        back to the plain decode launch (this fallback plus the backoff is
        what keeps the acceptance≈0 regime at baseline speed)."""
        ecfg = self.engine.ecfg
        pack = self.engine.pack_cfg
        w = ecfg.spec_k + 1
        toks = np.zeros((self.n_slots, w), np.int32)
        lens = np.ones((self.n_slots,), np.int32)
        any_draft = False
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            toks[i, 0] = self._last_tok[i]
            if act.forced:
                continue  # suffix ingestion: seed-only, next token is known
            if self._spec_cooldown[i] > 0:
                self._spec_cooldown[i] -= 1
                continue
            c, r = self._counters(act)
            # simulate the seed append: the headroom cap is on POST-seed
            # n_resid (drafts sit at n_resid + i - 1, i <= lens - 1 <= R)
            if r >= pack.residual and (
                    not ecfg.paged or c + pack.block <= ecfg.capacity):
                r -= pack.block
            r += 1
            kb = min(ecfg.spec_k, pack.residual - r, act.remaining - 1)
            if kb <= 0:
                continue
            d = self._drafter.draft(i, kb)
            if not d:
                continue
            toks[i, 1:1 + len(d)] = d
            lens[i] = 1 + len(d)
            any_draft = True
        return (toks, lens) if any_draft else None

    def _decode_spec(self, finished: list[Request]) -> None:
        """Speculative launch: per-slot n-gram drafts verified by ONE
        batched q_len=w forward over the compressed paged cache; the
        accepted prefix commits by counter advance, rejected drafts die as
        dead bytes past ``n_resid``. Acceptance rule: draft i is accepted
        iff it equals the greedy argmax after window position i-1 — so
        every emitted token equals what stepwise decode would have emitted
        (for ANY draft content), and per-request outputs stay
        bit-identical to the plain path. Speculation only changes how many
        tokens one model pass yields."""
        plan = self._plan_spec()
        if plan is None:
            self._decode_plain(finished)
            return
        toks, lens = plan
        w = toks.shape[1]
        # TIGHT compressed-region bound: the headroom cap guarantees the
        # window never flushes after the seed, so post-seed ``n_comp`` is
        # known exactly on the host — the verify bucket only has to cover
        # it (the plain chunk path can flush mid-chunk, so it must bound by
        # total tokens; this tighter bound is speculation-only and is a
        # real fraction of the verify win at long residuals)
        n_comp_max = 1
        for a in self.slots:
            if a is None:
                continue
            c, r = self._counters(a)
            if r >= self.engine.pack_cfg.residual and (
                    not self.engine.ecfg.paged or
                    c + self.engine.pack_cfg.block <= self.engine.ecfg.capacity):
                c += self.engine.pack_cfg.block  # the seed append flushes
            n_comp_max = max(n_comp_max, c)
        n_bucket = self.engine.bucket_for(n_comp_max)
        active = [s is not None for s in self.slots]
        # one dispatch: verify + accept + commit + free-row masking (the
        # commit lands in-graph BEFORE the retire resets below, so a
        # retiring row's reset is never resurrected by a late commit)
        hat, n_accept, self.cache = self.engine.decode_verify(
            self.cache, toks, lens, active, n_bucket
        )
        now = time.perf_counter()
        self.stats.decode_steps += 1
        self.stats.chunk_launches += 1
        self.stats.spec_launches += 1
        self._log_launch(1, n_bucket)
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            self.stats.occupied_slot_steps += 1
            if act.forced:
                # teacher-forced suffix ingestion: the row rode the verify
                # launch seed-only (lens == 1, its seed append committed);
                # the model's next token is overridden by the known one
                t = act.forced.pop(0)
                self._last_tok[i] = t
                self._drafter.extend(i, (t,))
                continue
            m = int(n_accept[i])  # accepted drafts (in-graph rule)
            kb = int(lens[i]) - 1
            self.stats.spec_drafted += kb
            self.stats.spec_accepted += m
            if kb > 0 and self.engine.ecfg.spec_backoff > 0:
                if m == 0:
                    # every draft died: exponential cooldown before this
                    # slot may draft again (capped at ecfg.spec_backoff)
                    self._spec_backoff[i] = min(
                        max(1, self._spec_backoff[i] * 2),
                        self.engine.ecfg.spec_backoff,
                    )
                    self._spec_cooldown[i] = self._spec_backoff[i]
                else:
                    self._spec_backoff[i] = 0
            # emit the m accepted tokens plus the model's own next token
            # (the correction when m < kb, the bonus token when m == kb)
            emitted = []
            for j in range(m + 1):
                t = int(hat[i, j])
                emitted.append(t)
                act.out.append(t)
                if act.req.t_first is None:  # first real token of a hit
                    act.req.t_first = now
                act.req.token_times.append(now)
                self._last_tok[i] = t
                self.stats.tokens_out += 1
                if (self.eos_id is not None and t == self.eos_id) or \
                        len(act.out) >= act.req.max_new:
                    act.done = True
                    break  # tokens past EOS are junk
            self._drafter.extend(i, emitted)
        for i, act in enumerate(self.slots):
            if act is not None and act.done:
                finished.append(self._retire_slot(i))

    def run(self) -> list[Request]:
        """Drain the queue and all slots; returns every finished request."""
        finished: list[Request] = []
        while self.queue or self.n_occupied or self._task is not None:
            finished.extend(self.step())
        return finished
