"""Serving engine: calibration, jitted prefill/decode, wave-batched requests.

Build sequence (mirrors a production bring-up):
  1. CALIBRATE — run a short prefill with the uncompressed policy, collect
     raw K/V, pick static TierSpecs (core.cache.calibrate_specs). This is
     the paper's per-model configuration sweep (§IV-B) done once at engine
     build, before compilation.
  2. COMPILE — jit prefill + decode with the calibrated PackKVConfig.
  3. SERVE — requests are grouped into waves (batched prefill, batched
     greedy decode to completion). Finished rows keep decoding with their
     output masked — the uniform-length contract the compressed cache's
     shared block structure relies on. Continuous (per-slot) batching
     would need per-row n_comp; recorded as future work in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.cache import PackKVConfig, calibrate_specs
from ..models import get_model

Array = jax.Array


@dataclasses.dataclass
class EngineConfig:
    capacity: int = 4096  # compressed-region token capacity
    max_batch: int = 8
    backend: str = "xla"  # xla | pallas
    calibrate: bool = True
    calib_tokens: int = 192  # multiple of the 64-token block


class Engine:
    def __init__(self, cfg: ArchConfig, params, pack_cfg: PackKVConfig,
                 ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.api = get_model(cfg)
        self.pack_cfg = (
            self._calibrate(pack_cfg) if (
                ecfg.calibrate
                and pack_cfg.policy == "packkv"
                and cfg.family not in ("rwkv6",)
            ) else pack_cfg
        )
        self._prefill = jax.jit(
            partial(self.api.prefill, cfg=cfg, pack_cfg=self.pack_cfg,
                    capacity=ecfg.capacity)
        )
        self._decode = jax.jit(
            partial(self.api.decode_step, cfg=cfg, backend=ecfg.backend)
        )

    # -- calibration --------------------------------------------------------
    def _calibrate(self, pack_cfg: PackKVConfig) -> PackKVConfig:
        S = self.ecfg.calib_tokens
        rng = np.random.default_rng(0)
        B = 1
        batch = {"tokens": jnp.asarray(rng.integers(0, self.cfg.vocab, (B, S)),
                                       jnp.int32)}
        if self.cfg.input_mode == "tokens_patches":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(B, self.cfg.n_patches, self.cfg.d_model)),
                jnp.float32,
            )
        none_cfg = dataclasses.replace(pack_cfg, policy="none")
        cap = max(S + self.cfg.n_patches * (self.cfg.input_mode == "tokens_patches"),
                  pack_cfg.block)
        cap = -(-cap // pack_cfg.block) * pack_cfg.block
        if self.cfg.family == "hybrid_rglru":
            _, state = self.api.prefill(self.params, self.cfg, none_cfg, cap, batch)
            cache = state.cache
            n = min(int(jnp.min(cache.n_comp)), self.cfg.window)
        else:
            _, cache = self.api.prefill(self.params, self.cfg, none_cfg, cap, batch)
            n = int(jnp.min(cache.n_comp))
        n = (n // pack_cfg.block) * pack_cfg.block
        if n == 0:
            return pack_cfg
        rk, rv = cache.raw_k, cache.raw_v  # [L?, B, H, cap, D]
        lead = rk.shape[: rk.ndim - 3]
        D = rk.shape[-1]
        k = rk.reshape(-1, *rk.shape[-3:])[:, :, :n, :]  # [L*B, H, n, D]
        v = rv.reshape(-1, *rv.shape[-3:])[:, :, :n, :]
        return calibrate_specs(k, v, pack_cfg)

    # -- serving ------------------------------------------------------------
    def prefill(self, batch: dict):
        return self._prefill(self.params, batch=batch)

    def decode(self, cache, token: Array):
        return self._decode(self.params, cache=cache, token=token)

    def generate(self, batch: dict, max_new: int, eos_id: int | None = None):
        """Greedy wave decode. Returns tokens [B, max_new] (masked past EOS)."""
        logits, cache = self.prefill(batch)
        B = logits.shape[0]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        done = jnp.zeros((B,), bool)
        outs = []
        for _ in range(max_new):
            outs.append(np.asarray(tok[:, 0]))
            if eos_id is not None:
                done = done | (tok[:, 0] == eos_id)
                if bool(done.all()):
                    break
            logits, cache = self.decode(cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return np.stack(outs, axis=1), cache


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # [S]
    max_new: int
    output: np.ndarray | None = None


class WaveServer:
    """Groups queued requests into fixed-size waves and serves each wave
    with one batched prefill + shared decode loop (left-pad to the wave's
    max prompt length)."""

    def __init__(self, engine: Engine, pad_id: int = 0):
        self.engine = engine
        self.pad_id = pad_id
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run_wave(self) -> list[Request]:
        if not self.queue:
            return []
        B = self.engine.ecfg.max_batch
        wave, self.queue = self.queue[:B], self.queue[B:]
        S = max(len(r.tokens) for r in wave)
        S = -(-S // 64) * 64  # block-align prompts
        toks = np.full((len(wave), S), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.tokens):] = r.tokens  # left-pad
        max_new = max(r.max_new for r in wave)
        out, _ = self.engine.generate({"tokens": jnp.asarray(toks)}, max_new)
        for i, r in enumerate(wave):
            r.output = out[i, : r.max_new]
            self.done[r.rid] = r
        return wave
