"""Small shared utilities."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def pytree_dataclass(cls=None, *, meta_fields: tuple[str, ...] = ()):
    """Frozen dataclass registered as a JAX pytree.

    Fields named in ``meta_fields`` are static (hashable aux data); the rest
    are array children.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=meta_fields
        )
        return c

    return wrap if cls is None else wrap(cls)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: top-level (with ``check_vma``)
    on new jax, ``jax.experimental.shard_map`` (with ``check_rep``) on
    older releases like the 0.4.x baked into the container image."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "dtype")
    )


def bits_required_jnp(rng: jnp.ndarray) -> jnp.ndarray:
    """ceil(log2(r+1)) for non-negative integer ranges; 0 when r == 0."""
    r = rng.astype(jnp.float32)
    return jnp.where(rng > 0, jnp.floor(jnp.log2(jnp.maximum(r, 1.0))) + 1.0, 0.0).astype(
        jnp.int32
    )
