from .optimizer import OptConfig, OptState, adamw_update, init_opt_state, make_schedule  # noqa: F401
from .train import init_training, make_train_step  # noqa: F401
