"""AdamW + LR schedules (cosine, WSD) — no external optimizer dependency.

WSD (warmup-stable-decay) is MiniCPM's schedule (arXiv:2404.06395), wired
to the minicpm-2b config's training preset.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..utils import pytree_dataclass

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"  # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1  # WSD: fraction of steps in the final decay


@pytree_dataclass
class OptState:
    mu: object  # first moment (f32, param-shaped pytree)
    nu: object  # second moment
    step: Array  # i32 []


def make_schedule(cfg: OptConfig) -> Callable[[Array], Array]:
    w, T = cfg.warmup_steps, cfg.total_steps

    def cosine(step):
        warm = step / jnp.maximum(w, 1)
        prog = jnp.clip((step - w) / jnp.maximum(T - w, 1), 0.0, 1.0)
        return cfg.lr * jnp.where(
            step < w, warm, 0.5 * (1 + jnp.cos(jnp.pi * prog))
        )

    def wsd(step):
        decay_start = int(T * (1 - cfg.decay_frac))
        warm = step / jnp.maximum(w, 1)
        dec = 1.0 - jnp.clip(
            (step - decay_start) / jnp.maximum(T - decay_start, 1), 0.0, 1.0
        )
        stable = jnp.where(step < decay_start, 1.0, dec)
        return cfg.lr * jnp.where(step < w, warm, stable)

    def constant(step):
        return jnp.asarray(cfg.lr)

    return {"cosine": cosine, "wsd": wsd, "constant": constant}[cfg.schedule]


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return OptState(mu=zeros, nu=jax.tree_util.tree_map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(grads, state: OptState, params, cfg: OptConfig):
    """One AdamW step with global-norm clipping; returns (params, state, gnorm)."""
    sched = make_schedule(cfg)
    step = state.step + 1
    lr = sched(step)
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(mu=new_m, nu=new_v, step=step), gnorm
