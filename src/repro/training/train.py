"""Train-step builder: loss + grad, microbatch accumulation, AdamW.

``make_train_step(api, cfg, opt_cfg, grad_accum)`` returns a pure function
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
suitable for jit/pjit. Batches carry the GLOBAL batch dim; gradient
accumulation splits it into ``grad_accum`` sequential microbatches via
lax.scan (activation memory / grad_accum, same math).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.registry import ModelApi
from .optimizer import OptConfig, OptState, adamw_update, init_opt_state

Array = jax.Array


def _split_micro(batch: dict, accum: int) -> dict:
    def sp(x):
        B = x.shape[0]
        assert B % accum == 0, f"batch {B} % accum {accum}"
        return x.reshape(accum, B // accum, *x.shape[1:])

    return {k: sp(v) for k, v in batch.items()}


def make_train_step(api: ModelApi, cfg: ArchConfig, opt_cfg: OptConfig,
                    grad_accum: int = 1, param_pspecs=None,
                    accum_pspecs=None):
    """param_pspecs: optional PartitionSpec tree matching params. When given,
    gradients are explicitly pinned to the param sharding — GSPMD does not
    reliably propagate param sharding into the scan-backward accumulator
    carries, which otherwise materialize FULL f32 stacked-layer gradients
    per device (EXPERIMENTS.md §Perf M4).

    accum_pspecs: sharding for the f32 microbatch gradient accumulator
    (typically the ZeRO-1 moment specs: param specs + 'data' on a free dim)
    so accumulation at grad_accum>1 costs params/|mesh| instead of
    params/|model| bytes (§Perf M6)."""

    def loss_fn(params, micro):
        return api.loss_fn(params, cfg, micro)

    def _pin(grads, pspecs):
        if pspecs is None:
            return grads
        from ..distributed.sharding import _ACTIVE_MESH  # set by launcher

        if _ACTIVE_MESH is None:
            return grads
        from jax.sharding import NamedSharding

        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(_ACTIVE_MESH, s)
            ),
            grads, pspecs,
        )

    def train_step(params, opt_state: OptState, batch: dict):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin(grads, param_pspecs)
        else:
            micros = _split_micro(batch, grad_accum)
            acc_specs = accum_pspecs if accum_pspecs is not None else param_pspecs

            def body(acc, micro):
                l_acc, g_acc = acc
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                g = _pin(g, param_pspecs)
                g_acc = _pin(
                    jax.tree_util.tree_map(jnp.add, g_acc, g), acc_specs
                )
                return (l_acc + l, g_acc), None

            zero_g = _pin(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ),
                acc_specs,
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zero_g), micros
            )
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)

        params, opt_state, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def init_training(api: ModelApi, cfg: ArchConfig, key) -> tuple:
    params = api.init(key, cfg)
    return params, init_opt_state(params)
