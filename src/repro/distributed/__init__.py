from .sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    constrain,
    dp_axes,
    opt_state_specs,
    param_specs,
    set_active_mesh,
    spec_with_fallback,
    to_named,
)
from .grad_compress import GradCompressConfig, roundtrip_grads  # noqa: F401
from .fault import CheckpointPolicy, StragglerMonitor, downscale_plan  # noqa: F401
