"""Sharding rule engine: logical dims -> mesh axes, divisibility-aware.

Params, optimizer state, batches and decode caches get PartitionSpecs from
path-based rules. Strategy:

  * batch        -> ('pod', 'data')     (DP; falls back to replicate if B
                                          doesn't divide, e.g. long_500k B=1)
  * heads / d_ff / vocab / experts / lru width -> 'model'  (TP/EP)
  * cache context dim -> 'model'        (context parallelism for decode —
                                          the compressed KV cache itself is
                                          sharded, which the paper never
                                          attempts; softmax crosses shards
                                          via GSPMD-inserted all-reduce)
  * optimizer moments -> additionally ZeRO-1-sharded over 'data' on the
                         first free divisible dim.

Every rule is divisibility-checked against the mesh; a dim that doesn't
divide its axis is replicated (or the axis moves to the next preferred
dim), so ANY (arch × mesh) pair lowers — the fallback is part of the
engine, not ad-hoc per config.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = _axis_size(mesh, axes)
    return n > 1 and dim % n == 0


def spec_with_fallback(shape, want, mesh: Mesh) -> P:
    """want: per-dim desired axes (str | tuple | None). Drops non-dividing."""
    out = []
    used: set[str] = set()
    for dim, ax in zip(shape, want):
        if ax is None:
            out.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(a in used for a in axs) or not _fits(dim, mesh, axs):
            out.append(None)
            continue
        used.update(axs)
        out.append(ax if isinstance(ax, str) else tuple(axs))
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules (matched on the LAST path component name)
# ---------------------------------------------------------------------------

# each entry: list of per-dim preferred axes for the leaf's TRAILING dims;
# leading (stacked-layer) dims are padded with None automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"^embed$", ("model", None)),
    (r"^head$", (None, "model")),
    (r"^(wq|wk|wv|wg|wr|w_in|w_gate_branch)$", (None, "model")),
    (r"^(wo|w_out)$", ("model", None)),
    (r"^(w_gate|w_up)$", (None, "model")),       # dense mlp [D, F]
    (r"^(w_down)$", ("model", None)),            # dense mlp [F, D]
    (r"^cm_wk$", (None, "model")),
    (r"^cm_wv$", ("model", None)),
    (r"^cm_wr$", (None, "model")),
    (r"^(lru_wa|lru_wx)$", (None, "model")),
    (r"^conv_w$", (None, "model")),
    (r"^router$", (None, None)),
    (r"^(wA|wB|mu|u|w0|lru_lambda)$", None),     # replicate small/odd leaves
    (r"(ln|norm)", None),                        # all norms replicated
]

# MoE expert tensors are 3D [E, D, Fe] / [E, Fe, D]: prefer experts axis,
# fall back to the Fe axis if E doesn't divide (qwen2-moe E=60).
_MOE_RULES: list[tuple[str, tuple]] = [
    (r"^(w_gate|w_up)$", ("model", None, ("model",))),
    (r"^w_down$", ("model", ("model",), None)),
]


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return out


def _match_param(names: list[str], ndim: int, mesh: Mesh, shape) -> P:
    leaf = names[-1]
    rules = _PARAM_RULES
    if ndim == 3 and leaf in ("w_gate", "w_up", "w_down") and "mlp" in names:
        # stacked-layer dense mlp [L, D, F] is also 3D; disambiguate by
        # trying expert rules first only when BOTH trailing dims large —
        # expert tensors are [E, D, Fe]; stacked dense are [L, D, F].
        pass  # handled by trailing-dim padding below
    for pat, want in rules:
        if re.search(pat, leaf):
            if want is None:
                return P(*([None] * ndim))
            # try expert-style 3D match for moe leaves
            if len(want) < ndim:
                pad = ndim - len(want)
                full = (None,) * pad + tuple(want)
            else:
                full = tuple(want[-ndim:])
            # MoE: expert tensors are [E, D, Fe] unstacked (ndim 3, not under
            # a stacked 'layers' scan) or [L, E, D, Fe] stacked (ndim 4).
            # Dense stacked mlp is [L, D, F] (ndim 3 UNDER 'layers') — its
            # leading dim is the scan axis and must NOT be sharded, or every
            # scan iteration gathers the full stack.
            is_expert = ndim >= 4 or (ndim == 3 and "layers" not in names)
            if (
                leaf in ("w_gate", "w_up", "w_down")
                and is_expert
                and len(want) == 2
                and _fits(shape[-3], mesh, "model")
                and not any(isinstance(a, str) for a in full[:-2])
            ):
                # expert dim gets 'model'; drop model from trailing dims
                full = (
                    (None,) * (ndim - 3)
                    + ("model",)
                    + tuple(None if a == "model" else a for a in want)
                )
            return spec_with_fallback(shape, full, mesh)
    return P(*([None] * ndim))


def param_specs(params, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params``."""

    def f(path, leaf):
        names = _path_names(path)
        return _match_param(names, leaf.ndim, mesh, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, params)


def opt_state_specs(params, mesh: Mesh):
    """ZeRO-1: moments take the param spec + 'data' on the first free dim."""
    p_specs = param_specs(params, mesh)

    def zero(leaf, spec: P):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        if "data" in mesh.axis_names:
            for i, (dim, ax) in enumerate(zip(leaf.shape, parts)):
                if ax is None and _fits(dim, mesh, "data"):
                    parts[i] = "data"
                    break
        return P(*parts)

    moments = jax.tree_util.tree_map(zero, params, p_specs)
    from ..training.optimizer import OptState

    return OptState(mu=moments, nu=moments, step=P())


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def batch_specs(batch: dict, mesh: Mesh):
    dp = dp_axes(mesh)

    def f(leaf):
        want = [dp] + [None] * (leaf.ndim - 1)
        return spec_with_fallback(leaf.shape, want, mesh)

    return jax.tree_util.tree_map(f, batch)


_CTX_LAST = {"payload", "mins", "shifts", "scale", "zero"}  # context dim last

# cache leaves whose trailing dims carry a KV-head axis (serving lanes)
_HEAD_LEAVES = _CTX_LAST | {"raw_k", "raw_v", "resid_k", "resid_v", "chan_perm"}
# leaves stored pool-major when paged: [lead.., H_kv, pool_pages, ...]
# (no batch dim — the page table maps rows to pages)
_POOL_LEAVES = _CTX_LAST | {"raw_k", "raw_v"}
# the replicated page ledger + per-row counters: the host scheduler's
# single source of truth, identical on every device by construction
_LEDGER = {"n_comp", "n_resid", "pos", "step", "page_table", "free",
           "n_free", "ref"}


def cache_leaf_spec(names: list[str], shape, mesh: Mesh, *, n_lead: int,
                    dp=(), ctx_axis: str | None = None,
                    head_axis: str | None = None, paged: bool = False) -> P:
    """One leaf-path -> PartitionSpec rule shared by training
    (``cache_specs``: batch -> DP, context -> 'model') and serving
    (``serving_cache_specs``: KV-head -> 'kv', ledger replicated).

    names: path component names ending in the leaf field name; n_lead:
    stacked leading dims before batch (layers); dp / ctx_axis / head_axis:
    the axes each role maps to (empty/None = that role stays replicated);
    paged: the cache stores ``_POOL_LEAVES`` pool-major. Every rule is
    divisibility-checked via ``spec_with_fallback``.
    """
    leaf_name = names[-1]
    nd = len(shape)
    want: list = [None] * nd
    if leaf_name in _LEDGER:
        return P(*want)
    # how many leading stacked dims (layers/groups/2-subblocks)?
    lead = min(n_lead + (1 if "rec" in names or "tail" in names else 0), nd - 1)
    if leaf_name in ("tail_lru_h", "tail_conv"):
        lead = 1
    pool = paged and leaf_name in _POOL_LEAVES
    if dp and nd > lead and not pool:
        want[lead] = dp  # batch dim
    if head_axis is not None and leaf_name in _HEAD_LEAVES:
        hd_dim = lead if pool else lead + 1
        if hd_dim < nd:
            want[hd_dim] = head_axis
    if ctx_axis is not None:
        if leaf_name in _CTX_LAST and nd >= lead + 2:
            want[-1] = ctx_axis
        elif leaf_name in ("raw_k", "raw_v") and nd >= lead + 3:
            want[-2] = ctx_axis
        elif leaf_name in ("S",) and nd >= lead + 3:
            want[lead + 1] = ctx_axis  # rwkv heads
        elif leaf_name in ("lru_h",) and nd >= lead + 2:
            want[-1] = ctx_axis  # lru width
        elif leaf_name in ("conv",) and nd >= lead + 3:
            want[-1] = ctx_axis
    return spec_with_fallback(shape, want, mesh)


def cache_specs(cache, mesh: Mesh, n_lead: int = 1):
    """Decode-cache specs (training). n_lead: stacked leading dims before
    batch (layers).

    Rules: batch dim -> DP axes; compressed-context dim -> 'model'
    (context parallelism); residual/raw context stays local; everything
    divisibility-checked.
    """
    dp = dp_axes(mesh)

    def f(path, leaf):
        return cache_leaf_spec(_path_names(path), leaf.shape, mesh,
                               n_lead=n_lead, dp=dp, ctx_axis="model")

    return jax.tree_util.tree_map_with_path(f, cache)


def serving_cache_specs(cache, mesh: Mesh, head_axis: str = "kv"):
    """Serving-engine cache specs: payloads sharded by KV head over
    ``head_axis``, page ledger + per-row counters replicated (see
    kernels/sharded.py and docs/architecture.md). The cache batch dim
    stays replicated — the ``dp`` mesh axis partitions attention WORK by
    row masking, never cache state, so appends are identical everywhere."""
    n_lead = cache.n_comp.ndim - 1  # stacked (layers) dims before batch
    paged = getattr(cache, "pages", None) is not None

    def f(path, leaf):
        return cache_leaf_spec(_path_names(path), leaf.shape, mesh,
                               n_lead=n_lead, head_axis=head_axis,
                               paged=paged)

    return jax.tree_util.tree_map_with_path(f, cache)


def serving_specs(tree, mesh: Mesh, head_axis: str = "kv"):
    """Specs for an arbitrary serving dispatch in/out pytree: every
    ``LayerKVCache`` node gets ``serving_cache_specs``; any other leaf
    (params, logits, tokens, scratch) is replicated."""
    from ..core.cache import LayerKVCache

    def node(x):
        if isinstance(x, LayerKVCache):
            return serving_cache_specs(x, mesh, head_axis)
        return jax.tree_util.tree_map(lambda _: P(), x)

    return jax.tree_util.tree_map(
        node, tree, is_leaf=lambda x: isinstance(x, LayerKVCache))


def to_named(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# in-graph logical constraints (sequence parallelism etc.)
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Optional[Mesh] = None


def set_active_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def constrain(x, *axes):
    """with_sharding_constraint by axis names; no-op without an active mesh.

    Used inside model forwards to pin the residual stream to
    (batch=DP, seq='model') — sequence parallelism that keeps rematted
    activations within HBM at 4k×256 global.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    dp = dp_axes(mesh)
    resolved = [dp if a == "batch" else a for a in axes]
    spec = spec_with_fallback(x.shape, resolved, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
