"""Gradient compression for data-parallel all-reduce — the paper's own
machinery (token-wise quantization + bit-packing) applied to gradients,
with error-feedback residuals. Beyond-paper but paper-native (DESIGN.md §5).

Real compressed DP all-reduce = all-gather(compressed shards) + local
reduce: bytes on the wire are the COMPRESSED bytes. Implemented with
shard_map over the 'data' axis so the collective is explicit; the GSPMD
train path stays uncompressed (default).

Compression here is row-wise (the gradient analogue of token-wise): each
row of a 2D-reshaped gradient gets (scale, zero); integers are range-
reduced exactly like the KV pipeline. ``wire_bits`` reports the analytic
on-wire size so benchmarks can account bandwidth savings.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    bits: int = 4  # integer width on the wire
    row: int = 1024  # quantization row length
    error_feedback: bool = True


def _quant_rows(g: Array, cfg: GradCompressConfig):
    """g: [R, row] -> (q u8/u16, scale [R,1], zero [R,1])."""
    lo = g.min(axis=1, keepdims=True)
    hi = g.max(axis=1, keepdims=True)
    maxq = 2**cfg.bits - 1
    scale = jnp.where(hi > lo, (hi - lo) / maxq, 1.0)
    q = jnp.clip(jnp.round((g - lo) / scale), 0, maxq)
    return q.astype(jnp.uint8), scale, lo


def _dequant_rows(q: Array, scale: Array, zero: Array) -> Array:
    return q.astype(jnp.float32) * scale + zero


def compress_leaf(g: Array, cfg: GradCompressConfig, resid: Array | None):
    """Quantize one gradient leaf (+error feedback). Returns
    (q, scale, zero, new_resid)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % cfg.row
    flat = jnp.pad(flat, (0, pad))
    if resid is not None:
        flat = flat + resid
    rows = flat.reshape(-1, cfg.row)
    q, s, z = _quant_rows(rows, cfg)
    new_resid = None
    if cfg.error_feedback:
        new_resid = (rows - _dequant_rows(q, s, z)).reshape(-1)
    return q, s, z, new_resid


def decompress_leaf(q, s, z, shape) -> Array:
    flat = _dequant_rows(q, s, z).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def roundtrip_grads(grads, cfg: GradCompressConfig, resids):
    """Per-replica compress->decompress (models the wire codec exactly;
    the averaging across replicas is then done on dequantized values, as a
    compressed all-gather+local-reduce would). Returns (grads, resids)."""
    flat, treedef = jax.tree_util.tree_flatten(grads)
    r_flat = jax.tree_util.tree_leaves(resids) if resids is not None else [None] * len(flat)
    out, new_r = [], []
    for g, r in zip(flat, r_flat):
        q, s, z, nr = compress_leaf(g, cfg, r)
        out.append(decompress_leaf(q, s, z, g.shape).astype(g.dtype))
        new_r.append(nr if nr is not None else jnp.zeros(0, jnp.float32))
    return treedef.unflatten(out), treedef.unflatten(new_r)


def init_residuals(params, cfg: GradCompressConfig):
    def f(p):
        n = p.size
        pad = (-n) % cfg.row
        return jnp.zeros(n + pad, jnp.float32)

    return jax.tree_util.tree_map(f, params)


def wire_bits(params, cfg: GradCompressConfig) -> int:
    """Analytic on-wire bits of one compressed gradient exchange."""
    total = 0
    for p in jax.tree_util.tree_leaves(params):
        n = p.size
        rows = -(-n // cfg.row)
        total += n * cfg.bits + rows * 64  # fp32 scale+zero per row
    return total


def compression_ratio(params, cfg: GradCompressConfig) -> float:
    raw = sum(p.size for p in jax.tree_util.tree_leaves(params)) * 32
    return raw / wire_bits(params, cfg)


# ---------------------------------------------------------------------------
# explicit compressed DP all-reduce (shard_map over 'data')
# ---------------------------------------------------------------------------


def compressed_psum_mean(grads, cfg: GradCompressConfig, axis: str = "data"):
    """Inside shard_map: compressed all-gather + local reduce over ``axis``.

    Each replica quantizes its local grads; the all-gather moves ONLY the
    quantized payload + per-row metadata; replicas then dequantize-and-mean
    locally. Error feedback is handled by the caller (roundtrip residual).
    """

    def leaf(g):
        q, s, z, _ = compress_leaf(g, cfg, None)
        qg = jax.lax.all_gather(q, axis)  # [n, R, row] u8 on the wire
        sg = jax.lax.all_gather(s, axis)
        zg = jax.lax.all_gather(z, axis)
        deq = jax.vmap(_dequant_rows)(qg, sg, zg)  # [n, R, row]
        mean = deq.mean(axis=0).reshape(-1)
        m = 1
        for d in g.shape:
            m *= d
        return mean[:m].reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(leaf, grads)
