"""Fault tolerance: checkpoint/restart policy, straggler detection,
elastic rescale bookkeeping.

On a real multi-pod deployment these hooks sit in the launcher loop; in
this CPU container they are exercised by tests that simulate preemption
(train loop killed between steps, restarted from the latest valid
checkpoint — including a corrupted-last-checkpoint case) and stragglers
(injected slow steps).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional


@dataclasses.dataclass
class CheckpointPolicy:
    every_steps: int = 50
    keep: int = 3

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every_steps == 0


class StragglerMonitor:
    """Flags steps slower than ``threshold``× the rolling median.

    At fleet scale the launcher reacts by (a) logging the slow host,
    (b) requesting a data-shard reassignment, and (c) after ``patience``
    consecutive flags, excluding the host (elastic downscale + restore).
    Here the monitor implements the detection + decision logic; tests
    inject synthetic timings.
    """

    def __init__(self, window: int = 32, threshold: float = 3.0, patience: int = 3):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.patience = patience
        self.consecutive = 0
        self.flagged_steps: list[int] = []
        self._step = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> str:
        assert self._t0 is not None
        return self.observe(time.perf_counter() - self._t0)

    def observe(self, dt: float) -> str:
        """Returns 'ok' | 'straggler' | 'exclude'."""
        self._step += 1
        med = sorted(self.times)[len(self.times) // 2] if self.times else dt
        self.times.append(dt)
        if len(self.times) >= 4 and dt > self.threshold * med:
            self.consecutive += 1
            self.flagged_steps.append(self._step)
            if self.consecutive >= self.patience:
                return "exclude"
            return "straggler"
        self.consecutive = 0
        return "ok"


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fires when the serving scheduler's step counter
    reaches ``step`` (see ``serving.engine.SlotServer._apply_faults``)."""

    step: int
    kind: str  # see FaultPlan.KINDS
    arg: float = 0.0


class FaultPlan:
    """Deterministic fault schedule for the serving scheduler.

    A pure-host seam injected into ``SlotServer`` (and the property-test
    stub engine): every fault fires at an exact scheduler step, so a run is
    reproducible down to the launch sequence — the harness that drives the
    scheduler's conservation invariants (free + held pages == pool,
    refcounts, reservations) through hostile schedules.

    Kinds:
      * ``pool_squeeze`` — hold back ``arg`` pool pages from admission
        (``arg = 0`` releases the squeeze). Simulates pool exhaustion /
        an external tenant without touching device state.
      * ``cancel`` — cancel ``arg`` live requests: occupied slots in
        ascending slot order first, then queued requests in submit order,
        then the in-flight prefill task (deterministic victim order).
      * ``deadline`` — force-expire the same selection (their deadline is
        rewritten to the epoch, so the next reap retires them as expired).
      * ``chunk_abort`` — abort the in-flight chunked admission at its
        current chunk boundary and requeue the request (prefill restarts
        from scratch; reservation and scratch must not leak).
      * ``straggler`` — feed a synthetic ``arg``-second launch time to the
        decode-launch watchdog (drives spec-decode degradation).
      * ``park`` — force a voluntary end-of-turn on up to ``arg`` occupied
        slots (ascending slot order): the request retires with its partial
        output (status ``parked``) and its cache state parks in the
        session store when one is configured.
      * ``resume`` — fabricate up to ``arg`` returning sessions from the
        oldest parked traces (a short fixed continuation suffix, rids from
        a dedicated range far above real traffic) and submit them.
      * ``session_expire`` — force-expire up to ``arg`` parked sessions
        (oldest first), as a TTL lapse would — drives the
        expiry-racing-resume storms.
    """

    KINDS = ("pool_squeeze", "cancel", "deadline", "chunk_abort", "straggler",
             "park", "resume", "session_expire")

    def __init__(self, events=()):
        self.events: list[FaultEvent] = sorted(events, key=lambda e: e.step)
        for e in self.events:
            if e.kind not in self.KINDS:
                raise ValueError(f"unknown fault kind {e.kind!r}")
        self.fired: list[FaultEvent] = []

    def at(self, step: int) -> list[FaultEvent]:
        """Events scheduled for ``step`` (the scheduler marks them fired)."""
        return [e for e in self.events if e.step == step]

    @classmethod
    def storm(cls, kind: str, start: int, count: int, every: int = 1,
              arg: float = 1.0) -> "FaultPlan":
        """``count`` events of ``kind`` from ``start``, one per ``every``
        steps — cancel storms, deadline storms, straggler bursts."""
        return cls([FaultEvent(step=start + i * every, kind=kind, arg=arg)
                    for i in range(count)])

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan([*self.events, *other.events])


@dataclasses.dataclass
class ElasticPlan:
    """Mesh transition for an elastic rescale event.

    The checkpoint format is mesh-agnostic (full arrays in the manifest),
    so a rescale is restore-with-new-shardings; this records the decision.
    """

    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    reason: str  # 'exclude-straggler' | 'node-failure' | 'scale-up'

    @property
    def new_device_count(self) -> int:
        n = 1
        for d in self.new_shape:
            n *= d
        return n


def downscale_plan(shape: tuple[int, ...], reason: str) -> ElasticPlan:
    """Halve the data axis (the standard failure-domain response)."""
    axes = list(shape)
    # data axis is the last-but-one by convention ((pod,) data, model)
    i = len(axes) - 2
    if axes[i] % 2 == 0 and axes[i] > 1:
        axes[i] //= 2
    return ElasticPlan(old_shape=shape, new_shape=tuple(axes), reason=reason)
