"""Dense transformer family (llama-style): minitron, smollm, minicpm,
qwen3 (+qk_norm), hubert (encoder mode, frame inputs), internvl2 (VLM:
patch-prefix inputs).

scan-over-layers with stacked params (compile-time O(1) in depth); train
forward uses double-chunked flash attention + remat; decode runs the
PackKV computation-aware decompression path per layer.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import constrain
from ..core.cache import (
    LayerKVCache,
    PackKVConfig,
    alloc_layer_cache,
    append_token,
    prefill_cache,
)
from ..kernels import dense_decode_attention, packed_decode_attention
from ..kernels.sharded import active_lane, local_heads
from .layers import (
    attention_init,
    ctx_attention,
    dense_init,
    flash_attention,
    mlp_apply,
    mlp_init,
    qkv_proj,
    resume_attention,
    rmsnorm,
    rmsnorm_init,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ArchConfig) -> dict:
    from .moe import moe_init

    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": attention_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qk_norm
        ),
        "ln2": rmsnorm_init(cfg.d_model),
        "mlp": moe_init(k2, cfg) if cfg.family == "moe" else mlp_init(
            k2, cfg.d_model, cfg.d_ff
        ),
    }


def _apply_mlp(cfg: ArchConfig, layer_params: dict, h: Array):
    """SwiGLU or MoE MLP on the normalized hidden; returns (out, aux)."""
    from .moe import moe_apply

    if cfg.family == "moe":
        return moe_apply(layer_params["mlp"], h, cfg)
    return mlp_apply(layer_params["mlp"], h), jnp.zeros((), jnp.float32)


def init_params(key, cfg: ArchConfig) -> dict:
    keys = jax.random.split(key, 3)
    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "layers": layers,
        "final_ln": rmsnorm_init(cfg.d_model),
        "head": dense_init(keys[1], cfg.d_model, cfg.vocab),
    }
    if cfg.input_mode in ("tokens", "tokens_patches"):
        params["embed"] = (
            jax.random.normal(keys[2], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    return params


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------


def _embed_inputs(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """Resolve input modality to hidden states [B, S, D]."""
    if cfg.input_mode == "tokens":
        return params["embed"][batch["tokens"]]
    if cfg.input_mode == "frames":  # audio stub: precomputed frame embeddings
        return batch["frames"].astype(jnp.bfloat16)
    if cfg.input_mode == "tokens_patches":  # VLM stub: patch-embedding prefix
        tok = params["embed"][batch["tokens"]]
        return jnp.concatenate([batch["patches"].astype(tok.dtype), tok], axis=1)
    raise ValueError(cfg.input_mode)


def _block_train(cfg: ArchConfig, p: dict, h: Array, positions: Array):
    hn = rmsnorm(h, p["ln1"])
    q, k, v = qkv_proj(
        p["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions,
        cfg.rope_theta, cfg.qk_norm, cfg.use_rope,
    )
    # sequence-parallel attention layout (§Perf H3): q stays seq-sharded,
    # k/v are all-gathered ONCE per layer (they're Hkv·S·hd — small under
    # GQA); every flash tile is then shard-local. Without the pins GSPMD
    # bounces activations between layouts per kv-chunk (measured 825 GB of
    # collectives per step on minitron train).
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    attn = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window)
    B, S, _ = h.shape
    attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    h = h + jnp.dot(attn.astype(h.dtype), p["attn"]["wo"])
    m, aux = _apply_mlp(cfg, p, rmsnorm(h, p["ln2"]))
    return h + m, aux


def forward_train(params: dict, cfg: ArchConfig, batch: dict):
    """Full-sequence forward -> (logits [B, S, V] f32, aux loss scalar)."""
    h = _embed_inputs(params, cfg, batch)
    S = h.shape[1]
    positions = jnp.arange(S)

    block = jax.checkpoint(lambda hh, pp: _block_train(cfg, pp, hh, positions))

    def body(carry, layer_params):
        hh, aux = carry
        hh, a = block(hh, layer_params)
        # sequence parallelism: rematted residual stream sharded over 'model'
        hh = constrain(hh, "batch", "model", None)
        return (hh, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    h = rmsnorm(h, params["final_ln"])
    return jnp.dot(h, params["head"]).astype(jnp.float32), aux / cfg.n_layers


def encode(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """Encoder-only forward to final hidden states [B, S, D] (hubert's
    'prefill' — there is no KV cache for an encoder)."""
    h = _embed_inputs(params, cfg, batch)
    S = h.shape[1]
    positions = jnp.arange(S)
    block = jax.checkpoint(lambda hh, pp: _block_train(cfg, pp, hh, positions))

    def body(hh, layer_params):
        hh, _ = block(hh, layer_params)
        return constrain(hh, "batch", "model", None), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    return rmsnorm(h, params["final_ln"])


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def alloc_cache(cfg: ArchConfig, pack_cfg: PackKVConfig, batch: int, capacity: int):
    """Stacked per-layer caches [n_layers, ...]. Inside a shard_map lane
    (kernels/sharded.py) the head dim is this shard's local block."""
    one = lambda _: alloc_layer_cache(
        pack_cfg, batch, local_heads(cfg.n_kv_heads), cfg.hd, capacity
    )
    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def prefill(params: dict, cfg: ArchConfig, pack_cfg: PackKVConfig, capacity: int,
            batch: dict):
    """Process the prompt; returns (last-token logits [B, V], stacked cache)."""
    h = _embed_inputs(params, cfg, batch)
    B, S, _ = h.shape
    positions = jnp.arange(S)

    def body(hh, layer_params):
        hn = rmsnorm(hh, layer_params["ln1"])
        q, k, v = qkv_proj(
            layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta, cfg.qk_norm, cfg.use_rope,
        )
        attn = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
        hh = hh + jnp.dot(attn.astype(hh.dtype), layer_params["attn"]["wo"])
        m, _ = _apply_mlp(cfg, layer_params, rmsnorm(hh, layer_params["ln2"]))
        hh = hh + m
        lane = active_lane()
        if lane is not None:
            # prefill attention stays replicated (identical on every
            # shard); only the CACHE is built head-local
            k, v = lane.split(k, 1), lane.split(v, 1)
        cache_l = alloc_layer_cache(pack_cfg, B, local_heads(cfg.n_kv_heads),
                                    cfg.hd, capacity)
        cache_l = prefill_cache(cache_l, k, v)  # compress-as-you-prefill
        return hh, cache_l

    h, cache = jax.lax.scan(body, h, params["layers"])
    h = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(h, params["head"])[:, 0].astype(jnp.float32)
    return logits, cache


def prefill_into_slot(params: dict, cfg: ArchConfig, pack_cfg: PackKVConfig,
                      capacity: int, cache, slot, batch: dict):
    """Admit ONE request into row ``slot`` of a stacked decode cache.

    ``batch`` holds a single sequence (leading dim 1) at its TRUE length —
    no padding, so no pad tokens ever enter the cache and the row's
    compression calibration sees exactly the data a batch-size-1 prefill
    would. Rows other than ``slot`` are untouched (they may be mid-decode).
    Returns (last-token logits [1, V], updated cache). ``slot`` may be a
    traced scalar, so one compiled program serves every slot per prompt
    length.

    Paged caches admit through a DENSE mini-cache sized to the prompt (the
    compression math is identical, so the bytes are), then scatter it into
    freshly-popped pool pages — the slot's resident footprint is
    ``ceil(prompt_blocks / page_size)`` pages, not ``capacity`` tokens.
    """
    from ..core.cache import insert_row, insert_row_paged, paged_mini_spec

    if pack_cfg.paged:
        dense_cfg, cap_mini, n_pages = paged_mini_spec(
            pack_cfg, batch["tokens"].shape[-1]
        )
        logits, row = prefill(params, cfg, dense_cfg, cap_mini, batch)
        return logits, insert_row_paged(cache, slot, row, n_pages)
    logits, row = prefill(params, cfg, pack_cfg, capacity, batch)
    return logits, insert_row(cache, slot, row)


def reset_cache_slot(cache, slot):
    """Free row ``slot`` of a stacked decode cache (counters to zero)."""
    from ..core.cache import reset_slot

    return reset_slot(cache, slot)


def evacuate_cache_slot(cache, slot, n_pages: int = 0, n_shared: int = 0):
    """Swap row ``slot`` out to a dense B=1 mini-cache and free the row
    (page-level preemption; see ``core.cache.evacuate_row``). ``n_pages``
    and ``n_shared`` are STATIC — the scheduler's exact host-side mirror of
    the row's live page count and its shared-prefix length. Returns
    (cache with the slot freed, host-transportable mini)."""
    from ..core.cache import evacuate_row

    return evacuate_row(cache, slot, n_pages, n_shared)


def restore_cache_slot(cache, slot, mini, shared_phys,
                       n_pages: int = 0, n_shared: int = 0):
    """Stream an evacuated row back into slot ``slot`` — shared-prefix
    pages re-mapped by reference, suffix bytes scattered into fresh pages
    (``core.cache.restore_row``). Pure data movement: decode resumes from
    the restored row bit-identically, no forward pass."""
    from ..core.cache import restore_row

    return restore_row(cache, slot, mini, shared_phys, n_pages, n_shared)


def _prefill_segment(params: dict, cfg: ArchConfig, pack_cfg: PackKVConfig,
                     mini, tokens: Array, n_ctx: int):
    """One chunk of a chunked prefill: forward ``tokens`` ([1, S]) with the
    mini-cache's first ``n_ctx`` (STATIC) compressed tokens as read-only
    context, appending the segment's own K/V to the mini-cache.

    The compressed context is DEQUANTIZED for the segment's attention (the
    'none' policy reads its raw pages directly) — the defining numeric of
    the prefix-cache regime: a chunk's output depends only on the prompt
    prefix up to its end, never on later tokens, so any page-aligned resume
    point is exact. Returns (last-token logits [1, V], mini).
    """
    from ..core.cache import prefill_append
    from ..core.tiered import dequantize_tiered, slice_tiered_prefix

    h = params["embed"][tokens]
    B, S, _ = h.shape
    positions = n_ctx + jnp.arange(S)
    sm_scale = 1.0 / (cfg.hd ** 0.5)

    def body(hh, xs):
        layer_params, cache_l = xs
        hn = rmsnorm(hh, layer_params["ln1"])
        q, k, v = qkv_proj(
            layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta, cfg.qk_norm, cfg.use_rope,
        )
        lane = active_lane()
        if lane is not None:
            # the mini-cache context is head-local inside a lane: slice
            # the segment's q/k/v to the same head block, attend locally
            # (per-head softmax is head-independent), merge disjointly
            q = lane.split(q, 1)
            k, v = lane.split(k, 1), lane.split(v, 1)
        if n_ctx:
            if pack_cfg.policy == "none":
                ck = cache_l.raw_k[..., :n_ctx, :]
                cv = cache_l.raw_v[..., :n_ctx, :]
            else:
                ck = jnp.swapaxes(dequantize_tiered(
                    slice_tiered_prefix(cache_l.k, n_ctx)), -1, -2)
                cv = jnp.swapaxes(dequantize_tiered(
                    slice_tiered_prefix(cache_l.v, n_ctx)), -1, -2)
            k_all = jnp.concatenate(
                [ck.astype(jnp.float32), k.astype(jnp.float32)], axis=2)
            v_all = jnp.concatenate(
                [cv.astype(jnp.float32), v.astype(jnp.float32)], axis=2)
        else:
            k_all, v_all = k, v
        attn = ctx_attention(q, k_all, v_all, n_ctx, sm_scale)
        if lane is not None:
            attn = lane.merge(attn, 1, cfg.n_heads)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
        hh = hh + jnp.dot(attn.astype(hh.dtype), layer_params["attn"]["wo"])
        m, _ = _apply_mlp(cfg, layer_params, rmsnorm(hh, layer_params["ln2"]))
        hh = hh + m
        cache_l = prefill_append(cache_l, k, v, calibrate=(n_ctx == 0))
        return hh, cache_l

    h, mini = jax.lax.scan(body, h, (params["layers"], mini))
    h = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(h, params["head"])[:, 0].astype(jnp.float32)
    return logits, mini


def prefill_into_slot_prefix(params: dict, cfg: ArchConfig,
                             pack_cfg: PackKVConfig, capacity: int, cache,
                             slot, batch: dict, prefix_phys: Array,
                             k_perm: Array, v_perm: Array, *, n_prefix: int):
    """Prefix-cache admission: CHUNKED prefill with suffix-only compute.

    The prompt is processed in page-aligned chunks through a dense B=1
    mini-cache; each chunk attends to the already-compressed earlier pages
    as read-only context and chunk 0 calibrates the channel permutation.
    Because a chunk's computation depends only on the prompt prefix up to
    its end, resuming from ANY page boundary reproduces a cold run
    bit-for-bit: the ``n_prefix`` (STATIC, page-aligned, < prompt length)
    tokens whose compressed pool pages ``prefix_phys`` (i32
    [n_prefix / page_size]) were matched by the host-side prefix index are
    mapped into the slot BY REFERENCE — zero attention-query FLOPs, zero
    compression work, zero page pops for shared tokens.

    ``k_perm``/``v_perm`` ([n_layers, Hkv, D], from the index entry) carry
    the donor's page-0 calibration so suffix blocks compress under the
    identical permutation; both are ignored when ``n_prefix == 0`` (a COLD
    admission under a prefix-cache engine runs the same chunked math, which
    is what makes a later hit on its registered pages exact). Returns
    (last-token logits [1, V], updated stacked cache).
    """
    from ..core.cache import (
        insert_row_paged,
        paged_mini_spec,
        seed_prefix_from_pages,
    )

    assert pack_cfg.paged, "prefix-cache admission requires the paged pool"
    tokens = batch["tokens"]
    S = tokens.shape[-1]
    page = pack_cfg.page_size
    Lb = (S // pack_cfg.block) * pack_cfg.block
    Lp = (Lb // page) * page  # the prompt's own cacheable (full-page) prefix
    assert n_prefix % page == 0 and n_prefix <= Lp and n_prefix < S, (
        n_prefix, Lp, S)
    dense_cfg, cap_mini, n_pages = paged_mini_spec(pack_cfg, S)
    mini = alloc_cache(cfg, dense_cfg, 1, cap_mini)
    if n_prefix:
        mini = seed_prefix_from_pages(cache, mini, prefix_phys, n_prefix,
                                      k_perm, v_perm)
    bounds = list(range(n_prefix, Lp + 1, page))
    if S > Lp:
        bounds.append(S)
    logits = None
    for s0, s1 in zip(bounds, bounds[1:]):
        logits, mini = _prefill_segment(params, cfg, pack_cfg, mini,
                                        tokens[:, s0:s1], n_ctx=s0)
    cache = insert_row_paged(cache, slot, mini, n_pages,
                             n_shared=n_prefix // page,
                             shared_phys=prefix_phys)
    return logits, cache


def prefill_chunk_init(cfg: ArchConfig, pack_cfg: PackKVConfig, capacity: int,
                       *, prompt_len: int):
    """Scratch for a chunked (interleaved) admission WITHOUT a prefix cache:
    raw bf16 K/V accumulators sized to the full prompt, one per layer.

    Chunks write their keys in place and attend over the whole scratch
    through ``resume_attention`` (unwritten tokens are causally masked, so
    their zeros never contribute); compression is DEFERRED to
    ``prefill_chunk_insert`` so the calibration sees exactly the bytes the
    monolithic ``prefill`` would — which is what makes chunked admission
    bit-identical to the one-shot path on both policies.
    """
    z = jnp.zeros((cfg.n_layers, 1, cfg.n_kv_heads, prompt_len, cfg.hd),
                  jnp.bfloat16)
    return {"k": z, "v": z}


def prefill_chunk(params: dict, cfg: ArchConfig, pack_cfg: PackKVConfig,
                  scratch, tokens: Array, *, n_ctx: int):
    """One bounded chunk of an interleaved admission. tokens: [1, Sc] at
    absolute positions ``n_ctx + arange(Sc)`` (STATIC ``n_ctx``).

    Returns (last-token logits [1, V], scratch with this chunk's K/V
    written). Only the final chunk's logits are meaningful (they equal the
    monolithic prefill's last-token logits)."""
    h = params["embed"][tokens]
    B, Sc, _ = h.shape
    positions = n_ctx + jnp.arange(Sc)

    def body(hh, xs):
        layer_params, k_s, v_s = xs
        hn = rmsnorm(hh, layer_params["ln1"])
        q, k, v = qkv_proj(
            layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta, cfg.qk_norm, cfg.use_rope,
        )
        k_s = jax.lax.dynamic_update_slice_in_dim(
            k_s, k.astype(k_s.dtype), n_ctx, axis=2)
        v_s = jax.lax.dynamic_update_slice_in_dim(
            v_s, v.astype(v_s.dtype), n_ctx, axis=2)
        # attend over the written prefix only (a STATIC bound — n_ctx and
        # Sc are trace constants): keys past n_ctx+Sc are unwritten zeros
        # the causal mask would discard anyway, but slicing them off keeps
        # the chunk's attention cost at Sc*(n_ctx+Sc) — the triangle the
        # monolithic pass pays in one rectangle. Rounded up to the kv tile
        # so resume_attention's chunking constraint holds for any length.
        t_used = n_ctx + Sc
        if t_used > 1024:
            t_used = min(k_s.shape[2], -(-t_used // 1024) * 1024)
        attn = resume_attention(q, k_s[:, :, :t_used], v_s[:, :, :t_used],
                                n_ctx, causal=cfg.causal, window=cfg.window)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, Sc, cfg.n_heads * cfg.hd)
        hh = hh + jnp.dot(attn.astype(hh.dtype), layer_params["attn"]["wo"])
        m, _ = _apply_mlp(cfg, layer_params, rmsnorm(hh, layer_params["ln2"]))
        return hh + m, (k_s, v_s)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["layers"], scratch["k"], scratch["v"])
    )
    h = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(h, params["head"])[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs}


def prefill_chunk_insert(cfg: ArchConfig, pack_cfg: PackKVConfig,
                         capacity: int, cache, slot, scratch):
    """Finish a chunked admission: compress the accumulated raw prompt K/V
    exactly as the monolithic ``prefill`` does (same ``prefill_cache`` call
    over the same bytes -> identical calibration, identical tiers) and
    scatter the row into ``slot``. Paged caches go through the same dense
    mini-cache + ``insert_row_paged`` route as ``prefill_into_slot``."""
    from ..core.cache import insert_row, insert_row_paged, paged_mini_spec

    S = scratch["k"].shape[-2]
    if pack_cfg.paged:
        dense_cfg, cap_mini, n_pages = paged_mini_spec(pack_cfg, S)
    else:
        dense_cfg, cap_mini, n_pages = pack_cfg, capacity, None

    def body(_, xs):
        k, v = xs
        lane = active_lane()
        if lane is not None:
            # the raw scratch is replicated full-head; the row it
            # compresses into is head-local (per-head quantization and
            # calibration are head-independent, so the local bytes equal
            # the single-device row's head slice)
            k, v = lane.split(k, 1), lane.split(v, 1)
        cache_l = alloc_layer_cache(dense_cfg, 1, local_heads(cfg.n_kv_heads),
                                    cfg.hd, cap_mini)
        return None, prefill_cache(cache_l, k, v)

    _, row = jax.lax.scan(body, None, (scratch["k"], scratch["v"]))
    if pack_cfg.paged:
        return insert_row_paged(cache, slot, row, n_pages)
    return insert_row(cache, slot, row)


def prefix_chunk_bounds(pack_cfg: PackKVConfig, prompt_len: int,
                        n_prefix: int) -> list[int]:
    """Segment bounds of a prefix-cache admission (host-side): the EXACT
    per-page segmentation ``prefill_into_slot_prefix`` traces, so running
    the same segments one dispatch at a time reproduces its bytes."""
    page = pack_cfg.page_size
    Lb = (prompt_len // pack_cfg.block) * pack_cfg.block
    Lp = (Lb // page) * page
    bounds = list(range(n_prefix, Lp + 1, page))
    if prompt_len > Lp:
        bounds.append(prompt_len)
    return bounds


def prefix_chunk_init(cfg: ArchConfig, pack_cfg: PackKVConfig, capacity: int,
                      cache, prefix_phys: Array, k_perm: Array, v_perm: Array,
                      *, n_prefix: int, prompt_len: int):
    """Mini-cache for an interleaved prefix-cache admission: the dense B=1
    cache ``prefill_into_slot_prefix`` allocates, seeded with the matched
    shared pages (and their donor calibration) when ``n_prefix > 0``."""
    from ..core.cache import paged_mini_spec, seed_prefix_from_pages

    dense_cfg, cap_mini, _ = paged_mini_spec(pack_cfg, prompt_len)
    mini = alloc_cache(cfg, dense_cfg, 1, cap_mini)
    if n_prefix:
        mini = seed_prefix_from_pages(cache, mini, prefix_phys, n_prefix,
                                      k_perm, v_perm)
    return mini


def prefix_chunk(params: dict, cfg: ArchConfig, pack_cfg: PackKVConfig,
                 mini, tokens: Array, *, n_ctx: int):
    """One page-aligned segment of an interleaved prefix-cache admission
    (``_prefill_segment`` dispatched standalone — the mini-cache round-trips
    host<->device between segments as concrete arrays, so splitting the
    trace is value-preserving)."""
    return _prefill_segment(params, cfg, pack_cfg, mini, tokens, n_ctx)


def prefix_chunk_insert(pack_cfg: PackKVConfig, cache, slot, mini,
                        prefix_phys: Array, *, n_prefix: int,
                        prompt_len: int):
    """Finish an interleaved prefix-cache admission: scatter the mini-cache
    into freshly-popped pool pages, mapping the ``n_prefix`` shared tokens'
    pages by reference (same call ``prefill_into_slot_prefix`` ends with)."""
    from ..core.cache import insert_row_paged, paged_mini_spec

    _, _, n_pages = paged_mini_spec(pack_cfg, prompt_len)
    return insert_row_paged(cache, slot, mini, n_pages,
                            n_shared=n_prefix // pack_cfg.page_size,
                            shared_phys=prefix_phys)


def decode_step(params: dict, cfg: ArchConfig, cache, token: Array,
                *, backend: str = "xla", n_bucket: int | None = None):
    """One decode token. token: [B, 1] int32. Returns (logits [B,V], cache).

    ``n_bucket`` (STATIC python int): bucketed launch — attention reads only
    the first ``n_bucket`` tokens of the compressed region (see
    ``core.cache.bucket_length``). Must upper-bound every row's ``n_comp``
    AFTER this step's append/flush; None reads the full capacity.
    """
    h = params["embed"][token] if cfg.input_mode != "frames" else token
    B = h.shape[0]
    # per-row positions (continuous batching: every slot has its own length);
    # counters are identical across layers, so layer 0's [B] vector suffices
    pos = cache.n_comp[0] + cache.n_resid[0]  # [B]
    positions = pos[:, None, None]  # broadcasts to [B, H, 1] in RoPE
    sm_scale = 1.0 / (cfg.hd ** 0.5)

    from ..core.cache import slice_compressed

    def body(hh, xs):
        layer_params, cache_l = xs
        hn = rmsnorm(hh, layer_params["ln1"])
        q, k, v = qkv_proj(
            layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta, cfg.qk_norm, cfg.use_rope,
        )
        qd = q[:, :, 0]  # [B, H, Dh]
        lane = active_lane()
        owned = lane.owned_rows(B) if lane is not None else None
        if lane is not None:
            # KV-head lane (kernels/sharded.py): local head blocks in,
            # append + attention on local heads, one disjoint psum out
            qd = lane.split(qd, 1)
            k, v = lane.split(k, 1), lane.split(v, 1)
        cache_l = append_token(cache_l, k, v)
        # dp shards read through counter-masked views (non-owned rows span
        # zero tokens -> exact 0.0, discarded by the merge); appends above
        # always use the real counters so replicated state stays identical
        rd = lane.mask_read(cache_l, owned) if lane is not None else cache_l
        if cache_l.cfg.policy == "none":
            read = slice_compressed(rd, n_bucket)
            attn = dense_decode_attention(
                qd, read.raw_k, read.raw_v, read.resid_k, read.resid_v,
                read.n_comp, read.n_resid, sm_scale,
            )
        elif cache_l.pages is not None and backend == "pallas":
            # page-indexed fused kernel: context tiles resolve their
            # physical page in-kernel, no gathered copy is materialized
            from ..kernels import paged_decode_attention

            attn = paged_decode_attention(
                qd, rd, sm_scale, n_bucket=n_bucket, backend=backend,
            )
        else:
            # paged + xla reads through the page-table gather inside
            # slice_compressed; dense mode slices the contiguous prefix
            read = slice_compressed(rd, n_bucket)
            attn = packed_decode_attention(
                qd, read.k, read.v, read.resid_k, read.resid_v,
                read.n_comp, read.n_resid, sm_scale, backend=backend,
            )
        if lane is not None:
            attn = lane.merge(attn, 1, cfg.n_heads, owned)
        attn = attn.reshape(B, 1, cfg.n_heads * cfg.hd)
        hh = hh + jnp.dot(attn.astype(hh.dtype), layer_params["attn"]["wo"])
        m, _ = _apply_mlp(cfg, layer_params, rmsnorm(hh, layer_params["ln2"]))
        hh = hh + m
        return hh, cache_l

    h, cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(h, params["head"])[:, 0].astype(jnp.float32)
    return logits, cache


def verify_steps(params: dict, cfg: ArchConfig, cache, tokens: Array,
                 lens: Array, active: Array, *, backend: str = "xla",
                 n_bucket: int | None = None):
    """Speculative verify: ONE batched forward over a q_len=w draft window,
    with the acceptance decision and the commit fused in-graph.

    tokens: [B, w] i32 — per row, the seed token (the row's last committed
    token, exactly what ``decode_step`` would be fed) followed by w-1
    drafted tokens; rows with shorter windows pad with junk. lens: i32 [B]
    in [1, w] — seed + drafts valid per row (ragged windows share one
    compiled program; junk positions compute garbage nobody reads).
    active: bool [B] — occupied slots; free rows ride along and are
    re-zeroed in-graph (``mask_free_slots``), exactly as ``decode_steps``
    does per step.

    Returns (hat [B, w] i32, n_accept [B] i32, cache): ``hat[b, i]`` is
    the greedy argmax the stepwise ``decode_step`` would emit after
    consuming window position i, and ``n_accept[b]`` the length of the
    longest draft prefix those argmaxes confirm (draft i is accepted iff
    it equals the greedy token after position i-1 — the standard
    speculative-decoding rule, so the emitted stream ``hat[b, :n_accept+1]``
    is exact for ARBITRARY draft content). The cache comes back already
    committed (``core.cache.commit_window``) — one dispatch covers
    verify + accept + commit + free-row masking, which is what keeps the
    per-launch overhead at parity with a ``decode_steps`` chunk.

    BITWISE identity with the stepwise path is by construction: the
    seed appends through the real ``append_token`` (flush/page pop and all),
    drafts land at the stepwise residual offsets via
    ``core.cache.append_window`` (counters untouched), and window position
    i attends through the SAME per-token attention kernel with
    ``n_resid + i`` — the exact counter value stepwise step i sees after
    its own append (``append_token`` appends BEFORE attending, so each
    query attends to itself; ``n_comp`` is static after the seed's flush
    because the headroom-capped window never flushes again). RoPE
    positions are ``(n_comp + n_resid) + i`` read BEFORE the seed append —
    flushes conserve the sum, so they equal the stepwise per-step
    positions. The xla branches batch the w per-position kernels through
    ``jax.vmap`` over the window axis — per-query arithmetic (dot
    contractions, row-wise max/sum reductions) is unchanged, only stacked,
    so the vmapped launch stays bit-identical to the unrolled one (the
    verify-vs-stepwise tests pin this). Until the commit, draft bytes are
    invisible to every masked read. Inside a shard_map lane
    (kernels/sharded.py) the window runs on this shard's head block with
    the same per-position kernels and merges through the same disjoint
    psum as ``decode_step``, so sharded verify stays bit-identical too.
    """
    from ..core.cache import (
        append_window, commit_window, mask_free_slots, slice_compressed,
    )

    h = params["embed"][tokens] if cfg.input_mode != "frames" else tokens
    B, w = tokens.shape
    pos0 = cache.n_comp[0] + cache.n_resid[0]  # [B], pre-append totals
    positions = pos0[:, None, None] + jnp.arange(w)[None, None, :]  # [B,1,w]
    sm_scale = 1.0 / (cfg.hd ** 0.5)
    offs = jnp.arange(w)

    def body(hh, xs):
        layer_params, cache_l = xs
        hn = rmsnorm(hh, layer_params["ln1"])
        q, k, v = qkv_proj(
            layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta, cfg.qk_norm, cfg.use_rope,
        )
        lane = active_lane()
        owned = lane.owned_rows(B) if lane is not None else None
        if lane is not None:
            q = lane.split(q, 1)
            k, v = lane.split(k, 1), lane.split(v, 1)
        cache_l = append_window(cache_l, k, v, lens)
        rd = lane.mask_read(cache_l, owned) if lane is not None else cache_l
        # q: [B, H, w, Dh]. The attention is UNROLLED per window position,
        # each position invoking the exact per-token kernel decode_step
        # uses — NOT vmapped/batched over w: a batched lowering changes the
        # floating-point reduction order at ULP level, and any ULP drift in
        # an accepted draft's attention output propagates into the K/V
        # bytes written for deeper layers, silently diverging the cache
        # from the stepwise path (a later launch's argmax then flips). The
        # bulk matmuls (qkv / wo / MLP / head) ARE batched over w — their
        # per-row contractions are byte-stable under batching (pinned by
        # the verify-vs-stepwise and end-to-end exactness tests).
        if cache_l.cfg.policy == "none":
            read = slice_compressed(rd, n_bucket)
            attn = jnp.stack([
                dense_decode_attention(
                    q[:, :, i], read.raw_k, read.raw_v, read.resid_k,
                    read.resid_v, read.n_comp, read.n_resid + i, sm_scale,
                ) for i in range(w)
            ], axis=2)
        elif cache_l.pages is not None and backend == "pallas":
            from ..kernels import paged_decode_attention

            attn = jnp.stack([
                paged_decode_attention(
                    q[:, :, i],
                    dataclasses.replace(rd, n_resid=rd.n_resid + i),
                    sm_scale, n_bucket=n_bucket, backend=backend,
                ) for i in range(w)
            ], axis=2)
        else:
            read = slice_compressed(rd, n_bucket)
            attn = jnp.stack([
                packed_decode_attention(
                    q[:, :, i], read.k, read.v, read.resid_k, read.resid_v,
                    read.n_comp, read.n_resid + i, sm_scale, backend=backend,
                ) for i in range(w)
            ], axis=2)
        if lane is not None:
            attn = lane.merge(attn, 1, cfg.n_heads, owned)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, w, cfg.n_heads * cfg.hd)
        hh = hh + jnp.dot(attn.astype(hh.dtype), layer_params["attn"]["wo"])
        m, _ = _apply_mlp(cfg, layer_params, rmsnorm(hh, layer_params["ln2"]))
        hh = hh + m
        return hh, cache_l

    h, cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = rmsnorm(h, params["final_ln"])
    logits = jnp.dot(h, params["head"]).astype(jnp.float32)  # [B, w, V]
    hat = jnp.argmax(logits, -1).astype(jnp.int32)
    # acceptance: leading run of drafts confirmed by the window argmaxes,
    # clipped to each row's valid drafts (lens - 1; free rows have lens=1
    # so their junk can never commit)
    match = (hat[:, :-1] == tokens[:, 1:]) & \
        (offs[None, :-1] < (lens - 1)[:, None])
    n_accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    cache = commit_window(cache, n_accept)
    cache = mask_free_slots(cache, jnp.asarray(active, bool))
    return hat, n_accept, cache


def decode_steps(params: dict, cfg: ArchConfig, cache, token: Array,
                 active: Array, n_steps: Array, eos_id: Array,
                 *, t_max: int, backend: str = "xla",
                 n_bucket: int | None = None):
    """Multi-step greedy decode: up to ``t_max`` tokens in ONE jitted call.

    A ``lax.while_loop`` over ``decode_step`` replaces per-token Python
    dispatch; jit the wrapper with the cache DONATED so each chunk updates
    the cache buffers in place instead of copying them every token. The
    loop early-exits once every active row has emitted ``eos_id``.

    token:   [B, 1] i32 — each row's last generated token.
    active:  bool [B] — occupied slots; free rows ride along with their
             counters re-zeroed every step (same invariant as
             ``core.cache.mask_free_slots`` in the per-step path).
    n_steps: i32 traced, <= t_max (STATIC) — the scheduler picks
             min(chunk, min over active rows of remaining budget) so no row
             overshoots its ``max_new``.
    eos_id:  i32 traced; -1 disables EOS early exit.
    Returns (tokens i32 [t_max, B] — rows past the exit step are zeros,
    n_exec i32 — executed steps, cache). Outputs for a row past its own EOS
    are junk the scheduler discards; rows are independent, so every token
    up to each row's EOS is bit-identical to step-at-a-time decode.
    """
    from ..core.cache import mask_free_slots

    B = token.shape[0]
    act = jnp.asarray(active, bool)
    out0 = jnp.zeros((t_max, B), jnp.int32)

    def cond(carry):
        i, _, _, done, _ = carry
        return (i < n_steps) & jnp.logical_not(jnp.all(done))

    def body(carry):
        i, cache, tok, done, out = carry
        logits, cache = decode_step(
            params, cfg, cache, tok, backend=backend, n_bucket=n_bucket
        )
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)  # [B]
        out = jax.lax.dynamic_update_slice(out, nxt[None, :], (i, 0))
        done = done | (nxt == eos_id)
        cache = mask_free_slots(cache, act)
        return i + 1, cache, nxt[:, None], done, out

    i, cache, _, _, out = jax.lax.while_loop(
        cond, body, (jnp.int32(0), cache, token, jnp.logical_not(act), out0)
    )
    return out, i, cache
