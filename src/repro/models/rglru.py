"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention, 1:2.

Layer pattern: groups of (recurrent, recurrent, attention), each sub-layer
followed by an MLP. 38 layers = 12 scanned groups + 2 trailing recurrent
blocks. The local-attention layers carry a PackKV-compressed sliding-window
cache (ring-buffer append — valid by decode-attention permutation
invariance); RG-LRU layers carry O(1) state, so ``long_500k`` decodes with
a bounded memory footprint.

Recurrent block: x -> [linear -> causal depthwise conv(4) -> RG-LRU] ⊙
gelu(linear) -> linear. RG-LRU: a_t = exp(-8·softplus(Λ)·r_t),
h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    append_token,
    insert_row,
    mask_free_slots,
    prefill_cache,
    reset_slot,
)
from ..kernels import dense_decode_attention, packed_decode_attention
from ..utils import pytree_dataclass
from .layers import (
    attention_init,
    dense_init,
    flash_attention,
    mlp_apply,
    mlp_init,
    qkv_proj,
    resume_attention,
    rmsnorm,
    rmsnorm_init,
)

Array = jax.Array

CONV_W = 4
LRU_C = 8.0


@pytree_dataclass
class RGState:
    """Decode state. Grouped leaves are stacked [n_groups, ...]."""

    lru_h: Array  # f32 [n_groups, 2, B, R]
    conv: Array  # bf16 [n_groups, 2, B, CONV_W-1, R]
    cache: object  # LayerKVCache stacked [n_groups, ...] (window capacity)
    tail_lru_h: Array  # f32 [n_tail, B, R]
    tail_conv: Array  # bf16 [n_tail, B, CONV_W-1, R]
    pos: Array  # i32 [B] per-row decoded length (slot-table bookkeeping)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _rec_block_init(key, cfg: ArchConfig) -> dict:
    D = cfg.d_model
    R = cfg.lru_dim or D
    ks = jax.random.split(key, 7)
    return {
        "ln": rmsnorm_init(D),
        "w_in": dense_init(ks[0], D, R),
        "w_gate_branch": dense_init(ks[1], D, R),
        "conv_w": (jax.random.normal(ks[2], (CONV_W, R)) * 0.1).astype(jnp.bfloat16),
        "lru_wa": dense_init(ks[3], R, R, jnp.float32),
        "lru_wx": dense_init(ks[4], R, R, jnp.float32),
        "lru_lambda": jax.random.uniform(ks[5], (R,), jnp.float32, 0.4, 0.9),
        "w_out": dense_init(ks[6], R, D),
        "mlp_ln": rmsnorm_init(D),
        "mlp": mlp_init(jax.random.fold_in(key, 7), D, cfg.d_ff),
    }


def _attn_block_init(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln": rmsnorm_init(cfg.d_model),
        "attn": attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "mlp_ln": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff),
    }


def _group_init(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "rec": jax.vmap(lambda k: _rec_block_init(k, cfg))(jnp.stack([k1, k2])),
        "attn": _attn_block_init(k3, cfg),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    n_groups, n_tail = divmod(cfg.n_layers, 3)
    ks = jax.random.split(key, 4)
    gkeys = jax.random.split(ks[0], n_groups)
    tkeys = jax.random.split(ks[1], max(n_tail, 1))
    return {
        "groups": jax.vmap(lambda k: _group_init(k, cfg))(gkeys),
        "tail": jax.vmap(lambda k: _rec_block_init(k, cfg))(tkeys[:n_tail])
        if n_tail
        else None,
        "embed": (jax.random.normal(ks[2], (cfg.vocab, cfg.d_model)) * 0.02).astype(
            jnp.bfloat16
        ),
        "final_ln": rmsnorm_init(cfg.d_model),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab),
    }


# ---------------------------------------------------------------------------
# RG-LRU + conv
# ---------------------------------------------------------------------------


def _causal_conv_seq(w: Array, x: Array, x_hist: Array):
    """Depthwise causal conv via shifted adds. x: [B,T,R]; x_hist: [B,CONV_W-1,R]."""
    xp = jnp.concatenate([x_hist, x], axis=1)  # [B, T+3, R]
    T = x.shape[1]
    y = sum(w[i] * jax.lax.dynamic_slice_in_dim(xp, i, T, 1) for i in range(CONV_W))
    return y, xp[:, -(CONV_W - 1) :]  # new history


def _rg_lru_seq(p: dict, x: Array, h0: Array):
    """x: [B,T,R] f32-gated LRU scan; returns (y [B,T,R], h_final [B,R])."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["lru_wa"])
    i = jax.nn.sigmoid(xf @ p["lru_wx"])
    log_a = -LRU_C * jax.nn.softplus(p["lru_lambda"]) * r  # [B,T,R]
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t
        return h, h

    aT = jnp.moveaxis(a, 1, 0)
    gT = jnp.moveaxis(gx, 1, 0)
    h, ys = jax.lax.scan(step, h0, (aT, gT))
    return jnp.moveaxis(ys, 0, 1), h


def _rec_block_seq(p: dict, cfg: ArchConfig, h: Array, conv_hist: Array, h0: Array):
    """Full recurrent residual block over a sequence."""
    x = rmsnorm(h, p["ln"])
    y1 = x @ p["w_in"]
    y2 = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32)).astype(h.dtype)
    y1, new_hist = _causal_conv_seq(p["conv_w"], y1, conv_hist)
    y1, h_fin = _rg_lru_seq(p, y1, h0)
    out = (y1.astype(h.dtype) * y2) @ p["w_out"]
    h = h + out
    h = h + mlp_apply(p["mlp"], rmsnorm(h, p["mlp_ln"]))
    return h, new_hist, h_fin


def _attn_block_seq(p: dict, cfg: ArchConfig, h: Array, positions: Array):
    x = rmsnorm(h, p["ln"])
    q, k, v = qkv_proj(
        p["attn"], x, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions, cfg.rope_theta
    )
    attn = flash_attention(q, k, v, causal=True, window=cfg.window)
    B, S, _ = h.shape
    attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    h = h + jnp.dot(attn.astype(h.dtype), p["attn"]["wo"])
    h = h + mlp_apply(p["mlp"], rmsnorm(h, p["mlp_ln"]))
    return h, (k, v)


# ---------------------------------------------------------------------------
# train / prefill / decode
# ---------------------------------------------------------------------------


def _zeros_states(cfg: ArchConfig, B: int):
    R = cfg.lru_dim or cfg.d_model
    return (
        jnp.zeros((B, CONV_W - 1, R), jnp.bfloat16),
        jnp.zeros((B, R), jnp.float32),
    )


def forward_train(params: dict, cfg: ArchConfig, batch: dict):
    tokens = batch["tokens"]
    B, T = tokens.shape
    h = params["embed"][tokens]
    positions = jnp.arange(T)
    conv0, h0 = _zeros_states(cfg, B)

    def group_body(hh, gp):
        for r in range(2):
            rp = jax.tree_util.tree_map(lambda a: a[r], gp["rec"])
            hh, _, _ = _rec_block_seq(rp, cfg, hh, conv0, h0)
        hh, _ = _attn_block_seq(gp["attn"], cfg, hh, positions)
        return hh, None

    from ..distributed.sharding import constrain

    block = jax.checkpoint(group_body)

    def wrapped(c, x):
        hh, y = block(c, x)
        return constrain(hh, "batch", "model", None), y

    h, _ = jax.lax.scan(wrapped, h, params["groups"])
    if params["tail"] is not None:
        n_tail = jax.tree_util.tree_leaves(params["tail"])[0].shape[0]
        for t in range(n_tail):
            tp = jax.tree_util.tree_map(lambda a: a[t], params["tail"])
            h, _, _ = _rec_block_seq(tp, cfg, h, conv0, h0)
    h = rmsnorm(h, params["final_ln"])
    return jnp.dot(h, params["head"]).astype(jnp.float32), jnp.zeros((), jnp.float32)


def alloc_state(cfg: ArchConfig, pack_cfg: PackKVConfig, batch: int) -> RGState:
    n_groups, n_tail = divmod(cfg.n_layers, 3)
    R = cfg.lru_dim or cfg.d_model
    W = cfg.window
    one_cache = lambda _: alloc_layer_cache(
        pack_cfg, batch, cfg.n_kv_heads, cfg.hd, W
    )
    return RGState(
        lru_h=jnp.zeros((n_groups, 2, batch, R), jnp.float32),
        conv=jnp.zeros((n_groups, 2, batch, CONV_W - 1, R), jnp.bfloat16),
        cache=jax.vmap(one_cache)(jnp.arange(n_groups)),
        tail_lru_h=jnp.zeros((n_tail, batch, R), jnp.float32),
        tail_conv=jnp.zeros((n_tail, batch, CONV_W - 1, R), jnp.bfloat16),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def prefill(params: dict, cfg: ArchConfig, pack_cfg: PackKVConfig, capacity: int,
            batch: dict):
    """capacity is ignored for the windowed cache (window is the capacity)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    W = cfg.window
    h = params["embed"][tokens]
    positions = jnp.arange(T)
    conv0, h0 = _zeros_states(cfg, B)
    Wc = min(T, W)  # tokens that land in the window cache (static)

    def group_body(hh, gp):
        states = []
        for r in range(2):
            rp = jax.tree_util.tree_map(lambda a: a[r], gp["rec"])
            hh, hist, hf = _rec_block_seq(rp, cfg, hh, conv0, h0)
            states.append((hist, hf))
        hh, (k, v) = _attn_block_seq(gp["attn"], cfg, hh, positions)
        cache_l = alloc_layer_cache(pack_cfg, B, cfg.n_kv_heads, cfg.hd, W)
        cache_l = prefill_cache(cache_l, k[..., -Wc:, :], v[..., -Wc:, :])
        lru = jnp.stack([states[0][1], states[1][1]])
        conv = jnp.stack([states[0][0], states[1][0]])
        return hh, (lru, conv, cache_l)

    h, (lru, conv, cache) = jax.lax.scan(group_body, h, params["groups"])
    n_tail = cfg.n_layers % 3
    tails_l, tails_c = [], []
    for t in range(n_tail):
        tp = jax.tree_util.tree_map(lambda a: a[t], params["tail"])
        h, hist, hf = _rec_block_seq(tp, cfg, h, conv0, h0)
        tails_l.append(hf)
        tails_c.append(hist)
    hl = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(hl, params["head"])[:, 0].astype(jnp.float32)
    state = RGState(
        lru_h=lru, conv=conv, cache=cache,
        tail_lru_h=jnp.stack(tails_l) if n_tail else jnp.zeros((0, B, cfg.lru_dim or cfg.d_model), jnp.float32),
        tail_conv=jnp.stack(tails_c) if n_tail else jnp.zeros((0, B, CONV_W - 1, cfg.lru_dim or cfg.d_model), jnp.bfloat16),
        pos=jnp.full((B,), T, jnp.int32),
    )
    return logits, state


def _rec_block_step(p: dict, cfg: ArchConfig, h: Array, conv_hist: Array, h0: Array):
    """One-token recurrent block. h: [B, D]."""
    x = rmsnorm(h, p["ln"])
    y1 = x @ p["w_in"]  # [B, R]
    y2 = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32)).astype(h.dtype)
    xp = jnp.concatenate([conv_hist, y1[:, None]], axis=1)  # [B, CONV_W, R]
    yc = jnp.einsum("cr,bcr->br", p["conv_w"], xp)
    new_hist = xp[:, 1:]
    xf = yc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["lru_wa"])
    i = jax.nn.sigmoid(xf @ p["lru_wx"])
    a = jnp.exp(-LRU_C * jax.nn.softplus(p["lru_lambda"]) * r)
    hn = a * h0 + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i * xf)
    out = (hn.astype(h.dtype) * y2) @ p["w_out"]
    h = h + out
    h = h + mlp_apply(p["mlp"], rmsnorm(h, p["mlp_ln"]))
    return h, new_hist, hn


def decode_step(params: dict, cfg: ArchConfig, cache: RGState, token: Array,
                *, backend: str = "xla", n_bucket: int | None = None):
    """One decode token with windowed PackKV attention caches.

    ``n_bucket`` is accepted for registry-signature uniformity and ignored:
    the windowed ring cache is already bounded at ``cfg.window`` tokens, so
    there is no dead capacity to slice away.
    """
    del n_bucket
    state = cache  # uniform arg name across families (registry contract)
    B = token.shape[0]
    W = cfg.window
    h = params["embed"][token[:, 0]]  # [B, D]
    pos = state.pos
    positions = pos[:, None, None]  # [B,1,1]: per-row RoPE positions
    sm_scale = 1.0 / (cfg.hd ** 0.5)

    def group_body(hh, xs):
        gp, lru, conv, cache_l = xs
        new_lru, new_conv = [], []
        for r in range(2):
            rp = jax.tree_util.tree_map(lambda a: a[r], gp["rec"])
            hh, hist, hf = _rec_block_step(rp, cfg, hh, conv[r], lru[r])
            new_lru.append(hf)
            new_conv.append(hist)
        x = rmsnorm(hh, gp["attn"]["ln"])
        q, k, v = qkv_proj(
            gp["attn"]["attn"], x[:, None], cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta,
        )
        qd = q[:, :, 0]
        if cache_l.cfg.policy == "none":
            cache_l = append_token(cache_l, k, v, ring=True)
            n_valid = jnp.minimum(cache_l.n_comp, W)
            attn = dense_decode_attention(
                qd, cache_l.raw_k, cache_l.raw_v, cache_l.resid_k, cache_l.resid_v,
                n_valid, cache_l.n_resid, sm_scale,
            )
        else:
            cache_l = append_token(cache_l, k, v, ring=True)
            n_valid = jnp.minimum(cache_l.n_comp, W)
            attn = packed_decode_attention(
                qd, cache_l.k, cache_l.v, cache_l.resid_k, cache_l.resid_v,
                n_valid, cache_l.n_resid, sm_scale, backend=backend,
            )
        attn = attn.reshape(B, cfg.n_heads * cfg.hd)
        hh = hh + attn.astype(hh.dtype) @ gp["attn"]["attn"]["wo"]
        hh = hh + mlp_apply(gp["attn"]["mlp"], rmsnorm(hh, gp["attn"]["mlp_ln"]))
        return hh, (jnp.stack(new_lru), jnp.stack(new_conv), cache_l)

    h, (lru, conv, cache) = jax.lax.scan(
        group_body, h, (params["groups"], state.lru_h, state.conv, state.cache)
    )
    n_tail = state.tail_lru_h.shape[0]
    tails_l, tails_c = [], []
    for t in range(n_tail):
        tp = jax.tree_util.tree_map(lambda a: a[t], params["tail"])
        h, hist, hf = _rec_block_step(tp, cfg, h, state.tail_conv[t], state.tail_lru_h[t])
        tails_l.append(hf)
        tails_c.append(hist)
    hl = rmsnorm(h, params["final_ln"])
    logits = jnp.dot(hl, params["head"]).astype(jnp.float32)
    new_state = RGState(
        lru_h=lru, conv=conv, cache=cache,
        tail_lru_h=jnp.stack(tails_l) if n_tail else state.tail_lru_h,
        tail_conv=jnp.stack(tails_c) if n_tail else state.tail_conv,
        pos=pos + 1,
    )
    return logits, new_state


# ---------------------------------------------------------------------------
# slot ops (continuous batching) + chunked admission
# ---------------------------------------------------------------------------
# A slot is one batch row of every state leaf: the windowed attention caches
# go through the core helpers (insert_row / reset_slot / mask_free_slots, the
# same ones the transformer families use), the O(1) recurrent leaves are
# plain row scatters. Leaf batch axes: grouped [n_groups, 2, B, ...], cache
# counters [n_groups, B], tail [n_tail, B, ...], pos [B].


def insert_state_row(state: RGState, slot, row: RGState) -> RGState:
    """Scatter a B=1 prefill's state into row ``slot`` (traced ok)."""
    put2 = lambda dst, src: dst.at[:, :, slot].set(src[:, :, 0])
    put1 = lambda dst, src: dst.at[:, slot].set(src[:, 0])
    return RGState(
        lru_h=put2(state.lru_h, row.lru_h),
        conv=put2(state.conv, row.conv),
        cache=insert_row(state.cache, slot, row.cache),
        tail_lru_h=put1(state.tail_lru_h, row.tail_lru_h),
        tail_conv=put1(state.tail_conv, row.tail_conv),
        pos=state.pos.at[slot].set(row.pos[0]),
    )


def prefill_into_slot(params: dict, cfg: ArchConfig, pack_cfg, capacity: int,
                      cache: RGState, slot, batch: dict):
    """Admit ONE request into row ``slot`` at its TRUE length. The old
    WaveServer left-pad wave fed pad tokens through the RG-LRU recurrence
    AND the window cache; a B=1 prefill scattered into the row cannot."""
    logits, row = prefill(params, cfg, pack_cfg, capacity, batch)
    return logits, insert_state_row(cache, slot, row)


def reset_state_slot(state: RGState, slot) -> RGState:
    """Recycle row ``slot``: window-cache counters to zero via the core
    reset, recurrent leaves zeroed outright (they have no masking counter —
    a stale LRU state would leak into the next occupant's first token)."""
    z2 = lambda a: a.at[:, :, slot].set(jnp.zeros_like(a[:, :, slot]))
    z1 = lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot]))
    return RGState(
        lru_h=z2(state.lru_h),
        conv=z2(state.conv),
        cache=reset_slot(state.cache, slot),
        tail_lru_h=z1(state.tail_lru_h),
        tail_conv=z1(state.tail_conv),
        pos=state.pos.at[slot].set(0),
    )


def mask_free_rows(state: RGState, active: Array) -> RGState:
    """Re-zero state rows of inactive slots after a ride-along decode
    (``where`` so even a NaN in a dead row cannot survive)."""
    def m(a, lead):  # ``active`` broadcast at batch axis ``lead``
        am = active.reshape((1,) * lead + (-1,) + (1,) * (a.ndim - lead - 1))
        return jnp.where(am, a, jnp.zeros_like(a))

    return RGState(
        lru_h=m(state.lru_h, 2),
        conv=m(state.conv, 2),
        cache=mask_free_slots(state.cache, active),
        tail_lru_h=m(state.tail_lru_h, 1),
        tail_conv=m(state.tail_conv, 1),
        pos=jnp.where(active, state.pos, 0),
    )


def prefill_chunk_init(cfg: ArchConfig, pack_cfg, capacity: int,
                       *, prompt_len: int) -> dict:
    """Chunked-admission scratch: zero B=1 recurrent state plus a raw bf16
    K/V scratch per attention layer sized to the FULL prompt (the window
    cache is built once at insert — compression is deferred, so chunked
    bytes match the monolithic prefill's)."""
    n_groups, n_tail = divmod(cfg.n_layers, 3)
    R = cfg.lru_dim or cfg.d_model
    return {
        "k": jnp.zeros((n_groups, 1, cfg.n_kv_heads, prompt_len, cfg.hd),
                       jnp.bfloat16),
        "v": jnp.zeros((n_groups, 1, cfg.n_kv_heads, prompt_len, cfg.hd),
                       jnp.bfloat16),
        "lru_h": jnp.zeros((n_groups, 2, 1, R), jnp.float32),
        "conv": jnp.zeros((n_groups, 2, 1, CONV_W - 1, R), jnp.bfloat16),
        "tail_lru_h": jnp.zeros((n_tail, 1, R), jnp.float32),
        "tail_conv": jnp.zeros((n_tail, 1, CONV_W - 1, R), jnp.bfloat16),
    }


def prefill_chunk(params: dict, cfg: ArchConfig, pack_cfg, scratch: dict,
                  tokens: Array, *, n_ctx: int):
    """One bounded chunk of an interleaved admission (STATIC ``n_ctx``).

    Recurrent blocks resume exactly — the conv history is the last
    CONV_W-1 inputs and the LRU carry is the scan state, both carried in
    ``scratch`` — and attention resumes via ``resume_attention`` over the
    full-prompt K/V scratch (bit-identical per query row to the monolithic
    ``flash_attention``, window mask included). Composing chunks therefore
    reproduces the one-shot prefill's floats (see the transformer twin)."""
    B, Sc = tokens.shape
    h = params["embed"][tokens]
    positions = n_ctx + jnp.arange(Sc)

    def group_body(hh, xs):
        gp, lru, conv, k_s, v_s = xs
        new_lru, new_conv = [], []
        for r in range(2):
            rp = jax.tree_util.tree_map(lambda a: a[r], gp["rec"])
            hh, hist, hf = _rec_block_seq(rp, cfg, hh, conv[r], lru[r])
            new_lru.append(hf)
            new_conv.append(hist)
        x = rmsnorm(hh, gp["attn"]["ln"])
        q, k, v = qkv_proj(
            gp["attn"]["attn"], x, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            positions, cfg.rope_theta,
        )
        k_s = jax.lax.dynamic_update_slice_in_dim(
            k_s, k.astype(k_s.dtype), n_ctx, axis=2
        )
        v_s = jax.lax.dynamic_update_slice_in_dim(
            v_s, v.astype(v_s.dtype), n_ctx, axis=2
        )
        # static live-prefix slice (see the transformer twin): unwritten
        # scratch keys are masked zeros — dropping them keeps each chunk's
        # attention at Sc*(n_ctx+Sc) work, tiled cleanly past 1024
        t_used = n_ctx + Sc
        if t_used > 1024:
            t_used = min(k_s.shape[2], -(-t_used // 1024) * 1024)
        attn = resume_attention(q, k_s[:, :, :t_used], v_s[:, :, :t_used],
                                n_ctx, causal=True, window=cfg.window)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, Sc, cfg.n_heads * cfg.hd)
        hh = hh + jnp.dot(attn.astype(hh.dtype), gp["attn"]["attn"]["wo"])
        hh = hh + mlp_apply(gp["attn"]["mlp"], rmsnorm(hh, gp["attn"]["mlp_ln"]))
        return hh, (jnp.stack(new_lru), jnp.stack(new_conv), k_s, v_s)

    h, (lru, conv, k_s, v_s) = jax.lax.scan(
        group_body, h,
        (params["groups"], scratch["lru_h"], scratch["conv"],
         scratch["k"], scratch["v"]),
    )
    n_tail = scratch["tail_lru_h"].shape[0]
    tails_l, tails_c = [], []
    for t in range(n_tail):
        tp = jax.tree_util.tree_map(lambda a: a[t], params["tail"])
        h, hist, hf = _rec_block_seq(
            tp, cfg, h, scratch["tail_conv"][t], scratch["tail_lru_h"][t]
        )
        tails_l.append(hf)
        tails_c.append(hist)
    hl = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(hl, params["head"])[:, 0].astype(jnp.float32)
    new_scratch = {
        "k": k_s, "v": v_s, "lru_h": lru, "conv": conv,
        "tail_lru_h": jnp.stack(tails_l) if n_tail else scratch["tail_lru_h"],
        "tail_conv": jnp.stack(tails_c) if n_tail else scratch["tail_conv"],
    }
    return logits, new_scratch


def prefill_chunk_insert(cfg: ArchConfig, pack_cfg, capacity: int,
                         cache: RGState, slot, scratch: dict) -> RGState:
    """Finish a chunked admission: compress the last ``min(T, window)``
    scratch tokens per attention layer into a fresh B=1 window cache —
    the SAME ``prefill_cache`` call (same inputs, so same bytes) the
    monolithic prefill makes — and scatter the whole row into ``slot``."""
    T = scratch["k"].shape[3]
    W = cfg.window
    Wc = min(T, W)

    def one_group(carry, ys):
        k, v = ys
        cache_l = alloc_layer_cache(pack_cfg, 1, cfg.n_kv_heads, cfg.hd, W)
        cache_l = prefill_cache(cache_l, k[..., -Wc:, :], v[..., -Wc:, :])
        return carry, cache_l

    _, row_cache = jax.lax.scan(one_group, 0, (scratch["k"], scratch["v"]))
    row = RGState(
        lru_h=scratch["lru_h"], conv=scratch["conv"], cache=row_cache,
        tail_lru_h=scratch["tail_lru_h"], tail_conv=scratch["tail_conv"],
        pos=jnp.full((1,), T, jnp.int32),
    )
    return insert_state_row(cache, slot, row)
