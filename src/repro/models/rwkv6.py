"""RWKV-6 "Finch" (arXiv:2404.05892): linear RNN with data-dependent decay.

PackKV is INAPPLICABLE here (DESIGN.md §4): decode state is O(1) in context
length — a per-head [N, N] matrix — so there is no growing KV cache to
compress. The arch is implemented without the technique; its fixed-size
WKV state can optionally round-trip through the paper's quantizer
(``state_rel_scale``), which is a beyond-paper extra, not the contribution.

Faithful-enough simplifications (recorded here): static channel mixing
coefficients for r/k/v/g token-shift interpolation; the defining Finch
feature — LoRA data-dependent decay w_t — is kept exactly:
``w_t = exp(-exp(w0 + tanh(x_w A) B))``.

Recurrence per head (k, v, r ∈ R^N, state S ∈ R^{N×N}):
  y_t = (S_{t-1} + diag(u) k_tᵀ v_t)ᵀ r_t
  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..utils import pytree_dataclass
from .layers import dense_init, rmsnorm, rmsnorm_init, softmax_xent

Array = jax.Array

LORA_RANK = 64
WKV_CHUNK = 64  # remat chunk length for the sequential WKV scan (§Perf M3)
CHUNK_C = 16  # chunked matmul-form WKV chunk length (§Perf H2)
# per-step decay clamp for the factorized form: C/2·|MIN_LOGW| = 32 keeps
# every factor exponent f32-safe with no pair-weight distortion (decays
# faster than e^-4/step are fully forgotten in <3 steps anyway)
MIN_LOGW = -4.0
_EXP_CLIP = 40.0  # belt-and-braces on factor exponents (inert given clamp)


@pytree_dataclass
class RwkvState:
    """Decode state: [n_layers, ...] stacked."""

    S: Array  # f32 [n_layers, B, H, N, N] wkv state
    tm_x: Array  # bf16 [n_layers, B, D] last token (time-mix shift)
    cm_x: Array  # bf16 [n_layers, B, D] last token (channel-mix shift)
    pos: Array  # i32 [B] per-row decoded length (slot-table bookkeeping)


def init_layer(key, cfg: ArchConfig) -> dict:
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    ks = jax.random.split(key, 10)
    return {
        "ln1": rmsnorm_init(D),
        "ln2": rmsnorm_init(D),
        "mu": (jax.random.uniform(ks[0], (5, D)) * 0.5 + 0.25).astype(jnp.bfloat16),
        "w0": jnp.zeros((D,), jnp.float32) - 6.0,  # slow default decay
        "wA": dense_init(ks[1], D, LORA_RANK, jnp.float32),
        "wB": (jax.random.normal(ks[2], (LORA_RANK, D)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[3], (H, N)) * 0.1).astype(jnp.float32),
        "wr": dense_init(ks[4], D, D),
        "wk": dense_init(ks[5], D, D),
        "wv": dense_init(ks[6], D, D),
        "wg": dense_init(ks[7], D, D),
        "wo": dense_init(ks[8], D, D),
        "ln_x": rmsnorm_init(D),
        # channel mix
        "cm_wk": dense_init(ks[9], D, cfg.d_ff),
        "cm_wv": dense_init(jax.random.fold_in(key, 99), cfg.d_ff, D),
        "cm_wr": dense_init(jax.random.fold_in(key, 98), D, D),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    k0, k1, k2 = jax.random.split(key, 3)
    layer_keys = jax.random.split(k0, cfg.n_layers)
    return {
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "embed": (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02).astype(
            jnp.bfloat16
        ),
        "final_ln": rmsnorm_init(cfg.d_model),
        "head": dense_init(k2, cfg.d_model, cfg.vocab),
    }


def _decay(p: dict, xw: Array) -> Array:
    """Data-dependent decay w_t in (0, 1). xw: [..., D] -> [..., D] f32."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    return jnp.exp(-jnp.exp(p["w0"] + lora))


def _wkv_chunked(r, k, v, w, u, S0):
    """Chunked matmul-form WKV (§Perf H2): the exact recurrence
      y_t = r_t·(S_{t-1} + diag(u) k_tᵀ v_t);  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    evaluated per CHUNK_C-token chunk as three matmuls, so the [N,N] state
    is read/written once per chunk (÷C HBM traffic) and the per-step VPU
    elementwise work becomes MXU matmuls.

    Factorization: with lp = cumsum(log w), the cross-token weight
    exp(lp_{t-1} - lp_s) splits as exp(lp_{t-1} - ρ)·exp(ρ - lp_s) around
    the mid-chunk reference ρ; clamping log w >= MIN_LOGW bounds both
    factors' exponents by C/2·|MIN_LOGW| < 88 (f32-safe). Verified against
    the sequential scan in tests/test_rwkv_chunked.py.

    r,k,v,w: [B,T,H,N] (w = decay in (0,1)); u: [H,N]; S0: [B,H,N,N].
    Returns (y [B,T,H,N], S_final).
    """
    B, T, H, N = r.shape
    C = CHUNK_C
    assert T % C == 0
    tm = lambda a: jnp.moveaxis(a, 1, 0).reshape(T // C, C, B, H, N)
    rs, ks, vs = tm(r), tm(k), tm(v)
    lws = tm(jnp.maximum(jnp.log(jnp.maximum(w, 1e-38)), MIN_LOGW))
    mask = jnp.tril(jnp.ones((C, C)), -1)  # strict lower: s < t

    def chunk(S, inp):
        rc, kc, vc, lw = inp  # [C,B,H,N]
        lp = jnp.cumsum(lw, axis=0)  # [C,B,H,N]
        lp_prev = jnp.concatenate([jnp.zeros_like(lp[:1]), lp[:-1]], axis=0)
        rho = lp[C // 2]  # [B,H,N]
        W1 = rc * jnp.exp(jnp.clip(lp_prev - rho, -_EXP_CLIP, _EXP_CLIP))
        W2 = kc * jnp.exp(jnp.clip(rho - lp, -_EXP_CLIP, _EXP_CLIP))
        scores = jnp.einsum("tbhn,sbhn->bhts", W1, W2)
        scores = scores * mask[None, None]
        y_intra = jnp.einsum("bhts,sbhm->tbhm", scores, vc)
        y_S0 = jnp.einsum("tbhn,bhnm->tbhm", rc * jnp.exp(lp_prev), S)
        y_diag = jnp.sum(rc * u[None, None] * kc, -1, keepdims=True) * vc
        decay_end = jnp.exp(lp[-1])  # [B,H,N]
        S_new = decay_end[..., :, None] * S + jnp.einsum(
            "tbhn,tbhm->bhnm", kc * jnp.exp(lp[-1][None] - lp), vc
        )
        return S_new, y_S0 + y_intra + y_diag

    S, ys = jax.lax.scan(chunk, S0, (rs, ks, vs, lws))
    y = ys.reshape(T, B, H, N)
    return jnp.moveaxis(y, 0, 1), S


def _time_mix_seq(p: dict, cfg: ArchConfig, x: Array, x_prev: Array, S0: Array):
    """Sequential WKV over [B, S, D]; returns (y, S_final, last_x)."""
    B, T, D = x.shape
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)  # shifted
    mu = p["mu"]
    mix = lambda i: x + mu[i] * (xs - x)
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, N).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, N).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, N).astype(jnp.float32)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    w = _decay(p, xw).reshape(B, T, H, N)  # [B,T,H,N]
    u = p["u"]  # [H, N]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,N] each
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B,H,N,N]
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    def _seq(r_, k_, v_, w_, S_init):
        """Sequential scan over [B, t, H, N] slices; remat-chunked when the
        span is long (saving S per step costs t·|S| at backward peak — 34 GB
        at 4k×16 local batch; checkpoint WKV_CHUNK-step chunks, §Perf M3)."""
        t = r_.shape[1]
        rs, ks_, vs, ws = (jnp.moveaxis(a, 1, 0) for a in (r_, k_, v_, w_))
        C = WKV_CHUNK if t % WKV_CHUNK == 0 and t > WKV_CHUNK else 1
        if C > 1:
            chunked = lambda a: a.reshape(t // C, C, *a.shape[1:])
            rs, ks_, vs, ws = (chunked(a) for a in (rs, ks_, vs, ws))

            @jax.checkpoint
            def chunk_step(S, inp):
                return jax.lax.scan(step, S, inp)

            S, ys = jax.lax.scan(chunk_step, S_init, (rs, ks_, vs, ws))
            ys = ys.reshape(t, *ys.shape[2:])
        else:
            S, ys = jax.lax.scan(step, S_init, (rs, ks_, vs, ws))
        return jnp.moveaxis(ys, 0, 1), S  # [B, t, H, N], S

    # MIXED path: chunked matmul form (§Perf H2) over the CHUNK_C-aligned
    # prefix, sequential scan over the sub-chunk tail. Always taking the
    # chunked form for the aligned bulk (instead of only when T % CHUNK_C
    # == 0) makes the recurrence COMPOSE bit-exactly across any 16-aligned
    # split: running [0, Tb) then [Tb, T) from the carried state replays
    # the identical per-chunk scan — the invariant the chunk-interleaved
    # SlotServer admission relies on (chunk sizes are page multiples).
    Tb = (T // CHUNK_C) * CHUNK_C
    if Tb == 0:
        y4, S = _seq(r, k, v, w, S0)
    elif Tb == T:
        y4, S = _wkv_chunked(r, k, v, w, u, S0)
    else:
        y_a, S_mid = _wkv_chunked(r[:, :Tb], k[:, :Tb], v[:, :Tb], w[:, :Tb],
                                  u, S0)
        y_b, S = _seq(r[:, Tb:], k[:, Tb:], v[:, Tb:], w[:, Tb:], S_mid)
        y4 = jnp.concatenate([y_a, y_b], axis=1)
    y = y4.reshape(B, T, D)
    y = rmsnorm(y.astype(x.dtype), p["ln_x"]) * g.astype(x.dtype)
    return (y @ p["wo"]), S, x[:, -1]


def _channel_mix_seq(p: dict, x: Array, x_prev: Array):
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xk = x + 0.5 * (xs - x)
    xr = x + 0.5 * (xs - x)
    kk = jnp.square(jax.nn.relu((xk @ p["cm_wk"]).astype(jnp.float32))).astype(x.dtype)
    return jax.nn.sigmoid((xr @ p["cm_wr"]).astype(jnp.float32)).astype(x.dtype) * (
        kk @ p["cm_wv"]
    ), x[:, -1]


def forward_train(params: dict, cfg: ArchConfig, batch: dict):
    tokens = batch["tokens"]
    B, T = tokens.shape
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    h = params["embed"][tokens]

    def body(hh, lp):
        z = jnp.zeros((B, D), hh.dtype)
        S0 = jnp.zeros((B, H, N, N), jnp.float32)
        y, _, _ = _time_mix_seq(lp, cfg, rmsnorm(hh, lp["ln1"]), z, S0)
        hh = hh + y
        c, _ = _channel_mix_seq(lp, rmsnorm(hh, lp["ln2"]), z)
        return hh + c, None

    from ..distributed.sharding import constrain

    def wrapped(hh, lp):
        hh, y = jax.checkpoint(body)(hh, lp)
        return constrain(hh, "batch", "model", None), y

    h, _ = jax.lax.scan(wrapped, h, params["layers"])
    h = rmsnorm(h, params["final_ln"])
    return jnp.dot(h, params["head"]).astype(jnp.float32), jnp.zeros((), jnp.float32)


def alloc_state(cfg: ArchConfig, batch: int) -> RwkvState:
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    L = cfg.n_layers
    return RwkvState(
        S=jnp.zeros((L, batch, H, N, N), jnp.float32),
        tm_x=jnp.zeros((L, batch, D), jnp.bfloat16),
        cm_x=jnp.zeros((L, batch, D), jnp.bfloat16),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _forward_seq(params: dict, cfg: ArchConfig, tokens: Array,
                 state: RwkvState):
    """Run a token span through the recurrence, RESUMING from ``state``
    (zero state == a cold prefill). Because the mixed WKV path composes
    bit-exactly at CHUNK_C-aligned splits and the token-shift/channel-mix
    carries are exactly the last token, prefilling [0, c) then [c, T) from
    the carried state reproduces the one-shot prefill — the chunked
    admission path IS this function called per chunk."""
    B, T = tokens.shape
    h = params["embed"][tokens]

    def body(hh, xs):
        lp, S0, tm0, cm0 = xs
        xin = rmsnorm(hh, lp["ln1"])
        y, S, tm_x = _time_mix_seq(lp, cfg, xin, tm0, S0)
        hh = hh + y
        xc = rmsnorm(hh, lp["ln2"])
        c, cm_x = _channel_mix_seq(lp, xc, cm0)
        return hh + c, (S, tm_x, cm_x)

    h, (S, tm_x, cm_x) = jax.lax.scan(
        body, h, (params["layers"], state.S, state.tm_x, state.cm_x)
    )
    hl = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(hl, params["head"])[:, 0].astype(jnp.float32)
    return logits, RwkvState(S=S, tm_x=tm_x, cm_x=cm_x, pos=state.pos + T)


def prefill(params: dict, cfg: ArchConfig, pack_cfg, capacity, batch: dict):
    """Run the prompt through the recurrence; state is the 'cache'."""
    tokens = batch["tokens"]
    return _forward_seq(params, cfg, tokens, alloc_state(cfg, tokens.shape[0]))


# -- slot ops (continuous batching over recurrent rows) ----------------------
# The O(1) per-row state makes these trivial: a slot is one batch row of
# every state leaf, admission is a B=1 prefill scattered into that row, and
# recycling just zeroes it. No paging, no counters — but the SAME SlotServer
# admission/retire path as the transformer families (docs/serving.md).


def insert_state_row(state: RwkvState, slot, row: RwkvState) -> RwkvState:
    """Scatter a B=1 prefill's state into row ``slot`` (traced ok)."""
    put = lambda dst, src: dst.at[:, slot].set(src[:, 0])
    return RwkvState(
        S=put(state.S, row.S),
        tm_x=put(state.tm_x, row.tm_x),
        cm_x=put(state.cm_x, row.cm_x),
        pos=state.pos.at[slot].set(row.pos[0]),
    )


def prefill_into_slot(params: dict, cfg: ArchConfig, pack_cfg, capacity: int,
                      cache: RwkvState, slot, batch: dict):
    """Admit ONE request into row ``slot`` at its TRUE length (no padding:
    the old WaveServer left-pad path fed pad tokens through the recurrence,
    polluting S/tm_x/cm_x — a B=1 prefill scattered into the row cannot)."""
    logits, row = prefill(params, cfg, pack_cfg, capacity, batch)
    return logits, insert_state_row(cache, slot, row)


def reset_state_slot(state: RwkvState, slot) -> RwkvState:
    zero = lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot]))
    return RwkvState(
        S=zero(state.S), tm_x=zero(state.tm_x), cm_x=zero(state.cm_x),
        pos=state.pos.at[slot].set(0),
    )


def mask_free_rows(state: RwkvState, active: Array) -> RwkvState:
    """Re-zero state rows of inactive slots (junk-append hygiene; uses
    ``where`` so even a NaN in a dead row cannot survive)."""
    def m(a):  # leaves [L, B, ...]
        am = active.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(am, a, jnp.zeros_like(a))

    return RwkvState(
        S=m(state.S), tm_x=m(state.tm_x), cm_x=m(state.cm_x),
        pos=jnp.where(active, state.pos, 0),
    )


def prefill_chunk_init(cfg: ArchConfig, pack_cfg, capacity: int,
                       *, prompt_len: int) -> RwkvState:
    """Chunked-admission scratch: a zero B=1 state (the resume point)."""
    del prompt_len
    return alloc_state(cfg, 1)


def prefill_chunk(params: dict, cfg: ArchConfig, pack_cfg,
                  scratch: RwkvState, tokens: Array, *, n_ctx: int):
    """One bounded chunk of an interleaved admission: advance the B=1 state
    through ``tokens``. ``n_ctx`` is implied by the carried state (accepted
    for cross-family signature uniformity); chunk boundaries must be
    CHUNK_C-aligned for bit-exact composition — page sizes are."""
    del n_ctx
    return _forward_seq(params, cfg, tokens, scratch)


def prefill_chunk_insert(cfg: ArchConfig, pack_cfg, capacity: int,
                         cache: RwkvState, slot, scratch: RwkvState):
    return insert_state_row(cache, slot, scratch)


def decode_step(params: dict, cfg: ArchConfig, cache: RwkvState, token: Array,
                *, backend: str = "xla", n_bucket: int | None = None):
    """One decode token. token [B, 1] -> (logits [B, V], state).

    ``n_bucket`` is accepted for registry-signature uniformity and ignored:
    recurrent state is O(1) in sequence length — nothing to bucket.
    """
    del n_bucket
    state = cache  # uniform arg name across families (registry contract)
    B = token.shape[0]
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    h = params["embed"][token[:, 0]]  # [B, D]

    def body(hh, xs):
        lp, S, tm_x, cm_x = xs
        xin = rmsnorm(hh, lp["ln1"])
        mu = lp["mu"]
        mix = lambda i: xin + mu[i] * (tm_x - xin)
        xr, xk, xv, xw, xg = (mix(i) for i in range(5))
        r = (xr @ lp["wr"]).reshape(B, H, N).astype(jnp.float32)
        k = (xk @ lp["wk"]).reshape(B, H, N).astype(jnp.float32)
        v = (xv @ lp["wv"]).reshape(B, H, N).astype(jnp.float32)
        g = jax.nn.silu((xg @ lp["wg"]).astype(jnp.float32))
        w = _decay(lp, xw).reshape(B, H, N)
        kv = k[..., :, None] * v[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", r, S + lp["u"][None, :, :, None] * kv)
        S = w[..., :, None] * S + kv
        y = y.reshape(B, D)
        y = rmsnorm(y.astype(hh.dtype), lp["ln_x"]) * g.astype(hh.dtype).reshape(B, D)
        hh = hh + y @ lp["wo"]
        xc = rmsnorm(hh, lp["ln2"])
        xkc = xc + 0.5 * (cm_x - xc)
        xrc = xc + 0.5 * (cm_x - xc)
        kk = jnp.square(jax.nn.relu((xkc @ lp["cm_wk"]).astype(jnp.float32))).astype(
            xc.dtype
        )
        c = jax.nn.sigmoid((xrc @ lp["cm_wr"]).astype(jnp.float32)).astype(xc.dtype) * (
            kk @ lp["cm_wv"]
        )
        return hh + c, (S, xin, xc)

    h, (S, tm_x, cm_x) = jax.lax.scan(
        body, h, (params["layers"], state.S, state.tm_x, state.cm_x)
    )
    hl = rmsnorm(h, params["final_ln"])
    logits = jnp.dot(hl, params["head"]).astype(jnp.float32)
    return logits, RwkvState(S=S, tm_x=tm_x, cm_x=cm_x, pos=state.pos + 1)
