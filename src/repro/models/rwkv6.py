"""RWKV-6 "Finch" (arXiv:2404.05892): linear RNN with data-dependent decay.

PackKV is INAPPLICABLE here (DESIGN.md §4): decode state is O(1) in context
length — a per-head [N, N] matrix — so there is no growing KV cache to
compress. The arch is implemented without the technique; its fixed-size
WKV state can optionally round-trip through the paper's quantizer
(``state_rel_scale``), which is a beyond-paper extra, not the contribution.

Faithful-enough simplifications (recorded here): static channel mixing
coefficients for r/k/v/g token-shift interpolation; the defining Finch
feature — LoRA data-dependent decay w_t — is kept exactly:
``w_t = exp(-exp(w0 + tanh(x_w A) B))``.

Recurrence per head (k, v, r ∈ R^N, state S ∈ R^{N×N}):
  y_t = (S_{t-1} + diag(u) k_tᵀ v_t)ᵀ r_t
  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..utils import pytree_dataclass
from .layers import dense_init, rmsnorm, rmsnorm_init, softmax_xent

Array = jax.Array

LORA_RANK = 64
WKV_CHUNK = 64  # remat chunk length for the sequential WKV scan (§Perf M3)
CHUNK_C = 16  # chunked matmul-form WKV chunk length (§Perf H2)
# per-step decay clamp for the factorized form: C/2·|MIN_LOGW| = 32 keeps
# every factor exponent f32-safe with no pair-weight distortion (decays
# faster than e^-4/step are fully forgotten in <3 steps anyway)
MIN_LOGW = -4.0
_EXP_CLIP = 40.0  # belt-and-braces on factor exponents (inert given clamp)


@pytree_dataclass
class RwkvState:
    """Decode state: [n_layers, ...] stacked."""

    S: Array  # f32 [n_layers, B, H, N, N] wkv state
    tm_x: Array  # bf16 [n_layers, B, D] last token (time-mix shift)
    cm_x: Array  # bf16 [n_layers, B, D] last token (channel-mix shift)
    pos: Array  # i32 []


def init_layer(key, cfg: ArchConfig) -> dict:
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    ks = jax.random.split(key, 10)
    return {
        "ln1": rmsnorm_init(D),
        "ln2": rmsnorm_init(D),
        "mu": (jax.random.uniform(ks[0], (5, D)) * 0.5 + 0.25).astype(jnp.bfloat16),
        "w0": jnp.zeros((D,), jnp.float32) - 6.0,  # slow default decay
        "wA": dense_init(ks[1], D, LORA_RANK, jnp.float32),
        "wB": (jax.random.normal(ks[2], (LORA_RANK, D)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[3], (H, N)) * 0.1).astype(jnp.float32),
        "wr": dense_init(ks[4], D, D),
        "wk": dense_init(ks[5], D, D),
        "wv": dense_init(ks[6], D, D),
        "wg": dense_init(ks[7], D, D),
        "wo": dense_init(ks[8], D, D),
        "ln_x": rmsnorm_init(D),
        # channel mix
        "cm_wk": dense_init(ks[9], D, cfg.d_ff),
        "cm_wv": dense_init(jax.random.fold_in(key, 99), cfg.d_ff, D),
        "cm_wr": dense_init(jax.random.fold_in(key, 98), D, D),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    k0, k1, k2 = jax.random.split(key, 3)
    layer_keys = jax.random.split(k0, cfg.n_layers)
    return {
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "embed": (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02).astype(
            jnp.bfloat16
        ),
        "final_ln": rmsnorm_init(cfg.d_model),
        "head": dense_init(k2, cfg.d_model, cfg.vocab),
    }


def _decay(p: dict, xw: Array) -> Array:
    """Data-dependent decay w_t in (0, 1). xw: [..., D] -> [..., D] f32."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    return jnp.exp(-jnp.exp(p["w0"] + lora))


def _wkv_chunked(r, k, v, w, u, S0):
    """Chunked matmul-form WKV (§Perf H2): the exact recurrence
      y_t = r_t·(S_{t-1} + diag(u) k_tᵀ v_t);  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    evaluated per CHUNK_C-token chunk as three matmuls, so the [N,N] state
    is read/written once per chunk (÷C HBM traffic) and the per-step VPU
    elementwise work becomes MXU matmuls.

    Factorization: with lp = cumsum(log w), the cross-token weight
    exp(lp_{t-1} - lp_s) splits as exp(lp_{t-1} - ρ)·exp(ρ - lp_s) around
    the mid-chunk reference ρ; clamping log w >= MIN_LOGW bounds both
    factors' exponents by C/2·|MIN_LOGW| < 88 (f32-safe). Verified against
    the sequential scan in tests/test_rwkv_chunked.py.

    r,k,v,w: [B,T,H,N] (w = decay in (0,1)); u: [H,N]; S0: [B,H,N,N].
    Returns (y [B,T,H,N], S_final).
    """
    B, T, H, N = r.shape
    C = CHUNK_C
    assert T % C == 0
    tm = lambda a: jnp.moveaxis(a, 1, 0).reshape(T // C, C, B, H, N)
    rs, ks, vs = tm(r), tm(k), tm(v)
    lws = tm(jnp.maximum(jnp.log(jnp.maximum(w, 1e-38)), MIN_LOGW))
    mask = jnp.tril(jnp.ones((C, C)), -1)  # strict lower: s < t

    def chunk(S, inp):
        rc, kc, vc, lw = inp  # [C,B,H,N]
        lp = jnp.cumsum(lw, axis=0)  # [C,B,H,N]
        lp_prev = jnp.concatenate([jnp.zeros_like(lp[:1]), lp[:-1]], axis=0)
        rho = lp[C // 2]  # [B,H,N]
        W1 = rc * jnp.exp(jnp.clip(lp_prev - rho, -_EXP_CLIP, _EXP_CLIP))
        W2 = kc * jnp.exp(jnp.clip(rho - lp, -_EXP_CLIP, _EXP_CLIP))
        scores = jnp.einsum("tbhn,sbhn->bhts", W1, W2)
        scores = scores * mask[None, None]
        y_intra = jnp.einsum("bhts,sbhm->tbhm", scores, vc)
        y_S0 = jnp.einsum("tbhn,bhnm->tbhm", rc * jnp.exp(lp_prev), S)
        y_diag = jnp.sum(rc * u[None, None] * kc, -1, keepdims=True) * vc
        decay_end = jnp.exp(lp[-1])  # [B,H,N]
        S_new = decay_end[..., :, None] * S + jnp.einsum(
            "tbhn,tbhm->bhnm", kc * jnp.exp(lp[-1][None] - lp), vc
        )
        return S_new, y_S0 + y_intra + y_diag

    S, ys = jax.lax.scan(chunk, S0, (rs, ks, vs, lws))
    y = ys.reshape(T, B, H, N)
    return jnp.moveaxis(y, 0, 1), S


def _time_mix_seq(p: dict, cfg: ArchConfig, x: Array, x_prev: Array, S0: Array):
    """Sequential WKV over [B, S, D]; returns (y, S_final, last_x)."""
    B, T, D = x.shape
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)  # shifted
    mu = p["mu"]
    mix = lambda i: x + mu[i] * (xs - x)
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, N).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, N).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, N).astype(jnp.float32)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    w = _decay(p, xw).reshape(B, T, H, N)  # [B,T,H,N]
    u = p["u"]  # [H, N]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,N] each
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B,H,N,N]
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    if T % CHUNK_C == 0:
        # chunked matmul form (§Perf H2): state r/w once per chunk
        y4, S = _wkv_chunked(r, k, v, w, u, S0)
        y = y4.reshape(B, T, D)
    else:
        rs, ks_, vs, ws = (jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
        # chunked remat fallback: saving S per step costs T·|S| at backward
        # peak (34 GB at 4k×16 local batch); checkpoint WKV_CHUNK-step
        # chunks instead (§Perf M3).
        C = WKV_CHUNK if T % WKV_CHUNK == 0 else 1
        if C > 1:
            chunked = lambda a: a.reshape(T // C, C, *a.shape[1:])
            rs, ks_, vs, ws = (chunked(a) for a in (rs, ks_, vs, ws))

            @jax.checkpoint
            def chunk_step(S, inp):
                return jax.lax.scan(step, S, inp)

            S, ys = jax.lax.scan(chunk_step, S0, (rs, ks_, vs, ws))
            ys = ys.reshape(T, *ys.shape[2:])
        else:
            S, ys = jax.lax.scan(step, S0, (rs, ks_, vs, ws))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, T, D)  # [B,T,D]
    y = rmsnorm(y.astype(x.dtype), p["ln_x"]) * g.astype(x.dtype)
    return (y @ p["wo"]), S, x[:, -1]


def _channel_mix_seq(p: dict, x: Array, x_prev: Array):
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xk = x + 0.5 * (xs - x)
    xr = x + 0.5 * (xs - x)
    kk = jnp.square(jax.nn.relu((xk @ p["cm_wk"]).astype(jnp.float32))).astype(x.dtype)
    return jax.nn.sigmoid((xr @ p["cm_wr"]).astype(jnp.float32)).astype(x.dtype) * (
        kk @ p["cm_wv"]
    ), x[:, -1]


def forward_train(params: dict, cfg: ArchConfig, batch: dict):
    tokens = batch["tokens"]
    B, T = tokens.shape
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    h = params["embed"][tokens]

    def body(hh, lp):
        z = jnp.zeros((B, D), hh.dtype)
        S0 = jnp.zeros((B, H, N, N), jnp.float32)
        y, _, _ = _time_mix_seq(lp, cfg, rmsnorm(hh, lp["ln1"]), z, S0)
        hh = hh + y
        c, _ = _channel_mix_seq(lp, rmsnorm(hh, lp["ln2"]), z)
        return hh + c, None

    from ..distributed.sharding import constrain

    def wrapped(hh, lp):
        hh, y = jax.checkpoint(body)(hh, lp)
        return constrain(hh, "batch", "model", None), y

    h, _ = jax.lax.scan(wrapped, h, params["layers"])
    h = rmsnorm(h, params["final_ln"])
    return jnp.dot(h, params["head"]).astype(jnp.float32), jnp.zeros((), jnp.float32)


def alloc_state(cfg: ArchConfig, batch: int) -> RwkvState:
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    L = cfg.n_layers
    return RwkvState(
        S=jnp.zeros((L, batch, H, N, N), jnp.float32),
        tm_x=jnp.zeros((L, batch, D), jnp.bfloat16),
        cm_x=jnp.zeros((L, batch, D), jnp.bfloat16),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill(params: dict, cfg: ArchConfig, pack_cfg, capacity, batch: dict):
    """Run the prompt through the recurrence; state is the 'cache'."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    h = params["embed"][tokens]

    def body(hh, lp):
        z = jnp.zeros((B, D), hh.dtype)
        S0 = jnp.zeros((B, H, N, N), jnp.float32)
        xin = rmsnorm(hh, lp["ln1"])
        y, S, tm_x = _time_mix_seq(lp, cfg, xin, z, S0)
        hh = hh + y
        xc = rmsnorm(hh, lp["ln2"])
        c, cm_x = _channel_mix_seq(lp, xc, z)
        return hh + c, (S, tm_x, cm_x)

    h, (S, tm_x, cm_x) = jax.lax.scan(body, h, params["layers"])
    hl = rmsnorm(h[:, -1:], params["final_ln"])
    logits = jnp.dot(hl, params["head"])[:, 0].astype(jnp.float32)
    return logits, RwkvState(S=S, tm_x=tm_x, cm_x=cm_x, pos=jnp.int32(T))


def decode_step(params: dict, cfg: ArchConfig, cache: RwkvState, token: Array,
                *, backend: str = "xla", n_bucket: int | None = None):
    """One decode token. token [B, 1] -> (logits [B, V], state).

    ``n_bucket`` is accepted for registry-signature uniformity and ignored:
    recurrent state is O(1) in sequence length — nothing to bucket.
    """
    del n_bucket
    state = cache  # uniform arg name across families (registry contract)
    B = token.shape[0]
    D = cfg.d_model
    H = cfg.wkv_heads or cfg.n_heads
    N = D // H
    h = params["embed"][token[:, 0]]  # [B, D]

    def body(hh, xs):
        lp, S, tm_x, cm_x = xs
        xin = rmsnorm(hh, lp["ln1"])
        mu = lp["mu"]
        mix = lambda i: xin + mu[i] * (tm_x - xin)
        xr, xk, xv, xw, xg = (mix(i) for i in range(5))
        r = (xr @ lp["wr"]).reshape(B, H, N).astype(jnp.float32)
        k = (xk @ lp["wk"]).reshape(B, H, N).astype(jnp.float32)
        v = (xv @ lp["wv"]).reshape(B, H, N).astype(jnp.float32)
        g = jax.nn.silu((xg @ lp["wg"]).astype(jnp.float32))
        w = _decay(lp, xw).reshape(B, H, N)
        kv = k[..., :, None] * v[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", r, S + lp["u"][None, :, :, None] * kv)
        S = w[..., :, None] * S + kv
        y = y.reshape(B, D)
        y = rmsnorm(y.astype(hh.dtype), lp["ln_x"]) * g.astype(hh.dtype).reshape(B, D)
        hh = hh + y @ lp["wo"]
        xc = rmsnorm(hh, lp["ln2"])
        xkc = xc + 0.5 * (cm_x - xc)
        xrc = xc + 0.5 * (cm_x - xc)
        kk = jnp.square(jax.nn.relu((xkc @ lp["cm_wk"]).astype(jnp.float32))).astype(
            xc.dtype
        )
        c = jax.nn.sigmoid((xrc @ lp["cm_wr"]).astype(jnp.float32)).astype(xc.dtype) * (
            kk @ lp["cm_wv"]
        )
        return hh + c, (S, xin, xc)

    h, (S, tm_x, cm_x) = jax.lax.scan(
        body, h, (params["layers"], state.S, state.tm_x, state.cm_x)
    )
    hl = rmsnorm(h, params["final_ln"])
    logits = jnp.dot(hl, params["head"]).astype(jnp.float32)
    return logits, RwkvState(S=S, tm_x=tm_x, cm_x=cm_x, pos=state.pos + 1)
