"""Shared neural-net layers (functional, pytree params, bf16-friendly).

Conventions:
  * params are nested dicts of jnp arrays; init fns take a jax PRNG key.
  * compute dtype bf16, accumulation/normalization in f32.
  * attention memory is bounded by double-chunked flash attention (pure
    lax.scan — no Pallas needed at train time; decode uses the PackKV
    fused kernels from repro.kernels).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> Array:
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.dot(x, w_gate)
    u = jnp.dot(x, w_up)
    return jnp.dot(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w_down)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 1e4) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: [..., S, Dh]; positions: [S] or broadcastable to x[..., S]."""
    Dh = x.shape[-1]
    freqs = rope_freqs(Dh, theta)  # [Dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash attention (training/prefill) — double-chunked, O(S·chunk) memory
# ---------------------------------------------------------------------------


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    sm_scale: float | None = None,
) -> Array:
    """Memory-bounded attention with GQA broadcast.

    q: [B, Hq, S, Dh]; k, v: [B, Hkv, S, Dh]. window>0 = sliding-window
    (local) attention of that width; causal applies the usual lower-
    triangular mask. Returns [B, Hq, S, Dh].
    """
    B, Hq, S, Dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(Dh)
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    assert S % qc == 0 and S % kc == 0
    nq, nk = S // qc, S // kc

    qg = q.reshape(B, Hkv, G, S, Dh)
    # [nq, B, Hkv, G, qc, Dh]
    q_ch = jnp.moveaxis(qg.reshape(B, Hkv, G, nq, qc, Dh), 3, 0)
    k_ch = jnp.moveaxis(k.reshape(B, Hkv, nk, kc, Dh), 2, 0)
    v_ch = jnp.moveaxis(v.reshape(B, Hkv, nk, kc, Dh), 2, 0)

    q_pos_base = jnp.arange(nq) * qc
    kv_pos_base = jnp.arange(nk) * kc

    def one_q_chunk(carry, xs):
        qi, qpb = xs  # [B,Hkv,G,qc,Dh], scalar
        qpos = qpb + jnp.arange(qc)  # [qc]

        def inner(acc, ys):
            ki, vi, kpb = ys
            m_p, l_p, o_p = acc
            kpos = kpb + jnp.arange(kc)  # [kc]
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qi.astype(jnp.float32), ki.astype(jnp.float32)
            ) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask, s, NEG_INF)
            m_n = jnp.maximum(m_p, s.max(-1))
            alpha = jnp.exp(m_p - m_n)
            p = jnp.exp(s - m_n[..., None])
            p = jnp.where(mask, p, 0.0)
            l_n = l_p * alpha + p.sum(-1)
            o_n = o_p * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32)
            )
            return (m_n, l_n, o_n), None

        m0 = jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, qc, Dh), jnp.float32)
        # remat each kv-chunk: backward recomputes the [*, qc, kc] score
        # tile instead of saving one per (q-chunk × kv-chunk) pair — drops
        # peak training memory by ~nq·nk× (see EXPERIMENTS.md §Perf M1)
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(inner), (m0, l0, o0), (k_ch, v_ch, kv_pos_base)
        )
        out = o / jnp.maximum(l[..., None], 1e-30)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(one_q_chunk, None, (q_ch, q_pos_base))
    # outs: [nq, B, Hkv, G, qc, Dh] -> [B, Hq, S, Dh]
    outs = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, G, S, Dh)
    return outs.reshape(B, Hq, S, Dh)


def resume_attention(
    q: Array,
    k_all: Array,
    v_all: Array,
    n_ctx: int,
    *,
    causal: bool = True,
    window: int = 0,
    kv_chunk: int = 1024,
    sm_scale: float | None = None,
) -> Array:
    """Chunk-resumable flash attention: queries at absolute positions
    ``n_ctx + arange(Sc)`` over a FULL-length key scratch.

    q: [B, Hq, Sc, Dh]; k_all/v_all: [B, Hkv, T, Dh] where only the first
    ``n_ctx + Sc`` keys are valid — later entries are unwritten scratch,
    excluded by the causal mask exactly like a not-yet-reached key in the
    monolithic pass. Mirrors ``flash_attention``'s inner loop op-for-op
    (same kv tiling ``kc = min(kv_chunk, T)``, same einsum contractions,
    same NEG_INF masking and running m/l/o merge) so each query row's
    output is BIT-IDENTICAL to the row a monolithic ``flash_attention``
    over the full T-token sequence computes: per-row results depend only
    on that row's masked key set, and the reduction order over keys is the
    chunk scan in both. This is what lets the chunked-interleaved prefill
    reproduce the monolithic engine's floats (see docs/serving.md).
    """
    B, Hq, Sc, Dh = q.shape
    Hkv = k_all.shape[1]
    G = Hq // Hkv
    T = k_all.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(Dh)
    kc = min(kv_chunk, T)
    assert T % kc == 0, (T, kc)
    nk = T // kc

    qg = q.reshape(B, Hkv, G, Sc, Dh)
    k_ch = jnp.moveaxis(k_all.reshape(B, Hkv, nk, kc, Dh), 2, 0)
    v_ch = jnp.moveaxis(v_all.reshape(B, Hkv, nk, kc, Dh), 2, 0)
    kv_pos_base = jnp.arange(nk) * kc
    qpos = n_ctx + jnp.arange(Sc)  # [Sc] absolute positions

    def inner(acc, ys):
        ki, vi, kpb = ys
        m_p, l_p, o_p = acc
        kpos = kpb + jnp.arange(kc)  # [kc]
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), ki.astype(jnp.float32)
        ) * scale
        mask = jnp.ones((Sc, kc), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_n = jnp.maximum(m_p, s.max(-1))
        alpha = jnp.exp(m_p - m_n)
        p = jnp.exp(s - m_n[..., None])
        p = jnp.where(mask, p, 0.0)
        l_n = l_p * alpha + p.sum(-1)
        o_n = o_p * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32)
        )
        return (m_n, l_n, o_n), None

    m0 = jnp.full((B, Hkv, G, Sc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sc), jnp.float32)
    o0 = jnp.zeros((B, Hkv, G, Sc, Dh), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        jax.checkpoint(inner), (m0, l0, o0), (k_ch, v_ch, kv_pos_base)
    )
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(B, Hq, Sc, Dh)


def ctx_attention(q: Array, k_all: Array, v_all: Array, n_ctx: int,
                  sm_scale: float) -> Array:
    """Segment attention for chunked prefill: queries over [context | self].

    q: [B, Hq, S, Dh]; k_all/v_all: [B, Hkv, n_ctx + S, Dh] where the first
    ``n_ctx`` (STATIC) keys are read-only context (fully visible to every
    query — they are strictly in the past) and the remaining S are the
    segment's own keys (causal). One f32 softmax: segments are at most one
    page of queries, so the [S, n_ctx + S] score tile stays small.
    """
    B, Hq, S, Dh = q.shape
    Hkv = k_all.shape[1]
    T = k_all.shape[2]
    qg = q.astype(jnp.float32).reshape(B, Hkv, Hq // Hkv, S, Dh)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qg,
                   k_all.astype(jnp.float32)) * sm_scale
    mask = jnp.arange(T)[None, :] <= (n_ctx + jnp.arange(S))[:, None]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, v_all.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    return out.reshape(B, Hq, S, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block params
# ---------------------------------------------------------------------------


def attention_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool = False, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def qkv_proj(p: dict, x: Array, n_heads: int, n_kv: int, head_dim: int,
             positions: Array, rope_theta: float = 1e4, qk_norm: bool = False,
             use_rope: bool = True):
    """x: [B, S, D] -> q [B,H,S,Dh], k/v [B,Hkv,S,Dh] (k rotated, cache-ready)."""
    B, S, _ = x.shape
    q = jnp.dot(x, p["wq"]).reshape(B, S, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = jnp.dot(x, p["wk"]).reshape(B, S, n_kv, head_dim).transpose(0, 2, 1, 3)
    v = jnp.dot(x, p["wv"]).reshape(B, S, n_kv, head_dim).transpose(0, 2, 1, 3)
    if qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp_apply(p: dict, x: Array) -> Array:
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def softmax_xent(logits: Array, labels: Array) -> Array:
    """Mean cross-entropy. logits [..., V] f32-upcast, labels [...] int."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
