"""Model registry: one uniform functional interface over all families.

ModelApi:
  init(key, cfg) -> params
  forward_train(params, cfg, batch) -> (logits, aux)
  loss_fn(params, cfg, batch) -> scalar loss
  prefill(params, cfg, pack_cfg, capacity, batch) -> (last_logits, cache)
  decode_step(params, cfg, cache, token, backend=...) -> (logits, cache)
  alloc_cache(cfg, pack_cfg, batch, capacity) -> cache pytree

Slot ops (continuous batching; None for families whose decode state cannot
be row-recycled yet — rwkv6/rglru carry recurrent per-layer state):
  prefill_into_slot(params, cfg, pack_cfg, capacity, cache, slot, batch)
      -> (last_logits [1, V], cache with row ``slot`` replaced)
  reset_slot(cache, slot) -> cache with row ``slot`` freed
  decode_multi(params, cfg, cache, token, active, n_steps, eos_id,
               t_max=..., backend=..., n_bucket=...)
      -> (tokens [t_max, B], n_exec, cache) — donated multi-step decode
      chunk (jit with donate_argnames=("cache",); see transformer.decode_steps)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import softmax_xent
from . import rglru, rwkv6, transformer

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    forward_train: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    alloc_cache: Callable
    prefill_into_slot: Optional[Callable] = None
    reset_slot: Optional[Callable] = None
    decode_multi: Optional[Callable] = None
    # Prefix-cache admission (PR 5): chunked prefill that maps a matched
    # page-aligned prompt prefix into the slot by reference and computes
    # only the suffix. None for families without page-addressable KV
    # (rwkv6 / hybrid_rglru recurrent state) — the Engine rejects
    # --prefix-cache for those with a clear error.
    prefill_prefix: Optional[Callable] = None

    @property
    def supports_slots(self) -> bool:
        return self.prefill_into_slot is not None


def _make_loss(forward_train):
    def loss_fn(params, cfg: ArchConfig, batch):
        from ..distributed.sharding import constrain

        logits, aux = forward_train(params, cfg, batch)
        if cfg.input_mode == "tokens_patches":
            logits = logits[:, cfg.n_patches :]  # loss on the text positions
        # f32 logits are the largest training activation; pin them to
        # (batch=DP, seq='model') so no device holds a full-vocab ×
        # full-seq copy (EXPERIMENTS.md §Perf M2)
        logits = constrain(logits, "batch", "model", None)
        return softmax_xent(logits, batch["labels"]) + AUX_WEIGHT * aux

    return loss_fn


def _transformer_api() -> ModelApi:
    return ModelApi(
        init=transformer.init_params,
        forward_train=transformer.forward_train,
        loss_fn=_make_loss(transformer.forward_train),
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        alloc_cache=transformer.alloc_cache,
        prefill_into_slot=transformer.prefill_into_slot,
        reset_slot=transformer.reset_cache_slot,
        decode_multi=transformer.decode_steps,
        prefill_prefix=transformer.prefill_into_slot_prefix,
    )


def _rwkv_api() -> ModelApi:
    return ModelApi(
        init=rwkv6.init_params,
        forward_train=rwkv6.forward_train,
        loss_fn=_make_loss(rwkv6.forward_train),
        prefill=rwkv6.prefill,
        decode_step=rwkv6.decode_step,
        alloc_cache=lambda cfg, pack_cfg, batch, capacity: rwkv6.alloc_state(
            cfg, batch
        ),
    )


def _rglru_api() -> ModelApi:
    return ModelApi(
        init=rglru.init_params,
        forward_train=rglru.forward_train,
        loss_fn=_make_loss(rglru.forward_train),
        prefill=rglru.prefill,
        decode_step=rglru.decode_step,
        alloc_cache=lambda cfg, pack_cfg, batch, capacity: rglru.alloc_state(
            cfg, pack_cfg, batch
        ),
    )


_FAMILIES = {
    "dense": _transformer_api,
    "moe": _transformer_api,
    "encoder": _transformer_api,
    "vlm": _transformer_api,
    "rwkv6": _rwkv_api,
    "hybrid_rglru": _rglru_api,
}


def get_model(cfg: ArchConfig) -> ModelApi:
    try:
        return _FAMILIES[cfg.family]()
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r}") from None
