"""Model registry: one uniform functional interface over all families.

ModelApi:
  init(key, cfg) -> params
  forward_train(params, cfg, batch) -> (logits, aux)
  loss_fn(params, cfg, batch) -> scalar loss
  prefill(params, cfg, pack_cfg, capacity, batch) -> (last_logits, cache)
  decode_step(params, cfg, cache, token, backend=...) -> (logits, cache)
  alloc_cache(cfg, pack_cfg, batch, capacity) -> cache pytree

Slot ops (continuous batching — EVERY family implements these; the decode
state is row-recycled whether it is a paged KV cache or O(1) recurrent
state):
  prefill_into_slot(params, cfg, pack_cfg, capacity, cache, slot, batch)
      -> (last_logits [1, V], cache with row ``slot`` replaced)
  reset_slot(cache, slot) -> cache with row ``slot`` freed
  mask_free(cache, active) -> cache with inactive rows re-zeroed after a
      ride-along decode step
  decode_multi(params, cfg, cache, token, active, n_steps, eos_id,
               t_max=..., backend=..., n_bucket=...)
      -> (tokens [t_max, B], n_exec, cache) — donated multi-step decode
      chunk (jit with donate_argnames=("cache",); see transformer.decode_steps)
      None for recurrent families (per-token launches there).

Chunked admission (PR 6 — interleaved prefill/decode; every family):
  prefill_chunk_init(cfg, pack_cfg, capacity, prompt_len=S) -> scratch
  prefill_chunk(params, cfg, pack_cfg, scratch, tokens, n_ctx=...)
      -> (last_logits [1, V], scratch) — one bounded chunk; STATIC n_ctx
  prefill_chunk_insert(cfg, pack_cfg, capacity, cache, slot, scratch)
      -> cache with row ``slot`` built from the finished scratch
Chunk boundaries must be page-aligned (transformer: exact flash resume
points; rwkv6: WKV chunk alignment) — the scheduler only ever cuts at
``prefill_chunk_pages * page_size`` multiples.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import softmax_xent
from . import rglru, rwkv6, transformer

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    forward_train: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    alloc_cache: Callable
    prefill_into_slot: Optional[Callable] = None
    reset_slot: Optional[Callable] = None
    mask_free: Optional[Callable] = None
    decode_multi: Optional[Callable] = None
    # Speculative verify (ISSUE 7): one batched forward over a q_len=w
    # draft window against the paged compressed cache
    # (transformer.verify_steps). None for the recurrent families — a
    # recurrent state update is inherently sequential per token, so the
    # Engine rejects --spec-decode for them with a clear error.
    decode_verify: Optional[Callable] = None
    # Prefix-cache admission (PR 5): chunked prefill that maps a matched
    # page-aligned prompt prefix into the slot by reference and computes
    # only the suffix. None for families without page-addressable KV
    # (rwkv6 / hybrid_rglru recurrent state) — the Engine rejects
    # --prefix-cache for those with a clear error.
    prefill_prefix: Optional[Callable] = None
    # Chunked interleaved admission (PR 6; see module docstring):
    prefill_chunk_init: Optional[Callable] = None
    prefill_chunk: Optional[Callable] = None
    prefill_chunk_insert: Optional[Callable] = None
    # Prefix-cache × chunked admission (transformer only): per-segment
    # resume through a paged mini-cache (bounds from prefix_chunk_bounds).
    prefix_chunk_bounds: Optional[Callable] = None
    prefix_chunk_init: Optional[Callable] = None
    prefix_chunk: Optional[Callable] = None
    prefix_chunk_insert: Optional[Callable] = None
    # Preemption (ISSUE 8): swap a slot row out to host RAM (compressed
    # pages + residual + counters) and stream it back bit-identically.
    # None for the recurrent families for now — their O(1) state row could
    # be copied out trivially, but the restore/refcount plumbing is
    # KV-specific, so the Engine rejects --preempt for them loudly.
    evacuate_slot: Optional[Callable] = None
    restore_slot: Optional[Callable] = None

    @property
    def supports_slots(self) -> bool:
        return self.prefill_into_slot is not None

    @property
    def supports_paged(self) -> bool:
        """Page-addressable KV (paged pool, buckets, prefix cache). The
        recurrent families' O(1) state has no pages to address."""
        return self.prefill_prefix is not None


def _make_loss(forward_train):
    def loss_fn(params, cfg: ArchConfig, batch):
        from ..distributed.sharding import constrain

        logits, aux = forward_train(params, cfg, batch)
        if cfg.input_mode == "tokens_patches":
            logits = logits[:, cfg.n_patches :]  # loss on the text positions
        # f32 logits are the largest training activation; pin them to
        # (batch=DP, seq='model') so no device holds a full-vocab ×
        # full-seq copy (EXPERIMENTS.md §Perf M2)
        logits = constrain(logits, "batch", "model", None)
        return softmax_xent(logits, batch["labels"]) + AUX_WEIGHT * aux

    return loss_fn


def _transformer_api() -> ModelApi:
    from ..core.cache import mask_free_slots

    return ModelApi(
        init=transformer.init_params,
        forward_train=transformer.forward_train,
        loss_fn=_make_loss(transformer.forward_train),
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        alloc_cache=transformer.alloc_cache,
        prefill_into_slot=transformer.prefill_into_slot,
        reset_slot=transformer.reset_cache_slot,
        mask_free=mask_free_slots,
        decode_multi=transformer.decode_steps,
        decode_verify=transformer.verify_steps,
        prefill_prefix=transformer.prefill_into_slot_prefix,
        prefill_chunk_init=transformer.prefill_chunk_init,
        prefill_chunk=transformer.prefill_chunk,
        prefill_chunk_insert=transformer.prefill_chunk_insert,
        prefix_chunk_bounds=transformer.prefix_chunk_bounds,
        prefix_chunk_init=transformer.prefix_chunk_init,
        prefix_chunk=transformer.prefix_chunk,
        prefix_chunk_insert=transformer.prefix_chunk_insert,
        evacuate_slot=transformer.evacuate_cache_slot,
        restore_slot=transformer.restore_cache_slot,
    )


def _rwkv_api() -> ModelApi:
    return ModelApi(
        init=rwkv6.init_params,
        forward_train=rwkv6.forward_train,
        loss_fn=_make_loss(rwkv6.forward_train),
        prefill=rwkv6.prefill,
        decode_step=rwkv6.decode_step,
        alloc_cache=lambda cfg, pack_cfg, batch, capacity: rwkv6.alloc_state(
            cfg, batch
        ),
        prefill_into_slot=rwkv6.prefill_into_slot,
        reset_slot=rwkv6.reset_state_slot,
        mask_free=rwkv6.mask_free_rows,
        prefill_chunk_init=rwkv6.prefill_chunk_init,
        prefill_chunk=rwkv6.prefill_chunk,
        prefill_chunk_insert=rwkv6.prefill_chunk_insert,
    )


def _rglru_api() -> ModelApi:
    return ModelApi(
        init=rglru.init_params,
        forward_train=rglru.forward_train,
        loss_fn=_make_loss(rglru.forward_train),
        prefill=rglru.prefill,
        decode_step=rglru.decode_step,
        alloc_cache=lambda cfg, pack_cfg, batch, capacity: rglru.alloc_state(
            cfg, pack_cfg, batch
        ),
        prefill_into_slot=rglru.prefill_into_slot,
        reset_slot=rglru.reset_state_slot,
        mask_free=rglru.mask_free_rows,
        prefill_chunk_init=rglru.prefill_chunk_init,
        prefill_chunk=rglru.prefill_chunk,
        prefill_chunk_insert=rglru.prefill_chunk_insert,
    )


_FAMILIES = {
    "dense": _transformer_api,
    "moe": _transformer_api,
    "encoder": _transformer_api,
    "vlm": _transformer_api,
    "rwkv6": _rwkv_api,
    "hybrid_rglru": _rglru_api,
}


def get_model(cfg: ArchConfig) -> ModelApi:
    try:
        return _FAMILIES[cfg.family]()
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r}") from None
