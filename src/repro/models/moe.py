"""Mixture-of-Experts MLP (qwen2-moe, moonshot): shared + routed top-k.

Two execution paths:

* ``_moe_dense`` — single-device / test path: capacity-bounded
  scatter/gather dispatch (positions from a [T·k, E] cumsum), experts as
  one batched SwiGLU. FLOPs are 2·3·E·C·D·Fe — capacity_factor× the ideal
  top-k compute, not the E× blow-up of mask-dense MoE.

* sharded path (active mesh) — the dispatch is wrapped in shard_map:
  tokens stay LOCAL to their DP shard (per-shard capacity), expert FFN
  weights are tensor-parallel over 'model' on the Fe dim with a psum to
  combine partials (Megatron-style TP inside the expert). Without this,
  GSPMD replicates the global [E·C, D] dispatch buffer on every device
  (measured 43 GB/device at 256×4096 — EXPERIMENTS.md §Perf M5).

Aux load-balancing loss follows Switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..utils import round_up
from .layers import dense_init

Array = jax.Array


def moe_init(key, cfg: ArchConfig) -> dict:
    E, D, Fe = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    std = 1.0 / (D ** 0.5)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, Fe)) * std).astype(jnp.bfloat16),
        "w_up": (jax.random.normal(ks[2], (E, D, Fe)) * std).astype(jnp.bfloat16),
        "w_down": (
            jax.random.normal(ks[3], (E, Fe, D)) * (1.0 / Fe ** 0.5)
        ).astype(jnp.bfloat16),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], D, Fs),
            "w_up": dense_init(kss[1], D, Fs),
            "w_down": dense_init(kss[2], Fs, D),
        }
    return p


def _dispatch_compute(x2: Array, router: Array, wg: Array, wu: Array,
                      wd: Array, E: int, k: int, capacity_factor: float):
    """Core routed-expert compute on LOCAL tokens x2 [T, D].

    wg/wu/wd may be Fe-slices (TP inside shard_map); returns the PARTIAL
    output (caller psums over 'model' when sliced) and the aux loss.
    """
    T, D = x2.shape
    logits = jnp.dot(x2.astype(jnp.float32), router)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss
    me = probs.mean(axis=0)  # [E]
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    C = round_up(max(int(T * k / E * capacity_factor), 8), 8)  # static
    fid = idx.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(fid, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # position in expert
    valid = (pos < C)[:, None].astype(x2.dtype)
    slot = fid * C + jnp.minimum(pos, C - 1)

    xrep = jnp.repeat(x2, k, axis=0)  # token-major, matches idx.reshape(-1)
    buf = jnp.zeros((E * C, D), x2.dtype).at[slot].add(xrep * valid)
    buf = buf.reshape(E, C, D)

    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E * C, D)

    out = y[slot] * valid * gate_vals.reshape(-1, 1).astype(x2.dtype)
    return out.reshape(T, k, D).sum(axis=1), aux


def _moe_dense(p: dict, x: Array, cfg: ArchConfig, capacity_factor: float):
    B, S, D = x.shape
    out, aux = _dispatch_compute(
        x.reshape(B * S, D), p["router"], p["w_gate"], p["w_up"], p["w_down"],
        cfg.n_experts, cfg.moe_topk, capacity_factor,
    )
    return out.reshape(B, S, D), aux


def _moe_sharded(p: dict, x: Array, cfg: ArchConfig, capacity_factor: float,
                 mesh):
    """shard_map dispatch: DP-local tokens, Fe-TP experts (+psum 'model')."""
    from ..distributed.sharding import dp_axes, spec_with_fallback

    dp = dp_axes(mesh)
    B, S, D = x.shape
    Fe = p["w_gate"].shape[-1]
    tp = "model" in mesh.axis_names and Fe % mesh.shape["model"] == 0
    x_spec = spec_with_fallback(x.shape, [dp, None, None], mesh)
    w_spec = P(None, None, "model") if tp else P(None, None, None)
    wd_spec = P(None, "model", None) if tp else P(None, None, None)

    def local(x_l, router, wg, wu, wd):
        Bl, Sl, _ = x_l.shape
        out, aux = _dispatch_compute(
            x_l.reshape(Bl * Sl, D), router, wg, wu, wd,
            cfg.n_experts, cfg.moe_topk, capacity_factor,
        )
        if tp:
            out = jax.lax.psum(out, "model")
        if dp and x_spec[0] is not None:
            aux = jax.lax.pmean(aux, dp if len(dp) > 1 else dp[0])
        return out.reshape(Bl, Sl, D), aux

    from ..utils import shard_map_compat

    f = shard_map_compat(
        local, mesh=mesh,
        in_specs=(x_spec, P(), w_spec, w_spec, wd_spec),
        out_specs=(x_spec, P()),
    )
    return f(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_apply(p: dict, x: Array, cfg: ArchConfig, capacity_factor: float = 1.25):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    from ..distributed.sharding import _ACTIVE_MESH

    if _ACTIVE_MESH is not None and _ACTIVE_MESH.size > 1:
        out, aux = _moe_sharded(p, x, cfg, capacity_factor, _ACTIVE_MESH)
    else:
        out, aux = _moe_dense(p, x, cfg, capacity_factor)

    if cfg.n_shared_experts:  # shared experts: plain TP dense mlp
        B, S, D = x.shape
        x2 = x.reshape(B * S, D)
        sp = p["shared"]
        gs = jnp.dot(x2, sp["w_gate"])
        us = jnp.dot(x2, sp["w_up"])
        out = out + jnp.dot(
            jax.nn.silu(gs.astype(jnp.float32)).astype(x2.dtype) * us, sp["w_down"]
        ).reshape(B, S, D)
    return out, aux
