"""Encode-aware repacking (paper §III-B3, Algorithm 1).

Reorders KV token vectors inside a block so that each bit-packing pack holds
similar vectors, shrinking per-pack ranges and therefore encoded widths.
Correctness rests on the permutation invariance of decode attention
(Att(q, PK, PV) == Att(q, K, V)); the permutation is applied JOINTLY to K and
V rows and never needs to be undone at decode time.

Implementations:

* ``greedy_repack``   — Algorithm 1: seed each pack with the vector closest
  to the centroid of the remaining set, then grow it by least incremental
  bit cost. O(N²D) on the host; storage-tier only.
* ``median_repack``   — "V Median Repacking": sort tokens by the median of
  their (quantized) V vector. O(N log N); also available in-graph (jnp) so
  the runtime cache can repack on-TPU.
* ``identity_repack`` — baseline (mode "none").

All return a permutation ``perm`` with the meaning: row i of the repacked
block is row ``perm[i]`` of the input.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .bitpack import bits_required


def identity_repack(q: np.ndarray, pack_size: int) -> np.ndarray:
    return np.arange(q.shape[0])


def median_repack(qv: np.ndarray, pack_size: int) -> np.ndarray:
    """Sort token rows by the median of their V vector (paper §III-B3)."""
    med = np.median(np.asarray(qv), axis=1)
    return np.argsort(med, kind="stable")


def median_repack_jnp(qv: jnp.ndarray) -> jnp.ndarray:
    """In-graph V-median repacking: jit/TPU-friendly (argsort + gather)."""
    med = jnp.median(qv, axis=-1)
    return jnp.argsort(med, axis=-1, stable=True)


def _pack_cost(mins: np.ndarray, maxs: np.ndarray, count: int) -> int:
    """Bit cost of one pack given per-dim running min/max ([D] each)."""
    return int(bits_required(maxs - mins).sum()) * count


def greedy_repack(q: np.ndarray, pack_size: int) -> np.ndarray:
    """Algorithm 1: greedy repacking for bit-packing.

    q: [N, D] quantized integers (the vectors being grouped — K, V, or the
    concatenation [K|V] for joint optimization).

    Returns perm [N] — concatenation of emitted packs.

    Incremental cost uses vectorized candidate evaluation: for pack state
    (running per-dim min/max), candidate j's marginal cost is
    sum_d bits(max(max_d, q_jd) - min(min_d, q_jd)) - current_bits, evaluated
    for all remaining j at once (O(R·D) per selection → O(N²D) total, as the
    paper states).
    """
    q = np.asarray(q, dtype=np.int64)
    n, d = q.shape
    assert n % pack_size == 0
    remaining = np.arange(n)
    order: list[int] = []
    while remaining.size:
        rq = q[remaining]
        centroid = rq.mean(axis=0)
        seed_pos = int(np.argmin(((rq - centroid) ** 2).sum(axis=1)))
        cur_min = rq[seed_pos].copy()
        cur_max = rq[seed_pos].copy()
        pack = [int(remaining[seed_pos])]
        remaining = np.delete(remaining, seed_pos)
        while len(pack) < pack_size and remaining.size:
            rq = q[remaining]
            cand_min = np.minimum(cur_min, rq)  # [R, D]
            cand_max = np.maximum(cur_max, rq)
            cost = bits_required(cand_max - cand_min).sum(axis=1)
            j = int(np.argmin(cost))
            cur_min = cand_min[j]
            cur_max = cand_max[j]
            pack.append(int(remaining[j]))
            remaining = np.delete(remaining, j)
        order.extend(pack)
    return np.asarray(order)


REPACKERS = {
    "none": lambda qk, qv, pack_size: identity_repack(qk, pack_size),
    "greedy_k": lambda qk, qv, pack_size: greedy_repack(qk, pack_size),
    "greedy_v": lambda qk, qv, pack_size: greedy_repack(qv, pack_size),
    "greedy_joint": lambda qk, qv, pack_size: greedy_repack(
        np.concatenate([qk, qv], axis=1), pack_size
    ),
    "median_v": lambda qk, qv, pack_size: median_repack(qv, pack_size),
}


def repack(qk: np.ndarray, qv: np.ndarray, pack_size: int, mode: str) -> np.ndarray:
    """Compute the joint K/V row permutation for ``mode``."""
    try:
        fn = REPACKERS[mode]
    except KeyError:
        raise ValueError(f"unknown repacking mode {mode!r}; one of {list(REPACKERS)}")
    return fn(qk, qv, pack_size)
