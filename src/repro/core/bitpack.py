"""Per-pack adaptive bit-packing — the paper's STORAGE format (§III-B3).

A *pack* is ``pack_size`` consecutive quantized integers along the context
(channel) direction. Per pack we store:

  * ``min``   — the pack minimum (subtracted before encoding),
  * ``width`` — ``ceil(log2(range+1))`` bits per value (0 when the pack is
    constant),
  * payload  — ``pack_size * width`` bits.

This module implements the exact variable-width format on the host (numpy):
it is the unit of CR accounting for every benchmark table, the offload/
checkpoint format, and the oracle the TPU compute-tier format (tiered.py) is
compared against. The compute path never touches this code at decode time —
that is the whole point of the paper's asymmetry argument (§III-A): encode is
rare and cheap, decode must be fused with the mat-vec (kernels/).

Sizes are reported in *bits* and include all metadata so compression ratios
match the paper's accounting style (KIVI 2-bit/64-group → 6.4x, 3-bit →
4.57x reproduce exactly with the same formulas).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# Size model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SizeModel:
    """Metadata field widths used in CR accounting.

    width_field_bits: per-pack encoded-length field (widths 0..15).
    min_field_bits:   per-pack minimum field.
    token_meta_bits:  per-(token, head) quantization metadata — fp16 scale +
      fp16 zero, as in KIVI's accounting.
    raw_bits:         uncompressed element width (fp16).
    """

    width_field_bits: int = 4
    min_field_bits: int = 8
    token_meta_bits: int = 32
    raw_bits: int = 16


DEFAULT_SIZE_MODEL = SizeModel()


def bits_required(rng: np.ndarray) -> np.ndarray:
    """ceil(log2(range+1)); 0 for constant packs. Vectorized."""
    rng = np.asarray(rng)
    out = np.zeros(rng.shape, dtype=np.int64)
    nz = rng > 0
    out[nz] = np.floor(np.log2(rng[nz])).astype(np.int64) + 1
    return out


def packed_payload_bits(q: np.ndarray, pack_size: int, axis: int = 0) -> int:
    """Analytic payload size (no metadata) of per-pack adaptive packing."""
    q = np.moveaxis(np.asarray(q), axis, 0)
    n = q.shape[0]
    assert n % pack_size == 0, f"{n} % {pack_size} != 0"
    qp = q.reshape(n // pack_size, pack_size, *q.shape[1:])
    rng = qp.max(axis=1) - qp.min(axis=1)
    return int(bits_required(rng).sum() * pack_size)


def packed_total_bits(
    q: np.ndarray,
    pack_size: int,
    axis: int = 0,
    size_model: SizeModel = DEFAULT_SIZE_MODEL,
    n_token_meta: int | None = None,
) -> int:
    """Payload + per-pack metadata + per-token quantization metadata.

    n_token_meta: number of (token, head) quantization units covered by q;
      defaults to q.shape[axis] (token-wise quantization of one head's block).
    """
    q = np.asarray(q)
    n = q.shape[axis]
    n_packs = (n // pack_size) * (q.size // n)
    payload = packed_payload_bits(q, pack_size, axis)
    meta = n_packs * (size_model.width_field_bits + size_model.min_field_bits)
    if n_token_meta is None:
        n_token_meta = n
    return payload + meta + n_token_meta * size_model.token_meta_bits


def compression_ratio(
    q: np.ndarray,
    pack_size: int,
    axis: int = 0,
    size_model: SizeModel = DEFAULT_SIZE_MODEL,
    n_token_meta: int | None = None,
) -> float:
    raw = q.size * size_model.raw_bits
    return raw / packed_total_bits(q, pack_size, axis, size_model, n_token_meta)


# ---------------------------------------------------------------------------
# Actual bitstream (round-trip exact)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedBlock:
    """One bit-packed 2D block (the storage unit of block_format.py).

    Packing runs along axis 0 of ``shape`` (the context direction); each of
    the ``shape[1]`` columns is split into ``shape[0]/pack_size`` packs.
    """

    payload: np.ndarray  # uint32 bitstream words
    widths: np.ndarray  # uint8  [n_cols, n_packs]
    mins: np.ndarray  # int32  [n_cols, n_packs]
    pack_size: int
    shape: tuple[int, int]
    payload_bits: int

    def total_bits(self, size_model: SizeModel = DEFAULT_SIZE_MODEL) -> int:
        n_packs = self.widths.size
        return self.payload_bits + n_packs * (
            size_model.width_field_bits + size_model.min_field_bits
        )


class _BitWriter:
    def __init__(self):
        self.words: list[int] = []
        self.cur = 0
        self.fill = 0

    def write(self, vals: np.ndarray, width: int) -> None:
        if width == 0:
            return
        for v in vals.tolist():
            self.cur |= (int(v) & ((1 << width) - 1)) << self.fill
            self.fill += width
            while self.fill >= 32:
                self.words.append(self.cur & 0xFFFFFFFF)
                self.cur >>= 32
                self.fill -= 32

    def finish(self) -> np.ndarray:
        if self.fill:
            self.words.append(self.cur & 0xFFFFFFFF)
        return np.asarray(self.words, dtype=np.uint32)


class _BitReader:
    def __init__(self, words: np.ndarray):
        self.words = words
        self.pos = 0  # bit position

    def read(self, count: int, width: int) -> np.ndarray:
        if width == 0:
            return np.zeros(count, dtype=np.int64)
        out = np.empty(count, dtype=np.int64)
        mask = (1 << width) - 1
        for i in range(count):
            w, b = divmod(self.pos, 32)
            v = int(self.words[w]) >> b
            got = 32 - b
            while got < width:
                w += 1
                v |= int(self.words[w]) << got
                got += 32
            out[i] = v & mask
            self.pos += width
        return out


def pack_block(q: np.ndarray, pack_size: int) -> PackedBlock:
    """Bit-pack a 2D integer block [N, D] along axis 0 (context)."""
    q = np.asarray(q, dtype=np.int64)
    n, d = q.shape
    assert n % pack_size == 0
    n_packs = n // pack_size
    qp = q.reshape(n_packs, pack_size, d)
    mins = qp.min(axis=1)  # [n_packs, d]
    rng = qp.max(axis=1) - mins
    widths = bits_required(rng)  # [n_packs, d]
    writer = _BitWriter()
    # column-major: all packs of column 0, then column 1, ... (paper Fig. 9
    # stores per-column pack runs; the interleaving for bank conflicts is a
    # GPU-ism we do not replicate — see DESIGN.md §3).
    for col in range(d):
        for p in range(n_packs):
            writer.write(qp[p, :, col] - mins[p, col], int(widths[p, col]))
    payload = writer.finish()
    payload_bits = int((widths * pack_size).sum())
    return PackedBlock(
        payload=payload,
        widths=widths.T.astype(np.uint8),  # [d, n_packs]
        mins=mins.T.astype(np.int32),
        pack_size=pack_size,
        shape=(n, d),
        payload_bits=payload_bits,
    )


def unpack_block(blk: PackedBlock) -> np.ndarray:
    n, d = blk.shape
    n_packs = n // blk.pack_size
    out = np.empty((n, d), dtype=np.int64)
    reader = _BitReader(blk.payload)
    for col in range(d):
        for p in range(n_packs):
            w = int(blk.widths[col, p])
            vals = reader.read(blk.pack_size, w) + int(blk.mins[col, p])
            out[p * blk.pack_size : (p + 1) * blk.pack_size, col] = vals
    return out
