"""KIVI baseline (Liu et al. 2024) — the paper's accuracy/CR comparison point.

KIVI: asymmetric quantization — **channel-wise** (per-channel, grouped along
the context dim) for K, **token-wise** for V, with a small residual window of
recent tokens kept in full precision. Bit-widths are integers (2/3/4); the
compression ratio includes fp16 (scale, zero) metadata per group:

  CR(b, g) = 16 / (b + 32/g)

e.g. 2-bit/64-group -> 6.4x, 3-bit/64 -> 4.57x, 4-bit/128 -> ~3.56x — the
exact numbers quoted in the paper's §III-B2.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .quantization import (
    QuantConfig,
    dequantize_channelwise,
    dequantize_tokenwise,
    quantize_channelwise,
    quantize_tokenwise,
)


@dataclasses.dataclass(frozen=True)
class KIVIConfig:
    k_bits: int = 2
    v_bits: int = 2
    group_size: int = 64  # K channel-group length along context
    residual: int = 128  # recent tokens kept in fp16


def kivi_cr(bits: int, group_size: int, raw_bits: int = 16) -> float:
    return raw_bits / (bits + 32.0 / group_size)


def kivi_cr_from_rel_scale(rel_scale: float, group_size: int = 64) -> float:
    """CR of the smallest integer bit-width whose error <= rel_scale/2.

    b-bit quantization has rel error bound 1/(2*(2^b - 1)); the smallest b
    with 1/(2^b - 1) <= rel_scale is b = ceil(log2(1/rel + 1)).
    """
    levels = int(np.ceil(1.0 / rel_scale)) + 1
    bits = int(np.ceil(np.log2(levels)))
    bits = max(2, min(bits, 8))  # KIVI supports integer widths >= 2
    return kivi_cr(bits, group_size)


def compress_k(k: jnp.ndarray, cfg: KIVIConfig):
    qc = QuantConfig(granularity="channel", group_size=cfg.group_size, bits=cfg.k_bits)
    return quantize_channelwise(k, qc)


def decompress_k(q, scale, zero, cfg: KIVIConfig, dtype=jnp.float32):
    return dequantize_channelwise(q, scale, zero, cfg.group_size, dtype)


def compress_v(v: jnp.ndarray, cfg: KIVIConfig):
    qc = QuantConfig(granularity="token", bits=cfg.v_bits)
    return quantize_tokenwise(v, qc)


def decompress_v(q, scale, zero, cfg: KIVIConfig, dtype=jnp.float32):
    return dequantize_tokenwise(q, scale, zero, dtype)
