"""Static-shape tiered packing — the TPU COMPUTE-tier format (DESIGN.md §3).

The paper's per-pack adaptive widths produce variable-length buffers that
Mosaic/XLA cannot address statically. We keep the adaptive-width *win* while
making every buffer static:

* Channels of each (kv-head) are **bucketed into width tiers** (e.g. 1/2/4/8
  bits). Bucket membership is a per-head channel permutation computed from
  calibration statistics (the prefill KV). Permuting K channels is absorbed
  by permuting q (free); permuting V channels is undone by inverse-permuting
  the attention output (free).
* Within a tier, values are packed at the tier width into dense uint32 words
  along the context dimension — statically shaped, appendable at 64-token
  block granularity.
* **Shift-packs**: each pack of 8 stores an int8 ``min`` and a 2-bit
  ``shift``; values are stored as ``(q - min) >> shift`` so a pack whose
  local range exceeds the tier width degrades gracefully (error bound
  scales by 2^shift) instead of overflowing. Four shifts share one uint8.

Layout (channels-major — matches both the packing direction and the decode
mat-vec access pattern, so no transpose is ever materialized):

  payload[t] : u32 [..., C_t, L*w_t/32]
  mins[t]    : i8  [..., C_t, L/pack]
  shifts[t]  : u8  [..., C_t, ceil(L/pack/4)]

Per-token quantization metadata (scale, zero — fp32 here, counted as fp16 in
CR accounting) lives next to the buffers and is folded into the mat-vec
(see kernels/ref.py) rather than applied during decompression.

Two physical layouts share the ``TieredCache`` container (normative spec in
docs/formats.md):

* **Dense** (the default): every buffer leads with ``[..., B, H_kv]`` and
  the token axis covers the full ``capacity``; slot ``b`` owns row ``b``.
* **Paged pool** (``alloc_tiered_pool``): payload/mins/shifts/scale/zero
  lead with ``[H_kv, n_pool_pages]`` and the token axis covers ONE page;
  slots address pages through a ``core.cache.PagePool`` table, and
  ``gather_tiered_pages`` reassembles the dense layout bit-identically
  (pages are multiples of ``4 * pack_size`` tokens, so payload words, pack
  metadata and shift bytes all split on exact page boundaries).

Invariants relied on by every consumer: the token axis is pack-aligned
(``capacity % pack_size == 0``); ``chan_perm`` is always per-slot
``[..., B, H_kv, D]`` (calibration is per-request — even the paged pool
keeps it slot-major); pack ``mins`` saturate to int8 instead of wrapping
(``pack_tier``), so a decoded value is always within one clamp of the
quantizer output.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import bits_required_jnp, cdiv, pytree_dataclass

Array = jax.Array

PACK = 8  # values per pack (paper Fig. 13: 8/16 optimal; 8 aligns with u32 at <=4b)
MAX_SHIFT = 3


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Static tier layout for one cache tensor (K or V).

    widths: ascending bit widths, each in {0,1,2,4,8,16} (must divide 32 or be 0).
    counts: channels per tier; sums to head_dim. Multiples of 8 recommended
      (VREG sublane alignment) and of the TP shard count.
    """

    widths: tuple[int, ...] = (2, 4, 8)
    counts: tuple[int, ...] = (32, 64, 32)
    pack_size: int = PACK

    def __post_init__(self):
        for w in self.widths:
            assert w == 0 or 32 % w == 0, f"width {w} must divide 32"
        assert len(self.widths) == len(self.counts)
        assert tuple(sorted(self.widths)) == tuple(self.widths)

    @property
    def head_dim(self) -> int:
        return sum(self.counts)

    def words_per_token(self, tier: int) -> float:
        return self.widths[tier] / 32.0

    def payload_words(self, tier: int, n_tokens: int) -> int:
        return n_tokens * self.widths[tier] // 32 if self.widths[tier] else 0

    def avg_bits_per_value(self) -> float:
        """Payload + pack metadata bits per value (excl. token meta)."""
        d = self.head_dim
        payload = sum(w * c for w, c in zip(self.widths, self.counts)) / d
        meta = (8 + 2) / self.pack_size  # i8 min + 2b shift per pack
        return payload + meta

    @staticmethod
    def for_head_dim(head_dim: int, widths=(2, 4, 8), fracs=(0.25, 0.5, 0.25)):
        assert abs(sum(fracs) - 1.0) < 1e-6
        counts = [int(round(f * head_dim / 8)) * 8 for f in fracs[:-1]]
        counts.append(head_dim - sum(counts))
        return TierSpec(widths=tuple(widths), counts=tuple(counts))


@pytree_dataclass(meta_fields=("width", "pack_size"))
class TierBuffer:
    payload: Array  # u32 [..., C_t, L*w/32]
    mins: Array  # i8  [..., C_t, L/pack]
    shifts: Array  # u8  [..., C_t, ceil(L/pack/4)]
    width: int
    pack_size: int


@pytree_dataclass(meta_fields=("spec",))
class TieredCache:
    """One compressed cache tensor (K or V of one layer stack).

    Leading dims of every array are [..., (layers?) B, H_kv].
    """

    tiers: tuple[TierBuffer, ...]
    chan_perm: Array  # i32 [..., H_kv, D] position -> original channel
    scale: Array  # f32 [..., B, H_kv, L] per-token quant scale
    zero: Array  # f32 [..., B, H_kv, L]
    spec: TierSpec

    @property
    def capacity(self) -> int:
        return self.scale.shape[-1]


# ---------------------------------------------------------------------------
# Packing / unpacking primitives (pure jnp, static shapes)
# ---------------------------------------------------------------------------


def pack_words(stored: Array, width: int) -> Array:
    """Pack integer values (already < 2**width) along the last dim into u32.

    stored: [..., L] -> u32 [..., L*width/32].
    """
    if width == 0:
        return jnp.zeros(stored.shape[:-1] + (0,), jnp.uint32)
    vpw = 32 // width
    *lead, L = stored.shape
    assert L % vpw == 0
    s = stored.astype(jnp.uint32).reshape(*lead, L // vpw, vpw)
    offsets = (jnp.arange(vpw, dtype=jnp.uint32) * width).astype(jnp.uint32)
    return jnp.sum(s << offsets, axis=-1, dtype=jnp.uint32)


def unpack_words(words: Array, width: int, n: int) -> Array:
    """Inverse of pack_words: u32 [..., n*width/32] -> i32 [..., n]."""
    if width == 0:
        return jnp.zeros(words.shape[:-1] + (n,), jnp.int32)
    vpw = 32 // width
    offsets = (jnp.arange(vpw, dtype=jnp.uint32) * width).astype(jnp.uint32)
    mask = jnp.uint32(2**width - 1)
    vals = (words[..., None] >> offsets) & mask
    return vals.reshape(*words.shape[:-1], n).astype(jnp.int32)


def pack_shift_fields(shifts: Array) -> Array:
    """Pack 2-bit shift fields, 4 per uint8. shifts: [..., P] -> u8 [..., ceil(P/4)]."""
    *lead, P = shifts.shape
    pad = (-P) % 4
    s = jnp.pad(shifts, [(0, 0)] * len(lead) + [(0, pad)]).astype(jnp.uint32)
    s = s.reshape(*lead, (P + pad) // 4, 4)
    offsets = jnp.arange(4, dtype=jnp.uint32) * 2
    return jnp.sum(s << offsets, axis=-1, dtype=jnp.uint32).astype(jnp.uint8)


def unpack_shift_fields(packed: Array, P: int) -> Array:
    idx = jnp.arange(P)
    word = jnp.take(packed.astype(jnp.int32), idx // 4, axis=-1)
    return (word >> (2 * (idx % 4))) & 3


def pack_tier(q: Array, width: int, pack_size: int = PACK) -> TierBuffer:
    """Pack quantized integers of one tier's channels.

    q: i32 [..., C_t, L] channels-major. Returns a TierBuffer.
    """
    *lead, C, L = q.shape
    assert L % pack_size == 0
    P = L // pack_size
    qp = q.reshape(*lead, C, P, pack_size)
    mins = qp.min(axis=-1)  # [..., C, P]
    rng = qp.max(axis=-1) - mins
    needed = bits_required_jnp(rng)
    shift = jnp.clip(needed - width, 0, MAX_SHIFT)
    # Saturate mins to the i8 field instead of letting astype wrap: a wrap
    # is a ±256 reconstruction error, a clip is bounded by the clamp below.
    mins = jnp.clip(mins, -128, 127)
    stored = (qp - mins[..., None]) >> shift[..., None]
    # Clamp in case needed - width > MAX_SHIFT (outlier beyond tier budget;
    # bounded by construction when the top tier width >= ceil(log2(levels))),
    # or in case the min was saturated above.
    stored = jnp.clip(stored, 0, (1 << width) - 1 if width else 0)
    payload = pack_words(stored.reshape(*lead, C, L), width)
    return TierBuffer(
        payload=payload,
        mins=mins.astype(jnp.int8),
        shifts=pack_shift_fields(shift),
        width=width,
        pack_size=pack_size,
    )


def unpack_tier(buf: TierBuffer, L: int) -> Array:
    """Reconstruct quantized integers: i32 [..., C_t, L] (approx if shifted)."""
    pack_size = buf.pack_size
    P = L // pack_size
    stored = unpack_words(buf.payload, buf.width, L)
    *lead, C, _ = stored.shape
    stored = stored.reshape(*lead, C, P, pack_size)
    shift = unpack_shift_fields(buf.shifts, P)[..., None]  # [..., C, P, 1]
    mins = buf.mins.astype(jnp.int32)[..., None]
    # mid-rise reconstruction of dropped low bits
    half = jnp.where(shift > 0, (1 << jnp.maximum(shift - 1, 0)), 0)
    q = (stored << shift) + half + mins
    return q.reshape(*lead, C, L)


# ---------------------------------------------------------------------------
# Channel tier assignment (calibration)
# ---------------------------------------------------------------------------


def required_channel_widths(q: Array, pack_size: int = PACK) -> Array:
    """Max per-pack width needed by each channel.

    q: i32 [..., C, L] -> i32 [..., C].
    """
    *lead, C, L = q.shape
    qp = q.reshape(*lead, C, L // pack_size, pack_size)
    rng = qp.max(axis=-1) - qp.min(axis=-1)
    return bits_required_jnp(rng).max(axis=-1)


def assign_channel_tiers(widths: Array, spec: TierSpec) -> Array:
    """Channel permutation: ascending required width fills tiers in order.

    widths: i32 [..., D] -> perm i32 [..., D]; perm[i] = original channel at
    packed position i. Positions [0, counts[0]) belong to tier 0, etc.
    """
    return jnp.argsort(widths, axis=-1, stable=True)


def choose_tier_spec(
    widths,
    candidates: tuple[int, ...] = (1, 2, 4, 8),
    pack_size: int = PACK,
    align: int = 8,
    slack: int = 0,
) -> TierSpec:
    """Pick STATIC tier widths/counts from calibrated channel widths.

    Host-side (numpy): called once at engine build from a calibration pass,
    before the decode step is compiled — the TPU analogue of the paper's
    per-model empirical configuration (§IV-B). The returned spec is static
    so every compiled buffer shape is fixed.

    widths: i32 [..., D] required per-channel widths (leading dims = heads/
      batches are pooled worst-case per channel RANK, so every head can fill
      each tier without shift when slack=0).
    slack: allow channels needing up to ``width + slack`` bits into a tier
      (absorbed by shift-packs at 2^slack error growth) — trades accuracy
      for compression like the paper's rel-scale knob.
    """
    w = np.asarray(widths)
    D = w.shape[-1]
    rank_w = np.sort(w.reshape(-1, D), axis=1).max(axis=0)  # worst head per rank
    need = int(rank_w.max())
    cands = [c for c in candidates if c < need + 1] or [candidates[0]]
    top = min([c for c in candidates if c >= need] or [max(candidates)])
    if top not in cands:
        cands.append(top)
    specs: list[tuple[int, int]] = []
    offs = 0
    for c in cands[:-1]:
        n = int((rank_w <= c + slack).sum())
        n = (n // align) * align
        take = max(0, n - offs)
        if take:
            specs.append((c, take))
            offs += take
    if D - offs:
        specs.append((cands[-1], D - offs))
    return TierSpec(
        widths=tuple(c for c, _ in specs),
        counts=tuple(n for _, n in specs),
        pack_size=pack_size,
    )


def chan_inverse_perm(perm: Array) -> Array:
    D = perm.shape[-1]
    inv = jnp.zeros_like(perm)
    return jnp.put_along_axis(
        inv, perm, jnp.broadcast_to(jnp.arange(D), perm.shape), axis=-1, inplace=False
    )


# ---------------------------------------------------------------------------
# Whole-cache helpers
# ---------------------------------------------------------------------------


def split_tiers(x: Array, spec: TierSpec, axis: int = -2):
    """Split a channels-major array into per-tier chunks along ``axis``."""
    sizes = np.cumsum(spec.counts)[:-1]
    return jnp.split(x, sizes, axis=axis)


def pack_tiered(
    q_chan_major: Array,
    chan_perm: Array,
    scale: Array,
    zero: Array,
    spec: TierSpec,
) -> TieredCache:
    """Pack a full quantized tensor into a TieredCache.

    q_chan_major: i32 [..., H_kv, D, L] (original channel order).
    chan_perm:    i32 [..., H_kv, D] from assign_channel_tiers.
    scale, zero:  f32 [..., H_kv, L].
    """
    # permute channels into tier order
    qp = jnp.take_along_axis(q_chan_major, chan_perm[..., None], axis=-2)
    tiers = tuple(
        pack_tier(chunk, w, spec.pack_size)
        for chunk, w in zip(split_tiers(qp, spec), spec.widths)
    )
    return TieredCache(
        tiers=tiers, chan_perm=chan_perm, scale=scale, zero=zero, spec=spec
    )


def unpack_tiered(cache: TieredCache) -> Array:
    """i32 [..., H_kv, D, L] in TIER order (apply chan_perm to undo)."""
    L = cache.capacity
    return jnp.concatenate([unpack_tier(t, L) for t in cache.tiers], axis=-2)


def dequantize_tiered(cache: TieredCache, dtype=jnp.float32) -> Array:
    """Dense [..., H_kv, D, L] in ORIGINAL channel order (oracle path)."""
    q = unpack_tiered(cache).astype(jnp.float32)
    x = q * cache.scale[..., None, :] + cache.zero[..., None, :]
    inv = chan_inverse_perm(cache.chan_perm)
    return jnp.take_along_axis(x, inv[..., None], axis=-2).astype(dtype)


def tiered_bits_per_value(spec: TierSpec, head_dim: int | None = None) -> float:
    """Compute-tier bits/value incl. pack + token metadata (for CR tables)."""
    d = head_dim or spec.head_dim
    return spec.avg_bits_per_value() + 32.0 / d  # fp16 scale+zero per (token, head)


def alloc_tiered(
    batch: int, h_kv: int, capacity: int, spec: TierSpec, lead: tuple[int, ...] = ()
) -> TieredCache:
    """Preallocate an empty TieredCache (zeros) with static capacity."""
    P = capacity // spec.pack_size
    tiers = tuple(
        TierBuffer(
            payload=jnp.zeros(
                (*lead, batch, h_kv, c, spec.payload_words(i, capacity)), jnp.uint32
            ),
            mins=jnp.zeros((*lead, batch, h_kv, c, P), jnp.int8),
            shifts=jnp.zeros((*lead, batch, h_kv, c, cdiv(P, 4)), jnp.uint8),
            width=w,
            pack_size=spec.pack_size,
        )
        for i, (w, c) in enumerate(zip(spec.widths, spec.counts))
    )
    D = spec.head_dim
    return TieredCache(
        tiers=tiers,
        chan_perm=jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), (*lead, batch, h_kv, D)),
        scale=jnp.ones((*lead, batch, h_kv, capacity), jnp.float32),
        zero=jnp.zeros((*lead, batch, h_kv, capacity), jnp.float32),
        spec=spec,
    )


def slice_tiered_prefix(cache: TieredCache, n: int) -> TieredCache:
    """Static prefix view: the first ``n`` tokens of every buffer.

    ``n`` must be a python int (the whole point is a smaller static shape),
    a multiple of ``4 * pack_size`` so payload words, pack metadata and the
    4-packs-per-byte shift fields all slice on exact boundaries. Slicing is
    free at trace time (XLA fuses the slice into the consuming kernel, so
    only the live prefix bytes are read from HBM) and keeps every kernel
    launch proportional to the bucketed live length instead of capacity.
    """
    if n >= cache.capacity:
        return cache
    spec = cache.spec
    assert n % (4 * spec.pack_size) == 0, (n, spec.pack_size)
    P = n // spec.pack_size
    tiers = tuple(
        TierBuffer(
            payload=t.payload[..., : n * t.width // 32],
            mins=t.mins[..., :P],
            shifts=t.shifts[..., : P // 4],
            width=t.width,
            pack_size=t.pack_size,
        )
        for t in cache.tiers
    )
    return TieredCache(
        tiers=tiers,
        chan_perm=cache.chan_perm,
        scale=cache.scale[..., :n],
        zero=cache.zero[..., :n],
        spec=spec,
    )


def slice_tiered_suffix(cache: TieredCache, start: int) -> TieredCache:
    """Static suffix view: every buffer's tokens from ``start`` onward.

    The mirror of ``slice_tiered_prefix`` — ``start`` must be a python int
    multiple of ``4 * pack_size`` (page starts always are). Used to scatter
    only the NEWLY-compressed pages of a prefix-cache admission while the
    shared prefix is mapped by reference."""
    if start == 0:
        return cache
    spec = cache.spec
    assert start % (4 * spec.pack_size) == 0, (start, spec.pack_size)
    P0 = start // spec.pack_size
    tiers = tuple(
        TierBuffer(
            payload=t.payload[..., start * t.width // 32:],
            mins=t.mins[..., P0:],
            shifts=t.shifts[..., P0 // 4:],
            width=t.width,
            pack_size=t.pack_size,
        )
        for t in cache.tiers
    )
    return TieredCache(
        tiers=tiers,
        chan_perm=cache.chan_perm,
        scale=cache.scale[..., start:],
        zero=cache.zero[..., start:],
        spec=spec,
    )


def write_tiered_prefix(dst: TieredCache, src: TieredCache) -> TieredCache:
    """Write ``src``'s whole token range into the leading tokens of ``dst``.

    Data leaves only (payload/mins/shifts/scale/zero); ``dst.chan_perm`` is
    kept — per-slot metadata is the caller's to set. ``src.capacity`` must
    be a multiple of ``4 * pack_size`` (gathered whole pages always are).
    Used to seed a dense mini-cache with a shared compressed prefix."""
    n = src.capacity
    spec = dst.spec
    assert n % (4 * spec.pack_size) == 0, (n, spec.pack_size)
    put = lambda d, s: d.at[..., : s.shape[-1]].set(s.astype(d.dtype))
    tiers = tuple(
        TierBuffer(
            payload=put(dt.payload, st.payload) if dt.width else dt.payload,
            mins=put(dt.mins, st.mins),
            shifts=put(dt.shifts, st.shifts),
            width=dt.width,
            pack_size=dt.pack_size,
        )
        for dt, st in zip(dst.tiers, src.tiers)
    )
    return dataclasses.replace(
        dst,
        tiers=tiers,
        scale=put(dst.scale, src.scale),
        zero=put(dst.zero, src.zero),
    )


def alloc_tiered_pool(
    batch: int, h_kv: int, n_pool_pages: int, page_size: int, spec: TierSpec
) -> TieredCache:
    """Preallocate a PAGE-POOL TieredCache (see module docstring).

    Data leaves lead with ``[H_kv, n_pool_pages]`` and their token axis
    covers one ``page_size``-token page; ``chan_perm`` stays per-slot
    ``[batch, H_kv, D]``. Physical page ``p`` of every leaf holds the same
    ``page_size`` tokens of whichever slot owns ``p`` in the page table.
    """
    assert page_size % (4 * spec.pack_size) == 0, (page_size, spec.pack_size)
    P = page_size // spec.pack_size
    tiers = tuple(
        TierBuffer(
            payload=jnp.zeros(
                (h_kv, n_pool_pages, c, spec.payload_words(i, page_size)),
                jnp.uint32,
            ),
            mins=jnp.zeros((h_kv, n_pool_pages, c, P), jnp.int8),
            shifts=jnp.zeros((h_kv, n_pool_pages, c, cdiv(P, 4)), jnp.uint8),
            width=w,
            pack_size=spec.pack_size,
        )
        for i, (w, c) in enumerate(zip(spec.widths, spec.counts))
    )
    D = spec.head_dim
    return TieredCache(
        tiers=tiers,
        chan_perm=jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), (batch, h_kv, D)),
        scale=jnp.ones((h_kv, n_pool_pages, page_size), jnp.float32),
        zero=jnp.zeros((h_kv, n_pool_pages, page_size), jnp.float32),
        spec=spec,
    )


def page_prefix_ids(page_table: Array, n_tokens: int, page_size: int) -> Array:
    """THE page-resolution arithmetic: the page-table prefix addressing the
    first ``n_tokens`` of every row.

    ``n_tokens`` is STATIC and must be a whole number of pages (buckets are
    page-aligned by ``Engine.bucket_for``). Every dense-view consumer —
    ``cache.gather_paged``, the kernel-side rank-1 metadata prep in
    ``kernels/ops.py`` and the tier gathers below — resolves pages through
    this one helper so the ``[B, n_tokens // page_size]`` contract lives in
    exactly one place.
    """
    assert n_tokens % page_size == 0, (n_tokens, page_size)
    return page_table[..., : n_tokens // page_size]


def gather_page_meta(leaf: Array, page_table: Array, n_tokens: int,
                     page_size: int) -> Array:
    """Rank-1 per-token metadata (scale/zero) gathered through the table.

    The paged Pallas kernels resolve payload pages IN-KERNEL but take
    scale/zero as dense rank-1 inputs — this is that kernel-side metadata
    prep, sharing ``page_prefix_ids`` with the full gathers."""
    return gather_pool_leaf(leaf, page_prefix_ids(page_table, n_tokens, page_size))


def gather_pool_leaf(leaf: Array, idx: Array, token_axis: int = -1) -> Array:
    """Gather pool pages into the dense layout along the token axis.

    leaf: ``[H_kv, n_pool_pages, ...]`` pool buffer whose ``token_axis``
    covers one page; idx: i32 ``[B, k]`` physical page ids (a page-table
    prefix). Returns ``[B, H_kv, ...]`` with the token axis covering
    ``k * page_units`` — the dense layout the kernels consume.
    """
    x = leaf[:, idx]  # [H, B, k, ...]
    ta = (token_axis % leaf.ndim) + 1  # token axis position within x
    x = jnp.moveaxis(x, (1, 0, 2), (0, 1, ta - 1))  # [B, H, ..., k, units, ...]
    return x.reshape(*x.shape[: ta - 1], x.shape[ta - 1] * x.shape[ta], *x.shape[ta + 1 :])


def gather_tiered_pages(pool: TieredCache, idx: Array) -> TieredCache:
    """Page-table gather: pool layout -> dense layout (the XLA read path).

    pool: paged-layout TieredCache; idx: i32 [B, k] page-table prefix.
    Returns a dense TieredCache of capacity ``k * page_size`` whose live
    bytes are bit-identical to a dense cache holding the same tokens (page
    boundaries land on payload-word / pack / shift-byte boundaries by the
    ``4 * pack_size`` page alignment). Entries of ``idx`` past a row's live
    pages are stale-but-valid ids, so the gather stays in-range and the
    garbage columns are masked by ``n_comp`` downstream.
    """
    tiers = tuple(
        TierBuffer(
            payload=gather_pool_leaf(t.payload, idx),
            mins=gather_pool_leaf(t.mins, idx),
            shifts=gather_pool_leaf(t.shifts, idx),
            width=t.width,
            pack_size=t.pack_size,
        )
        for t in pool.tiers
    )
    return TieredCache(
        tiers=tiers,
        chan_perm=pool.chan_perm,
        scale=gather_pool_leaf(pool.scale, idx),
        zero=gather_pool_leaf(pool.zero, idx),
        spec=pool.spec,
    )


def append_block(cache: TieredCache, block: TieredCache, offset: Array) -> TieredCache:
    """Seamless append: write a packed block at token ``offset`` (multiple of
    the block length). Static shapes; offset is a traced scalar."""
    spec = cache.spec
    Lb = block.capacity
    new_tiers = []
    for t, b in zip(cache.tiers, block.tiers):
        w = t.width
        word_off = offset * w // 32 if w else 0
        pk_off = offset // spec.pack_size
        payload = (
            jax.lax.dynamic_update_slice_in_dim(t.payload, b.payload, word_off, axis=-1)
            if w
            else t.payload
        )
        mins = jax.lax.dynamic_update_slice_in_dim(t.mins, b.mins, pk_off, axis=-1)
        shifts = jax.lax.dynamic_update_slice_in_dim(
            t.shifts, b.shifts, pk_off // 4, axis=-1
        )
        new_tiers.append(
            TierBuffer(payload=payload, mins=mins, shifts=shifts, width=w,
                       pack_size=t.pack_size)
        )
    scale = jax.lax.dynamic_update_slice_in_dim(cache.scale, block.scale, offset, axis=-1)
    zero = jax.lax.dynamic_update_slice_in_dim(cache.zero, block.zero, offset, axis=-1)
    return TieredCache(
        tiers=tuple(new_tiers),
        chan_perm=cache.chan_perm,
        scale=scale,
        zero=zero,
        spec=spec,
    )


def append_block_rows(
    cache: TieredCache, block: TieredCache, offsets: Array
) -> TieredCache:
    """Per-row ``append_block``: row b's packed block lands at offsets[b].

    cache/block leaves lead with [B, ...]; offsets: i32 [B] (block-aligned,
    traced). The vmap keeps every shape static while each row writes at its
    own token offset — the substrate for continuous per-slot batching.
    """
    return jax.vmap(append_block)(cache, block, offsets)
