"""Compression-policy registry (DESIGN.md §2).

A policy names a full cache configuration preset; launchers and the
serving engine resolve ``--policy`` strings here. ``packkv_storage``
denotes the exact-paper host format (CompressedKVStream) used for
offload/checkpoints; the runtime decode policies map onto PackKVConfig.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .cache import PackKVConfig

_REGISTRY: dict[str, Callable[[], PackKVConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


@register("none")
def _none() -> PackKVConfig:
    """Uncompressed bf16 cache — the cuBLAS-equivalent baseline."""
    return PackKVConfig(policy="none")


@register("kivi")
def _kivi() -> PackKVConfig:
    """Integer quantization only (single 4-bit tier, no adaptive widths)."""
    return PackKVConfig(policy="kivi")


@register("packkv")
def _packkv() -> PackKVConfig:
    """Full paper pipeline: token-wise quant + V-median repack + tiers."""
    return PackKVConfig(policy="packkv")


@register("packkv_tight")
def _packkv_tight() -> PackKVConfig:
    """Near-lossless setting (rel scales 0.02) for fidelity-critical serving."""
    return PackKVConfig(policy="packkv", k_rel_scale=0.02, v_rel_scale=0.02)


@register("packkv_aggressive")
def _packkv_aggressive() -> PackKVConfig:
    """Paper Table II/V turning-point regime (max compression at ~5% drop)."""
    return PackKVConfig(policy="packkv", k_rel_scale=0.2, v_rel_scale=0.3)


def get_policy(name: str, **overrides) -> PackKVConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def available() -> list[str]:
    return sorted(_REGISTRY)
