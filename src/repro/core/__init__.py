"""PackKV core: the paper's contribution as composable JAX modules.

Pipeline (paper Fig. 2): quantization -> encode-aware repacking ->
bit-packing -> seamless appending -> computation-aware decompression.

Two on-device formats:
  * storage tier (bitpack.py/block_format.py) — exact paper format,
    per-pack adaptive widths; CR accounting, offload, checkpoints.
  * compute tier (tiered.py) — static-shape TPU format consumed by the
    fused kernels in repro.kernels.
"""
from .quantization import QuantConfig, dequantize, quantize  # noqa: F401
from .bitpack import (  # noqa: F401
    DEFAULT_SIZE_MODEL,
    SizeModel,
    compression_ratio,
    pack_block,
    packed_total_bits,
    unpack_block,
)
from .repacking import greedy_repack, median_repack, median_repack_jnp, repack  # noqa: F401
from .block_format import CompressedKVStream  # noqa: F401
from .tiered import (  # noqa: F401
    TierBuffer,
    TierSpec,
    TieredCache,
    alloc_tiered,
    append_block,
    assign_channel_tiers,
    dequantize_tiered,
    pack_tiered,
    required_channel_widths,
    tiered_bits_per_value,
    unpack_tiered,
)
from .kivi import KIVIConfig, kivi_cr, kivi_cr_from_rel_scale  # noqa: F401

from .policy import available as available_policies, get_policy  # noqa: F401,E402
