"""Block-independent compressed storage format with seamless appending
(paper §III-B4).

Each 64-token block of each kv-head is compressed independently
(quantize -> repack -> bit-pack) and serialized as a self-describing chunk;
chunks append to a flat stream without touching earlier chunks. A directory
of (head, token_range, offset) entries makes any block independently
addressable — the property that enables the paper's single-kernel
decompression and our per-tier grids.

This is the STORAGE/offload tier (host-side, exact paper format). The
compute tier is tiered.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .bitpack import (
    DEFAULT_SIZE_MODEL,
    PackedBlock,
    SizeModel,
    pack_block,
    unpack_block,
)
from .quantization import QuantConfig
from .repacking import repack


@dataclasses.dataclass
class BlockEntry:
    head: int
    token_start: int
    n_tokens: int
    perm: np.ndarray  # joint K/V row permutation used at encode time
    k_block: PackedBlock
    v_block: PackedBlock
    k_meta: np.ndarray  # [n_tokens, 2] (scale, zero) per token
    v_meta: np.ndarray


@dataclasses.dataclass
class CompressedKVStream:
    """Appendable stream of independently compressed KV blocks."""

    pack_size: int = 8
    repack_mode: str = "greedy_joint"
    k_quant: QuantConfig = dataclasses.field(
        default_factory=lambda: QuantConfig(rel_scale=0.1, granularity="token")
    )
    v_quant: QuantConfig = dataclasses.field(
        default_factory=lambda: QuantConfig(rel_scale=0.2, granularity="token")
    )
    entries: list[BlockEntry] = dataclasses.field(default_factory=list)

    # -- encode ------------------------------------------------------------
    def append(self, k: np.ndarray, v: np.ndarray, head: int, token_start: int):
        """Compress one block. k, v: [n_tokens, D] float."""
        n = k.shape[0]
        qk, sk, zk = _np_quant_tokenwise(k, self.k_quant)
        qv, sv, zv = _np_quant_tokenwise(v, self.v_quant)
        perm = repack(qk, qv, self.pack_size, self.repack_mode)
        entry = BlockEntry(
            head=head,
            token_start=token_start,
            n_tokens=n,
            perm=perm,
            k_block=pack_block(qk[perm], self.pack_size),
            v_block=pack_block(qv[perm], self.pack_size),
            k_meta=np.stack([sk[perm], zk[perm]], axis=1),
            v_meta=np.stack([sv[perm], zv[perm]], axis=1),
        )
        self.entries.append(entry)
        return entry

    # -- decode ------------------------------------------------------------
    def decode_block(self, idx: int, restore_order: bool = False):
        e = self.entries[idx]
        qk = unpack_block(e.k_block)
        qv = unpack_block(e.v_block)
        k = qk * e.k_meta[:, :1] + e.k_meta[:, 1:]
        v = qv * e.v_meta[:, :1] + e.v_meta[:, 1:]
        if restore_order:
            inv = np.argsort(e.perm)
            k, v = k[inv], v[inv]
        return k, v

    def decode_head(self, head: int, restore_order: bool = False):
        ks, vs = [], []
        for i, e in enumerate(self.entries):
            if e.head == head:
                k, v = self.decode_block(i, restore_order)
                ks.append(k)
                vs.append(v)
        return np.concatenate(ks, axis=0), np.concatenate(vs, axis=0)

    # -- accounting ---------------------------------------------------------
    def total_bits(self, size_model: SizeModel = DEFAULT_SIZE_MODEL) -> int:
        bits = 0
        for e in self.entries:
            bits += e.k_block.total_bits(size_model) + e.v_block.total_bits(size_model)
            bits += 2 * e.n_tokens * size_model.token_meta_bits
        return bits

    def raw_bits(self, size_model: SizeModel = DEFAULT_SIZE_MODEL) -> int:
        vals = sum(
            e.n_tokens * (e.k_block.shape[1] + e.v_block.shape[1]) for e in self.entries
        )
        return vals * size_model.raw_bits

    def compression_ratio(self, size_model: SizeModel = DEFAULT_SIZE_MODEL) -> float:
        return self.raw_bits(size_model) / max(self.total_bits(size_model), 1)

    # -- serialization (flat stream: proves append-only layout) -------------
    def serialize(self) -> tuple[np.ndarray, list[dict]]:
        words: list[np.ndarray] = []
        directory: list[dict] = []
        off = 0
        for e in self.entries:
            chunk = np.concatenate([e.k_block.payload, e.v_block.payload])
            directory.append(
                {
                    "head": e.head,
                    "token_start": e.token_start,
                    "offset_words": off,
                    "k_words": len(e.k_block.payload),
                    "v_words": len(e.v_block.payload),
                }
            )
            words.append(chunk)
            off += len(chunk)
        flat = np.concatenate(words) if words else np.zeros(0, np.uint32)
        return flat, directory


def _np_quant_tokenwise(x: np.ndarray, cfg: QuantConfig):
    lo = x.min(axis=1, keepdims=True)
    hi = x.max(axis=1, keepdims=True)
    rng = hi - lo
    scale = rng / cfg.max_q if cfg.bits is not None else cfg.rel_scale * rng
    safe = np.where(scale > 0, scale, 1.0)
    q = np.clip(np.round((x - lo) / safe), 0, cfg.max_q).astype(np.int64)
    return q, safe[:, 0], lo[:, 0]
