"""Error-controlled quantization for KV-cache tensors.

Implements the paper's only lossy step (PackKV §III-B2) plus the KIVI
granularities used as the baseline:

* **token-wise**  — one (scale, zero) per (token, head): PackKV's choice for
  both K and V.
* **channel-wise** — one (scale, zero) per (channel-group, channel): KIVI's
  choice for K (group size 32/64/128 along the context dim).

Error model (paper §IV-A): ``scale = rel_quant_scale * (max - min)`` so the
max abs error is ``scale / 2 = rel_error_bound * (max - min)``.

All functions are pure jnp and jit-friendly; integer outputs use int32 (the
storage width is decided later by bit-packing, not here).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the (lossy) quantization stage.

    Attributes:
      rel_scale: relative quantization scale in (0, 1]. actual scale =
        rel_scale * (max - min) of the quantization unit.
      granularity: 'token' (PackKV) or 'channel' (KIVI-K).
      group_size: context-dim group length for channel-wise quantization.
      bits: optional hard cap on integer width (KIVI-style b-bit quant). When
        set, levels = 2**bits and rel_scale is ignored.
    """

    rel_scale: float = 0.1
    granularity: str = "token"
    group_size: int = 64
    bits: int | None = None

    @property
    def levels(self) -> int:
        if self.bits is not None:
            return 2 ** self.bits
        # round(1/rel) + 1 integer levels cover [min, max] with step
        # rel*(max-min); matches the paper's rel_error_bound = rel/2.
        return int(round(1.0 / self.rel_scale)) + 1

    @property
    def max_q(self) -> int:
        return self.levels - 1


def _minmax(x: Array, axis, keepdims=True):
    return jnp.min(x, axis=axis, keepdims=keepdims), jnp.max(
        x, axis=axis, keepdims=keepdims
    )


def quantize_tokenwise(x: Array, cfg: QuantConfig):
    """Token-wise quantization over the last dim.

    x: [..., L, D] (typically [B, H, L, D]); each (..., L) vector of length D
    gets its own (scale, zero).

    Returns (q:int32 same shape, scale:f32 [...,L,1], zero:f32 [...,L,1]).
    """
    lo, hi = _minmax(x, axis=-1)
    rng = hi - lo
    if cfg.bits is not None:
        scale = rng / cfg.max_q
    else:
        scale = cfg.rel_scale * rng
    # Guard degenerate all-equal vectors.
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round((x - lo) / safe), 0, cfg.max_q).astype(jnp.int32)
    return q, safe.astype(jnp.float32), lo.astype(jnp.float32)


def dequantize_tokenwise(q: Array, scale: Array, zero: Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale + zero).astype(dtype)


def quantize_channelwise(x: Array, cfg: QuantConfig):
    """Channel-wise (KIVI-K) quantization.

    x: [..., L, D]. The context dim L is split into groups of ``group_size``;
    each (group, channel) pair gets its own (scale, zero), i.e. statistics are
    taken along the context dim inside the group.

    L must be divisible by group_size (callers pad; the runtime cache always
    compresses full blocks).
    Returns (q, scale [..., L/g, 1, D], zero [..., L/g, 1, D]).
    """
    g = cfg.group_size
    *lead, L, D = x.shape
    assert L % g == 0, f"context {L} not divisible by group {g}"
    xg = x.reshape(*lead, L // g, g, D)
    lo, hi = _minmax(xg, axis=-2)
    rng = hi - lo
    if cfg.bits is not None:
        scale = rng / cfg.max_q
    else:
        scale = cfg.rel_scale * rng
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round((xg - lo) / safe), 0, cfg.max_q).astype(jnp.int32)
    return (
        q.reshape(*lead, L, D),
        safe.astype(jnp.float32),
        lo.astype(jnp.float32),
    )


def dequantize_channelwise(
    q: Array, scale: Array, zero: Array, group_size: int, dtype=jnp.float32
):
    *lead, L, D = q.shape
    g = group_size
    qg = q.reshape(*lead, L // g, g, D).astype(jnp.float32)
    x = qg * scale + zero
    return x.reshape(*lead, L, D).astype(dtype)


def quantize(x: Array, cfg: QuantConfig):
    if cfg.granularity == "token":
        return quantize_tokenwise(x, cfg)
    if cfg.granularity == "channel":
        return quantize_channelwise(x, cfg)
    raise ValueError(f"unknown granularity {cfg.granularity!r}")


def dequantize(q: Array, scale: Array, zero: Array, cfg: QuantConfig, dtype=jnp.float32):
    if cfg.granularity == "token":
        return dequantize_tokenwise(q, scale, zero, dtype)
    if cfg.granularity == "channel":
        return dequantize_channelwise(q, scale, zero, cfg.group_size, dtype)
    raise ValueError(f"unknown granularity {cfg.granularity!r}")


@partial(jax.jit, static_argnames=("levels",))
def _error_bound_check(x, q, scale, zero, levels):
    deq = q.astype(jnp.float32) * scale + zero
    return jnp.max(jnp.abs(deq - x) / jnp.maximum(scale, 1e-30))


def max_relative_error(x: Array, cfg: QuantConfig) -> float:
    """max |x - deq| / scale — should be <= 0.5 (+ rounding eps)."""
    q, s, z = quantize(x, cfg)
    if cfg.granularity == "channel":
        deq = dequantize(q, s, z, cfg)
        return float(jnp.max(jnp.abs(deq - x) / jnp.maximum(jnp.max(s), 1e-30)))
    return float(_error_bound_check(x, q, s, z, cfg.levels))
