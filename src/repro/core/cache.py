"""Runtime PackKV cache manager (paper §III-B1/B4 + §III-C glue).

Mirrors the paper's system: a fixed-size **residual buffer** of recent tokens
in full precision; when it fills past one truncated block (64 tokens), the
oldest block is quantized, repacked (in-graph V-median), tier-packed and
**appended** to the compressed region. Everything is static-shape and
jit-compatible (lax.cond / dynamic_update_slice), so the same code path runs
under pjit on the production mesh.

Sequence state is **per row**: ``n_comp``/``n_resid`` are ``[B]`` i32
vectors, every append/flush runs at per-row offsets (vmapped
``dynamic_update_slice``), and rows flush independently — the substrate for
continuous (per-slot) batching in ``serving.engine``. ``reset_slot`` and
``insert_prefill`` recycle one row while the others keep decoding.

Three policies share one pytree layout so serve_step signatures are uniform:
  * ``none``   — raw bf16 cache (the cuBLAS-equivalent baseline).
  * ``kivi``   — integer quantization only (single tier, no adaptive widths).
  * ``packkv`` — full pipeline (token-wise quant + repack + tiered packing).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..utils import pytree_dataclass
from .quantization import QuantConfig
from .repacking import median_repack_jnp
from .tiered import (
    TierSpec,
    TieredCache,
    alloc_tiered,
    append_block,
    append_block_rows,
    assign_channel_tiers,
    pack_tiered,
    required_channel_widths,
)

Array = jax.Array

BLOCK = 64  # truncated block size (consistent with KIVI, paper §IV-A)


@dataclasses.dataclass(frozen=True)
class PackKVConfig:
    """Tunable knobs of the paper's pipeline (paper §IV-A)."""

    policy: str = "packkv"  # none | kivi | packkv
    k_rel_scale: float = 0.1
    v_rel_scale: float = 0.2
    pack_size: int = 8
    repack: str = "median_v"  # none | median_v (in-graph)
    residual: int = 128  # max buffer size (recent tokens kept fp16)
    block: int = BLOCK
    k_tiers: tuple[int, ...] = (2, 4, 8)
    k_fracs: tuple[float, ...] = (0.25, 0.5, 0.25)
    v_tiers: tuple[int, ...] = (2, 4, 8)
    v_fracs: tuple[float, ...] = (0.25, 0.5, 0.25)
    # Calibrated static specs (engine build time, core.tiered.choose_tier_spec);
    # override the frac-based defaults when set.
    k_spec_static: Optional[TierSpec] = None
    v_spec_static: Optional[TierSpec] = None

    def k_quant(self) -> QuantConfig:
        return QuantConfig(rel_scale=self.k_rel_scale, granularity="token")

    def v_quant(self) -> QuantConfig:
        return QuantConfig(rel_scale=self.v_rel_scale, granularity="token")

    def k_spec(self, head_dim: int) -> TierSpec:
        if self.k_spec_static is not None:
            return self.k_spec_static
        if self.policy == "kivi":
            return TierSpec(widths=(4,), counts=(head_dim,), pack_size=self.pack_size)
        return TierSpec.for_head_dim(head_dim, self.k_tiers, self.k_fracs)

    def v_spec(self, head_dim: int) -> TierSpec:
        if self.v_spec_static is not None:
            return self.v_spec_static
        if self.policy == "kivi":
            return TierSpec(widths=(4,), counts=(head_dim,), pack_size=self.pack_size)
        return TierSpec.for_head_dim(head_dim, self.v_tiers, self.v_fracs)


@pytree_dataclass(meta_fields=("cfg",))
class LayerKVCache:
    """Per-layer decode cache. ``k``/``v`` are None for policy='none'."""

    k: Optional[TieredCache]  # compressed region (channels-major)
    v: Optional[TieredCache]
    raw_k: Optional[Array]  # policy='none': bf16 [B, Hkv, Lcap, D]
    raw_v: Optional[Array]
    resid_k: Array  # bf16 [B, Hkv, R, D]
    resid_v: Array
    n_comp: Array  # i32 [B] per-row tokens in compressed/raw region
    n_resid: Array  # i32 [B] per-row tokens in residual buffer
    cfg: PackKVConfig

    @property
    def capacity(self) -> int:
        return self.raw_k.shape[-2] if self.cfg.policy == "none" else self.k.capacity


def alloc_layer_cache(
    cfg: PackKVConfig,
    batch: int,
    h_kv: int,
    head_dim: int,
    capacity: int,
    dtype=jnp.bfloat16,
) -> LayerKVCache:
    """Preallocate a cache with static ``capacity`` (compressed region)."""
    R = cfg.residual
    resid = jnp.zeros((batch, h_kv, R, head_dim), dtype)
    zero_i = jnp.zeros((batch,), jnp.int32)
    if cfg.policy == "none":
        raw = jnp.zeros((batch, h_kv, capacity, head_dim), dtype)
        return LayerKVCache(
            k=None, v=None, raw_k=raw, raw_v=raw, resid_k=resid, resid_v=resid,
            n_comp=zero_i, n_resid=zero_i, cfg=cfg,
        )
    k = alloc_tiered(batch, h_kv, capacity, cfg.k_spec(head_dim))
    v = alloc_tiered(batch, h_kv, capacity, cfg.v_spec(head_dim))
    return LayerKVCache(
        k=k, v=v, raw_k=None, raw_v=None, resid_k=resid, resid_v=resid,
        n_comp=zero_i, n_resid=zero_i, cfg=cfg,
    )


# ---------------------------------------------------------------------------
# Quantize + repack + pack one block (in-graph)
# ---------------------------------------------------------------------------


def _quant_tokenwise(x: Array, qc: QuantConfig):
    """x: [B,H,N,D] -> (q i32, scale f32 [B,H,N], zero f32 [B,H,N]).

    Integers are CENTERED at zero (q in [-c, max_q - c], c = (max_q+1)//2)
    with the offset folded into the zero-point. Uncentered ints live in
    [0, max_q]; at tight rel scales (max_q up to 255) a pack whose values
    are all high — exactly what V-median repacking produces — then has a
    pack-min above 127 and wraps the int8 ``mins`` field of the tier
    format. Centering keeps every reachable pack-min inside int8 as long
    as max_q <= 255.
    """
    lo = x.min(axis=-1)
    hi = x.max(axis=-1)
    rng = (hi - lo).astype(jnp.float32)
    scale = jnp.where(rng > 0, qc.rel_scale * rng, 1.0)
    c = (qc.max_q + 1) // 2
    q = jnp.clip(
        jnp.round((x.astype(jnp.float32) - lo[..., None].astype(jnp.float32)) / scale[..., None]),
        0,
        qc.max_q,
    ).astype(jnp.int32) - c
    return q, scale, lo.astype(jnp.float32) + c * scale


def compress_block(
    k: Array, v: Array, cfg: PackKVConfig, k_perm: Array, v_perm: Array
) -> tuple[TieredCache, TieredCache]:
    """Compress one [B,H,N,D] block pair into single-block TieredCaches.

    k_perm/v_perm: [B,H,D] channel->tier assignment (from calibration).
    """
    qk, sk, zk = _quant_tokenwise(k, cfg.k_quant())
    qv, sv, zv = _quant_tokenwise(v, cfg.v_quant())
    qk, qv, perm = _repack_tokens(qk, qv, cfg)
    if perm is not None:
        # per-token metadata rides along with the joint permutation
        take_meta = lambda a: jnp.take_along_axis(a, perm, axis=-1)
        sk, zk = take_meta(sk), take_meta(zk)
        sv, zv = take_meta(sv), take_meta(zv)
    # channels-major
    qk_cm = jnp.swapaxes(qk, -1, -2)  # [B,H,D,N]
    qv_cm = jnp.swapaxes(qv, -1, -2)
    kc = pack_tiered(qk_cm, k_perm, sk, zk, cfg.k_spec(k.shape[-1]))
    vc = pack_tiered(qv_cm, v_perm, sv, zv, cfg.v_spec(v.shape[-1]))
    return kc, vc


def _repack_tokens(qk: Array, qv: Array, cfg: PackKVConfig):
    """Joint token permutation (paper §III-B3); returns permuted (qk, qv, perm).

    perm is None for repack='none'. Permutation is computed from the V part
    (V-median) and applied jointly to K and V — valid by the permutation
    invariance of decode attention.
    """
    if cfg.repack != "median_v":
        return qk, qv, None
    perm = median_repack_jnp(qv.reshape(*qv.shape[:-2], -1, qv.shape[-1]))
    take = lambda a: jnp.take_along_axis(a, perm[..., None], axis=-2)
    return take(qk), take(qv), perm


def calibrate_channel_tiers(k: Array, v: Array, cfg: PackKVConfig):
    """Assign channel tiers from (prefill) data. k, v: [B,H,L,D].

    Widths are measured AFTER token repacking so the tier assignment sees
    the exact pack ranges the compressor will encode.
    """
    qk, _, _ = _quant_tokenwise(k, cfg.k_quant())
    qv, _, _ = _quant_tokenwise(v, cfg.v_quant())
    L = k.shape[-2]
    Lb = (L // cfg.block) * cfg.block
    if Lb == 0:  # not enough data — identity assignment
        D = k.shape[-1]
        eye = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), k.shape[:-2] + (D,))
        return eye, eye
    qk, qv, _ = _repack_tokens(qk[..., :Lb, :], qv[..., :Lb, :], cfg)
    wk = required_channel_widths(jnp.swapaxes(qk, -1, -2), cfg.pack_size)
    wv = required_channel_widths(jnp.swapaxes(qv, -1, -2), cfg.pack_size)
    D = k.shape[-1]
    return (
        assign_channel_tiers(wk, cfg.k_spec(D)),
        assign_channel_tiers(wv, cfg.v_spec(D)),
    )


def calibrate_specs(k: Array, v: Array, cfg: PackKVConfig, slack: int = 0):
    """Host-side: pick static TierSpecs from calibration K/V ([B,H,L,D]).

    Returns a new PackKVConfig with k_spec_static / v_spec_static set. Run
    once at engine build (before compiling the decode step) — the TPU
    analogue of the paper's per-model configuration sweep (§IV-B).
    """
    from .tiered import choose_tier_spec

    qk, _, _ = _quant_tokenwise(k, cfg.k_quant())
    qv, _, _ = _quant_tokenwise(v, cfg.v_quant())
    L = k.shape[-2]
    Lb = (L // cfg.block) * cfg.block
    if Lb == 0:  # not enough calibration data for one block
        return cfg
    qk, qv, _ = _repack_tokens(qk[..., :Lb, :], qv[..., :Lb, :], cfg)
    wk = required_channel_widths(jnp.swapaxes(qk, -1, -2), cfg.pack_size)
    wv = required_channel_widths(jnp.swapaxes(qv, -1, -2), cfg.pack_size)
    return dataclasses.replace(
        cfg,
        k_spec_static=choose_tier_spec(wk, pack_size=cfg.pack_size, slack=slack),
        v_spec_static=choose_tier_spec(wv, pack_size=cfg.pack_size, slack=slack),
    )


# ---------------------------------------------------------------------------
# Length-aware launch buckets
# ---------------------------------------------------------------------------

BUCKET_UNIT = 256  # smallest bucket; multiple of every kernel tile_l in use


def bucket_length(n_max: int, capacity: int, unit: int = BUCKET_UNIT) -> int:
    """Host-side: the launch bucket covering ``n_max`` live tokens.

    Buckets are power-of-two multiples of ``unit`` clamped to ``capacity``
    (plus ``capacity`` itself), so a serving engine compiles at most
    ``log2(capacity / unit) + 1`` decode variants while every launch does
    work proportional to the live prefix, not the allocation. ``n_max`` is
    the scheduler's host-side upper bound on ``max(n_comp)`` — slicing to a
    larger-than-needed bucket is correct (masked), slicing below a row's
    live length is not.
    """
    if capacity <= unit or n_max >= capacity:
        return capacity
    b = unit
    while b < n_max:
        b *= 2
    return min(b, capacity)


def bucket_set(capacity: int, unit: int = BUCKET_UNIT) -> tuple[int, ...]:
    """Every bucket ``bucket_length`` can return for this capacity."""
    out = []
    b = unit
    while b < capacity:
        out.append(b)
        b *= 2
    return tuple(out) + (capacity,)


def slice_compressed(cache: LayerKVCache, n_bucket: int | None) -> LayerKVCache:
    """Static prefix view of the compressed region for a bucketed launch.

    Returns a LayerKVCache whose compressed buffers (tiered k/v, or raw_k/
    raw_v for policy='none') cover only the first ``n_bucket`` tokens; the
    residual buffer and the per-row counters are untouched (counters stay
    valid because ``n_bucket >= max(n_comp)`` by construction). Use ONLY
    for reads (attention) — appends must go through the full-capacity
    cache.
    """
    from .tiered import slice_tiered_prefix

    if n_bucket is None or n_bucket >= cache.capacity:
        return cache
    if cache.cfg.policy == "none":
        return dataclasses.replace(
            cache,
            raw_k=cache.raw_k[..., :n_bucket, :],
            raw_v=cache.raw_v[..., :n_bucket, :],
        )
    return dataclasses.replace(
        cache,
        k=slice_tiered_prefix(cache.k, n_bucket),
        v=slice_tiered_prefix(cache.v, n_bucket),
    )


# ---------------------------------------------------------------------------
# Per-row primitives
# ---------------------------------------------------------------------------


def row_update_tokens(buf: Array, new: Array, starts: Array) -> Array:
    """Per-row write along the token axis (-2).

    buf: [B, ..., N, D]; new: [B, ..., n, D]; starts: i32 [B].
    """
    upd = lambda b, x, s: jax.lax.dynamic_update_slice_in_dim(b, x, s, axis=-2)
    return jax.vmap(upd)(buf, new.astype(buf.dtype), starts)


def select_rows(mask: Array, new, old):
    """Pytree where: row b takes ``new`` where mask[b] (leaves lead with B)."""
    def sel(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(sel, new, old)


# ---------------------------------------------------------------------------
# Cache update ops
# ---------------------------------------------------------------------------


def prefill_cache(cache: LayerKVCache, k: Array, v: Array) -> LayerKVCache:
    """Fill the cache from prefill K/V ([B,H,L,D]). L is static here.

    Compresses all complete blocks; the remainder goes to the residual.
    Calibrates channel tiers from the prefill data (per batch, head).
    """
    cfg = cache.cfg
    B, H, L, D = k.shape
    n_blocks = L // cfg.block
    Lb = n_blocks * cfg.block
    if cfg.policy == "none":
        raw_k = jax.lax.dynamic_update_slice_in_dim(
            cache.raw_k, k[..., :Lb, :].astype(cache.raw_k.dtype), 0, axis=-2
        )
        raw_v = jax.lax.dynamic_update_slice_in_dim(
            cache.raw_v, v[..., :Lb, :].astype(cache.raw_v.dtype), 0, axis=-2
        )
        new = dataclasses.replace(cache, raw_k=raw_k, raw_v=raw_v)
    else:
        k_perm, v_perm = calibrate_channel_tiers(k[..., :Lb, :], v[..., :Lb, :], cfg)
        kc, vc = compress_block(k[..., :Lb, :], v[..., :Lb, :], cfg, k_perm, v_perm)
        new_k = append_block(
            dataclasses.replace(cache.k, chan_perm=k_perm), kc, jnp.int32(0)
        )
        new_v = append_block(
            dataclasses.replace(cache.v, chan_perm=v_perm), vc, jnp.int32(0)
        )
        new = dataclasses.replace(cache, k=new_k, v=new_v)
    rem = L - Lb
    resid_k, resid_v = cache.resid_k, cache.resid_v
    if rem:
        resid_k = jax.lax.dynamic_update_slice_in_dim(
            resid_k, k[..., Lb:, :].astype(resid_k.dtype), 0, axis=-2
        )
        resid_v = jax.lax.dynamic_update_slice_in_dim(
            resid_v, v[..., Lb:, :].astype(resid_v.dtype), 0, axis=-2
        )
    return dataclasses.replace(
        new,
        resid_k=resid_k,
        resid_v=resid_v,
        n_comp=jnp.full((B,), Lb, jnp.int32),
        n_resid=jnp.full((B,), rem, jnp.int32),
    )


def append_token(
    cache: LayerKVCache, k_new: Array, v_new: Array, ring: bool = False
) -> LayerKVCache:
    """Decode-step append at per-row offsets. k_new/v_new: [B,H,1,D].

    Writes into the residual at each row's own ``n_resid``; rows whose
    residual is full compress their oldest block and append it to the
    compressed region at their own ``n_comp`` (lax.cond over "any row needs
    a flush" — the amortized O(1) compression cost of paper §III-D; the
    per-row write is masked so rows flush independently).

    ring=True: sliding-window mode (recurrentgemma local attention) — the
    compressed region is a circular block buffer of ``capacity`` tokens;
    blocks overwrite the oldest slot. Valid because decode attention is
    permutation-invariant over the cached window (DESIGN.md §4); callers
    mask with ``n_valid = min(n_comp, capacity)``.
    """
    cfg = cache.cfg
    R = cfg.residual
    capacity = cache.capacity

    def write(c: LayerKVCache) -> LayerKVCache:
        rk = row_update_tokens(c.resid_k, k_new, c.n_resid)
        rv = row_update_tokens(c.resid_v, v_new, c.n_resid)
        return dataclasses.replace(c, resid_k=rk, resid_v=rv, n_resid=c.n_resid + 1)

    def flush(c: LayerKVCache) -> LayerKVCache:
        need = c.n_resid >= R  # [B] rows whose residual is full
        blk_k = c.resid_k[..., : cfg.block, :]
        blk_v = c.resid_v[..., : cfg.block, :]
        off = (c.n_comp % capacity) if ring else c.n_comp
        if cfg.policy == "none":
            raw_k = row_update_tokens(c.raw_k, blk_k, off)
            raw_v = row_update_tokens(c.raw_v, blk_v, off)
            c = dataclasses.replace(
                c,
                raw_k=select_rows(need, raw_k, c.raw_k),
                raw_v=select_rows(need, raw_v, c.raw_v),
            )
        else:
            kc, vc = compress_block(
                blk_k, blk_v, cfg, c.k.chan_perm, c.v.chan_perm
            )
            c = dataclasses.replace(
                c,
                k=select_rows(need, append_block_rows(c.k, kc, off), c.k),
                v=select_rows(need, append_block_rows(c.v, vc, off), c.v),
            )
        # shift flushed rows' residual left by one block
        rk = jnp.roll(c.resid_k, -cfg.block, axis=-2)
        rv = jnp.roll(c.resid_v, -cfg.block, axis=-2)
        step = jnp.where(need, cfg.block, 0).astype(jnp.int32)
        return dataclasses.replace(
            c,
            resid_k=select_rows(need, rk, c.resid_k),
            resid_v=select_rows(need, rv, c.resid_v),
            n_comp=c.n_comp + step,
            n_resid=c.n_resid - step,
        )

    cache = jax.lax.cond(jnp.any(cache.n_resid >= R), flush, lambda c: c, cache)
    return write(cache)


# ---------------------------------------------------------------------------
# Per-slot lifecycle (continuous batching)
# ---------------------------------------------------------------------------


def reset_slot(cache: LayerKVCache, slot) -> LayerKVCache:
    """Free row ``slot``: zero its counters so every cached token is masked.

    Buffer contents are left in place — they are dead bytes (all reads mask
    with the counters) and the next ``insert_prefill`` overwrites the whole
    row. Works on a single-layer cache ([B] counters) and on a stacked
    cache pytree ([n_layers, B] counters — the slot is always the last
    counter axis). ``slot`` may be traced.
    """
    return dataclasses.replace(
        cache,
        n_comp=cache.n_comp.at[..., slot].set(0),
        n_resid=cache.n_resid.at[..., slot].set(0),
    )


def mask_free_slots(cache, active: Array):
    """Zero the counters of rows where ``active`` is False.

    Free rows ride along in the batched decode step, so each step appends
    one junk token into them; zeroing their counters right after keeps the
    "free slot == zero counters" invariant true at rest, bounds the junk to
    one residual position, and prevents dead rows from ever triggering the
    flush branch. ``active``: bool [B]; counters may be [B] or stacked
    [n_layers, B] (broadcasts).
    """
    act = jnp.asarray(active).astype(cache.n_comp.dtype)
    return dataclasses.replace(
        cache, n_comp=cache.n_comp * act, n_resid=cache.n_resid * act
    )


def insert_row(cache, slot, row_cache):
    """Scatter batch-row 0 of ``row_cache`` into row ``slot`` of ``cache``.

    Both are LayerKVCache pytrees of identical layout (stacked or flat);
    ``row_cache`` has batch size 1. Every leaf leads with
    [(layers,)? B, ...], so the write is a pure tree_map. ``slot`` may be
    traced (jit-stable single-slot admission).
    """
    lead = cache.n_comp.ndim - 1  # 0 flat, 1 stacked

    def put(dst, src):
        if lead == 0:
            return dst.at[slot].set(src[0].astype(dst.dtype))
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

    return jax.tree_util.tree_map(put, cache, row_cache)


def insert_prefill(cache: LayerKVCache, slot, k: Array, v: Array) -> LayerKVCache:
    """Admit one sequence into row ``slot``: compress its prefill K/V
    ([H, L, D] or [1, H, L, D], static L) and overwrite the row.

    The remaining rows are untouched, so one slot can be recycled while the
    others keep decoding. Calibration (channel->tier permutation) runs on
    this sequence's own prefill, exactly as a batch-size-1 ``prefill_cache``
    would — per-row outputs stay bit-identical to an independent B=1 run.
    """
    if k.ndim == 3:
        k, v = k[None], v[None]
    cfg = cache.cfg
    h_kv, _, head_dim = k.shape[-3], k.shape[-2], k.shape[-1]
    sub = alloc_layer_cache(cfg, 1, h_kv, head_dim, cache.capacity,
                            dtype=cache.resid_k.dtype)
    sub = prefill_cache(sub, k, v)
    return insert_row(cache, slot, sub)
