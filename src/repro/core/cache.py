"""Runtime PackKV cache manager (paper §III-B1/B4 + §III-C glue).

Mirrors the paper's system: a fixed-size **residual buffer** of recent tokens
in full precision; when it fills past one truncated block (64 tokens), the
oldest block is quantized, repacked (in-graph V-median), tier-packed and
**appended** to the compressed region. Everything is static-shape and
jit-compatible (lax.cond / dynamic_update_slice), so the same code path runs
under pjit on the production mesh.

Three policies share one pytree layout so serve_step signatures are uniform:
  * ``none``   — raw bf16 cache (the cuBLAS-equivalent baseline).
  * ``kivi``   — integer quantization only (single tier, no adaptive widths).
  * ``packkv`` — full pipeline (token-wise quant + repack + tiered packing).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..utils import pytree_dataclass
from .quantization import QuantConfig
from .repacking import median_repack_jnp
from .tiered import (
    TierSpec,
    TieredCache,
    alloc_tiered,
    append_block,
    assign_channel_tiers,
    pack_tiered,
    required_channel_widths,
)

Array = jax.Array

BLOCK = 64  # truncated block size (consistent with KIVI, paper §IV-A)


@dataclasses.dataclass(frozen=True)
class PackKVConfig:
    """Tunable knobs of the paper's pipeline (paper §IV-A)."""

    policy: str = "packkv"  # none | kivi | packkv
    k_rel_scale: float = 0.1
    v_rel_scale: float = 0.2
    pack_size: int = 8
    repack: str = "median_v"  # none | median_v (in-graph)
    residual: int = 128  # max buffer size (recent tokens kept fp16)
    block: int = BLOCK
    k_tiers: tuple[int, ...] = (2, 4, 8)
    k_fracs: tuple[float, ...] = (0.25, 0.5, 0.25)
    v_tiers: tuple[int, ...] = (2, 4, 8)
    v_fracs: tuple[float, ...] = (0.25, 0.5, 0.25)
    # Calibrated static specs (engine build time, core.tiered.choose_tier_spec);
    # override the frac-based defaults when set.
    k_spec_static: Optional[TierSpec] = None
    v_spec_static: Optional[TierSpec] = None

    def k_quant(self) -> QuantConfig:
        return QuantConfig(rel_scale=self.k_rel_scale, granularity="token")

    def v_quant(self) -> QuantConfig:
        return QuantConfig(rel_scale=self.v_rel_scale, granularity="token")

    def k_spec(self, head_dim: int) -> TierSpec:
        if self.k_spec_static is not None:
            return self.k_spec_static
        if self.policy == "kivi":
            return TierSpec(widths=(4,), counts=(head_dim,), pack_size=self.pack_size)
        return TierSpec.for_head_dim(head_dim, self.k_tiers, self.k_fracs)

    def v_spec(self, head_dim: int) -> TierSpec:
        if self.v_spec_static is not None:
            return self.v_spec_static
        if self.policy == "kivi":
            return TierSpec(widths=(4,), counts=(head_dim,), pack_size=self.pack_size)
        return TierSpec.for_head_dim(head_dim, self.v_tiers, self.v_fracs)


@pytree_dataclass(meta_fields=("cfg",))
class LayerKVCache:
    """Per-layer decode cache. ``k``/``v`` are None for policy='none'."""

    k: Optional[TieredCache]  # compressed region (channels-major)
    v: Optional[TieredCache]
    raw_k: Optional[Array]  # policy='none': bf16 [B, Hkv, Lcap, D]
    raw_v: Optional[Array]
    resid_k: Array  # bf16 [B, Hkv, R, D]
    resid_v: Array
    n_comp: Array  # i32 [] tokens in compressed/raw region
    n_resid: Array  # i32 [] tokens in residual buffer
    cfg: PackKVConfig


def alloc_layer_cache(
    cfg: PackKVConfig,
    batch: int,
    h_kv: int,
    head_dim: int,
    capacity: int,
    dtype=jnp.bfloat16,
) -> LayerKVCache:
    """Preallocate a cache with static ``capacity`` (compressed region)."""
    R = cfg.residual
    resid = jnp.zeros((batch, h_kv, R, head_dim), dtype)
    zero_i = jnp.zeros((), jnp.int32)
    if cfg.policy == "none":
        raw = jnp.zeros((batch, h_kv, capacity, head_dim), dtype)
        return LayerKVCache(
            k=None, v=None, raw_k=raw, raw_v=raw, resid_k=resid, resid_v=resid,
            n_comp=zero_i, n_resid=zero_i, cfg=cfg,
        )
    k = alloc_tiered(batch, h_kv, capacity, cfg.k_spec(head_dim))
    v = alloc_tiered(batch, h_kv, capacity, cfg.v_spec(head_dim))
    return LayerKVCache(
        k=k, v=v, raw_k=None, raw_v=None, resid_k=resid, resid_v=resid,
        n_comp=zero_i, n_resid=zero_i, cfg=cfg,
    )


# ---------------------------------------------------------------------------
# Quantize + repack + pack one block (in-graph)
# ---------------------------------------------------------------------------


def _quant_tokenwise(x: Array, qc: QuantConfig):
    """x: [B,H,N,D] -> (q i32, scale f32 [B,H,N], zero f32 [B,H,N])."""
    lo = x.min(axis=-1)
    hi = x.max(axis=-1)
    rng = (hi - lo).astype(jnp.float32)
    scale = jnp.where(rng > 0, qc.rel_scale * rng, 1.0)
    q = jnp.clip(
        jnp.round((x.astype(jnp.float32) - lo[..., None].astype(jnp.float32)) / scale[..., None]),
        0,
        qc.max_q,
    ).astype(jnp.int32)
    return q, scale, lo.astype(jnp.float32)


def compress_block(
    k: Array, v: Array, cfg: PackKVConfig, k_perm: Array, v_perm: Array
) -> tuple[TieredCache, TieredCache]:
    """Compress one [B,H,N,D] block pair into single-block TieredCaches.

    k_perm/v_perm: [B,H,D] channel->tier assignment (from calibration).
    """
    qk, sk, zk = _quant_tokenwise(k, cfg.k_quant())
    qv, sv, zv = _quant_tokenwise(v, cfg.v_quant())
    qk, qv, perm = _repack_tokens(qk, qv, cfg)
    if perm is not None:
        # per-token metadata rides along with the joint permutation
        take_meta = lambda a: jnp.take_along_axis(a, perm, axis=-1)
        sk, zk = take_meta(sk), take_meta(zk)
        sv, zv = take_meta(sv), take_meta(zv)
    # channels-major
    qk_cm = jnp.swapaxes(qk, -1, -2)  # [B,H,D,N]
    qv_cm = jnp.swapaxes(qv, -1, -2)
    kc = pack_tiered(qk_cm, k_perm, sk, zk, cfg.k_spec(k.shape[-1]))
    vc = pack_tiered(qv_cm, v_perm, sv, zv, cfg.v_spec(v.shape[-1]))
    return kc, vc


def _repack_tokens(qk: Array, qv: Array, cfg: PackKVConfig):
    """Joint token permutation (paper §III-B3); returns permuted (qk, qv, perm).

    perm is None for repack='none'. Permutation is computed from the V part
    (V-median) and applied jointly to K and V — valid by the permutation
    invariance of decode attention.
    """
    if cfg.repack != "median_v":
        return qk, qv, None
    perm = median_repack_jnp(qv.reshape(*qv.shape[:-2], -1, qv.shape[-1]))
    take = lambda a: jnp.take_along_axis(a, perm[..., None], axis=-2)
    return take(qk), take(qv), perm


def calibrate_channel_tiers(k: Array, v: Array, cfg: PackKVConfig):
    """Assign channel tiers from (prefill) data. k, v: [B,H,L,D].

    Widths are measured AFTER token repacking so the tier assignment sees
    the exact pack ranges the compressor will encode.
    """
    qk, _, _ = _quant_tokenwise(k, cfg.k_quant())
    qv, _, _ = _quant_tokenwise(v, cfg.v_quant())
    L = k.shape[-2]
    Lb = (L // cfg.block) * cfg.block
    if Lb == 0:  # not enough data — identity assignment
        D = k.shape[-1]
        eye = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), k.shape[:-2] + (D,))
        return eye, eye
    qk, qv, _ = _repack_tokens(qk[..., :Lb, :], qv[..., :Lb, :], cfg)
    wk = required_channel_widths(jnp.swapaxes(qk, -1, -2), cfg.pack_size)
    wv = required_channel_widths(jnp.swapaxes(qv, -1, -2), cfg.pack_size)
    D = k.shape[-1]
    return (
        assign_channel_tiers(wk, cfg.k_spec(D)),
        assign_channel_tiers(wv, cfg.v_spec(D)),
    )


def calibrate_specs(k: Array, v: Array, cfg: PackKVConfig, slack: int = 0):
    """Host-side: pick static TierSpecs from calibration K/V ([B,H,L,D]).

    Returns a new PackKVConfig with k_spec_static / v_spec_static set. Run
    once at engine build (before compiling the decode step) — the TPU
    analogue of the paper's per-model configuration sweep (§IV-B).
    """
    from .tiered import choose_tier_spec

    qk, _, _ = _quant_tokenwise(k, cfg.k_quant())
    qv, _, _ = _quant_tokenwise(v, cfg.v_quant())
    L = k.shape[-2]
    Lb = (L // cfg.block) * cfg.block
    if Lb == 0:  # not enough calibration data for one block
        return cfg
    qk, qv, _ = _repack_tokens(qk[..., :Lb, :], qv[..., :Lb, :], cfg)
    wk = required_channel_widths(jnp.swapaxes(qk, -1, -2), cfg.pack_size)
    wv = required_channel_widths(jnp.swapaxes(qv, -1, -2), cfg.pack_size)
    return dataclasses.replace(
        cfg,
        k_spec_static=choose_tier_spec(wk, pack_size=cfg.pack_size, slack=slack),
        v_spec_static=choose_tier_spec(wv, pack_size=cfg.pack_size, slack=slack),
    )


# ---------------------------------------------------------------------------
# Cache update ops
# ---------------------------------------------------------------------------


def prefill_cache(cache: LayerKVCache, k: Array, v: Array) -> LayerKVCache:
    """Fill the cache from prefill K/V ([B,H,L,D]). L is static here.

    Compresses all complete blocks; the remainder goes to the residual.
    Calibrates channel tiers from the prefill data (per batch, head).
    """
    cfg = cache.cfg
    B, H, L, D = k.shape
    n_blocks = L // cfg.block
    Lb = n_blocks * cfg.block
    if cfg.policy == "none":
        raw_k = jax.lax.dynamic_update_slice_in_dim(
            cache.raw_k, k[..., :Lb, :].astype(cache.raw_k.dtype), 0, axis=-2
        )
        raw_v = jax.lax.dynamic_update_slice_in_dim(
            cache.raw_v, v[..., :Lb, :].astype(cache.raw_v.dtype), 0, axis=-2
        )
        new = dataclasses.replace(cache, raw_k=raw_k, raw_v=raw_v)
    else:
        k_perm, v_perm = calibrate_channel_tiers(k[..., :Lb, :], v[..., :Lb, :], cfg)
        kc, vc = compress_block(k[..., :Lb, :], v[..., :Lb, :], cfg, k_perm, v_perm)
        new_k = append_block(
            dataclasses.replace(cache.k, chan_perm=k_perm), kc, jnp.int32(0)
        )
        new_v = append_block(
            dataclasses.replace(cache.v, chan_perm=v_perm), vc, jnp.int32(0)
        )
        new = dataclasses.replace(cache, k=new_k, v=new_v)
    rem = L - Lb
    resid_k, resid_v = cache.resid_k, cache.resid_v
    if rem:
        resid_k = jax.lax.dynamic_update_slice_in_dim(
            resid_k, k[..., Lb:, :].astype(resid_k.dtype), 0, axis=-2
        )
        resid_v = jax.lax.dynamic_update_slice_in_dim(
            resid_v, v[..., Lb:, :].astype(resid_v.dtype), 0, axis=-2
        )
    return dataclasses.replace(
        new,
        resid_k=resid_k,
        resid_v=resid_v,
        n_comp=jnp.int32(Lb),
        n_resid=jnp.int32(rem),
    )


def append_token(
    cache: LayerKVCache, k_new: Array, v_new: Array, ring: bool = False
) -> LayerKVCache:
    """Decode-step append. k_new/v_new: [B,H,1,D].

    Writes into the residual; when the residual is full, compresses the
    oldest block and appends it to the compressed region (lax.cond — the
    amortized O(1) compression cost of paper §III-D).

    ring=True: sliding-window mode (recurrentgemma local attention) — the
    compressed region is a circular block buffer of ``capacity`` tokens;
    blocks overwrite the oldest slot. Valid because decode attention is
    permutation-invariant over the cached window (DESIGN.md §4); callers
    mask with ``n_valid = min(n_comp, capacity)``.
    """
    cfg = cache.cfg
    R = cfg.residual
    capacity = (
        cache.raw_k.shape[-2] if cfg.policy == "none" else cache.k.capacity
    )

    def write(c: LayerKVCache) -> LayerKVCache:
        rk = jax.lax.dynamic_update_slice_in_dim(
            c.resid_k, k_new.astype(c.resid_k.dtype), c.n_resid, axis=-2
        )
        rv = jax.lax.dynamic_update_slice_in_dim(
            c.resid_v, v_new.astype(c.resid_v.dtype), c.n_resid, axis=-2
        )
        return dataclasses.replace(c, resid_k=rk, resid_v=rv, n_resid=c.n_resid + 1)

    def flush(c: LayerKVCache) -> LayerKVCache:
        blk_k = c.resid_k[..., : cfg.block, :]
        blk_v = c.resid_v[..., : cfg.block, :]
        off = (c.n_comp % capacity) if ring else c.n_comp
        if cfg.policy == "none":
            raw_k = jax.lax.dynamic_update_slice_in_dim(
                c.raw_k, blk_k, off, axis=-2
            )
            raw_v = jax.lax.dynamic_update_slice_in_dim(
                c.raw_v, blk_v, off, axis=-2
            )
            c = dataclasses.replace(c, raw_k=raw_k, raw_v=raw_v)
        else:
            kc, vc = compress_block(
                blk_k, blk_v, cfg, c.k.chan_perm, c.v.chan_perm
            )
            c = dataclasses.replace(
                c,
                k=append_block(c.k, kc, off),
                v=append_block(c.v, vc, off),
            )
        # shift residual left by one block
        rk = jnp.roll(c.resid_k, -cfg.block, axis=-2)
        rv = jnp.roll(c.resid_v, -cfg.block, axis=-2)
        return dataclasses.replace(
            c,
            resid_k=rk,
            resid_v=rv,
            n_comp=c.n_comp + cfg.block,
            n_resid=c.n_resid - cfg.block,
        )

    cache = jax.lax.cond(cache.n_resid >= R, flush, lambda c: c, cache)
    return write(cache)
