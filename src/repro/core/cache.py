"""Runtime PackKV cache manager (paper §III-B1/B4 + §III-C glue).

Mirrors the paper's system: a fixed-size **residual buffer** of recent tokens
in full precision; when it fills past one truncated block (64 tokens), the
oldest block is quantized, repacked (in-graph V-median), tier-packed and
**appended** to the compressed region. Everything is static-shape and
jit-compatible (lax.cond / dynamic_update_slice), so the same code path runs
under pjit on the production mesh.

Sequence state is **per row**: ``n_comp``/``n_resid`` are ``[B]`` i32
vectors, every append/flush runs at per-row offsets (vmapped
``dynamic_update_slice``), and rows flush independently — the substrate for
continuous (per-slot) batching in ``serving.engine``. ``reset_slot`` and
``insert_prefill`` recycle one row while the others keep decoding.

Three policies share one pytree layout so serve_step signatures are uniform:
  * ``none``   — raw bf16 cache (the cuBLAS-equivalent baseline).
  * ``kivi``   — integer quantization only (single tier, no adaptive widths).
  * ``packkv`` — full pipeline (token-wise quant + repack + tiered packing).

The compressed region has two storage modes (``PackKVConfig.paged``):

  * **dense** — per-slot contiguous buffers sized to ``capacity`` (the
    PR-3 layout; the benchmark baseline). One long request pins
    ``capacity`` tokens of memory per slot however short the others are.
  * **paged** — a shared ``PagePool`` of ``page_size``-token physical
    pages plus a per-slot page table; a slot resident-allocates only
    ``ceil(n_comp / page_size)`` pages, freed back to the pool the moment
    the slot retires. Reads reassemble the dense layout bit-identically
    (``gather_paged``) or index pages in-kernel (paged Pallas kernels),
    so outputs are IDENTICAL to the dense path — tested in
    tests/test_paged.py.

Invariants this module maintains (see docs/architecture.md for diagrams):
  * ``n_comp`` is always block-aligned (``% cfg.block == 0``): tokens enter
    the compressed region only in whole 64-token blocks.
  * ``n_resid < cfg.residual`` at rest; a flush fires before the write that
    would overflow.
  * free slots have ``n_comp == n_resid == 0`` at rest (``reset_slot`` /
    ``mask_free_slots``), so their buffer bytes are dead and a free slot
    holds ZERO pool pages in paged mode.
  * a slot's live pages are the dense prefix ``page_table[b, :ceil(n_comp
    / page_size)]``; entries past it are stale but always in-range ids.
  * pool pages are REFCOUNTED (``PagePool.ref``): a page is free iff its
    count is zero, distinct slots (and the serving prefix index) may hold
    the same physical page, and a page with ``ref > 1`` is immutable —
    ``append_token``'s flush copies-on-write before mutating it. See the
    ``PagePool`` docstring and docs/architecture.md for the full contract.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import cdiv, pytree_dataclass, round_up
from .quantization import QuantConfig
from .repacking import median_repack_jnp
from .tiered import (
    TierBuffer,
    TierSpec,
    TieredCache,
    alloc_tiered,
    append_block,
    append_block_rows,
    assign_channel_tiers,
    pack_tiered,
    required_channel_widths,
)

Array = jax.Array

BLOCK = 64  # truncated block size (consistent with KIVI, paper §IV-A)


@dataclasses.dataclass(frozen=True)
class PackKVConfig:
    """Tunable knobs of the paper's pipeline (paper §IV-A)."""

    policy: str = "packkv"  # none | kivi | packkv
    k_rel_scale: float = 0.1
    v_rel_scale: float = 0.2
    pack_size: int = 8
    repack: str = "median_v"  # none | median_v (in-graph)
    residual: int = 128  # max buffer size (recent tokens kept fp16)
    block: int = BLOCK
    k_tiers: tuple[int, ...] = (2, 4, 8)
    k_fracs: tuple[float, ...] = (0.25, 0.5, 0.25)
    v_tiers: tuple[int, ...] = (2, 4, 8)
    v_fracs: tuple[float, ...] = (0.25, 0.5, 0.25)
    # Calibrated static specs (engine build time, core.tiered.choose_tier_spec);
    # override the frac-based defaults when set.
    k_spec_static: Optional[TierSpec] = None
    v_spec_static: Optional[TierSpec] = None
    # Paged compressed region (shared page pool + per-slot page tables).
    # page_size: power-of-two tokens per physical page — a multiple of
    # ``block`` and of ``4 * pack_size`` so blocks never straddle pages and
    # page boundaries land on payload-word/pack/shift-byte boundaries.
    # pool_pages: physical pages in the shared pool (None -> B * capacity /
    # page_size at alloc time, i.e. no oversubscription).
    paged: bool = False
    page_size: int = 256
    pool_pages: Optional[int] = None

    def k_quant(self) -> QuantConfig:
        return QuantConfig(rel_scale=self.k_rel_scale, granularity="token")

    def v_quant(self) -> QuantConfig:
        return QuantConfig(rel_scale=self.v_rel_scale, granularity="token")

    def k_spec(self, head_dim: int) -> TierSpec:
        if self.k_spec_static is not None:
            return self.k_spec_static
        if self.policy == "kivi":
            return TierSpec(widths=(4,), counts=(head_dim,), pack_size=self.pack_size)
        return TierSpec.for_head_dim(head_dim, self.k_tiers, self.k_fracs)

    def v_spec(self, head_dim: int) -> TierSpec:
        if self.v_spec_static is not None:
            return self.v_spec_static
        if self.policy == "kivi":
            return TierSpec(widths=(4,), counts=(head_dim,), pack_size=self.pack_size)
        return TierSpec.for_head_dim(head_dim, self.v_tiers, self.v_fracs)


@pytree_dataclass(meta_fields=("page_size",))
class PagePool:
    """Refcounted page allocator + per-slot page tables (paged mode only).

    ONE pool instance serves K, V and (policy='none') raw storage of a
    layer: they append in lock-step, so a single physical page id addresses
    the K page, the V page and the raw page holding the same
    ``page_size``-token span. The refcount contract (PR 5; the PR-4
    exclusive-ownership invariant is the ``ref <= 1`` special case):

      * ``ref[p]`` counts the HOLDERS of physical page ``p``: each slot row
        whose live table prefix contains ``p`` plus (serving) the host-side
        prefix index. ``ref[p] == 0`` ⇔ ``p`` is free ⇔ ``p`` is on the
        stack: ``free[:n_free]`` are exactly the ``ref == 0`` ids (entries
        above ``n_free`` are stale pops, never read).
      * a slot's live pages are the DENSE PREFIX
        ``page_table[b, :ceil(n_comp[b] / page_size)]``; entries past that
        prefix are stale but always in-range ids (gathers never go OOB).
      * pops hand out unique ids at ``ref = 1``; releasing a holder
        (``pool_release_row`` / ``release_pages``) decrements, and a page
        returns to the stack exactly when its count reaches zero.
      * a page with ``ref > 1`` is READ-ONLY: ``append_token``'s flush
        copy-on-write pops a private replacement before mutating it, so
        shared bytes never change while anyone else holds the page.
      * pool exhaustion is the SCHEDULER's job to prevent (page-reservation
        admission in ``serving.engine.SlotServer``); in-graph pops clamp
        their stack reads, so an impossible over-pop corrupts data but
        never faults.
    """

    page_table: Array  # i32 [B, max_pages] logical -> physical page id
    free: Array  # i32 [n_pool_pages] stack of free physical page ids
    n_free: Array  # i32 [] live stack height
    ref: Array  # i32 [n_pool_pages] holders per page (0 == free)
    page_size: int

    @property
    def n_pool_pages(self) -> int:
        return self.free.shape[-1]

    @property
    def max_pages(self) -> int:
        return self.page_table.shape[-1]


@pytree_dataclass(meta_fields=("cfg",))
class LayerKVCache:
    """Per-layer decode cache. ``k``/``v`` are None for policy='none'.

    Dense mode: compressed leaves lead with [B, Hkv] and cover
    ``capacity`` tokens. Paged mode (``pages`` is not None): compressed
    leaves are page pools leading with [Hkv, n_pool_pages] covering one
    page each (see ``tiered.alloc_tiered_pool`` / ``PagePool``); the
    residual buffer and the per-row counters keep the dense layout either
    way.
    """

    k: Optional[TieredCache]  # compressed region (channels-major)
    v: Optional[TieredCache]
    raw_k: Optional[Array]  # policy='none': bf16 [B, Hkv, Lcap, D]
    raw_v: Optional[Array]
    resid_k: Array  # bf16 [B, Hkv, R, D]
    resid_v: Array
    n_comp: Array  # i32 [B] per-row tokens in compressed/raw region
    n_resid: Array  # i32 [B] per-row tokens in residual buffer
    cfg: PackKVConfig
    pages: Optional[PagePool] = None  # paged mode: shared K/V/raw page pool

    @property
    def capacity(self) -> int:
        if self.pages is not None:
            return self.pages.max_pages * self.cfg.page_size
        return self.raw_k.shape[-2] if self.cfg.policy == "none" else self.k.capacity


def alloc_page_pool(
    batch: int, capacity: int, page_size: int, pool_pages: Optional[int] = None
) -> PagePool:
    """Fresh pool: every physical page free, tables zeroed (valid ids)."""
    max_pages = capacity // page_size
    P = batch * max_pages if pool_pages is None else pool_pages
    return PagePool(
        page_table=jnp.zeros((batch, max_pages), jnp.int32),
        # descending stack so pops hand out 0, 1, 2, ... (deterministic)
        free=jnp.arange(P - 1, -1, -1, dtype=jnp.int32),
        n_free=jnp.int32(P),
        ref=jnp.zeros((P,), jnp.int32),
        page_size=page_size,
    )


def alloc_layer_cache(
    cfg: PackKVConfig,
    batch: int,
    h_kv: int,
    head_dim: int,
    capacity: int,
    dtype=jnp.bfloat16,
) -> LayerKVCache:
    """Preallocate a cache with static ``capacity`` (compressed region).

    Paged mode resident-allocates ``cfg.pool_pages`` physical pages (default
    ``batch * capacity / page_size``) instead of ``batch * capacity``
    tokens; per-slot admission is then bounded by live pages, not worst-case
    capacity (see serving/engine.py).
    """
    R = cfg.residual
    resid = jnp.zeros((batch, h_kv, R, head_dim), dtype)
    zero_i = jnp.zeros((batch,), jnp.int32)
    if cfg.paged:
        page = cfg.page_size
        assert page & (page - 1) == 0, f"page_size {page} must be a power of two"
        assert capacity % page == 0 and page % cfg.block == 0, (capacity, page)
        pool = alloc_page_pool(batch, capacity, page, cfg.pool_pages)
        P = pool.n_pool_pages
        if cfg.policy == "none":
            raw = jnp.zeros((h_kv, P, page, head_dim), dtype)
            return LayerKVCache(
                k=None, v=None, raw_k=raw, raw_v=raw, resid_k=resid,
                resid_v=resid, n_comp=zero_i, n_resid=zero_i, cfg=cfg,
                pages=pool,
            )
        from .tiered import alloc_tiered_pool

        k = alloc_tiered_pool(batch, h_kv, P, page, cfg.k_spec(head_dim))
        v = alloc_tiered_pool(batch, h_kv, P, page, cfg.v_spec(head_dim))
        return LayerKVCache(
            k=k, v=v, raw_k=None, raw_v=None, resid_k=resid, resid_v=resid,
            n_comp=zero_i, n_resid=zero_i, cfg=cfg, pages=pool,
        )
    if cfg.policy == "none":
        raw = jnp.zeros((batch, h_kv, capacity, head_dim), dtype)
        return LayerKVCache(
            k=None, v=None, raw_k=raw, raw_v=raw, resid_k=resid, resid_v=resid,
            n_comp=zero_i, n_resid=zero_i, cfg=cfg,
        )
    k = alloc_tiered(batch, h_kv, capacity, cfg.k_spec(head_dim))
    v = alloc_tiered(batch, h_kv, capacity, cfg.v_spec(head_dim))
    return LayerKVCache(
        k=k, v=v, raw_k=None, raw_v=None, resid_k=resid, resid_v=resid,
        n_comp=zero_i, n_resid=zero_i, cfg=cfg,
    )


# ---------------------------------------------------------------------------
# Quantize + repack + pack one block (in-graph)
# ---------------------------------------------------------------------------


def _quant_tokenwise(x: Array, qc: QuantConfig):
    """x: [B,H,N,D] -> (q i32, scale f32 [B,H,N], zero f32 [B,H,N]).

    Integers are CENTERED at zero (q in [-c, max_q - c], c = (max_q+1)//2)
    with the offset folded into the zero-point. Uncentered ints live in
    [0, max_q]; at tight rel scales (max_q up to 255) a pack whose values
    are all high — exactly what V-median repacking produces — then has a
    pack-min above 127 and wraps the int8 ``mins`` field of the tier
    format. Centering keeps every reachable pack-min inside int8 as long
    as max_q <= 255.
    """
    lo = x.min(axis=-1)
    hi = x.max(axis=-1)
    rng = (hi - lo).astype(jnp.float32)
    scale = jnp.where(rng > 0, qc.rel_scale * rng, 1.0)
    c = (qc.max_q + 1) // 2
    q = jnp.clip(
        jnp.round((x.astype(jnp.float32) - lo[..., None].astype(jnp.float32)) / scale[..., None]),
        0,
        qc.max_q,
    ).astype(jnp.int32) - c
    return q, scale, lo.astype(jnp.float32) + c * scale


def compress_block(
    k: Array, v: Array, cfg: PackKVConfig, k_perm: Array, v_perm: Array
) -> tuple[TieredCache, TieredCache]:
    """Compress one [B,H,N,D] block pair into single-block TieredCaches.

    k_perm/v_perm: [B,H,D] channel->tier assignment (from calibration).
    """
    qk, sk, zk = _quant_tokenwise(k, cfg.k_quant())
    qv, sv, zv = _quant_tokenwise(v, cfg.v_quant())
    qk, qv, perm = _repack_tokens(qk, qv, cfg)
    if perm is not None:
        # per-token metadata rides along with the joint permutation
        take_meta = lambda a: jnp.take_along_axis(a, perm, axis=-1)
        sk, zk = take_meta(sk), take_meta(zk)
        sv, zv = take_meta(sv), take_meta(zv)
    # channels-major
    qk_cm = jnp.swapaxes(qk, -1, -2)  # [B,H,D,N]
    qv_cm = jnp.swapaxes(qv, -1, -2)
    kc = pack_tiered(qk_cm, k_perm, sk, zk, cfg.k_spec(k.shape[-1]))
    vc = pack_tiered(qv_cm, v_perm, sv, zv, cfg.v_spec(v.shape[-1]))
    return kc, vc


def _repack_tokens(qk: Array, qv: Array, cfg: PackKVConfig):
    """Joint token permutation (paper §III-B3); returns permuted (qk, qv, perm).

    perm is None for repack='none'. Permutation is computed from the V part
    (V-median) and applied jointly to K and V — valid by the permutation
    invariance of decode attention.
    """
    if cfg.repack != "median_v":
        return qk, qv, None
    perm = median_repack_jnp(qv.reshape(*qv.shape[:-2], -1, qv.shape[-1]))
    take = lambda a: jnp.take_along_axis(a, perm[..., None], axis=-2)
    return take(qk), take(qv), perm


def calibrate_channel_tiers(k: Array, v: Array, cfg: PackKVConfig):
    """Assign channel tiers from (prefill) data. k, v: [B,H,L,D].

    Widths are measured AFTER token repacking so the tier assignment sees
    the exact pack ranges the compressor will encode.
    """
    qk, _, _ = _quant_tokenwise(k, cfg.k_quant())
    qv, _, _ = _quant_tokenwise(v, cfg.v_quant())
    L = k.shape[-2]
    Lb = (L // cfg.block) * cfg.block
    if Lb == 0:  # not enough data — identity assignment
        D = k.shape[-1]
        eye = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), k.shape[:-2] + (D,))
        return eye, eye
    qk, qv, _ = _repack_tokens(qk[..., :Lb, :], qv[..., :Lb, :], cfg)
    wk = required_channel_widths(jnp.swapaxes(qk, -1, -2), cfg.pack_size)
    wv = required_channel_widths(jnp.swapaxes(qv, -1, -2), cfg.pack_size)
    D = k.shape[-1]
    return (
        assign_channel_tiers(wk, cfg.k_spec(D)),
        assign_channel_tiers(wv, cfg.v_spec(D)),
    )


def calibrate_specs(k: Array, v: Array, cfg: PackKVConfig, slack: int = 0):
    """Host-side: pick static TierSpecs from calibration K/V ([B,H,L,D]).

    Returns a new PackKVConfig with k_spec_static / v_spec_static set. Run
    once at engine build (before compiling the decode step) — the TPU
    analogue of the paper's per-model configuration sweep (§IV-B).
    """
    from .tiered import choose_tier_spec

    qk, _, _ = _quant_tokenwise(k, cfg.k_quant())
    qv, _, _ = _quant_tokenwise(v, cfg.v_quant())
    L = k.shape[-2]
    Lb = (L // cfg.block) * cfg.block
    if Lb == 0:  # not enough calibration data for one block
        return cfg
    qk, qv, _ = _repack_tokens(qk[..., :Lb, :], qv[..., :Lb, :], cfg)
    wk = required_channel_widths(jnp.swapaxes(qk, -1, -2), cfg.pack_size)
    wv = required_channel_widths(jnp.swapaxes(qv, -1, -2), cfg.pack_size)
    return dataclasses.replace(
        cfg,
        k_spec_static=choose_tier_spec(wk, pack_size=cfg.pack_size, slack=slack),
        v_spec_static=choose_tier_spec(wv, pack_size=cfg.pack_size, slack=slack),
    )


# ---------------------------------------------------------------------------
# Length-aware launch buckets
# ---------------------------------------------------------------------------

BUCKET_UNIT = 256  # smallest bucket; multiple of every kernel tile_l in use


def bucket_length(n_max: int, capacity: int, unit: int = BUCKET_UNIT) -> int:
    """Host-side: the launch bucket covering ``n_max`` live tokens.

    Buckets are power-of-two multiples of ``unit`` clamped to ``capacity``
    (plus ``capacity`` itself), so a serving engine compiles at most
    ``log2(capacity / unit) + 1`` decode variants while every launch does
    work proportional to the live prefix, not the allocation. ``n_max`` is
    the scheduler's host-side upper bound on ``max(n_comp)`` — slicing to a
    larger-than-needed bucket is correct (masked), slicing below a row's
    live length is not.
    """
    if capacity <= unit or n_max >= capacity:
        return capacity
    b = unit
    while b < n_max:
        b *= 2
    return min(b, capacity)


def bucket_set(capacity: int, unit: int = BUCKET_UNIT) -> tuple[int, ...]:
    """Every bucket ``bucket_length`` can return for this capacity."""
    out = []
    b = unit
    while b < capacity:
        out.append(b)
        b *= 2
    return tuple(out) + (capacity,)


def slice_compressed(cache: LayerKVCache, n_bucket: int | None) -> LayerKVCache:
    """Static prefix view of the compressed region for a bucketed launch.

    Returns a LayerKVCache whose compressed buffers (tiered k/v, or raw_k/
    raw_v for policy='none') cover only the first ``n_bucket`` tokens; the
    residual buffer and the per-row counters are untouched (counters stay
    valid because ``n_bucket >= max(n_comp)`` by construction). Use ONLY
    for reads (attention) — appends must go through the full-capacity
    cache.

    Paged caches return the page-table GATHER of the first ``n_bucket``
    tokens instead (``gather_paged``) — same dense-layout, read-only
    contract, so XLA-backed consumers need no paged special case.
    """
    from .tiered import slice_tiered_prefix

    if cache.pages is not None:
        return gather_paged(cache, n_bucket)
    if n_bucket is None or n_bucket >= cache.capacity:
        return cache
    if cache.cfg.policy == "none":
        return dataclasses.replace(
            cache,
            raw_k=cache.raw_k[..., :n_bucket, :],
            raw_v=cache.raw_v[..., :n_bucket, :],
        )
    return dataclasses.replace(
        cache,
        k=slice_tiered_prefix(cache.k, n_bucket),
        v=slice_tiered_prefix(cache.v, n_bucket),
    )


# ---------------------------------------------------------------------------
# Per-row primitives
# ---------------------------------------------------------------------------


def row_update_tokens(buf: Array, new: Array, starts: Array) -> Array:
    """Per-row write along the token axis (-2).

    buf: [B, ..., N, D]; new: [B, ..., n, D]; starts: i32 [B].
    """
    upd = lambda b, x, s: jax.lax.dynamic_update_slice_in_dim(b, x, s, axis=-2)
    return jax.vmap(upd)(buf, new.astype(buf.dtype), starts)


def select_rows(mask: Array, new, old):
    """Pytree where: row b takes ``new`` where mask[b] (leaves lead with B)."""
    def sel(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(sel, new, old)


# ---------------------------------------------------------------------------
# Paged pool primitives (jit-stable free-list ops + page writes/gathers)
# ---------------------------------------------------------------------------


def live_pages(n_comp: Array, page_size: int) -> Array:
    """Pages resident for ``n_comp`` compressed tokens (ceil division)."""
    return cdiv(n_comp, page_size)


def pool_pop_rows(pool: PagePool, need: Array, lp: Array) -> PagePool:
    """Pop one page for every row with ``need[b]`` and record it at logical
    index ``lp[b]`` of that row's table. Rows without ``need`` keep their
    current entry. Pops are unique (distinct stack positions per row) and
    land at ``ref = 1``."""
    B = need.shape[0]
    P = pool.n_pool_pages
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1  # position among needers
    pos = jnp.clip(pool.n_free - 1 - rank, 0, P - 1)
    phys = pool.free[pos]
    rows = jnp.arange(B)
    lp_c = jnp.clip(lp, 0, pool.max_pages - 1)
    cur = pool.page_table[rows, lp_c]
    table = pool.page_table.at[rows, lp_c].set(jnp.where(need, phys, cur))
    ref = pool.ref.at[jnp.where(need, phys, P)].set(1, mode="drop")
    n_free = jnp.maximum(pool.n_free - need.astype(jnp.int32).sum(), 0)
    return dataclasses.replace(pool, page_table=table, ref=ref, n_free=n_free)


def pool_pop_prefix(pool: PagePool, slot, k: int,
                    lp0: int = 0) -> tuple[PagePool, Array]:
    """Pop ``k`` (STATIC) pages and write them to
    ``page_table[slot, lp0:lp0 + k]`` at ``ref = 1``.

    Returns (pool, phys i32 [k]). Used by prefill-insert, where the page
    count is static because the prompt length is; ``lp0 > 0`` places the
    pops after a shared prefix mapped by ``pool_map_prefix``."""
    if lp0 + k > pool.max_pages:  # static: fails at trace time, clear error
        raise ValueError(
            f"prompt needs {lp0 + k} pages but a slot's table holds "
            f"{pool.max_pages}; its block-aligned length exceeds the "
            "compressed capacity — reject upstream (SlotServer.submit does)"
        )
    if k == 0:
        return pool, jnp.zeros((0,), jnp.int32)
    pos = jnp.clip(pool.n_free - k + jnp.arange(k), 0, pool.n_pool_pages - 1)
    phys = pool.free[pos]
    table = jax.lax.dynamic_update_slice(
        pool.page_table, phys[None, :], (jnp.asarray(slot, jnp.int32), lp0)
    )
    ref = pool.ref.at[phys].set(1)
    n_free = jnp.maximum(pool.n_free - k, 0)
    return dataclasses.replace(pool, page_table=table, ref=ref,
                               n_free=n_free), phys


def pool_pop_all_rows(pool: PagePool, k: int) -> tuple[PagePool, Array]:
    """Pop ``k`` (STATIC) pages for EVERY row (whole-batch prefill).

    Returns (pool, phys i32 [B, k])."""
    B = pool.page_table.shape[0]
    if k == 0:
        return pool, jnp.zeros((B, 0), jnp.int32)
    total = B * k
    pos = jnp.clip(pool.n_free - total + jnp.arange(total), 0,
                   pool.n_pool_pages - 1)
    phys = pool.free[pos].reshape(B, k)
    table = pool.page_table.at[:, :k].set(phys)
    ref = pool.ref.at[phys.reshape(-1)].set(1)
    n_free = jnp.maximum(pool.n_free - total, 0)
    return dataclasses.replace(pool, page_table=table, ref=ref,
                               n_free=n_free), phys


def _pool_release_ids(pool: PagePool, ids: Array) -> PagePool:
    """Drop ONE reference per entry of ``ids`` (i32 [m]).

    Entries ``>= n_pool_pages`` are sentinels (ignored); duplicates are
    allowed and each costs one reference (two rows COW-releasing the same
    shared page in one flush). Pages whose count reaches zero return to the
    free stack exactly once. Upstream contract violations are CONTAINED:
    per-id decrements are clamped to the page's current count (an id's
    occurrences past its refcount are dropped), so a count never goes
    negative, a free page is never double-pushed, and the conservation
    invariant survives to point at the buggy caller. O(m²) on the
    duplicate mask — m is a batch or table width.
    """
    P = pool.n_pool_pages
    ids = jnp.asarray(ids, jnp.int32)
    ids_c = jnp.clip(ids, 0, P - 1)
    in_range = ids < P
    eq = ids[:, None] == ids[None, :]
    # occurrence rank among duplicates; only the first ref[id] occurrences
    # actually decrement (the clamp that contains over-releases)
    occ = jnp.sum(jnp.tril(eq, -1) & in_range[None, :], axis=1)
    valid = in_range & (occ < pool.ref[ids_c])
    ref = pool.ref.at[jnp.where(valid, ids, P)].add(-1, mode="drop")
    hit0 = valid & (occ == 0) & (ref[ids_c] == 0)
    dst = jnp.where(hit0, pool.n_free + jnp.cumsum(hit0) - 1, P)
    free = pool.free.at[dst].set(ids, mode="drop")
    return dataclasses.replace(
        pool, ref=ref, free=free, n_free=pool.n_free + hit0.sum()
    )


def pool_release_row(pool: PagePool, slot, n_pages: Array) -> PagePool:
    """Release row ``slot``'s first ``n_pages`` (traced) table entries: one
    reference each; pages reaching ``ref == 0`` go back to the free stack.
    The table row is left stale (entries stay in-range)."""
    mp = pool.max_pages
    row = jax.lax.dynamic_slice(
        pool.page_table, (jnp.asarray(slot, jnp.int32), 0), (1, mp)
    )[0]
    k = jnp.clip(jnp.asarray(n_pages, jnp.int32), 0, mp)
    ids = jnp.where(jnp.arange(mp) < k, row, pool.n_pool_pages)
    return _pool_release_ids(pool, ids)


def pool_map_prefix(pool: PagePool, slot, phys: Array) -> PagePool:
    """SHARE: map already-allocated pages into ``page_table[slot, :k]`` by
    reference (``ref += 1``). ``phys``: i32 [k], STATIC k; every entry must
    currently have ``ref >= 1`` (held by another slot or the prefix index),
    so a mapped page is never simultaneously on the free stack."""
    k = phys.shape[0]
    if k == 0:
        return pool
    table = jax.lax.dynamic_update_slice(
        pool.page_table, phys[None, :], (jnp.asarray(slot, jnp.int32), 0)
    )
    ref = pool.ref.at[phys].add(1)
    return dataclasses.replace(pool, page_table=table, ref=ref)


def pool_acquire_ids(pool: PagePool, ids: Array) -> PagePool:
    """Add one reference per entry of ``ids`` (sentinel ``>= n_pool_pages``
    entries ignored). The prefix index pins its cached pages with this —
    acquired BEFORE the owning slot releases, so the count never dips to
    zero in between."""
    P = pool.n_pool_pages
    ids = jnp.asarray(ids, jnp.int32)
    ref = pool.ref.at[jnp.where(ids < P, ids, P)].add(1, mode="drop")
    return dataclasses.replace(pool, ref=ref)


def _pool_write_rows(
    pool_leaf: Array, blk: Array, phys_r: Array, phys_w: Array, off: Array,
    axis: int = -1,
) -> Array:
    """Per-row block write into pool pages (read-modify-write one page/row).

    pool_leaf: [H, P, ...] with ``axis`` covering one page; blk: [B, H, ...]
    with ``axis`` covering the block; off: i32 [B] element offset inside the
    page; phys_r: i32 [B] page to read (always in-range); phys_w: i32 [B]
    page to write — set masked rows to ``P`` so the scatter DROPS them
    (writing back the unmodified page would race with the owning row)."""
    cur = jnp.moveaxis(pool_leaf[:, phys_r], 0, 1)  # [B, H, ...]
    upd = jax.vmap(
        lambda c, b, o: jax.lax.dynamic_update_slice_in_dim(
            c, b.astype(c.dtype), o, axis=axis
        )
    )(cur, blk, off)
    return pool_leaf.at[:, phys_w].set(jnp.moveaxis(upd, 0, 1), mode="drop")


def _pool_write_tiered(
    pool_tc: TieredCache, blk: TieredCache, phys_r: Array, phys_w: Array,
    wo: Array,
) -> TieredCache:
    """Write per-row 64-token blocks into a tiered page pool at within-page
    token offset ``wo`` (i32 [B], block-aligned so packs/shift bytes land on
    exact boundaries: wo % block == 0, block % (4*pack) == 0)."""
    spec = pool_tc.spec
    tiers = []
    for t, b in zip(pool_tc.tiers, blk.tiers):
        w = t.width
        payload = (
            _pool_write_rows(t.payload, b.payload, phys_r, phys_w, wo * w // 32)
            if w else t.payload
        )
        mins = _pool_write_rows(t.mins, b.mins, phys_r, phys_w,
                                wo // spec.pack_size)
        shifts = _pool_write_rows(t.shifts, b.shifts, phys_r, phys_w,
                                  wo // spec.pack_size // 4)
        tiers.append(TierBuffer(payload=payload, mins=mins, shifts=shifts,
                                width=w, pack_size=t.pack_size))
    return dataclasses.replace(
        pool_tc,
        tiers=tuple(tiers),
        scale=_pool_write_rows(pool_tc.scale, blk.scale, phys_r, phys_w, wo),
        zero=_pool_write_rows(pool_tc.zero, blk.zero, phys_r, phys_w, wo),
    )


def _scatter_pages(pool_leaf: Array, blk: Array, phys: Array,
                   axis: int = -1) -> Array:
    """Scatter whole pages of a dense block into the pool.

    pool_leaf: [H, P, ...] with ``axis`` covering one page (``u`` units);
    blk: [B, H, ...] with ``axis`` covering up to ``k*u`` units (padded with
    zeros up to the page boundary); phys: i32 [B, k] target pages."""
    B, k = phys.shape
    ax = axis % blk.ndim
    u = pool_leaf.shape[axis % pool_leaf.ndim]
    pad = k * u - blk.shape[ax]
    if pad:
        widths = [(0, 0)] * blk.ndim
        widths[ax] = (0, pad)
        blk = jnp.pad(blk, widths)
    shape = blk.shape[:ax] + (k, u) + blk.shape[ax + 1:]
    x = blk.reshape(shape)  # [B, H, ..., k, u, ...]
    x = jnp.moveaxis(x, ax, 1)  # [B, k, H, ..., u, ...]
    x = x.reshape(B * k, *x.shape[2:])  # [B*k, H, ..., u, ...]
    x = jnp.moveaxis(x, 0, 1)  # [H, B*k, ..., u, ...]
    return pool_leaf.at[:, phys.reshape(-1)].set(
        x.astype(pool_leaf.dtype), mode="drop"
    )


def _scatter_pages_tiered(pool_tc: TieredCache, blk: TieredCache,
                          phys: Array) -> TieredCache:
    """Scatter a dense-layout compressed block (capacity <= k * page_size)
    into ``k`` pool pages per row. ``chan_perm`` is NOT touched (per-slot
    metadata; callers set it explicitly)."""
    tiers = tuple(
        TierBuffer(
            payload=_scatter_pages(pt.payload, bt.payload, phys),
            mins=_scatter_pages(pt.mins, bt.mins, phys),
            shifts=_scatter_pages(pt.shifts, bt.shifts, phys),
            width=pt.width,
            pack_size=pt.pack_size,
        )
        for pt, bt in zip(pool_tc.tiers, blk.tiers)
    )
    return dataclasses.replace(
        pool_tc,
        tiers=tiers,
        scale=_scatter_pages(pool_tc.scale, blk.scale, phys),
        zero=_scatter_pages(pool_tc.zero, blk.zero, phys),
    )


def gather_paged(cache: LayerKVCache, n_bucket: int | None = None) -> LayerKVCache:
    """Dense read view of a paged cache: gather the first ``n_bucket``
    tokens' pages of every slot through its page table (the XLA hot path;
    the paged Pallas kernels index the pool in-kernel instead).

    Returns a dense-layout LayerKVCache (``pages=None``) of compressed
    capacity ``n_bucket`` (full capacity when None), bit-identical on every
    live byte to what the dense storage mode would hold. Read-only — like
    ``slice_compressed``, appends must go through the paged cache."""
    assert cache.pages is not None
    page = cache.cfg.page_size
    n = cache.capacity if n_bucket is None else min(n_bucket, cache.capacity)
    from .tiered import page_prefix_ids

    idx = page_prefix_ids(cache.pages.page_table, n, page)
    if cache.cfg.policy == "none":
        from .tiered import gather_pool_leaf

        return dataclasses.replace(
            cache,
            raw_k=gather_pool_leaf(cache.raw_k, idx, token_axis=-2),
            raw_v=gather_pool_leaf(cache.raw_v, idx, token_axis=-2),
            pages=None,
        )
    from .tiered import gather_tiered_pages

    return dataclasses.replace(
        cache,
        k=gather_tiered_pages(cache.k, idx),
        v=gather_tiered_pages(cache.v, idx),
        pages=None,
    )


# ---------------------------------------------------------------------------
# Cache update ops
# ---------------------------------------------------------------------------


def prefill_cache(cache: LayerKVCache, k: Array, v: Array) -> LayerKVCache:
    """Fill the cache from prefill K/V ([B,H,L,D]). L is static here.

    Compresses all complete blocks; the remainder goes to the residual.
    Calibrates channel tiers from the prefill data (per batch, head).
    """
    cfg = cache.cfg
    B, H, L, D = k.shape
    n_blocks = L // cfg.block
    Lb = n_blocks * cfg.block
    if cache.pages is not None:
        return _prefill_cache_paged(cache, k, v, Lb)
    if cfg.policy == "none":
        raw_k = jax.lax.dynamic_update_slice_in_dim(
            cache.raw_k, k[..., :Lb, :].astype(cache.raw_k.dtype), 0, axis=-2
        )
        raw_v = jax.lax.dynamic_update_slice_in_dim(
            cache.raw_v, v[..., :Lb, :].astype(cache.raw_v.dtype), 0, axis=-2
        )
        new = dataclasses.replace(cache, raw_k=raw_k, raw_v=raw_v)
    else:
        k_perm, v_perm = calibrate_channel_tiers(k[..., :Lb, :], v[..., :Lb, :], cfg)
        kc, vc = compress_block(k[..., :Lb, :], v[..., :Lb, :], cfg, k_perm, v_perm)
        new_k = append_block(
            dataclasses.replace(cache.k, chan_perm=k_perm), kc, jnp.int32(0)
        )
        new_v = append_block(
            dataclasses.replace(cache.v, chan_perm=v_perm), vc, jnp.int32(0)
        )
        new = dataclasses.replace(cache, k=new_k, v=new_v)
    rem = L - Lb
    resid_k, resid_v = cache.resid_k, cache.resid_v
    if rem:
        resid_k = jax.lax.dynamic_update_slice_in_dim(
            resid_k, k[..., Lb:, :].astype(resid_k.dtype), 0, axis=-2
        )
        resid_v = jax.lax.dynamic_update_slice_in_dim(
            resid_v, v[..., Lb:, :].astype(resid_v.dtype), 0, axis=-2
        )
    return dataclasses.replace(
        new,
        resid_k=resid_k,
        resid_v=resid_v,
        n_comp=jnp.full((B,), Lb, jnp.int32),
        n_resid=jnp.full((B,), rem, jnp.int32),
    )


def _prefill_cache_paged(cache: LayerKVCache, k: Array, v: Array,
                         Lb: int) -> LayerKVCache:
    """Whole-batch prefill into a paged cache: every row pops
    ``ceil(Lb / page_size)`` pages and its compressed blocks are scattered
    page-by-page. Identical compression math to the dense path — only the
    placement differs, so the gathered view is bit-identical."""
    cfg = cache.cfg
    page = cfg.page_size
    B = k.shape[0]
    k_pg = cdiv(Lb, page)
    if B * k_pg > cache.pages.n_pool_pages:  # static: fails at trace time
        raise ValueError(
            f"whole-batch paged prefill needs {B * k_pg} pages but the pool "
            f"has {cache.pages.n_pool_pages}; an oversubscribed pool must "
            "admit through insert_prefill (page-reservation scheduling), "
            "not batch prefill"
        )
    pool, phys = pool_pop_all_rows(cache.pages, k_pg)
    new = dataclasses.replace(cache, pages=pool)
    if k_pg:
        if cfg.policy == "none":
            new = dataclasses.replace(
                new,
                raw_k=_scatter_pages(cache.raw_k, k[..., :Lb, :], phys, axis=-2),
                raw_v=_scatter_pages(cache.raw_v, v[..., :Lb, :], phys, axis=-2),
            )
        else:
            k_perm, v_perm = calibrate_channel_tiers(
                k[..., :Lb, :], v[..., :Lb, :], cfg
            )
            kc, vc = compress_block(k[..., :Lb, :], v[..., :Lb, :], cfg,
                                    k_perm, v_perm)
            new_k = _scatter_pages_tiered(cache.k, kc, phys)
            new_v = _scatter_pages_tiered(cache.v, vc, phys)
            new = dataclasses.replace(
                new,
                k=dataclasses.replace(new_k, chan_perm=k_perm),
                v=dataclasses.replace(new_v, chan_perm=v_perm),
            )
    rem = k.shape[-2] - Lb
    resid_k, resid_v = cache.resid_k, cache.resid_v
    if rem:
        resid_k = jax.lax.dynamic_update_slice_in_dim(
            resid_k, k[..., Lb:, :].astype(resid_k.dtype), 0, axis=-2
        )
        resid_v = jax.lax.dynamic_update_slice_in_dim(
            resid_v, v[..., Lb:, :].astype(resid_v.dtype), 0, axis=-2
        )
    return dataclasses.replace(
        new,
        resid_k=resid_k,
        resid_v=resid_v,
        n_comp=jnp.full((B,), Lb, jnp.int32),
        n_resid=jnp.full((B,), rem, jnp.int32),
    )


def _flush_paged(c: LayerKVCache, need: Array, blk_k: Array,
                 blk_v: Array) -> LayerKVCache:
    """Page-granular flush: rows in ``need`` compress their oldest block and
    write it into their current page at ``n_comp % page_size``; rows landing
    on a page boundary pop a fresh page first. Masked rows route their page
    write out of range (dropped) so they never race a live page.

    COPY-ON-WRITE: a row about to mutate a page with ``ref > 1`` (shared
    with another slot or pinned by the prefix index) pops a private
    replacement instead — the page write is read-modify-write, so reading
    the SHARED page and writing the FRESH one copies the prefix bytes and
    lands the new block in a single op. The shared page's bytes never
    change, and the row drops its reference to it. (The serving path keeps
    shared pages full, so COW never fires there — it is the safety net that
    makes ``ref > 1`` pages immutable unconditionally.)

    Rows at capacity NEVER flush (the dense path would overwrite its own
    last block — contained; here an over-cap flush would pop a page the
    scheduler's reservation ledger never counted, so the cap is what makes
    ``ceil(min(capacity, prompt + max_new) / page_size)`` a true upper
    bound on a slot's pages). Such a row's newest residual token degrades
    instead; reject requests beyond ``capacity + residual`` upstream.
    """
    cfg = c.cfg
    page = cfg.page_size
    pool = c.pages
    P = pool.n_pool_pages
    lp = c.n_comp // page  # logical page the block lands in
    wo = c.n_comp % page  # within-page token offset (block-aligned)
    rows = jnp.arange(need.shape[0])
    lp_c = jnp.clip(lp, 0, pool.max_pages - 1)
    old = pool.page_table[rows, lp_c]
    cow = need & (wo > 0) & (pool.ref[old] > 1)  # mid-page write, shared
    pool = pool_pop_rows(pool, (need & (wo == 0)) | cow, lp)
    phys = pool.page_table[rows, lp_c]
    # COW rows READ the shared page (so its prefix is copied through the
    # RMW) and drop their reference to it; everyone else reads in place
    phys_r = jnp.where(cow, old, phys)
    pool = _pool_release_ids(pool, jnp.where(cow, old, P))
    phys_w = jnp.where(need, phys, P)  # mask -> dropped
    if cfg.policy == "none":
        return dataclasses.replace(
            c,
            pages=pool,
            raw_k=_pool_write_rows(c.raw_k, blk_k, phys_r, phys_w, wo, axis=-2),
            raw_v=_pool_write_rows(c.raw_v, blk_v, phys_r, phys_w, wo, axis=-2),
        )
    kc, vc = compress_block(blk_k, blk_v, cfg, c.k.chan_perm, c.v.chan_perm)
    return dataclasses.replace(
        c,
        pages=pool,
        k=_pool_write_tiered(c.k, kc, phys_r, phys_w, wo),
        v=_pool_write_tiered(c.v, vc, phys_r, phys_w, wo),
    )


def append_token(
    cache: LayerKVCache, k_new: Array, v_new: Array, ring: bool = False
) -> LayerKVCache:
    """Decode-step append at per-row offsets. k_new/v_new: [B,H,1,D].

    Writes into the residual at each row's own ``n_resid``; rows whose
    residual is full compress their oldest block and append it to the
    compressed region at their own ``n_comp`` (lax.cond over "any row needs
    a flush" — the amortized O(1) compression cost of paper §III-D; the
    per-row write is masked so rows flush independently).

    ring=True: sliding-window mode (recurrentgemma local attention) — the
    compressed region is a circular block buffer of ``capacity`` tokens;
    blocks overwrite the oldest slot. Valid because decode attention is
    permutation-invariant over the cached window (DESIGN.md §4); callers
    mask with ``n_valid = min(n_comp, capacity)``.
    """
    cfg = cache.cfg
    R = cfg.residual
    capacity = cache.capacity

    def write(c: LayerKVCache) -> LayerKVCache:
        rk = row_update_tokens(c.resid_k, k_new, c.n_resid)
        rv = row_update_tokens(c.resid_v, v_new, c.n_resid)
        return dataclasses.replace(c, resid_k=rk, resid_v=rv, n_resid=c.n_resid + 1)

    def flush(c: LayerKVCache) -> LayerKVCache:
        need = c.n_resid >= R  # [B] rows whose residual is full
        blk_k = c.resid_k[..., : cfg.block, :]
        blk_v = c.resid_v[..., : cfg.block, :]
        off = (c.n_comp % capacity) if ring else c.n_comp
        if c.pages is not None:
            assert not ring, "paged storage has no ring (sliding-window) mode"
            # cap at capacity: an over-cap flush would pop a page the
            # scheduler's reservation ledger never counted (see
            # _flush_paged); the capped row's counters must not advance
            # either, so the guard applies to the whole flush
            need = need & (c.n_comp + cfg.block <= capacity)
            c = _flush_paged(c, need, blk_k, blk_v)
        elif cfg.policy == "none":
            raw_k = row_update_tokens(c.raw_k, blk_k, off)
            raw_v = row_update_tokens(c.raw_v, blk_v, off)
            c = dataclasses.replace(
                c,
                raw_k=select_rows(need, raw_k, c.raw_k),
                raw_v=select_rows(need, raw_v, c.raw_v),
            )
        else:
            kc, vc = compress_block(
                blk_k, blk_v, cfg, c.k.chan_perm, c.v.chan_perm
            )
            c = dataclasses.replace(
                c,
                k=select_rows(need, append_block_rows(c.k, kc, off), c.k),
                v=select_rows(need, append_block_rows(c.v, vc, off), c.v),
            )
        # shift flushed rows' residual left by one block
        rk = jnp.roll(c.resid_k, -cfg.block, axis=-2)
        rv = jnp.roll(c.resid_v, -cfg.block, axis=-2)
        step = jnp.where(need, cfg.block, 0).astype(jnp.int32)
        return dataclasses.replace(
            c,
            resid_k=select_rows(need, rk, c.resid_k),
            resid_v=select_rows(need, rv, c.resid_v),
            n_comp=c.n_comp + step,
            n_resid=c.n_resid - step,
        )

    cache = jax.lax.cond(jnp.any(cache.n_resid >= R), flush, lambda c: c, cache)
    return write(cache)


def append_window(cache: LayerKVCache, k_new: Array, v_new: Array,
                  lens: Array) -> LayerKVCache:
    """Speculative verify-window append. k_new/v_new: [B, H, w, D];
    lens: i32 [B] in [1, w] — row b's valid window length (seed + drafts).

    Position 0 (the SEED — the row's last committed token) goes through the
    real ``append_token``: it may flush a block, pop a page, and it
    advances the counters, exactly like the stepwise decode it replaces.
    Draft positions i = 1..w-1 are written into the residual buffer at
    per-row offset ``n_resid + i - 1`` WITHOUT advancing any counter and
    without ever flushing: draft bytes stay invisible to every masked read
    until ``commit_window`` advances ``n_resid`` over the accepted prefix,
    and a rejected draft needs no rollback at all — its slot is dead bytes
    the next seed append overwrites. Callers must cap ``lens`` so
    ``n_resid + lens - 1 <= cfg.residual`` AFTER the seed append (the
    scheduler's residual-headroom cap): the window then never crosses a
    compression flush or a page pop, which is what keeps mixed accept
    lengths across the batch consistent with the ``[B]`` counters and the
    page ledger. Writes are masked per position with ``i < lens``, so the
    junk padding of rows with shorter windows touches nothing.
    """
    w = k_new.shape[-2]
    cache = append_token(cache, k_new[..., :1, :], v_new[..., :1, :])
    R = cache.cfg.residual
    for i in range(1, w):
        write = i < lens  # [B]
        off = jnp.clip(cache.n_resid + (i - 1), 0, R - 1)
        rk = row_update_tokens(cache.resid_k, k_new[..., i : i + 1, :], off)
        rv = row_update_tokens(cache.resid_v, v_new[..., i : i + 1, :], off)
        cache = dataclasses.replace(
            cache,
            resid_k=select_rows(write, rk, cache.resid_k),
            resid_v=select_rows(write, rv, cache.resid_v),
        )
    return cache


def commit_window(cache, n_accept: Array):
    """Commit the accepted draft prefix of a verify window (see
    ``append_window``): advance ``n_resid`` by ``n_accept`` (i32 [B], zero
    for free or fully-rejected rows). Counters only — the accepted bytes
    are already sitting at the right residual offsets, rejected drafts die
    as dead bytes past ``n_resid``, and the compressed region / page
    ledger were never touched by drafts, so ``n_comp`` and every page
    refcount are conserved by construction. Works on flat [B] and stacked
    [n_layers, B] counters (broadcasts).
    """
    return dataclasses.replace(
        cache,
        n_resid=cache.n_resid + jnp.asarray(n_accept, cache.n_resid.dtype),
    )


# ---------------------------------------------------------------------------
# Per-slot lifecycle (continuous batching)
# ---------------------------------------------------------------------------


def reset_slot(cache: LayerKVCache, slot) -> LayerKVCache:
    """Free row ``slot``: zero its counters so every cached token is masked.

    Buffer contents are left in place — they are dead bytes (all reads mask
    with the counters) and the next ``insert_prefill`` overwrites the whole
    row. In paged mode the row's live pages are pushed back to the free
    stack first (a freed slot holds ZERO pool pages). Works on a
    single-layer cache ([B] counters) and on a stacked cache pytree
    ([n_layers, B] counters — the slot is always the last counter axis).
    ``slot`` may be traced.
    """
    if cache.pages is not None:
        if cache.n_comp.ndim == 2:  # stacked [n_layers, B]
            return jax.vmap(lambda c: _reset_slot_paged(c, slot))(cache)
        return _reset_slot_paged(cache, slot)
    return dataclasses.replace(
        cache,
        n_comp=cache.n_comp.at[..., slot].set(0),
        n_resid=cache.n_resid.at[..., slot].set(0),
    )


def _reset_slot_paged(cache: LayerKVCache, slot) -> LayerKVCache:
    pool = pool_release_row(
        cache.pages, slot,
        live_pages(cache.n_comp[slot], cache.cfg.page_size),
    )
    return dataclasses.replace(
        cache,
        pages=pool,
        n_comp=cache.n_comp.at[slot].set(0),
        n_resid=cache.n_resid.at[slot].set(0),
    )


def mask_free_slots(cache, active: Array):
    """Zero the counters of rows where ``active`` is False.

    Free rows ride along in the batched decode step, so each step appends
    one junk token into them; zeroing their counters right after keeps the
    "free slot == zero counters" invariant true at rest, bounds the junk to
    one residual position, and prevents dead rows from ever triggering the
    flush branch. ``active``: bool [B]; counters may be [B] or stacked
    [n_layers, B] (broadcasts).
    """
    act = jnp.asarray(active).astype(cache.n_comp.dtype)
    return dataclasses.replace(
        cache, n_comp=cache.n_comp * act, n_resid=cache.n_resid * act
    )


def insert_row(cache, slot, row_cache):
    """Scatter batch-row 0 of ``row_cache`` into row ``slot`` of ``cache``.

    Both are LayerKVCache pytrees of identical layout (stacked or flat);
    ``row_cache`` has batch size 1. Every leaf leads with
    [(layers,)? B, ...], so the write is a pure tree_map. ``slot`` may be
    traced (jit-stable single-slot admission).
    """
    lead = cache.n_comp.ndim - 1  # 0 flat, 1 stacked

    def put(dst, src):
        if lead == 0:
            return dst.at[slot].set(src[0].astype(dst.dtype))
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

    return jax.tree_util.tree_map(put, cache, row_cache)


def insert_prefill(cache: LayerKVCache, slot, k: Array, v: Array) -> LayerKVCache:
    """Admit one sequence into row ``slot``: compress its prefill K/V
    ([H, L, D] or [1, H, L, D], static L) and overwrite the row.

    The remaining rows are untouched, so one slot can be recycled while the
    others keep decoding. Calibration (channel->tier permutation) runs on
    this sequence's own prefill, exactly as a batch-size-1 ``prefill_cache``
    would — per-row outputs stay bit-identical to an independent B=1 run.

    Paged mode: the prompt is compressed through a DENSE mini-cache sized to
    the prompt (identical math, so identical bytes), then scattered into
    freshly-popped pool pages (``insert_row_paged``).
    """
    if k.ndim == 3:
        k, v = k[None], v[None]
    cfg = cache.cfg
    h_kv, _, head_dim = k.shape[-3], k.shape[-2], k.shape[-1]
    if cache.pages is not None:
        dense_cfg, cap_mini, n_pages = paged_mini_spec(cfg, k.shape[-2])
        sub = alloc_layer_cache(dense_cfg, 1, h_kv, head_dim, cap_mini,
                                dtype=cache.resid_k.dtype)
        sub = prefill_cache(sub, k, v)
        return insert_row_paged(cache, slot, sub, n_pages)
    sub = alloc_layer_cache(cfg, 1, h_kv, head_dim, cache.capacity,
                            dtype=cache.resid_k.dtype)
    sub = prefill_cache(sub, k, v)
    return insert_row(cache, slot, sub)


def paged_mini_spec(cfg: PackKVConfig, L: int) -> tuple[PackKVConfig, int, int]:
    """(dense_cfg, cap_mini, n_pages) for admitting an ``L``-token prompt
    into a paged cache through a dense mini-cache.

    The mini capacity MUST equal ``n_pages`` whole pages (when any block
    compresses) so the page scatter's zero-padding lines up — keep every
    caller on this one helper.
    """
    Lb = (L // cfg.block) * cfg.block
    cap_mini = max(cfg.page_size, round_up(Lb, cfg.page_size))
    return (
        dataclasses.replace(cfg, paged=False),
        cap_mini,
        cdiv(Lb, cfg.page_size),
    )


def insert_row_paged(cache: LayerKVCache, slot, row: LayerKVCache,
                     n_pages: int, n_shared: int = 0,
                     shared_phys: Optional[Array] = None) -> LayerKVCache:
    """Scatter a DENSE single-row cache into row ``slot`` of a paged cache.

    ``row`` is a dense-layout batch-1 cache (e.g. a prompt compressed by a
    B=1 ``prefill_cache``) whose compressed capacity is ``n_pages`` whole
    pages (STATIC — derived from the static prompt length). The slot's old
    pages are released, ``n_pages - n_shared`` fresh ones are popped, and
    the row's newly-compressed bytes land in them page-by-page; residual
    buffer, counters and ``chan_perm`` are scattered slot-wise.

    PREFIX SHARING: ``shared_phys`` (i32 [n_shared], STATIC length) maps
    already-allocated pages into the table's leading entries BY REFERENCE
    (``pool_map_prefix``) — their bytes are not touched and ``row``'s first
    ``n_shared`` pages of compressed content are ignored (they were seeded
    FROM those pages, see ``seed_prefix_from_pages``). Works on flat and
    stacked ([n_layers, ...]) caches; ``slot`` may be traced.
    """
    if cache.n_comp.ndim == 2:  # stacked: identical op per layer
        return jax.vmap(
            lambda c, r: _insert_row_paged(c, slot, r, n_pages, n_shared,
                                           shared_phys)
        )(cache, row)
    return _insert_row_paged(cache, slot, row, n_pages, n_shared, shared_phys)


def _insert_row_paged(cache: LayerKVCache, slot, row: LayerKVCache,
                      n_pages: int, n_shared: int = 0,
                      shared_phys: Optional[Array] = None) -> LayerKVCache:
    cfg = cache.cfg
    page = cfg.page_size
    # 1) release whatever the slot held (no-op for a reset/fresh slot)
    pool = pool_release_row(
        cache.pages, slot, live_pages(cache.n_comp[slot], cfg.page_size)
    )
    # 2) map the shared prefix by reference, pop fresh pages for the rest
    if n_shared:
        pool = pool_map_prefix(pool, slot, shared_phys)
    pool, phys = pool_pop_prefix(pool, slot, n_pages - n_shared, lp0=n_shared)
    new = dataclasses.replace(cache, pages=pool)
    # 3) scatter the newly-compressed bytes into the popped pages
    if n_pages - n_shared:
        if cfg.policy == "none":
            sfx = lambda a: a[..., n_shared * page:, :]
            new = dataclasses.replace(
                new,
                raw_k=_scatter_pages(cache.raw_k, sfx(row.raw_k), phys[None],
                                     axis=-2),
                raw_v=_scatter_pages(cache.raw_v, sfx(row.raw_v), phys[None],
                                     axis=-2),
            )
        else:
            from .tiered import slice_tiered_suffix

            new = dataclasses.replace(
                new,
                k=_scatter_pages_tiered(
                    cache.k, slice_tiered_suffix(row.k, n_shared * page),
                    phys[None]),
                v=_scatter_pages_tiered(
                    cache.v, slice_tiered_suffix(row.v, n_shared * page),
                    phys[None]),
            )
    # 4) per-slot metadata: channel permutation, residual, counters
    if cfg.policy != "none":
        new = dataclasses.replace(
            new,
            k=dataclasses.replace(
                new.k, chan_perm=new.k.chan_perm.at[slot].set(row.k.chan_perm[0])
            ),
            v=dataclasses.replace(
                new.v, chan_perm=new.v.chan_perm.at[slot].set(row.v.chan_perm[0])
            ),
        )
    return dataclasses.replace(
        new,
        resid_k=new.resid_k.at[slot].set(row.resid_k[0].astype(new.resid_k.dtype)),
        resid_v=new.resid_v.at[slot].set(row.resid_v[0].astype(new.resid_v.dtype)),
        n_comp=new.n_comp.at[slot].set(row.n_comp[0]),
        n_resid=new.n_resid.at[slot].set(row.n_resid[0]),
    )


# ---------------------------------------------------------------------------
# Prefix sharing (refcounted pages; the host side lives in serving/engine.py)
# ---------------------------------------------------------------------------


def _per_layer(cache: LayerKVCache, fn):
    """Apply ``fn`` per layer of a possibly-stacked cache pytree."""
    if cache.n_comp.ndim == 2:  # stacked [n_layers, B]
        return jax.vmap(fn)(cache)
    return fn(cache)


def share_pages(cache: LayerKVCache, slot, phys: Array) -> LayerKVCache:
    """Map already-allocated pages into the leading table entries of row
    ``slot`` BY REFERENCE (``ref += 1``; bytes untouched). ``phys``: i32
    [k], STATIC length; counters/metadata are the caller's to set (a full
    admission goes through ``insert_row_paged``, which composes this with
    the suffix pops). Stacked-aware; ``slot`` may be traced."""
    return _per_layer(
        cache,
        lambda c: dataclasses.replace(
            c, pages=pool_map_prefix(c.pages, slot, phys)
        ),
    )


def acquire_pages(cache: LayerKVCache, ids: Array) -> LayerKVCache:
    """Add one reference per entry of ``ids`` on every layer's pool —
    how the host-side prefix index pins cached pages. Sentinel entries
    (``>= pool_pages``) are ignored, so callers can pad to a fixed length
    for a single jit specialization."""
    return _per_layer(
        cache,
        lambda c: dataclasses.replace(
            c, pages=pool_acquire_ids(c.pages, ids)
        ),
    )


def release_pages(cache: LayerKVCache, ids: Array) -> LayerKVCache:
    """Drop one reference per entry of ``ids`` on every layer's pool; pages
    reaching ``ref == 0`` return to the free stack (prefix-index eviction).
    Sentinel entries are ignored (fixed-length padding, as above)."""
    return _per_layer(
        cache,
        lambda c: dataclasses.replace(
            c, pages=_pool_release_ids(c.pages, ids)
        ),
    )


def seed_prefix_from_pages(cache: LayerKVCache, mini: LayerKVCache,
                           phys: Array, n_prefix: int,
                           k_perm: Optional[Array] = None,
                           v_perm: Optional[Array] = None) -> LayerKVCache:
    """Seed a DENSE mini-cache with a shared compressed prefix.

    Gathers the ``n_prefix`` tokens held by pool pages ``phys`` (i32 [k],
    STATIC — ``k * page_size == n_prefix``) of the paged ``cache`` into the
    leading tokens of ``mini`` and sets ``n_comp = n_prefix``, ``n_resid =
    0``. ``k_perm``/``v_perm`` ([..., H, D], from the prefix index entry)
    restore the channel calibration the prefix was compressed under — the
    chunked prefill then appends suffix blocks under the SAME permutation,
    which is what makes a cache-hit admission bit-identical to a cold run.
    Both caches may be stacked ([n_layers, ...])."""
    from .tiered import gather_pool_leaf, gather_tiered_pages, write_tiered_prefix

    def one(c: LayerKVCache, m: LayerKVCache, kp, vp) -> LayerKVCache:
        idx = phys[None]  # [1, k]
        if c.cfg.policy == "none":
            rk = gather_pool_leaf(c.raw_k, idx, token_axis=-2)
            rv = gather_pool_leaf(c.raw_v, idx, token_axis=-2)
            m = dataclasses.replace(
                m,
                raw_k=m.raw_k.at[..., :n_prefix, :].set(rk.astype(m.raw_k.dtype)),
                raw_v=m.raw_v.at[..., :n_prefix, :].set(rv.astype(m.raw_v.dtype)),
            )
        else:
            mk = write_tiered_prefix(m.k, gather_tiered_pages(c.k, idx))
            mv = write_tiered_prefix(m.v, gather_tiered_pages(c.v, idx))
            m = dataclasses.replace(
                m,
                k=dataclasses.replace(mk, chan_perm=kp[None]),
                v=dataclasses.replace(mv, chan_perm=vp[None]),
            )
        B = m.n_comp.shape[0]
        return dataclasses.replace(
            m,
            n_comp=jnp.full((B,), n_prefix, jnp.int32),
            n_resid=jnp.zeros((B,), jnp.int32),
        )

    if cache.n_comp.ndim == 2:  # stacked: per-layer perms ride along
        if k_perm is None:
            return jax.vmap(lambda c, m: one(c, m, None, None))(cache, mini)
        return jax.vmap(one)(cache, mini, k_perm, v_perm)
    return one(cache, mini, k_perm, v_perm)


def prefill_append(cache: LayerKVCache, k: Array, v: Array,
                   calibrate: bool) -> LayerKVCache:
    """Append one segment of prefill K/V ([B,H,S,D], static S) to a DENSE
    cache at each row's own ``n_comp`` (the chunked-prefill building block).

    Preconditions (chunked prefill maintains both): ``n_resid == 0`` and
    ``n_comp`` block-aligned on every row. Complete blocks compress under
    the cache's EXISTING ``chan_perm``; ``calibrate=True`` — only the first
    segment of a cold chunked prefill — computes the permutation from THIS
    segment (the "page-0 calibration" that makes a shared prefix reusable:
    any request matching at least one page inherits the identical
    calibration data). The sub-block remainder goes to the residual.
    """
    cfg = cache.cfg
    S = k.shape[-2]
    Lb = (S // cfg.block) * cfg.block
    new = cache
    if Lb:
        kb, vb = k[..., :Lb, :], v[..., :Lb, :]
        if cfg.policy == "none":
            new = dataclasses.replace(
                new,
                raw_k=row_update_tokens(new.raw_k, kb, new.n_comp),
                raw_v=row_update_tokens(new.raw_v, vb, new.n_comp),
            )
        else:
            if calibrate:
                k_perm, v_perm = calibrate_channel_tiers(kb, vb, cfg)
            else:
                k_perm, v_perm = new.k.chan_perm, new.v.chan_perm
            kc, vc = compress_block(kb, vb, cfg, k_perm, v_perm)
            new = dataclasses.replace(
                new,
                k=append_block_rows(
                    dataclasses.replace(new.k, chan_perm=k_perm), kc,
                    new.n_comp),
                v=append_block_rows(
                    dataclasses.replace(new.v, chan_perm=v_perm), vc,
                    new.n_comp),
            )
    elif calibrate and cfg.policy != "none":
        # sub-block prompt: identity calibration, same as prefill_cache
        k_perm, v_perm = calibrate_channel_tiers(k[..., :0, :], v[..., :0, :],
                                                 cfg)
        new = dataclasses.replace(
            new,
            k=dataclasses.replace(new.k, chan_perm=k_perm),
            v=dataclasses.replace(new.v, chan_perm=v_perm),
        )
    rem = S - Lb
    if rem:
        new = dataclasses.replace(
            new,
            resid_k=row_update_tokens(new.resid_k, k[..., Lb:, :],
                                      new.n_resid),
            resid_v=row_update_tokens(new.resid_v, v[..., Lb:, :],
                                      new.n_resid),
        )
    return dataclasses.replace(
        new, n_comp=new.n_comp + Lb, n_resid=new.n_resid + rem
    )


# ---------------------------------------------------------------------------
# Preemption: compressed swap-out / swap-in (serving's SwapStore lives here
# because the evacuation format IS the cache layout — a dense B=1 mini-row)
# ---------------------------------------------------------------------------


def evacuate_row(cache, slot, n_pages: int = 0, n_shared: int = 0):
    """Evacuate row ``slot`` into a dense batch-1 mini-cache and FREE the row.

    The inverse-direction twin of the admission scatter: where
    ``insert_row_paged`` compresses through a dense mini and scatters it
    into pool pages, evacuation gathers the row's live pages back into a
    dense mini whose bytes a later ``restore_row`` scatters into fresh
    pages — placement-independent, so the restored row reads bit-identical.

    ``n_pages`` (STATIC) must equal the row's live page count
    ``ceil(n_comp / page_size)`` — the scheduler knows it exactly on the
    host (``SlotServer._counters``). ``n_shared`` leading pages are a
    prefix mapped by reference (shared-prefix admission): their BYTES are
    NOT copied — the row's reference is simply released (the prefix index
    still pins them) and ``restore_row`` re-maps the same physical ids.
    The mini therefore holds only the ``n_pages - n_shared`` suffix pages,
    plus the row's residual buffer, counters (FULL-row values, shared
    prefix included) and channel calibration.

    Dense caches (``pages is None``) evacuate the whole row slice
    (``n_pages``/``n_shared`` ignored). Returns ``(cache, mini)`` where
    ``cache`` has the slot's pages released and counters zeroed (exactly a
    ``reset_slot``) and ``mini`` is host-transportable (``jax.device_get``
    it into a ``SwapStore``). Works on flat and stacked caches; ``slot``
    may be traced.
    """
    if cache.pages is None:
        return _evacuate_row_dense(cache, slot)
    if cache.n_comp.ndim == 2:  # stacked: identical op per layer
        return jax.vmap(
            lambda c: _evacuate_row_paged(c, slot, n_pages, n_shared)
        )(cache)
    return _evacuate_row_paged(cache, slot, n_pages, n_shared)


def _evacuate_row_dense(cache, slot):
    lead = cache.n_comp.ndim - 1  # 0 flat, 1 stacked
    sl = jnp.asarray(slot, jnp.int32)
    mini = jax.tree_util.tree_map(
        lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, sl, 1, axis=lead),
        cache,
    )
    cache = dataclasses.replace(
        cache,
        n_comp=cache.n_comp.at[..., sl].set(0),
        n_resid=cache.n_resid.at[..., sl].set(0),
    )
    return cache, mini


def _evacuate_row_paged(cache: LayerKVCache, slot, n_pages: int,
                        n_shared: int) -> tuple[LayerKVCache, LayerKVCache]:
    from .tiered import gather_pool_leaf, gather_tiered_pages, \
        write_tiered_prefix

    cfg = cache.cfg
    page = cfg.page_size
    h_kv, head_dim = cache.resid_k.shape[1], cache.resid_k.shape[-1]
    sl = jnp.asarray(slot, jnp.int32)
    k_sfx = n_pages - n_shared  # suffix pages whose bytes the row owns
    assert k_sfx >= 0, (n_pages, n_shared)
    mini = alloc_layer_cache(
        dataclasses.replace(cfg, paged=False), 1, h_kv, head_dim,
        max(page, k_sfx * page), dtype=cache.resid_k.dtype,
    )
    if k_sfx:
        idx = jax.lax.dynamic_slice(
            cache.pages.page_table, (sl, n_shared), (1, k_sfx)
        )  # [1, k_sfx] physical ids of the owned suffix
        if cfg.policy == "none":
            rk = gather_pool_leaf(cache.raw_k, idx, token_axis=-2)
            rv = gather_pool_leaf(cache.raw_v, idx, token_axis=-2)
            put = lambda d, s: d.at[..., : k_sfx * page, :].set(
                s.astype(d.dtype))
            mini = dataclasses.replace(
                mini, raw_k=put(mini.raw_k, rk), raw_v=put(mini.raw_v, rv)
            )
        else:
            mini = dataclasses.replace(
                mini,
                k=write_tiered_prefix(mini.k, gather_tiered_pages(cache.k, idx)),
                v=write_tiered_prefix(mini.v, gather_tiered_pages(cache.v, idx)),
            )
    row1 = lambda a: jax.lax.dynamic_slice_in_dim(a, sl, 1, axis=0)
    if cfg.policy != "none":
        mini = dataclasses.replace(
            mini,
            k=dataclasses.replace(mini.k, chan_perm=row1(cache.k.chan_perm)),
            v=dataclasses.replace(mini.v, chan_perm=row1(cache.v.chan_perm)),
        )
    mini = dataclasses.replace(
        mini,
        resid_k=row1(cache.resid_k), resid_v=row1(cache.resid_v),
        n_comp=row1(cache.n_comp), n_resid=row1(cache.n_resid),
    )
    # free the row AFTER the gather: release every live page (one reference
    # each — shared-prefix pages stay alive through the index's pin) and
    # zero the counters, exactly a reset_slot
    pool = pool_release_row(
        cache.pages, sl, live_pages(cache.n_comp[sl], page)
    )
    cache = dataclasses.replace(
        cache, pages=pool,
        n_comp=cache.n_comp.at[sl].set(0),
        n_resid=cache.n_resid.at[sl].set(0),
    )
    return cache, mini


def restore_row(cache, slot, mini, shared_phys: Optional[Array] = None,
                n_pages: int = 0, n_shared: int = 0):
    """Stream an evacuated row back into slot ``slot`` (inverse of
    ``evacuate_row``; the swap-in half of preemption).

    Paged: maps the ``n_shared`` shared-prefix pages back BY REFERENCE
    (``shared_phys``, i32 [n_shared] — the SAME physical ids the row
    released; the prefix index kept them alive), pops ``n_pages -
    n_shared`` fresh pages and scatters the mini's suffix bytes into them,
    then restores residual / counters / channel calibration slot-wise.
    Page placement is the only thing that may differ from before the
    evacuation; every read masks through the page table, so decode resumes
    bit-identically. Dense: a plain ``insert_row``. No forward pass runs —
    restoration is pure data movement.
    """
    if cache.pages is None:
        return insert_row(cache, slot, mini)
    if cache.n_comp.ndim == 2:  # stacked: identical op per layer
        return jax.vmap(
            lambda c, m: _restore_row_paged(c, slot, m, shared_phys,
                                            n_pages, n_shared)
        )(cache, mini)
    return _restore_row_paged(cache, slot, mini, shared_phys, n_pages,
                              n_shared)


def _restore_row_paged(cache: LayerKVCache, slot, mini: LayerKVCache,
                       shared_phys: Optional[Array], n_pages: int,
                       n_shared: int) -> LayerKVCache:
    cfg = cache.cfg
    page = cfg.page_size
    k_sfx = n_pages - n_shared
    # 1) release whatever the slot held (no-op: a restored slot was free)
    pool = pool_release_row(
        cache.pages, slot, live_pages(cache.n_comp[slot], page)
    )
    # 2) shared prefix back by reference, fresh pages for the owned suffix
    if n_shared:
        pool = pool_map_prefix(pool, slot, shared_phys)
    pool, phys = pool_pop_prefix(pool, slot, k_sfx, lp0=n_shared)
    new = dataclasses.replace(cache, pages=pool)
    # 3) scatter the saved suffix bytes (the mini holds ONLY the suffix,
    #    in its leading tokens — unlike insert_row_paged's full-row input)
    if k_sfx:
        if cfg.policy == "none":
            new = dataclasses.replace(
                new,
                raw_k=_scatter_pages(cache.raw_k, mini.raw_k, phys[None],
                                     axis=-2),
                raw_v=_scatter_pages(cache.raw_v, mini.raw_v, phys[None],
                                     axis=-2),
            )
        else:
            new = dataclasses.replace(
                new,
                k=_scatter_pages_tiered(cache.k, mini.k, phys[None]),
                v=_scatter_pages_tiered(cache.v, mini.v, phys[None]),
            )
    # 4) per-slot metadata: channel permutation, residual, counters
    if cfg.policy != "none":
        new = dataclasses.replace(
            new,
            k=dataclasses.replace(
                new.k, chan_perm=new.k.chan_perm.at[slot].set(mini.k.chan_perm[0])
            ),
            v=dataclasses.replace(
                new.v, chan_perm=new.v.chan_perm.at[slot].set(mini.v.chan_perm[0])
            ),
        )
    return dataclasses.replace(
        new,
        resid_k=new.resid_k.at[slot].set(mini.resid_k[0].astype(new.resid_k.dtype)),
        resid_v=new.resid_v.at[slot].set(mini.resid_v[0].astype(new.resid_v.dtype)),
        n_comp=new.n_comp.at[slot].set(mini.n_comp[0]),
        n_resid=new.n_resid.at[slot].set(mini.n_resid[0]),
    )


class SwapStore:
    """Host-RAM tier for evacuated (preempted) slot rows.

    Maps request id -> (host copy of the evacuated mini-cache, scheduler
    metadata). PackKV's compressed tiers are what make this cheap: the
    swapped bytes are the ~10x-compressed pages plus one residual buffer,
    not raw K/V. Pure host state — the device transfers are the
    ``evacuate_row`` gather on put and the jitted ``restore_row`` scatter
    on the way back in.
    """

    def __init__(self):
        self._rows: dict[int, tuple[object, dict]] = {}
        self.swapped_out = 0  # evacuations stored (cumulative)
        self.swapped_in = 0  # restorations served (cumulative)
        self.peak_bytes = 0

    def put(self, rid: int, mini, meta: dict) -> None:
        assert rid not in self._rows, f"rid {rid} already swapped out"
        self._rows[rid] = (jax.device_get(mini), dict(meta))
        self.swapped_out += 1
        self.peak_bytes = max(self.peak_bytes, self.nbytes)

    def pop(self, rid: int) -> tuple[object, dict]:
        """Remove and return (mini, meta) for re-admission."""
        row = self._rows.pop(rid)
        self.swapped_in += 1
        return row

    def drop(self, rid: int) -> None:
        """Discard a swapped row (its request was cancelled / expired)."""
        self._rows.pop(rid, None)

    def meta(self, rid: int) -> dict:
        return self._rows[rid][1]

    def metas(self):
        """Iterate the metadata of every swapped row."""
        return (m for _, m in self._rows.values())

    def __contains__(self, rid: int) -> bool:
        return rid in self._rows

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def nbytes(self) -> int:
        """Resident host bytes across all swapped rows (scalar leaves —
        e.g. a stub engine's host counters — count zero)."""
        return sum(
            getattr(leaf, "nbytes", 0)
            for mini, _ in self._rows.values()
            for leaf in jax.tree_util.tree_leaves(mini)
        )


class SessionStore:
    """Voluntary multi-turn session cache: parked conversations, host-side.

    Where ``SwapStore`` holds *involuntarily* evacuated rows (preemption;
    keyed by live request id, drained the moment the victim resumes), this
    store holds rows parked *voluntarily* at retirement so a returning
    session skips re-prefill. Keys are the session's raw token trace
    (prompt + generated, as int64 bytes) — a namespace structurally
    disjoint from SwapStore's integer rids, so preemption swaps and
    session parks can never collide. Lookup is longest-parked-trace-
    prefix over the candidate trace lengths.

    Two tiers with a capacity-bounded host tier on top:

      * host RAM — ``jax.device_get`` copies of the evacuated mini-cache
        (PackKV-compressed pages + one residual buffer, so ~10x cheaper
        than raw KV), LRU-by-bytes against ``capacity_bytes``;
      * disk (optional, ``disk_dir``) — LRU spill target using the
        ``checkpoint.sharded`` savable-dtype mini serializers; without it
        LRU victims are dropped.

    ``ttl_s`` expires idle entries on both tiers (checked lazily at every
    public call against the injectable ``clock`` — tests freeze time).
    Scheduler metadata (including live prefix-trie node references, which
    are unserializable by design) always stays host-side; only the mini's
    arrays spill. Same-process only — the treedef for disk unflatten is
    cached from the first park, not persisted.
    """

    def __init__(self, capacity_bytes: int = 256 << 20,
                 ttl_s: Optional[float] = None,
                 disk_dir: Optional[str] = None, clock=None):
        self.capacity_bytes = int(capacity_bytes)
        self.ttl_s = ttl_s
        self.disk_dir = disk_dir
        self.clock = clock if clock is not None else time.monotonic
        # key -> {mini, meta, nbytes, t_used}; order == recency (LRU first)
        self._host: OrderedDict[bytes, dict] = OrderedDict()
        # key -> {path, meta, nbytes, t_used}
        self._disk: OrderedDict[bytes, dict] = OrderedDict()
        self._len_count: dict[int, int] = {}  # trace length -> #entries
        self._treedef = None
        self.parks = 0       # entries stored (cumulative)
        self.hits = 0        # entries served back (cumulative)
        self.evictions = 0   # capacity/replacement drops (entry lost)
        self.expired = 0     # TTL / forced expiries (entry lost)
        self.spills = 0      # host -> disk demotions
        self.loads = 0       # disk -> caller promotions
        self.peak_bytes = 0

    # -- keys ---------------------------------------------------------------

    @staticmethod
    def key_of(trace) -> bytes:
        return np.ascontiguousarray(np.asarray(trace, np.int64)).tobytes()

    @staticmethod
    def trace_of(key: bytes) -> np.ndarray:
        return np.frombuffer(key, np.int64)

    def _len_add(self, key: bytes, d: int) -> None:
        n = len(key) // 8
        c = self._len_count.get(n, 0) + d
        assert c >= 0
        if c:
            self._len_count[n] = c
        else:
            self._len_count.pop(n, None)

    # -- internals ----------------------------------------------------------

    def _spill_path(self, key: bytes) -> str:
        import hashlib
        import os

        return os.path.join(self.disk_dir,
                            f"sess-{hashlib.sha1(key).hexdigest()}")

    def _forget(self, key: bytes, counter: str) -> None:
        """Drop ``key`` from whichever tier holds it."""
        ent = self._host.pop(key, None)
        if ent is None:
            ent = self._disk.pop(key, None)
            if ent is not None:
                import shutil

                shutil.rmtree(ent["path"], ignore_errors=True)
        if ent is not None:
            self._len_add(key, -1)
            setattr(self, counter, getattr(self, counter) + 1)

    def _purge(self) -> None:
        if self.ttl_s is None:
            return
        now = self.clock()
        for tier in (self._host, self._disk):
            for key in [k for k, e in tier.items()
                        if now - e["t_used"] > self.ttl_s]:
                self._forget(key, "expired")

    def _shrink(self) -> None:
        """LRU-evict (or spill to disk) until the host tier fits."""
        while self.nbytes > self.capacity_bytes and self._host:
            key, ent = next(iter(self._host.items()))
            if self.disk_dir is not None:
                from ..checkpoint.sharded import save_mini

                path = self._spill_path(key)
                save_mini(path, ent["mini"])
                del self._host[key]
                self._disk[key] = {"path": path, "meta": ent["meta"],
                                   "nbytes": ent["nbytes"],
                                   "t_used": ent["t_used"]}
                self.spills += 1
            else:
                self._forget(key, "evictions")

    # -- public -------------------------------------------------------------

    def put(self, trace, mini, meta: dict) -> None:
        """Park a session under its token ``trace``. A re-park of the same
        trace replaces the old entry (latest wins; the old one counts as
        evicted)."""
        self._purge()
        key = self.key_of(trace)
        if key in self._host or key in self._disk:
            self._forget(key, "evictions")
        host_mini = jax.device_get(mini)
        if self._treedef is None:
            self._treedef = jax.tree_util.tree_structure(host_mini)
        nbytes = sum(np.asarray(leaf).nbytes
                     for leaf in jax.tree_util.tree_leaves(host_mini))
        self._host[key] = {"mini": host_mini, "meta": dict(meta),
                           "nbytes": nbytes, "t_used": self.clock()}
        self._len_add(key, +1)
        self.parks += 1
        self.peak_bytes = max(self.peak_bytes, self.nbytes)
        self._shrink()

    def match(self, tokens) -> Optional[bytes]:
        """Longest parked trace that is a prefix of ``tokens`` — a PEEK
        (``take`` claims it), so a blocked admission can retry later."""
        self._purge()
        if not self._len_count:
            return None
        toks = np.ascontiguousarray(np.asarray(tokens, np.int64))
        for n in sorted(self._len_count, reverse=True):
            if n > len(toks):
                continue
            key = toks[:n].tobytes()
            if key in self._host or key in self._disk:
                return key
        return None

    def meta(self, key: bytes) -> dict:
        ent = self._host.get(key) or self._disk.get(key)
        return ent["meta"]

    def take(self, key: bytes):
        """Claim a matched entry: remove it and return ``(mini, meta)``,
        promoting from disk if it was spilled."""
        ent = self._host.pop(key, None)
        if ent is not None:
            mini, meta = ent["mini"], ent["meta"]
        else:
            import shutil

            from ..checkpoint.sharded import load_mini

            ent = self._disk.pop(key)
            assert self._treedef is not None
            mini, _ = load_mini(ent["path"], self._treedef)
            meta = ent["meta"]
            shutil.rmtree(ent["path"], ignore_errors=True)
            self.loads += 1
        self._len_add(key, -1)
        self.hits += 1
        return mini, meta

    def drop(self, key: bytes) -> None:
        """Discard an entry that can no longer be served (e.g. its shared
        prefix pages were evicted from the trie while it was parked)."""
        self._forget(key, "evictions")

    def expire_now(self, n: int) -> int:
        """Force-expire the ``n`` least-recently-used entries across both
        tiers (fault injection: ``session_expire``). Returns the count."""
        order = sorted(
            [(e["t_used"], k) for k, e in self._host.items()]
            + [(e["t_used"], k) for k, e in self._disk.items()]
        )
        for _, key in order[:n]:
            self._forget(key, "expired")
        return min(n, len(order))

    def traces(self, n: int):
        """The ``n`` least-recently-used parked traces, oldest first
        (fault injection fabricates returning sessions from these)."""
        order = sorted(
            [(e["t_used"], k) for k, e in self._host.items()]
            + [(e["t_used"], k) for k, e in self._disk.items()]
        )
        return [self.trace_of(k) for _, k in order[:n]]

    def __contains__(self, key: bytes) -> bool:
        return key in self._host or key in self._disk

    def __len__(self) -> int:
        return len(self._host) + len(self._disk)

    @property
    def nbytes(self) -> int:
        """Resident host-tier bytes (disk entries don't count)."""
        return sum(e["nbytes"] for e in self._host.values())
