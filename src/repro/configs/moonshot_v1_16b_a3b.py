"""Moonlight-16B-A3B (kimi/moonshot) — 64 routed top-6
[hf:moonshotai/Moonlight-16B-A3B]. Moonlight additionally carries 2 shared
experts (DeepSeek-V3-style); the assignment line lists only the routed set,
so the shared pair is configured here per the HF card.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840, n_experts=64,
    n_shared_experts=2, moe_topk=6, d_ff_expert=1408,
)

SMOKE = ArchConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, head_dim=32, n_experts=8,
    n_shared_experts=1, moe_topk=3, d_ff_expert=128,
)
