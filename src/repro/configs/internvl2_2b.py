"""InternVL2-2B — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings [B, n_patches, d_model] concatenated as a
prefix to the token embeddings. The LM backbone (InternLM2-1.8B: GQA kv=8)
is fully implemented, including the PackKV decode path.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab=92553, head_dim=128,
    input_mode="tokens_patches", n_patches=256,
)

SMOKE = ArchConfig(
    name="internvl2-2b-smoke", family="vlm", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
    input_mode="tokens_patches", n_patches=16,
)
