"""Qwen3-32B — qk_norm, GQA, head_dim 128 decoupled from d_model [hf:Qwen]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120, n_heads=64,
    n_kv_heads=8, d_ff=25600, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen3-32b-smoke", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, qk_norm=True,
)
