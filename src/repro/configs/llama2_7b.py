"""Llama-2-7B — the paper's primary evaluation model (§IV-A).

Not part of the assigned-10 grid; used by the paper-reproduction
benchmarks (compression-ratio tables, throughput figures).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama2-7b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab=32000,
)

SMOKE = ArchConfig(
    name="llama2-7b-smoke", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=512, head_dim=32,
)
