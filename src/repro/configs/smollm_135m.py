"""SmolLM-135M — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense", n_layers=30, d_model=576, n_heads=9,
    n_kv_heads=3, d_ff=1536, vocab=49152,
)

SMOKE = ArchConfig(
    name="smollm-135m-smoke", family="dense", n_layers=2, d_model=96, n_heads=3,
    n_kv_heads=1, d_ff=192, vocab=512, head_dim=32,
)
