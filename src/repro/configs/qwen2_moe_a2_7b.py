"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=151936, n_experts=60, n_shared_experts=4,
    moe_topk=4, d_ff_expert=1408,
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, head_dim=32, n_experts=8,
    n_shared_experts=2, moe_topk=2, d_ff_expert=128,
)
