"""RWKV-6 "Finch" 1.6B — data-dependent decay, attention-free
[arXiv:2404.05892]. PackKV inapplicable (no KV cache) — DESIGN.md §4.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="rwkv6", n_layers=24, d_model=2048, n_heads=32,
    n_kv_heads=32, d_ff=7168, vocab=65536, wkv_heads=32,  # head size 64
)

SMOKE = ArchConfig(
    name="rwkv6-1.6b-smoke", family="rwkv6", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=512, wkv_heads=4,
)
