"""Minitron-4B — pruned Nemotron [arXiv:2407.14679; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=9216, vocab=256000, head_dim=128,
)

SMOKE = ArchConfig(
    name="minitron-4b-smoke", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
)
