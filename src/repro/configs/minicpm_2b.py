"""MiniCPM-2B — WSD schedule, llama-like arch [arXiv:2404.06395; hf].

The WSD (warmup-stable-decay) schedule is implemented in
repro.training.optimizer and selected by this config's training preset.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense", n_layers=40, d_model=2304, n_heads=36,
    n_kv_heads=36, d_ff=5760, vocab=122753,
)

SMOKE = ArchConfig(
    name="minicpm-2b-smoke", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=511,  # odd vocab keeps the padding path hot
)

TRAIN_SCHEDULE = "wsd"
