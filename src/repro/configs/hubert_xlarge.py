"""HuBERT-XLarge — encoder-only, wav2vec2 arch [arXiv:2106.07447].

Modality frontend (conv feature extractor) is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings [B, T, d_model].
w2v2's conv positional embedding is stubbed with RoPE (DESIGN.md §4).
No decode shapes (encoder-only).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, input_mode="frames",
    causal=False,
)

SMOKE = ArchConfig(
    name="hubert-xlarge-smoke", family="encoder", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=56, input_mode="frames",
    causal=False, head_dim=32,
)
