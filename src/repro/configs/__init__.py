"""Assigned architecture configs (one module per arch) + paper's own model."""
from .base import SHAPES, ArchConfig, ShapeCfg, cells, shape_applicable  # noqa: F401
from . import (  # noqa: F401
    hubert_xlarge,
    internvl2_2b,
    minicpm_2b,
    minitron_4b,
    moonshot_v1_16b_a3b,
    qwen2_moe_a2_7b,
    qwen3_32b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    smollm_135m,
)
from . import llama2_7b  # noqa: F401  (paper's primary eval model)

_MODULES = [
    minitron_4b, smollm_135m, minicpm_2b, qwen3_32b, qwen2_moe_a2_7b,
    moonshot_v1_16b_a3b, rwkv6_1_6b, hubert_xlarge, recurrentgemma_9b,
    internvl2_2b, llama2_7b,
]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES = {m.CONFIG.name: m.SMOKE for m in _MODULES}
ASSIGNED = [m.CONFIG.name for m in _MODULES[:-1]]  # the 10 graded archs


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]
