"""Architecture + shape configuration schema.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeCfg`` entries. ``cells()`` enumerates the
(arch × shape) grid with the skip rules recorded in DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | rwkv6 | hybrid_rglru | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    use_rope: bool = True
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    d_ff_expert: int = 0
    # hybrid (recurrentgemma): 1 attention block per `group` of blocks
    window: int = 0
    rec_per_attn: int = 0  # recurrent blocks per attention block (2 for RG)
    conv_width: int = 4
    lru_dim: int = 0  # RG-LRU width (defaults to d_model)
    # rwkv
    wkv_heads: int = 0
    # io
    input_mode: str = "tokens"  # tokens | frames | tokens_patches
    n_patches: int = 256
    causal: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("rwkv6", "hybrid_rglru")

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    # -- analytic parameter counts (roofline MODEL_FLOPS = 6·N·D) ----------
    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = V * D * 2  # embed + untied head
        if self.family == "rwkv6":
            per = 6 * D * D + 2 * D * F  # time-mix 5D²+wo, channel-mix 2DF+D²
            return emb + L * per
        attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * D
        dense_mlp = 3 * D * F
        if self.family == "moe":
            moe = self.n_experts * 3 * D * self.d_ff_expert + D * self.n_experts
            shared = self.n_shared_experts * 3 * D * self.d_ff_expert
            return emb + L * (attn + moe + shared)
        if self.family == "hybrid_rglru":
            lru_d = self.lru_dim or D
            rec = 2 * D * lru_d + 2 * lru_d * lru_d // 1 + lru_d * D  # approx
            group = self.rec_per_attn + 1
            n_attn = self.n_layers // group
            n_rec = self.n_layers - n_attn
            return emb + n_attn * (attn + dense_mlp) + n_rec * (rec + dense_mlp)
        return emb + L * (attn + dense_mlp)

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        D, L = self.d_model, self.n_layers
        hd = self.hd
        attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * D
        active = (self.moe_topk + self.n_shared_experts) * 3 * D * self.d_ff_expert
        return self.vocab * D * 2 + L * (attn + active + D * self.n_experts)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Skip rules from the assignment (recorded in DESIGN.md §4)."""
    if shape.kind == "decode" and not arch.has_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""


def cells(archs: dict[str, ArchConfig]):
    """All runnable (arch, shape) cells plus the skip list."""
    run, skip = [], []
    for a in archs.values():
        for s in SHAPES.values():
            ok, why = shape_applicable(a, s)
            (run if ok else skip).append((a.name, s.name) if ok else (a.name, s.name, why))
    return run, skip
