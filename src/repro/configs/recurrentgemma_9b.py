"""RecurrentGemma-9B — RG-LRU + local attention 1:2 [arXiv:2402.19427].

PackKV applies to the local-attention layers' bounded (window=2048) KV
cache via the ring-buffer append; RG-LRU layers carry O(1) state, so
long_500k decode has a fixed memory footprint.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid_rglru", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000, head_dim=256,
    window=2048, rec_per_attn=2, lru_dim=4096,
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke", family="hybrid_rglru", n_layers=5,
    d_model=128, n_heads=4, n_kv_heads=1, d_ff=256, vocab=512, head_dim=32,
    window=128, rec_per_attn=2, lru_dim=128,
)
