"""In-kernel decompression primitives shared by the Pallas kernels.

These run inside ``pl.pallas_call`` bodies: everything is static-shape,
uses only vectorizable integer ops (shift/mask/broadcast/reshape), and the
decoded values live purely in VMEM/VREGs — the TPU analogue of the paper's
"decompress into registers" (§III-C).
"""
from __future__ import annotations

import jax.numpy as jnp


def unpack_words_2d(words, width: int):
    """u32 [C, Wl] -> i32 [C, Wl * (32//width)] stored values."""
    assert width >= 1 and 32 % width == 0
    vpw = 32 // width
    offs = (jnp.arange(vpw, dtype=jnp.uint32) * width).astype(jnp.uint32)
    mask = jnp.uint32(2**width - 1)
    vals = (words[:, :, None] >> offs[None, None, :]) & mask
    C, Wl = words.shape
    return vals.reshape(C, Wl * vpw).astype(jnp.int32)


def unpack_shifts_2d(shift_bytes, n_packs: int):
    """u8 [C, ceil(P/4)] -> i32 [C, P] 2-bit shift fields."""
    sb = shift_bytes.astype(jnp.int32)
    offs = jnp.arange(4, dtype=jnp.int32) * 2
    sh = (sb[:, :, None] >> offs[None, None, :]) & 3
    C = sb.shape[0]
    return sh.reshape(C, sb.shape[1] * 4)[:, :n_packs]


def broadcast_packwise(per_pack, pack_size: int):
    """[C, P] -> [C, P*pack_size] repeating each pack value."""
    C, P = per_pack.shape
    return jnp.broadcast_to(per_pack[:, :, None], (C, P, pack_size)).reshape(
        C, P * pack_size
    )


def decode_tier_tile(payload, mins, shift_bytes, width: int, pack_size: int):
    """Decode one tier tile to integer values.

    payload:     u32 [C, TL*width/32]
    mins:        i8  [C, TL/pack_size]
    shift_bytes: u8  [C, ceil(TL/pack_size/4)]
    Returns f32 [C, TL] decoded quantized integers (mid-rise reconstruction
    of shift-dropped low bits), ready for the integer matvec.
    """
    stored = unpack_words_2d(payload, width)  # [C, TL]
    TL = stored.shape[1]
    P = TL // pack_size
    sh = unpack_shifts_2d(shift_bytes, P)  # [C, P]
    sh_t = broadcast_packwise(sh, pack_size)  # [C, TL]
    mins_t = broadcast_packwise(mins.astype(jnp.int32), pack_size)
    half = jnp.where(sh_t > 0, 1 << jnp.maximum(sh_t - 1, 0), 0)
    q = (stored << sh_t) + half + mins_t
    return q.astype(jnp.float32)
