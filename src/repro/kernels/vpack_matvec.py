"""Fused w·V decompress + matvec Pallas kernel (paper Fig. 11, TPU-adapted).

Dot products run along the context dimension — the same direction V is
bit-packed — so each decoded [C_t, TL] tile contracts immediately against
the [G, TL] weight tile. The paper's fp32 ``atomicAdd`` partial sums become
sequential accumulation over the context grid dimension into the output
block (deterministic; grid dim 1 is "arbitrary" = sequential on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_utils import tpu_params
from .unpack import decode_tier_tile

Array = jax.Array

DEFAULT_TILE_L = 256


def _kernel(payload_ref, mins_ref, shifts_ref, w_ref, out_ref, *, width, pack):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = decode_tier_tile(
        payload_ref[0], mins_ref[0], shifts_ref[0], width, pack
    )  # [C, TL]
    w = w_ref[0]  # [G, TL]
    out_ref[0] += jax.lax.dot_general(
        w, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def vpack_tier_out(
    payload: Array,
    mins: Array,
    shifts: Array,
    w: Array,
    *,
    width: int,
    pack_size: int,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
) -> Array:
    """One tier's weighted-V output (tier channel order, scale pre-folded).

    payload: u32 [BH, C, L*width/32]; w: f32 [BH, G, L] (weights*scale).
    Returns out f32 [BH, G, C].
    """
    BH, C, Wl = payload.shape
    G = w.shape[1]
    L = Wl * (32 // width)
    assert L % tile_l == 0 and tile_l % (pack_size * 4) == 0
    nL = L // tile_l
    tWl = tile_l * width // 32
    tP = tile_l // pack_size

    return pl.pallas_call(
        functools.partial(_kernel, width=width, pack=pack_size),
        grid=(BH, nL),
        in_specs=[
            pl.BlockSpec((1, C, tWl), lambda b, l: (b, 0, l)),
            pl.BlockSpec((1, C, tP), lambda b, l: (b, 0, l)),
            pl.BlockSpec((1, C, tP // 4), lambda b, l: (b, 0, l)),
            pl.BlockSpec((1, G, tile_l), lambda b, l: (b, 0, l)),
        ],
        out_specs=pl.BlockSpec((1, G, C), lambda b, l: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, C), jnp.float32),
        interpret=interpret,
        **tpu_params(("parallel", "arbitrary"), interpret),
    )(payload, mins, shifts, w)
