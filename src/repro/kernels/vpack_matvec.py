"""Fused w·V decompress + matvec Pallas kernel (paper Fig. 11, TPU-adapted).

Dot products run along the context dimension — the same direction V is
bit-packed — so each decoded [C_t, TL] tile contracts immediately against
the [G, TL] weight tile. The paper's fp32 ``atomicAdd`` partial sums become
sequential accumulation over the context grid dimension into the output
block (deterministic; grid dim 1 is "arbitrary" = sequential on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_utils import (
    load_page_id,
    load_tier_pool_tile,
    page_table_spec,
    pool_block_spec,
    tpu_params,
)
from .unpack import decode_tier_tile

Array = jax.Array

DEFAULT_TILE_L = 256


def _kernel(*refs, width, pack, masked, tile_l):
    if masked:
        payload_ref, mins_ref, shifts_ref, w_ref, n_ref, out_ref = refs
    else:
        payload_ref, mins_ref, shifts_ref, w_ref, out_ref = refs
        n_ref = None

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_start = pl.program_id(1) * tile_l  # outside pl.when (interpret mode)

    def accumulate():
        vals = decode_tier_tile(
            payload_ref[0], mins_ref[0], shifts_ref[0], width, pack
        )  # [C, TL]
        w = w_ref[0]  # [G, TL]
        if n_ref is not None:
            gidx = tile_start + jnp.arange(tile_l)
            w = jnp.where((gidx < n_ref[0, 0])[None, :], w, 0.0)
        out_ref[0] += jax.lax.dot_general(
            w, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )

    if n_ref is None:
        accumulate()
    else:
        # tile skipping: a fully-masked tile accumulates exactly zero — skip
        # the decode and both dot_generals (init above still runs at tile 0)
        pl.when(tile_start < n_ref[0, 0])(accumulate)


def vpack_tier_out(
    payload: Array,
    mins: Array,
    shifts: Array,
    w: Array,
    *,
    width: int,
    pack_size: int,
    n_valid: Array | None = None,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
) -> Array:
    """One tier's weighted-V output (tier channel order, scale pre-folded).

    payload: u32 [BH, C, L*width/32]; w: f32 [BH, G, L] (weights*scale).
    n_valid: optional i32 [BH] per-row valid length — weight columns past
    it are zeroed in-kernel before the contraction.
    Returns out f32 [BH, G, C].
    """
    BH, C, Wl = payload.shape
    G = w.shape[1]
    L = Wl * (32 // width)
    tile_l = min(tile_l, L)  # bucketed launches may slice below the tile
    assert L % tile_l == 0 and tile_l % (pack_size * 4) == 0
    nL = L // tile_l
    tWl = tile_l * width // 32
    tP = tile_l // pack_size

    in_specs = [
        pl.BlockSpec((1, C, tWl), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, C, tP), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, C, tP // 4), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, G, tile_l), lambda b, l: (b, 0, l)),
    ]
    args = [payload, mins, shifts, w]
    if n_valid is not None:
        in_specs.append(pl.BlockSpec((1, 1), lambda b, l: (b, 0)))
        args.append(n_valid.astype(jnp.int32).reshape(BH, 1))

    return pl.pallas_call(
        functools.partial(_kernel, width=width, pack=pack_size,
                          masked=n_valid is not None, tile_l=tile_l),
        grid=(BH, nL),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, C), lambda b, l: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, C), jnp.float32),
        interpret=interpret,
        **tpu_params(("parallel", "arbitrary"), interpret),
    )(*args)


def _paged_kernel(payload_ref, mins_ref, shifts_ref, w_ref, n_ref, tab_ref,
                  out_ref, *, width, pack, tile_l, tiles_per_page):
    """Paged weighted-V: page-table tile resolution + sequential
    accumulation (see packed_attention.py for the interpret-mode caveat)."""
    pid = pl.program_id(1)  # outside pl.when (interpret mode)
    tile_start = pid * tile_l
    lp = pid // tiles_per_page
    toff = pid % tiles_per_page

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def accumulate():
        phys = load_page_id(tab_ref, lp)
        vals = decode_tier_tile(
            *load_tier_pool_tile(payload_ref, mins_ref, shifts_ref, phys,
                                 toff, tile_l, width, pack),
            width, pack,
        )  # [C, TL]
        gidx = tile_start + jnp.arange(tile_l)
        w = jnp.where((gidx < n_ref[0, 0])[None, :], w_ref[0], 0.0)
        out_ref[0] += jax.lax.dot_general(
            w, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )

    # tile skipping: a fully-masked tile accumulates exactly zero
    pl.when(tile_start < n_ref[0, 0])(accumulate)


def vpack_tier_out_paged(
    payload: Array,
    mins: Array,
    shifts: Array,
    w: Array,
    page_table: Array,
    n_valid: Array,
    *,
    width: int,
    pack_size: int,
    page_size: int,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
) -> Array:
    """One tier's weighted-V output over a PAGED pool.

    payload/mins/shifts: pool layout [H_kv, n_pool_pages, C, ...];
    w: f32 [BH, G, n_tokens] dense bucket weights (scale pre-folded);
    page_table: i32 [B, max_pages]; n_valid: i32 [BH].
    Returns out f32 [BH, G, C] — bit-identical to ``vpack_tier_out`` on the
    gathered dense view.
    """
    h_kv = payload.shape[0]
    BH, G, n_tokens = w.shape
    C = payload.shape[2]
    tile_l = min(tile_l, page_size)
    assert page_size % tile_l == 0 and tile_l % (pack_size * 4) == 0
    assert n_tokens % page_size == 0 and n_tokens >= page_size
    n_pg = n_tokens // page_size
    tpp = page_size // tile_l

    in_specs = [
        pool_block_spec(payload, h_kv),
        pool_block_spec(mins, h_kv),
        pool_block_spec(shifts, h_kv),
        pl.BlockSpec((1, G, tile_l), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, 1), lambda b, l: (b, 0)),
        page_table_spec(n_pg, h_kv),
    ]
    return pl.pallas_call(
        functools.partial(_paged_kernel, width=width, pack=pack_size,
                          tile_l=tile_l, tiles_per_page=tpp),
        grid=(BH, n_pg * tpp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, C), lambda b, l: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, C), jnp.float32),
        interpret=interpret,
        **tpu_params(("parallel", "arbitrary"), interpret),
    )(payload, mins, shifts, w,
      n_valid.astype(jnp.int32).reshape(BH, 1), page_table[:, :n_pg])
