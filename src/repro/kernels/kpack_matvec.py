"""Fused K decompress + q·Kᵀ Pallas kernel (paper Fig. 8, TPU-adapted).

One ``pallas_call`` per width tier covers ALL (batch × kv-head) rows and all
context tiles in a single launch — the TPU analogue of the paper's
single-kernel decompression (§III-B4): grid = (B·H_kv, L/TL).

Each grid cell decodes a [C_t, TL] integer tile from packed u32 words in
VMEM and contracts it with the [G, C_t] query slice on the MXU, producing
the [G, TL] integer-score tile. Per-token (scale, zero) are folded outside
as rank-1 corrections (see kernels/ref.py docstring) so decompressed data
never exists outside VMEM/VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_utils import (
    load_page_id,
    load_tier_pool_tile,
    page_table_spec,
    pool_block_spec,
    tpu_params,
)
from .unpack import decode_tier_tile

Array = jax.Array

DEFAULT_TILE_L = 256


def _kernel(*refs, width, pack, masked, tile_l):
    if masked:
        payload_ref, mins_ref, shifts_ref, q_ref, n_ref, out_ref = refs
    else:
        payload_ref, mins_ref, shifts_ref, q_ref, out_ref = refs
        n_ref = None

    tile_start = pl.program_id(1) * tile_l  # outside pl.when (interpret mode)

    def compute():
        vals = decode_tier_tile(
            payload_ref[0], mins_ref[0], shifts_ref[0], width, pack
        )  # [C, TL] f32
        q = q_ref[0]  # [G, C] f32
        out = jax.lax.dot_general(
            q, vals, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        if n_ref is not None:
            gidx = tile_start + jnp.arange(tile_l)
            out = jnp.where((gidx < n_ref[0, 0])[None, :], out, 0.0)
        out_ref[0] = out

    if n_ref is None:
        compute()
        return
    # tile skipping: a tile starting at/past this row's valid length is all
    # masked — write its (zero) output without decoding or touching the MXU
    live = tile_start < n_ref[0, 0]
    pl.when(live)(compute)
    pl.when(jnp.logical_not(live))(
        lambda: out_ref.__setitem__(..., jnp.zeros_like(out_ref))
    )


def kpack_tier_scores(
    payload: Array,
    mins: Array,
    shifts: Array,
    q: Array,
    *,
    width: int,
    pack_size: int,
    n_valid: Array | None = None,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
) -> Array:
    """Integer score contribution of one tier.

    payload: u32 [BH, C, L*width/32]   mins: i8 [BH, C, L/pack]
    shifts:  u8  [BH, C, ceil(L/pack/4)]  q: f32 [BH, G, C] (tier channel slice)
    n_valid: optional i32 [BH] per-row valid length — score columns past it
    are zeroed in-kernel (per-slot batching: dead rows carry garbage packs).
    Returns si f32 [BH, G, L].
    """
    BH, C, Wl = payload.shape
    G = q.shape[1]
    L = Wl * (32 // width)
    tile_l = min(tile_l, L)  # bucketed launches may slice below the tile
    assert L % tile_l == 0 and tile_l % (pack_size * 4) == 0
    nL = L // tile_l
    tWl = tile_l * width // 32
    tP = tile_l // pack_size

    in_specs = [
        pl.BlockSpec((1, C, tWl), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, C, tP), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, C, tP // 4), lambda b, l: (b, 0, l)),
        pl.BlockSpec((1, G, C), lambda b, l: (b, 0, 0)),
    ]
    args = [payload, mins, shifts, q]
    if n_valid is not None:
        in_specs.append(pl.BlockSpec((1, 1), lambda b, l: (b, 0)))
        args.append(n_valid.astype(jnp.int32).reshape(BH, 1))

    grid = (BH, nL)
    return pl.pallas_call(
        functools.partial(_kernel, width=width, pack=pack_size,
                          masked=n_valid is not None, tile_l=tile_l),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, tile_l), lambda b, l: (b, 0, l)),
        out_shape=jax.ShapeDtypeStruct((BH, G, L), jnp.float32),
        interpret=interpret,
        **tpu_params(("parallel", "parallel"), interpret),
    )(*args)


def _paged_kernel(payload_ref, mins_ref, shifts_ref, q_ref, n_ref, tab_ref,
                  out_ref, *, width, pack, tile_l, tiles_per_page):
    """Paged tier scores: each grid step resolves one context tile's
    physical page through the page table (see packed_attention.py for the
    whole-pool-ref interpret-mode caveat)."""
    pid = pl.program_id(1)  # outside pl.when (interpret mode)
    tile_start = pid * tile_l
    lp = pid // tiles_per_page
    toff = pid % tiles_per_page

    def compute():
        phys = load_page_id(tab_ref, lp)
        vals = decode_tier_tile(
            *load_tier_pool_tile(payload_ref, mins_ref, shifts_ref, phys,
                                 toff, tile_l, width, pack),
            width, pack,
        )  # [C, TL] f32
        out = jax.lax.dot_general(
            q_ref[0], vals, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        gidx = tile_start + jnp.arange(tile_l)
        out_ref[0] = jnp.where((gidx < n_ref[0, 0])[None, :], out, 0.0)

    # tile skipping: dead tiles never resolve their page id
    live = tile_start < n_ref[0, 0]
    pl.when(live)(compute)
    pl.when(jnp.logical_not(live))(
        lambda: out_ref.__setitem__(..., jnp.zeros_like(out_ref))
    )


def kpack_tier_scores_paged(
    payload: Array,
    mins: Array,
    shifts: Array,
    q: Array,
    page_table: Array,
    n_valid: Array,
    n_tokens: int,
    *,
    width: int,
    pack_size: int,
    page_size: int,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
) -> Array:
    """One tier's integer scores over a PAGED pool.

    payload: u32 [H_kv, n_pool_pages, C, page*width/32] (mins/shifts pool
    layout likewise); q: f32 [BH, G, C]; page_table: i32 [B, max_pages];
    n_valid: i32 [BH] per-row valid lengths (paged rows are always ragged);
    n_tokens: STATIC bucket (multiple of ``page_size``).
    Returns si f32 [BH, G, n_tokens] — bit-identical to ``kpack_tier_scores``
    on the gathered dense view.
    """
    h_kv, P = payload.shape[0], payload.shape[1]
    BH, G, C = q.shape
    tile_l = min(tile_l, page_size)
    assert page_size % tile_l == 0 and tile_l % (pack_size * 4) == 0
    assert n_tokens % page_size == 0 and n_tokens >= page_size
    n_pg = n_tokens // page_size
    tpp = page_size // tile_l

    in_specs = [
        pool_block_spec(payload, h_kv),
        pool_block_spec(mins, h_kv),
        pool_block_spec(shifts, h_kv),
        pl.BlockSpec((1, G, C), lambda b, l: (b, 0, 0)),
        pl.BlockSpec((1, 1), lambda b, l: (b, 0)),
        page_table_spec(n_pg, h_kv),
    ]
    return pl.pallas_call(
        functools.partial(_paged_kernel, width=width, pack=pack_size,
                          tile_l=tile_l, tiles_per_page=tpp),
        grid=(BH, n_pg * tpp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, tile_l), lambda b, l: (b, 0, l)),
        out_shape=jax.ShapeDtypeStruct((BH, G, n_tokens), jnp.float32),
        interpret=interpret,
        **tpu_params(("parallel", "parallel"), interpret),
    )(payload, mins, shifts, q,
      n_valid.astype(jnp.int32).reshape(BH, 1), page_table[:, :n_pg])
