"""Single-launch fused decode attention over the compressed KV cache.

This is the paper's headline co-design (§III-C) adapted to TPU: ONE
``pallas_call`` decodes every K and V tier tile, computes the masked
softmax online (flash-style running max/sum), and accumulates the
weighted-V output — decompressed data and attention scores never leave
VMEM/VREGs; nothing is written back to HBM except the [G, D] output and
three [G] statistics used to merge with the full-precision residual
buffer via log-sum-exp (the deterministic TPU replacement for the paper's
fp32 ``atomicAdd`` partial sums).

Grid = (B·H_kv, L/TL): grid dim 0 parallel over heads/batch, dim 1
sequential over context tiles (the flash recurrence).

Inputs are generated programmatically from the K/V tier specs, so any
TierSpec combination lowers to a single kernel.

Two storage modes share the flash tile update (``_flash_tile_body``):

* ``fused_packed_attention`` — dense per-slot buffers, context tiles
  blocked by the BlockSpec grid (the PR-3 layout).
* ``fused_packed_attention_paged`` — the compressed bytes live in a shared
  page pool; each grid step resolves its logical page through the slot's
  page table (``pl.load`` on the table, then a dynamic page load from the
  pool — tile_l divides page_size, so one step reads one physical page).
  Per-token scale/zero are gathered to the dense layout outside the kernel
  (rank-1 metadata, bucket-sized); only the payload/mins/shifts pools are
  indexed in-kernel. NOTE: under interpret mode (this repo's CI) the pool
  rides in as a whole-array ref; a real TPU lowering would move the page
  table to scalar prefetch so only the addressed page is DMA'd into VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.tiered import TieredCache
from .pallas_utils import (
    load_page_id,
    load_tier_pool_tile,
    page_table_spec,
    pool_block_spec,
    tpu_params,
)
from .unpack import decode_tier_tile

Array = jax.Array

NEG_INF = -1e30
DEFAULT_TILE_L = 256


def _flash_tile_body(
    q,
    k_tiles,
    v_tiles,
    kscale_t,
    kzero_t,
    vscale_t,
    vzero_t,
    n_live,
    gidx,
    acc_ref,
    zsum_ref,
    m_ref,
    l_ref,
    *,
    k_offs,
    v_offs,
    sm_scale,
):
    """One context tile's flash update, shared by the dense and paged
    kernels. ``k_tiles``/``v_tiles`` are the decoded integer tiles
    ([C_t, TL] f32 per tier); ``*_t`` are the tile's per-token metadata
    ([TL] f32); ``gidx`` the global token indices of the tile."""
    # ---- K: integer scores for this tile ----------------------------------
    si = None
    for t, vals in enumerate(k_tiles):
        qs = q[:, k_offs[t] : k_offs[t + 1]]  # [G, Ck_t]
        d = jax.lax.dot_general(
            qs, vals, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        si = d if si is None else si + d  # [G, TL]
    qsum = jnp.sum(q, axis=-1, keepdims=True)  # [G, 1]
    scores = (si * kscale_t[None, :] + qsum * kzero_t[None, :]) * sm_scale

    valid = (gidx < n_live).astype(jnp.float32)[None, :]  # [1, TL]
    scores = jnp.where(valid > 0, scores, NEG_INF)

    # ---- online softmax ----------------------------------------------------
    m_prev = m_ref[0]  # [G]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)  # [G]
    p = jnp.exp(scores - m_new[:, None]) * valid  # [G, TL]
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1)
    m_ref[0] = m_new

    # ---- V: weighted accumulation ------------------------------------------
    ws = p * vscale_t[None, :]  # fold per-token scale into weights
    acc_ref[0] *= alpha[:, None]
    for t, vals in enumerate(v_tiles):
        d = jax.lax.dot_general(
            ws, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [G, Cv_t]
        acc_ref[0, :, v_offs[t] : v_offs[t + 1]] += d
    zsum_ref[0] = zsum_ref[0] * alpha + jnp.sum(p * vzero_t[None, :], axis=-1)


def _fused_kernel(
    *refs,
    nk: int,
    nv: int,
    k_widths,
    v_widths,
    k_offs,
    v_offs,
    pack: int,
    sm_scale: float,
    tile_l: int,
):
    """refs layout: [k_payload*nk, k_mins*nk, k_shifts*nk, kscale, kzero,
    v_payload*nv, v_mins*nv, v_shifts*nv, vscale, vzero, q, n_comp,
    acc_out, zsum_out, m_out, l_out]."""
    i = 0
    k_pay = refs[i : i + nk]; i += nk
    k_min = refs[i : i + nk]; i += nk
    k_shf = refs[i : i + nk]; i += nk
    kscale_ref, kzero_ref = refs[i], refs[i + 1]; i += 2
    v_pay = refs[i : i + nv]; i += nv
    v_min = refs[i : i + nv]; i += nv
    v_shf = refs[i : i + nv]; i += nv
    vscale_ref, vzero_ref = refs[i], refs[i + 1]; i += 2
    q_ref, n_ref = refs[i], refs[i + 1]; i += 2
    acc_ref, zsum_ref, m_ref, l_ref = refs[i : i + 4]

    pid = pl.program_id(1)

    @pl.when(pid == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zsum_ref[...] = jnp.zeros_like(zsum_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile skipping: a tile starting at/past this row's valid length is a
    # flash no-op (scores all NEG_INF -> m_new = m_prev, alpha = 1, p = 0),
    # so skip the K/V decode, both dot_generals and the softmax update
    @pl.when(pid * tile_l < n_ref[0, 0])
    def _live_tile():
        k_tiles = [
            decode_tier_tile(k_pay[t][0], k_min[t][0], k_shf[t][0],
                             k_widths[t], pack)
            for t in range(nk)
        ]
        v_tiles = [
            decode_tier_tile(v_pay[t][0], v_min[t][0], v_shf[t][0],
                             v_widths[t], pack)
            for t in range(nv)
        ]
        _flash_tile_body(
            q_ref[0], k_tiles, v_tiles,
            kscale_ref[0], kzero_ref[0], vscale_ref[0], vzero_ref[0],
            n_ref[0, 0], pid * tile_l + jnp.arange(tile_l),
            acc_ref, zsum_ref, m_ref, l_ref,
            k_offs=k_offs, v_offs=v_offs, sm_scale=sm_scale,
        )


def fused_packed_attention(
    q: Array,
    kc: TieredCache,
    vc: TieredCache,
    n_comp: Array,
    sm_scale: float,
    *,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
):
    """Compressed-region attention partials in ONE kernel launch.

    q: f32 [B, H, D] in ORIGINAL channel order. n_comp: scalar or per-row
    [B] valid lengths (continuous batching — each grid row masks to its own
    count). Returns (o_unnorm [B,H,Dv] in original channel order, m [B,H],
    l [B,H]) — log-sum-exp partials for merging with the residual buffer.
    """
    from ..core.tiered import chan_inverse_perm

    B, H, D = q.shape
    h_kv = kc.scale.shape[-2]
    G = H // h_kv
    BH = B * h_kv
    L = kc.capacity
    # bucketed launches can slice the cache below the default tile; clamp so
    # a small live prefix lowers as a single (smaller) tile
    tile_l = min(tile_l, L)
    assert L % tile_l == 0 and tile_l % (kc.spec.pack_size * 4) == 0
    nL = L // tile_l
    pack = kc.spec.pack_size
    Dv = vc.spec.head_dim

    # absorb the K channel permutation into q (free — paper §III-B3)
    qg = q.astype(jnp.float32).reshape(B, h_kv, G, D)
    qp = jnp.take_along_axis(qg, kc.chan_perm[:, :, None, :], axis=-1)

    flat = lambda a: a.reshape(BH, *a.shape[2:])
    k_pay = [flat(t.payload) for t in kc.tiers]
    k_min = [flat(t.mins) for t in kc.tiers]
    k_shf = [flat(t.shifts) for t in kc.tiers]
    v_pay = [flat(t.payload) for t in vc.tiers]
    v_min = [flat(t.mins) for t in vc.tiers]
    v_shf = [flat(t.shifts) for t in vc.tiers]
    kscale, kzero = flat(kc.scale), flat(kc.zero)
    vscale, vzero = flat(vc.scale), flat(vc.zero)
    qf = qp.reshape(BH, G, D)
    # per-(batch,kv-head) valid length: [B] rows broadcast across heads
    n_arr = jnp.asarray(n_comp, jnp.int32)
    if n_arr.ndim == 0:
        n_arr = n_arr[None, None]
    else:
        n_arr = n_arr[:, None]
    n_arr = jnp.broadcast_to(n_arr, (B, h_kv)).reshape(BH, 1)

    k_widths = tuple(t.width for t in kc.tiers)
    v_widths = tuple(t.width for t in vc.tiers)
    k_offs = (0, *[sum(kc.spec.counts[: i + 1]) for i in range(len(kc.spec.counts))])
    v_offs = (0, *[sum(vc.spec.counts[: i + 1]) for i in range(len(vc.spec.counts))])

    tP = tile_l // pack

    def tier_specs(cs, widths):
        sp = []
        for c, w in zip(cs, widths):
            sp.append(pl.BlockSpec((1, c, tile_l * w // 32), lambda b, l: (b, 0, l)))
        for c in cs:
            sp.append(pl.BlockSpec((1, c, tP), lambda b, l: (b, 0, l)))
        for c in cs:
            sp.append(pl.BlockSpec((1, c, tP // 4), lambda b, l: (b, 0, l)))
        return sp

    scale_spec = pl.BlockSpec((1, tile_l), lambda b, l: (b, l))
    in_specs = (
        tier_specs(kc.spec.counts, k_widths)
        + [scale_spec, scale_spec]
        + tier_specs(vc.spec.counts, v_widths)
        + [scale_spec, scale_spec]
        + [
            pl.BlockSpec((1, G, D), lambda b, l: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, l: (b, 0)),
        ]
    )
    out_specs = [
        pl.BlockSpec((1, G, Dv), lambda b, l: (b, 0, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, G, Dv), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
    ]

    kernel = functools.partial(
        _fused_kernel,
        nk=len(kc.tiers),
        nv=len(vc.tiers),
        k_widths=k_widths,
        v_widths=v_widths,
        k_offs=k_offs,
        v_offs=v_offs,
        pack=pack,
        sm_scale=sm_scale,
        tile_l=tile_l,
    )
    acc, zsum, m, lsum = pl.pallas_call(
        kernel,
        grid=(BH, nL),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **tpu_params(("parallel", "arbitrary"), interpret),
    )(
        *k_pay, *k_min, *k_shf, kscale, kzero,
        *v_pay, *v_min, *v_shf, vscale, vzero, qf, n_arr,
    )

    o = acc + zsum[..., None]  # zero-term correction (all channels)
    o = o.reshape(B, h_kv, G, Dv)
    inv = chan_inverse_perm(vc.chan_perm)
    o = jnp.take_along_axis(o, inv[:, :, None, :], axis=-1)
    return (
        o.reshape(B, H, Dv),
        m.reshape(B, H),
        lsum.reshape(B, H),
    )


# ---------------------------------------------------------------------------
# Paged variant: context tiles resolved through the page table in-kernel
# ---------------------------------------------------------------------------


def _paged_fused_kernel(
    *refs,
    nk: int,
    nv: int,
    k_widths,
    v_widths,
    k_offs,
    v_offs,
    pack: int,
    sm_scale: float,
    tile_l: int,
    tiles_per_page: int,
):
    """refs layout: [k_payload*nk, k_mins*nk, k_shifts*nk, kscale, kzero,
    v_payload*nv, v_mins*nv, v_shifts*nv, vscale, vzero, q, n_comp, table,
    acc_out, zsum_out, m_out, l_out]. Pool refs are whole-pool blocks of one
    kv-head; scale/zero are pre-gathered dense tiles; ``table`` is this
    row's page-table prefix."""
    i = 0
    k_pay = refs[i : i + nk]; i += nk
    k_min = refs[i : i + nk]; i += nk
    k_shf = refs[i : i + nk]; i += nk
    kscale_ref, kzero_ref = refs[i], refs[i + 1]; i += 2
    v_pay = refs[i : i + nv]; i += nv
    v_min = refs[i : i + nv]; i += nv
    v_shf = refs[i : i + nv]; i += nv
    vscale_ref, vzero_ref = refs[i], refs[i + 1]; i += 2
    q_ref, n_ref, tab_ref = refs[i], refs[i + 1], refs[i + 2]; i += 3
    acc_ref, zsum_ref, m_ref, l_ref = refs[i : i + 4]

    pid = pl.program_id(1)  # outside pl.when (interpret mode)
    lp = pid // tiles_per_page  # logical page of this tile
    toff = pid % tiles_per_page  # tile within the page

    @pl.when(pid == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zsum_ref[...] = jnp.zeros_like(zsum_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # same tile skipping as the dense kernel — a dead tile never even
    # resolves its page id
    @pl.when(pid * tile_l < n_ref[0, 0])
    def _live_tile():
        phys = load_page_id(tab_ref, lp)

        def tier_tiles(pays, mins, shfs, widths):
            return [
                decode_tier_tile(
                    *load_tier_pool_tile(pays[t], mins[t], shfs[t], phys,
                                         toff, tile_l, widths[t], pack),
                    widths[t], pack,
                )
                for t in range(len(pays))
            ]

        _flash_tile_body(
            q_ref[0], tier_tiles(k_pay, k_min, k_shf, k_widths),
            tier_tiles(v_pay, v_min, v_shf, v_widths),
            kscale_ref[0], kzero_ref[0], vscale_ref[0], vzero_ref[0],
            n_ref[0, 0], pid * tile_l + jnp.arange(tile_l),
            acc_ref, zsum_ref, m_ref, l_ref,
            k_offs=k_offs, v_offs=v_offs, sm_scale=sm_scale,
        )


def fused_packed_attention_paged(
    q: Array,
    kc: TieredCache,
    vc: TieredCache,
    page_table: Array,
    n_comp: Array,
    n_tokens: int,
    sm_scale: float,
    *,
    page_size: int,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
):
    """Compressed-region attention partials over a PAGED cache in one launch.

    kc/vc: pool-layout TieredCaches (leaves [H_kv, n_pool_pages, ...]);
    page_table: i32 [B, max_pages]; n_tokens: STATIC bucket size (multiple
    of ``page_size``) — the grid covers ``n_tokens / tile_l`` logical tiles
    and each live tile resolves its physical page through the table.
    Returns the same (o_unnorm, m, l) partials as ``fused_packed_attention``
    and is bit-identical to running it on the gathered dense view.
    """
    from ..core.tiered import chan_inverse_perm, gather_pool_leaf

    B, H, D = q.shape
    h_kv = kc.scale.shape[0]
    G = H // h_kv
    BH = B * h_kv
    tile_l = min(tile_l, page_size)
    assert page_size % tile_l == 0 and tile_l % (kc.spec.pack_size * 4) == 0
    assert n_tokens % page_size == 0, (n_tokens, page_size)
    n_pg = n_tokens // page_size
    tpp = page_size // tile_l
    nL = n_pg * tpp
    pack = kc.spec.pack_size
    Dv = vc.spec.head_dim

    qg = q.astype(jnp.float32).reshape(B, h_kv, G, D)
    qp = jnp.take_along_axis(qg, kc.chan_perm[:, :, None, :], axis=-1)
    qf = qp.reshape(BH, G, D)

    idx = page_table[:, :n_pg]  # [B, n_pg] live logical pages
    # per-token metadata is rank-1 and bucket-sized: gather it dense outside
    flatm = lambda a: gather_pool_leaf(a, idx).reshape(BH, n_tokens)
    kscale, kzero = flatm(kc.scale), flatm(kc.zero)
    vscale, vzero = flatm(vc.scale), flatm(vc.zero)

    n_arr = jnp.asarray(n_comp, jnp.int32)
    if n_arr.ndim == 0:
        n_arr = n_arr[None, None]
    else:
        n_arr = n_arr[:, None]
    n_arr = jnp.broadcast_to(n_arr, (B, h_kv)).reshape(BH, 1)

    k_widths = tuple(t.width for t in kc.tiers)
    v_widths = tuple(t.width for t in vc.tiers)
    k_offs = (0, *[sum(kc.spec.counts[: i + 1]) for i in range(len(kc.spec.counts))])
    v_offs = (0, *[sum(vc.spec.counts[: i + 1]) for i in range(len(vc.spec.counts))])

    def pool_specs(tiers):
        # whole-pool blocks of this grid row's kv-head (see module docstring
        # for the TPU scalar-prefetch caveat)
        return [
            pool_block_spec(getattr(t, leaf), h_kv)
            for leaf in ("payload", "mins", "shifts")
            for t in tiers
        ]

    scale_spec = pl.BlockSpec((1, tile_l), lambda b, l: (b, l))
    in_specs = (
        pool_specs(kc.tiers)
        + [scale_spec, scale_spec]
        + pool_specs(vc.tiers)
        + [scale_spec, scale_spec]
        + [
            pl.BlockSpec((1, G, D), lambda b, l: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, l: (b, 0)),
            page_table_spec(n_pg, h_kv),
        ]
    )
    out_specs = [
        pl.BlockSpec((1, G, Dv), lambda b, l: (b, 0, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, G, Dv), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
    ]

    kernel = functools.partial(
        _paged_fused_kernel,
        nk=len(kc.tiers),
        nv=len(vc.tiers),
        k_widths=k_widths,
        v_widths=v_widths,
        k_offs=k_offs,
        v_offs=v_offs,
        pack=pack,
        sm_scale=sm_scale,
        tile_l=tile_l,
        tiles_per_page=tpp,
    )
    pool_leaves = lambda tc: (
        [t.payload for t in tc.tiers]
        + [t.mins for t in tc.tiers]
        + [t.shifts for t in tc.tiers]
    )
    acc, zsum, m, lsum = pl.pallas_call(
        kernel,
        grid=(BH, nL),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **tpu_params(("parallel", "arbitrary"), interpret),
    )(
        *pool_leaves(kc), kscale, kzero,
        *pool_leaves(vc), vscale, vzero, qf, n_arr, idx,
    )

    o = acc + zsum[..., None]
    o = o.reshape(B, h_kv, G, Dv)
    inv = chan_inverse_perm(vc.chan_perm)
    o = jnp.take_along_axis(o, inv[:, :, None, :], axis=-1)
    return (
        o.reshape(B, H, Dv),
        m.reshape(B, H),
        lsum.reshape(B, H),
    )
