"""Single-launch fused decode attention over the compressed KV cache.

This is the paper's headline co-design (§III-C) adapted to TPU: ONE
``pallas_call`` decodes every K and V tier tile, computes the masked
softmax online (flash-style running max/sum), and accumulates the
weighted-V output — decompressed data and attention scores never leave
VMEM/VREGs; nothing is written back to HBM except the [G, D] output and
three [G] statistics used to merge with the full-precision residual
buffer via log-sum-exp (the deterministic TPU replacement for the paper's
fp32 ``atomicAdd`` partial sums).

Grid = (B·H_kv, L/TL): grid dim 0 parallel over heads/batch, dim 1
sequential over context tiles (the flash recurrence).

Inputs are generated programmatically from the K/V tier specs, so any
TierSpec combination lowers to a single kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.tiered import TieredCache
from .pallas_utils import tpu_params
from .unpack import decode_tier_tile

Array = jax.Array

NEG_INF = -1e30
DEFAULT_TILE_L = 256


def _fused_kernel(
    *refs,
    nk: int,
    nv: int,
    k_widths,
    v_widths,
    k_offs,
    v_offs,
    pack: int,
    sm_scale: float,
    tile_l: int,
):
    """refs layout: [k_payload*nk, k_mins*nk, k_shifts*nk, kscale, kzero,
    v_payload*nv, v_mins*nv, v_shifts*nv, vscale, vzero, q, n_comp,
    acc_out, zsum_out, m_out, l_out]."""
    i = 0
    k_pay = refs[i : i + nk]; i += nk
    k_min = refs[i : i + nk]; i += nk
    k_shf = refs[i : i + nk]; i += nk
    kscale_ref, kzero_ref = refs[i], refs[i + 1]; i += 2
    v_pay = refs[i : i + nv]; i += nv
    v_min = refs[i : i + nv]; i += nv
    v_shf = refs[i : i + nv]; i += nv
    vscale_ref, vzero_ref = refs[i], refs[i + 1]; i += 2
    q_ref, n_ref = refs[i], refs[i + 1]; i += 2
    acc_ref, zsum_ref, m_ref, l_ref = refs[i : i + 4]

    pid = pl.program_id(1)

    @pl.when(pid == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zsum_ref[...] = jnp.zeros_like(zsum_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile skipping: a tile starting at/past this row's valid length is a
    # flash no-op (scores all NEG_INF -> m_new = m_prev, alpha = 1, p = 0),
    # so skip the K/V decode, both dot_generals and the softmax update
    @pl.when(pid * tile_l < n_ref[0, 0])
    def _live_tile():
        q = q_ref[0]  # [G, D] in K-tier channel order

        # ---- K: integer scores for this tile ------------------------------
        si = None
        for t in range(nk):
            vals = decode_tier_tile(
                k_pay[t][0], k_min[t][0], k_shf[t][0], k_widths[t], pack
            )  # [Ck_t, TL]
            qs = q[:, k_offs[t] : k_offs[t + 1]]  # [G, Ck_t]
            d = jax.lax.dot_general(
                qs, vals, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            si = d if si is None else si + d  # [G, TL]
        qsum = jnp.sum(q, axis=-1, keepdims=True)  # [G, 1]
        scores = (si * kscale_ref[0][None, :] + qsum * kzero_ref[0][None, :]) * sm_scale

        gidx = pid * tile_l + jnp.arange(tile_l)
        valid = (gidx < n_ref[0, 0]).astype(jnp.float32)[None, :]  # [1, TL]
        scores = jnp.where(valid > 0, scores, NEG_INF)

        # ---- online softmax ------------------------------------------------
        m_prev = m_ref[0]  # [G]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)  # [G]
        p = jnp.exp(scores - m_new[:, None]) * valid  # [G, TL]
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1)
        m_ref[0] = m_new

        # ---- V: weighted accumulation --------------------------------------
        ws = p * vscale_ref[0][None, :]  # fold per-token scale into weights
        acc_ref[0] *= alpha[:, None]
        for t in range(nv):
            vals = decode_tier_tile(
                v_pay[t][0], v_min[t][0], v_shf[t][0], v_widths[t], pack
            )  # [Cv_t, TL]
            d = jax.lax.dot_general(
                ws, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )  # [G, Cv_t]
            acc_ref[0, :, v_offs[t] : v_offs[t + 1]] += d
        zsum_ref[0] = zsum_ref[0] * alpha + jnp.sum(p * vzero_ref[0][None, :], axis=-1)


def fused_packed_attention(
    q: Array,
    kc: TieredCache,
    vc: TieredCache,
    n_comp: Array,
    sm_scale: float,
    *,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool = True,
):
    """Compressed-region attention partials in ONE kernel launch.

    q: f32 [B, H, D] in ORIGINAL channel order. n_comp: scalar or per-row
    [B] valid lengths (continuous batching — each grid row masks to its own
    count). Returns (o_unnorm [B,H,Dv] in original channel order, m [B,H],
    l [B,H]) — log-sum-exp partials for merging with the residual buffer.
    """
    from ..core.tiered import chan_inverse_perm

    B, H, D = q.shape
    h_kv = kc.scale.shape[-2]
    G = H // h_kv
    BH = B * h_kv
    L = kc.capacity
    # bucketed launches can slice the cache below the default tile; clamp so
    # a small live prefix lowers as a single (smaller) tile
    tile_l = min(tile_l, L)
    assert L % tile_l == 0 and tile_l % (kc.spec.pack_size * 4) == 0
    nL = L // tile_l
    pack = kc.spec.pack_size
    Dv = vc.spec.head_dim

    # absorb the K channel permutation into q (free — paper §III-B3)
    qg = q.astype(jnp.float32).reshape(B, h_kv, G, D)
    qp = jnp.take_along_axis(qg, kc.chan_perm[:, :, None, :], axis=-1)

    flat = lambda a: a.reshape(BH, *a.shape[2:])
    k_pay = [flat(t.payload) for t in kc.tiers]
    k_min = [flat(t.mins) for t in kc.tiers]
    k_shf = [flat(t.shifts) for t in kc.tiers]
    v_pay = [flat(t.payload) for t in vc.tiers]
    v_min = [flat(t.mins) for t in vc.tiers]
    v_shf = [flat(t.shifts) for t in vc.tiers]
    kscale, kzero = flat(kc.scale), flat(kc.zero)
    vscale, vzero = flat(vc.scale), flat(vc.zero)
    qf = qp.reshape(BH, G, D)
    # per-(batch,kv-head) valid length: [B] rows broadcast across heads
    n_arr = jnp.asarray(n_comp, jnp.int32)
    if n_arr.ndim == 0:
        n_arr = n_arr[None, None]
    else:
        n_arr = n_arr[:, None]
    n_arr = jnp.broadcast_to(n_arr, (B, h_kv)).reshape(BH, 1)

    k_widths = tuple(t.width for t in kc.tiers)
    v_widths = tuple(t.width for t in vc.tiers)
    k_offs = (0, *[sum(kc.spec.counts[: i + 1]) for i in range(len(kc.spec.counts))])
    v_offs = (0, *[sum(vc.spec.counts[: i + 1]) for i in range(len(vc.spec.counts))])

    tP = tile_l // pack

    def tier_specs(cs, widths):
        sp = []
        for c, w in zip(cs, widths):
            sp.append(pl.BlockSpec((1, c, tile_l * w // 32), lambda b, l: (b, 0, l)))
        for c in cs:
            sp.append(pl.BlockSpec((1, c, tP), lambda b, l: (b, 0, l)))
        for c in cs:
            sp.append(pl.BlockSpec((1, c, tP // 4), lambda b, l: (b, 0, l)))
        return sp

    scale_spec = pl.BlockSpec((1, tile_l), lambda b, l: (b, l))
    in_specs = (
        tier_specs(kc.spec.counts, k_widths)
        + [scale_spec, scale_spec]
        + tier_specs(vc.spec.counts, v_widths)
        + [scale_spec, scale_spec]
        + [
            pl.BlockSpec((1, G, D), lambda b, l: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, l: (b, 0)),
        ]
    )
    out_specs = [
        pl.BlockSpec((1, G, Dv), lambda b, l: (b, 0, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
        pl.BlockSpec((1, G), lambda b, l: (b, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, G, Dv), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
        jax.ShapeDtypeStruct((BH, G), jnp.float32),
    ]

    kernel = functools.partial(
        _fused_kernel,
        nk=len(kc.tiers),
        nv=len(vc.tiers),
        k_widths=k_widths,
        v_widths=v_widths,
        k_offs=k_offs,
        v_offs=v_offs,
        pack=pack,
        sm_scale=sm_scale,
        tile_l=tile_l,
    )
    acc, zsum, m, lsum = pl.pallas_call(
        kernel,
        grid=(BH, nL),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **tpu_params(("parallel", "arbitrary"), interpret),
    )(
        *k_pay, *k_min, *k_shf, kscale, kzero,
        *v_pay, *v_min, *v_shf, vscale, vzero, qf, n_arr,
    )

    o = acc + zsum[..., None]  # zero-term correction (all channels)
    o = o.reshape(B, h_kv, G, Dv)
    inv = chan_inverse_perm(vc.chan_perm)
    o = jnp.take_along_axis(o, inv[:, :, None, :], axis=-1)
    return (
        o.reshape(B, H, Dv),
        m.reshape(B, H),
        lsum.reshape(B, H),
    )
