"""Public jit'd wrappers over the computation-aware decompression kernels.

Backend dispatch:
  * ``"xla"``    — pure-jnp path (kernels/ref.py). XLA still fuses
    decode+matvec, and the cache bytes read from HBM are the compressed
    bytes, so the paper's bandwidth argument holds; this is the default on
    CPU and the path the production dry-run lowers.
  * ``"pallas"`` — explicit Pallas kernels (interpret=True on CPU,
    compiled on TPU): single-launch fused decode attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.tiered import (
    TieredCache,
    chan_inverse_perm,
    gather_page_meta,
    page_prefix_ids,
)
from . import ref
from .kpack_matvec import kpack_tier_scores, kpack_tier_scores_paged
from .packed_attention import fused_packed_attention, fused_packed_attention_paged
from .vpack_matvec import vpack_tier_out, vpack_tier_out_paged

Array = jax.Array

NEG_INF = ref.NEG_INF


def _rows_to_bh(n: Array | None, B: int, h_kv: int) -> Array | None:
    """Broadcast scalar/[B] valid counts to the kernels' flat [BH] layout."""
    if n is None:
        return None
    n = jnp.asarray(n, jnp.int32)
    if n.ndim == 0:
        n = n[None]
    return jnp.broadcast_to(n[:, None], (B, h_kv)).reshape(B * h_kv)


def packed_qk_scores(
    q: Array,
    kc: TieredCache,
    sm_scale: float = 1.0,
    *,
    n_valid: Array | None = None,
    backend: str = "xla",
    tile_l: int = 256,
    interpret: bool = True,
) -> Array:
    """q·Kᵀ over the compressed K cache. q: [B,H,D] -> scores [B,H,L].

    n_valid (scalar or per-row [B]): zero out scores of positions >= the
    row's valid length (callers still NEG_INF-mask before softmax; the
    zeroing keeps dead-slot garbage from propagating).
    """
    B, H, D = q.shape
    h_kv = kc.scale.shape[-2]
    if backend == "xla":
        s = ref.kpack_scores_ref(q, kc, sm_scale)
        if n_valid is not None:
            s = jnp.where(ref.valid_mask(n_valid, kc.capacity, lead=2), s, 0.0)
        return s
    G = H // h_kv
    BH = B * h_kv
    L = kc.capacity
    qg = q.astype(jnp.float32).reshape(B, h_kv, G, D)
    qp = jnp.take_along_axis(qg, kc.chan_perm[:, :, None, :], axis=-1)
    qf = qp.reshape(BH, G, D)
    flat = lambda a: a.reshape(BH, *a.shape[2:])
    nv = _rows_to_bh(n_valid, B, h_kv)
    si = jnp.zeros((BH, G, L), jnp.float32)
    off = 0
    for t, c in zip(kc.tiers, kc.spec.counts):
        si = si + kpack_tier_scores(
            flat(t.payload), flat(t.mins), flat(t.shifts), qf[..., off : off + c],
            n_valid=nv, width=t.width, pack_size=t.pack_size, tile_l=tile_l,
            interpret=interpret,
        )
        off += c
    qsum = jnp.sum(qf, axis=-1, keepdims=True)
    # si columns past each row's n_valid are already zeroed IN-KERNEL; only
    # the rank-1 zero-term correction still needs the outer mask
    zc = flat(kc.zero)[:, None, :]
    if nv is not None:
        zc = jnp.where(ref.valid_mask(nv, L, lead=2), zc, 0.0)
    scores = si * flat(kc.scale)[:, None, :] + qsum * zc
    return (scores * sm_scale).reshape(B, H, L)


def packed_weighted_v(
    w: Array,
    vc: TieredCache,
    *,
    n_valid: Array | None = None,
    backend: str = "xla",
    tile_l: int = 256,
    interpret: bool = True,
) -> Array:
    """w·V over the compressed V cache. w: [B,H,L] -> out [B,H,D].

    n_valid (scalar or per-row [B]): positions >= the row's valid length
    contribute nothing — masked in-kernel on the pallas path, on the
    weights for the xla path (slot-table rows' tails hold recycled garbage).
    """
    B, H, L = w.shape
    h_kv = vc.scale.shape[-2]
    if backend == "xla":
        if n_valid is not None:
            w = jnp.where(ref.valid_mask(n_valid, L, lead=2), w, 0.0)
        return ref.vpack_out_ref(w, vc)
    G = H // h_kv
    BH = B * h_kv
    flat = lambda a: a.reshape(BH, *a.shape[2:])
    nv = _rows_to_bh(n_valid, B, h_kv)
    wf = w.astype(jnp.float32).reshape(BH, G, L)
    ws = wf * flat(vc.scale)[:, None, :]
    parts = [
        vpack_tier_out(
            flat(t.payload), flat(t.mins), flat(t.shifts), ws,
            n_valid=nv, width=t.width, pack_size=t.pack_size, tile_l=tile_l,
            interpret=interpret,
        )
        for t in vc.tiers
    ]
    out = jnp.concatenate(parts, axis=-1)  # [BH, G, Dv] tier order
    # zero-term correction runs outside the kernel -> mask its weights here
    if nv is not None:
        wf = jnp.where(ref.valid_mask(nv, L, lead=2), wf, 0.0)
    zterm = jnp.einsum("bgl,bl->bg", wf, flat(vc.zero))[..., None]
    out = out + zterm
    out = out.reshape(B, h_kv, G, -1)
    inv = chan_inverse_perm(vc.chan_perm)
    out = jnp.take_along_axis(out, inv[:, :, None, :], axis=-1)
    return out.reshape(B, H, -1)


def packed_qk_scores_paged(
    q: Array,
    kc: TieredCache,
    pages,
    n_tokens: int,
    sm_scale: float = 1.0,
    *,
    n_valid: Array,
    backend: str = "xla",
    tile_l: int = 256,
    interpret: bool = True,
) -> Array:
    """``packed_qk_scores`` over a PAGED K cache.

    kc: pool-layout TieredCache; pages: core.cache.PagePool; n_tokens:
    STATIC bucket (multiple of the page size). The xla backend gathers the
    live pages into the dense layout first; the pallas backend resolves
    each context tile's physical page inside the kernel. Returns scores
    f32 [B, H, n_tokens], bit-identical across the two routes.
    """
    B, H, D = q.shape
    h_kv = kc.scale.shape[0]
    if backend == "xla":
        from ..core.tiered import gather_tiered_pages

        idx = page_prefix_ids(pages.page_table, n_tokens, pages.page_size)
        return packed_qk_scores(
            q, gather_tiered_pages(kc, idx), sm_scale, n_valid=n_valid,
            backend="xla",
        )
    G = H // h_kv
    BH = B * h_kv
    qg = q.astype(jnp.float32).reshape(B, h_kv, G, D)
    qp = jnp.take_along_axis(qg, kc.chan_perm[:, :, None, :], axis=-1)
    qf = qp.reshape(BH, G, D)
    nv = _rows_to_bh(n_valid, B, h_kv)
    si = jnp.zeros((BH, G, n_tokens), jnp.float32)
    off = 0
    for t, c in zip(kc.tiers, kc.spec.counts):
        si = si + kpack_tier_scores_paged(
            t.payload, t.mins, t.shifts, qf[..., off : off + c],
            pages.page_table, nv, n_tokens, width=t.width,
            pack_size=t.pack_size, page_size=pages.page_size, tile_l=tile_l,
            interpret=interpret,
        )
        off += c
    qsum = jnp.sum(qf, axis=-1, keepdims=True)
    flatm = lambda a: gather_page_meta(
        a, pages.page_table, n_tokens, pages.page_size
    ).reshape(BH, n_tokens)
    zc = jnp.where(ref.valid_mask(nv, n_tokens, lead=2), flatm(kc.zero)[:, None, :], 0.0)
    scores = si * flatm(kc.scale)[:, None, :] + qsum * zc
    return (scores * sm_scale).reshape(B, H, n_tokens)


def packed_weighted_v_paged(
    w: Array,
    vc: TieredCache,
    pages,
    *,
    n_valid: Array,
    backend: str = "xla",
    tile_l: int = 256,
    interpret: bool = True,
) -> Array:
    """``packed_weighted_v`` over a PAGED V cache.

    w: [B, H, n_tokens] dense bucket weights (n_tokens a STATIC multiple of
    the page size). Same backend split as ``packed_qk_scores_paged``.
    """
    B, H, n_tokens = w.shape
    h_kv = vc.scale.shape[0]
    if backend == "xla":
        from ..core.tiered import gather_tiered_pages

        idx = page_prefix_ids(pages.page_table, n_tokens, pages.page_size)
        return packed_weighted_v(
            w, gather_tiered_pages(vc, idx), n_valid=n_valid, backend="xla"
        )
    G = H // h_kv
    BH = B * h_kv
    nv = _rows_to_bh(n_valid, B, h_kv)
    flatm = lambda a: gather_page_meta(
        a, pages.page_table, n_tokens, pages.page_size
    ).reshape(BH, n_tokens)
    wf = w.astype(jnp.float32).reshape(BH, G, n_tokens)
    ws = wf * flatm(vc.scale)[:, None, :]
    parts = [
        vpack_tier_out_paged(
            t.payload, t.mins, t.shifts, ws, pages.page_table, nv,
            width=t.width, pack_size=t.pack_size, page_size=pages.page_size,
            tile_l=tile_l, interpret=interpret,
        )
        for t in vc.tiers
    ]
    out = jnp.concatenate(parts, axis=-1)  # [BH, G, Dv] tier order
    wf = jnp.where(ref.valid_mask(nv, n_tokens, lead=2), wf, 0.0)
    zterm = jnp.einsum("bgl,bl->bg", wf, flatm(vc.zero))[..., None]
    out = out + zterm
    out = out.reshape(B, h_kv, G, -1)
    inv = chan_inverse_perm(vc.chan_perm)
    out = jnp.take_along_axis(out, inv[:, :, None, :], axis=-1)
    return out.reshape(B, H, -1)


def _residual_partials(q, resid_k, resid_v, n_resid, sm_scale):
    """LSE partials (o_unnorm, m, l) of attention over the residual buffer.

    n_resid: scalar or per-row [B] valid-token count.
    """
    B, H, D = q.shape
    h_kv = resid_k.shape[1]
    R = resid_k.shape[2]
    qg = q.astype(jnp.float32).reshape(B, h_kv, H // h_kv, D)
    s = jnp.einsum("bhgd,bhrd->bhgr", qg, resid_k.astype(jnp.float32)) * sm_scale
    mask = ref.valid_mask(n_resid, R, lead=3)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgr,bhrd->bhgd", p, resid_v.astype(jnp.float32))
    return o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)


def merge_partials(o1, m1, l1, o2, m2, l2):
    """Log-sum-exp merge of two unnormalized attention partials."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)[..., None]
    a2 = jnp.exp(m2 - m)[..., None]
    denom = l1[..., None] * a1 + l2[..., None] * a2
    return (o1 * a1 + o2 * a2) / jnp.maximum(denom, 1e-30)


def packed_decode_attention(
    q: Array,
    kc: TieredCache,
    vc: TieredCache,
    resid_k: Array,
    resid_v: Array,
    n_comp: Array,
    n_resid: Array,
    sm_scale: float,
    *,
    backend: str = "xla",
    tile_l: int = 256,
    interpret: bool = True,
) -> Array:
    """Full decode attention over [compressed | residual] regions."""
    if backend == "xla":
        return ref.packed_decode_attention_ref(
            q, kc, vc, resid_k, resid_v, n_comp, n_resid, sm_scale
        )
    o_c, m_c, l_c = fused_packed_attention(
        q, kc, vc, n_comp, sm_scale, tile_l=tile_l, interpret=interpret
    )
    o_r, m_r, l_r = _residual_partials(q, resid_k, resid_v, n_resid, sm_scale)
    return merge_partials(o_c, m_c, l_c, o_r, m_r, l_r)


def paged_decode_attention(
    q: Array,
    cache,
    sm_scale: float,
    *,
    n_bucket: int | None = None,
    backend: str = "xla",
    tile_l: int = 256,
    interpret: bool = True,
) -> Array:
    """Full decode attention over a PAGED compressed cache + residual.

    cache: a paged ``core.cache.LayerKVCache`` (compressed policy). The xla
    backend gathers the first ``n_bucket`` tokens' pages into the dense
    layout and runs the reference path; the pallas backend launches the
    page-indexed fused kernel directly on the pool. Both are bit-identical
    to ``packed_decode_attention`` on the dense storage mode.
    """
    n_tokens = cache.capacity if n_bucket is None else min(n_bucket, cache.capacity)
    if backend == "xla":
        from ..core.cache import gather_paged

        read = gather_paged(cache, n_tokens)
        return ref.packed_decode_attention_ref(
            q, read.k, read.v, read.resid_k, read.resid_v,
            read.n_comp, read.n_resid, sm_scale,
        )
    o_c, m_c, l_c = fused_packed_attention_paged(
        q, cache.k, cache.v, cache.pages.page_table, cache.n_comp, n_tokens,
        sm_scale, page_size=cache.cfg.page_size, tile_l=tile_l,
        interpret=interpret,
    )
    o_r, m_r, l_r = _residual_partials(
        q, cache.resid_k, cache.resid_v, cache.n_resid, sm_scale
    )
    return merge_partials(o_c, m_c, l_c, o_r, m_r, l_r)


dense_decode_attention = ref.dense_decode_attention_ref
