"""KV-head sharding lanes for the multi-device serving engine.

The engine shards the compressed pool PAYLOADS (K/V tier bytes, tier
scales/zeros, residual buffers, calibration perms) over a ``kv`` mesh
axis by KV head — the head-major pool layout ``[H_kv, pool_pages, ...]``
makes the head axis the natural partition — while the page LEDGER (page
table, free list, refcounts) and the per-row counters stay replicated,
so the host scheduler's reservation arithmetic reads one
device-identical source of truth (docs/architecture.md).

Every cache-touching jitted dispatch runs inside a shard_map "lane"
(``sharded_call``): the unmodified model code asks ``active_lane()``
whether it is on a head shard, slices its contiguous q/k/v head block
(GQA query heads group contiguously by KV head — kernels/ref
``_grouped_q`` — so one slice serves q, k and v), runs the ordinary
attention + cache-append math on local heads, and merges attention
outputs back with ONE ``psum`` of disjoint scatters per layer. Because
every per-head computation is head-independent (softmax, tier matvecs,
quantization, calibration are all per-(row, head)), and the merge adds
each output cell as ``x + 0 + ... + 0``, the sharded result is
BIT-IDENTICAL to the single-device run — no reduction-order change
anywhere.

Data-parallel slot sharding composes over a ``dp`` axis: cache STATE
stays replicated across ``dp`` (every shard runs the identical append),
and only the attention READ is partitioned — a shard masks the per-row
counters to its owned rows (a masked row spans zero context tokens and
every decode kernel guards its softmax denominator, so it contributes
exact ``0.0``), and the same disjoint-scatter psum assembles the row
blocks. Batches that don't divide ``dp`` degrade to fully-replicated
compute, still exact.

This module replaced the seed-era context-parallel decode prototype
(``context_parallel_decode_step``): head sharding needs no cross-shard
log-sum-exp merge at all — each shard owns complete softmax rows — so
there is now one sharded decode path, the lane, shared by decode,
verify, prefill-insert and the chunked/prefix admission segments.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from ..utils import shard_map_compat

Array = jax.Array

KV_AXIS = "kv"
DP_AXIS = "dp"

_LANE = None


@dataclasses.dataclass(frozen=True)
class Lane:
    """Shard coordinates of the current shard_map lane."""

    n_kv: int = 1
    n_dp: int = 1
    kv_axis: str = KV_AXIS
    dp_axis: str = DP_AXIS

    # -- head axis (kv) -----------------------------------------------------
    def heads(self, h: int) -> int:
        """Local head count for an ``h``-head global axis."""
        assert h % self.n_kv == 0, (h, self.n_kv)
        return h // self.n_kv

    def split(self, x: Array, axis: int) -> Array:
        """This shard's contiguous head block along ``axis``.

        Works for attention heads too (H = G * H_kv, kv-grouped
        contiguously), not just KV heads.
        """
        loc = self.heads(x.shape[axis])
        i = jax.lax.axis_index(self.kv_axis)
        return jax.lax.dynamic_slice_in_dim(x, i * loc, loc, axis)

    def merge(self, x: Array, axis: int, full: int, owned=None) -> Array:
        """Scatter the local head block into a zeros buffer and psum.

        Contributions are disjoint — distinct head blocks over ``kv``,
        and (when ``owned`` partitions rows) distinct row blocks over
        ``dp`` — so each merged cell is ``x + 0 + ... + 0``: exactly the
        single-device value. ``owned``: bool [B] row mask from
        ``owned_rows`` (row dim must be axis 0), or None when rows were
        not partitioned.
        """
        if owned is not None:
            own = owned.reshape(owned.shape + (1,) * (x.ndim - 1))
            x = jnp.where(own, x, jnp.zeros_like(x))
        loc = x.shape[axis]
        if full != loc:
            start = [0] * x.ndim
            start[axis] = jax.lax.axis_index(self.kv_axis) * loc
            shape = list(x.shape)
            shape[axis] = full
            x = jax.lax.dynamic_update_slice(
                jnp.zeros(shape, x.dtype), x, tuple(start))
        axes = [a for a, n in ((self.kv_axis, self.n_kv),) if n > 1]
        if owned is not None and self.n_dp > 1:
            axes.append(self.dp_axis)
        return jax.lax.psum(x, tuple(axes)) if axes else x

    # -- row axis (dp) ------------------------------------------------------
    def owned_rows(self, n_rows: int):
        """Bool [n_rows] mask of the rows this dp shard computes, or None
        when rows are not partitioned (``n_dp == 1``, or ``n_rows`` not
        divisible — every shard then computes every row, still exact)."""
        if self.n_dp == 1 or n_rows % self.n_dp:
            return None
        per = n_rows // self.n_dp
        i = jax.lax.axis_index(self.dp_axis)
        return (jnp.arange(n_rows) // per) == i

    def mask_read(self, cache_l, owned):
        """Counter-masked attention-read view of a layer cache: non-owned
        rows span zero context/residual tokens (their attention output is
        then exact 0.0). Only the READ is masked — appends and commits
        always use the unmasked cache so replicated state stays identical
        on every shard."""
        if owned is None:
            return cache_l
        zero = lambda n: jnp.where(owned, n, jnp.zeros_like(n))
        return dataclasses.replace(
            cache_l, n_comp=zero(cache_l.n_comp), n_resid=zero(cache_l.n_resid))


def active_lane() -> Lane | None:
    """The Lane of the current shard_map trace, or None outside one."""
    return _LANE


def local_heads(h: int) -> int:
    """``h`` heads as seen by the current lane (global count outside)."""
    lane = active_lane()
    return lane.heads(h) if lane is not None else h


@contextlib.contextmanager
def lane_scope(lane: Lane):
    global _LANE
    prev, _LANE = _LANE, lane
    try:
        yield lane
    finally:
        _LANE = prev


def sharded_call(fn, mesh, in_specs, out_specs):
    """shard_map ``fn`` with a Lane installed for the duration of its
    (synchronous) trace, so model code can ask ``active_lane()``."""
    lane = Lane(n_kv=int(mesh.shape.get(KV_AXIS, 1)),
                n_dp=int(mesh.shape.get(DP_AXIS, 1)))

    def local(*args):
        with lane_scope(lane):
            return fn(*args)

    return shard_map_compat(local, mesh, in_specs, out_specs)
