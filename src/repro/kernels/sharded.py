"""Context-parallel fused decode attention (beyond-paper, §Perf H1).

The compressed cache is sharded along the CONTEXT dim over 'model'. Under
plain GSPMD, the decode step's softmax/weighted-V force enormous
reshards (measured 8.4e10 collective bytes/step/device on qwen3-32b —
GSPMD even emits 'involuntary full rematerialization' warnings). But the
fused attention already produces log-sum-exp PARTIALS (o, m, l) — exactly
the right thing to merge ACROSS context shards too:

  each 'model' shard runs the fused kernel over its local context slice
  -> psum-merge the [B, H, D]+[B, H] partials (a few hundred KB)
  -> add the residual-buffer partial.

Same math (merge_partials is associative), ~1000× less wire traffic.

The decode-append flush also becomes shard-local: a 64-token block lands
entirely inside one context shard (block | shard sizes), so the owner
masks the write and everyone else no-ops — no cross-shard DUS.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.cache import LayerKVCache
from ..core.tiered import TierBuffer, TieredCache
from . import ops, ref

Array = jax.Array


def _local_cache_partials(q, kc: TieredCache, vc: TieredCache, n_comp,
                          sm_scale: float, axis: str):
    """Fused attention partials over THIS shard's context slice.

    n_comp: scalar or per-row [B] global valid length.
    """
    idx = jax.lax.axis_index(axis)
    L_loc = kc.capacity  # local capacity inside shard_map
    start = idx * L_loc
    n_local = jnp.clip(n_comp - start, 0, L_loc)
    s = ref.kpack_scores_ref(q, kc, sm_scale)  # [B, H, L_loc]
    mask = ref.valid_mask(n_local, L_loc, lead=2)
    s = jnp.where(mask, s, ref.NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = ref.vpack_out_ref(p, vc)
    # vpack zero-term used unmasked p=0 rows fine (p already masked)
    return o, m, l


def _local_dense_partials(q, raw_k, raw_v, n_comp, sm_scale: float, axis: str):
    """Policy='none' variant: dense scores over the local context slice."""
    idx = jax.lax.axis_index(axis)
    B, H, D = q.shape
    h_kv = raw_k.shape[1]
    L_loc = raw_k.shape[2]
    start = idx * L_loc
    n_local = jnp.clip(n_comp - start, 0, L_loc)
    qg = q.astype(jnp.float32).reshape(B, h_kv, H // h_kv, D)
    s = jnp.einsum("bhgd,bhld->bhgl", qg, raw_k.astype(jnp.float32)) * sm_scale
    s = s.reshape(B, H, L_loc)
    mask = ref.valid_mask(n_local, L_loc, lead=2)
    s = jnp.where(mask, s, ref.NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    pg = p.reshape(B, h_kv, H // h_kv, L_loc)
    o = jnp.einsum("bhgl,bhld->bhgd", pg, raw_v.astype(jnp.float32))
    return o.reshape(B, H, D), m, l


def _append_token_local(cache_l: LayerKVCache, k_new, v_new, axis: str,
                        n_shards: int, ring: bool):
    """Shard-local decode append at per-row offsets: each row's 64-token
    flush block lands in exactly one context shard (block | shard size);
    the owner masks the write per row."""
    from ..core.cache import (
        append_block_rows,
        compress_block,
        row_update_tokens,
        select_rows,
    )

    cfg = cache_l.cfg
    R = cfg.residual

    def write(c):
        rk = row_update_tokens(c.resid_k, k_new, c.n_resid)
        rv = row_update_tokens(c.resid_v, v_new, c.n_resid)
        return dataclasses.replace(c, resid_k=rk, resid_v=rv,
                                   n_resid=c.n_resid + 1)

    def flush(c):
        need = c.n_resid >= R  # [B]
        blk_k = c.resid_k[..., : cfg.block, :]
        blk_v = c.resid_v[..., : cfg.block, :]
        idx = jax.lax.axis_index(axis)
        L_loc = c.capacity  # local shard capacity inside shard_map
        g_off = (c.n_comp % (L_loc * n_shards)) if ring else c.n_comp
        owner = need & ((g_off // L_loc) == idx)  # [B]
        off = jnp.clip(g_off - idx * L_loc, 0, L_loc - cfg.block)
        if cfg.policy == "none":
            new_rk = row_update_tokens(c.raw_k, blk_k, off)
            new_rv = row_update_tokens(c.raw_v, blk_v, off)
            c = dataclasses.replace(
                c,
                raw_k=select_rows(owner, new_rk, c.raw_k),
                raw_v=select_rows(owner, new_rv, c.raw_v),
            )
        else:
            kc, vc = compress_block(blk_k, blk_v, cfg, c.k.chan_perm,
                                    c.v.chan_perm)
            nk = append_block_rows(c.k, kc, off)
            nv = append_block_rows(c.v, vc, off)
            c = dataclasses.replace(c, k=select_rows(owner, nk, c.k),
                                    v=select_rows(owner, nv, c.v))
        rk = jnp.roll(c.resid_k, -cfg.block, axis=-2)
        rv = jnp.roll(c.resid_v, -cfg.block, axis=-2)
        step = jnp.where(need, cfg.block, 0).astype(jnp.int32)
        return dataclasses.replace(c,
                                   resid_k=select_rows(need, rk, c.resid_k),
                                   resid_v=select_rows(need, rv, c.resid_v),
                                   n_comp=c.n_comp + step,
                                   n_resid=c.n_resid - step)

    cache_l = jax.lax.cond(jnp.any(cache_l.n_resid >= R), flush,
                           lambda c: c, cache_l)
    return write(cache_l)


def _cache_specs_local(cache, mesh, dp, axis: str):
    from ..distributed.sharding import spec_with_fallback

    ctx_last = {"payload", "mins", "shifts", "scale", "zero"}

    def f(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "name", path[-1])))
        nd = leaf.ndim
        want: list = [None] * nd
        if name in ("n_comp", "n_resid"):
            return spec_with_fallback(leaf.shape, want, mesh)
        if nd >= 2:
            want[0] = dp  # batch
        if name in ctx_last and nd >= 2:
            want[-1] = axis
        elif name in ("raw_k", "raw_v") and nd >= 3:
            want[-2] = axis
        return spec_with_fallback(leaf.shape, want, mesh)

    return jax.tree_util.tree_map_with_path(f, cache)


def context_parallel_decode_step(
    q: Array,
    k_new: Array,
    v_new: Array,
    cache: LayerKVCache,
    sm_scale: float,
    mesh,
    *,
    axis: str = "model",
    ring: bool = False,
) -> tuple[Array, LayerKVCache]:
    """Append one token + fused decode attention, context-parallel.

    q: [B, H, D]; k_new/v_new: [B, H_kv, 1, D]. The cache context dim is
    sharded over ``axis``; partials merge with log-sum-exp psums (a few
    hundred KB) instead of GSPMD reshards (§Perf H1)."""
    from ..distributed.sharding import dp_axes, spec_with_fallback

    dp = dp_axes(mesh)
    n_shards = mesh.shape[axis]
    q_spec = spec_with_fallback(q.shape, [dp, None, None], mesh)
    kv_spec = spec_with_fallback(k_new.shape, [dp, None, None, None], mesh)
    c_specs = _cache_specs_local(cache, mesh, dp, axis)

    def local(q_l, k_l, v_l, cache_l: LayerKVCache):
        cache_l = _append_token_local(cache_l, k_l, v_l, axis, n_shards, ring)
        n_valid = cache_l.n_comp
        if ring:
            n_valid = jnp.minimum(n_valid, cache_l.capacity * n_shards)
        if cache_l.cfg.policy == "none":
            o_c, m_c, l_c = _local_dense_partials(
                q_l, cache_l.raw_k, cache_l.raw_v, n_valid, sm_scale, axis)
        else:
            o_c, m_c, l_c = _local_cache_partials(
                q_l, cache_l.k, cache_l.v, n_valid, sm_scale, axis)
        # merge context-shard partials: tiny [B,H,D]+[B,H] exchanges
        m_g = jax.lax.pmax(m_c, axis)
        scale_ = jnp.exp(m_c - m_g)
        o_g = jax.lax.psum(o_c * scale_[..., None], axis)
        l_g = jax.lax.psum(l_c * scale_, axis)
        o_r, m_r, l_r = ops._residual_partials(
            q_l, cache_l.resid_k, cache_l.resid_v, cache_l.n_resid, sm_scale)
        out = ops.merge_partials(o_g, m_g, l_g, o_r, m_r, l_r)
        return out, cache_l

    from ..utils import shard_map_compat

    return shard_map_compat(
        local, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, c_specs),
        out_specs=(q_spec, c_specs),
    )(q, k_new, v_new, cache)
