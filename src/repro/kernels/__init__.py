"""Pallas TPU kernels for computation-aware decompression (paper §III-C).

Each kernel has a pure-jnp oracle in ref.py; ops.py dispatches between the
``"xla"`` (oracle, CPU default) and ``"pallas"`` (explicit kernels) backends.
"""
from .ops import (  # noqa: F401
    dense_decode_attention,
    merge_partials,
    packed_decode_attention,
    packed_qk_scores,
    packed_qk_scores_paged,
    packed_weighted_v,
    packed_weighted_v_paged,
    paged_decode_attention,
)
