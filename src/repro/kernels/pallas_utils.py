"""Shared Pallas plumbing (TPU compiler params with interpret fallback,
page-pool tile loads for the paged kernels)."""
from __future__ import annotations

from jax.experimental import pallas as pl


def load_page_id(table_ref, lp):
    """Resolve logical page ``lp`` (traced) through a [1, n_pages] page-table
    block: the in-kernel half of the page-table indirection."""
    return pl.load(table_ref, (pl.dslice(0, 1), pl.dslice(lp, 1)))[0, 0]


def load_pool_tile(ref, phys, start, size):
    """Dynamic tile load from a whole-pool ref.

    ref: [1, n_pool_pages, C, U] block (one kv-head's pool); phys: traced
    physical page id; start/size: element window on the last axis. Returns
    [C, size]. ``pl.dslice`` keeps every index a Slice, which both the
    interpret-mode discharge rule and the TPU lowering accept.
    """
    C = ref.shape[2]
    tile = pl.load(
        ref,
        (pl.dslice(0, 1), pl.dslice(phys, 1), pl.dslice(0, C),
         pl.dslice(start, size)),
    )
    return tile.reshape(C, size)


def load_tier_pool_tile(payload_ref, mins_ref, shifts_ref, phys, toff,
                        tile_l, width, pack):
    """Load one tier's (payload, mins, shifts) tile from whole-pool refs.

    ``phys``: traced physical page id; ``toff``: tile index within the
    page. The offset triple (words / packs / shift bytes per tile) is THE
    pool-layout contract (docs/formats.md) — keep every paged kernel on
    this helper so a layout change lands in one place.
    """
    return (
        load_pool_tile(payload_ref, phys, toff * (tile_l * width // 32),
                       tile_l * width // 32),
        load_pool_tile(mins_ref, phys, toff * (tile_l // pack),
                       tile_l // pack),
        load_pool_tile(shifts_ref, phys, toff * (tile_l // pack // 4),
                       tile_l // pack // 4),
    )


def pool_block_spec(leaf, h_kv: int):
    """BlockSpec handing a paged kernel ONE kv-head's whole pool.

    Grid dim 0 indexes (batch, kv-head) pairs batch-major, so the head is
    ``b % h_kv``. The other half of the pool-layout contract
    (``load_tier_pool_tile``) lives below — a layout change (e.g. moving
    the page table to scalar prefetch on real TPU) edits this module only.
    """
    return pl.BlockSpec((1, *leaf.shape[1:]), lambda b, l: (b % h_kv, 0, 0, 0))


def page_table_spec(n_pages: int, h_kv: int):
    """BlockSpec handing a paged kernel its row's live page-table prefix."""
    return pl.BlockSpec((1, n_pages), lambda b, l: (b // h_kv, 0))


def tpu_params(dimension_semantics: tuple[str, ...], interpret: bool) -> dict:
    """CompilerParams for TPU lowering; empty under interpret mode."""
    if interpret:
        return {}
    try:
        from jax.experimental.pallas import tpu as pltpu

        cp = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams"
        )
        return {"compiler_params": cp(dimension_semantics=dimension_semantics)}
    except Exception:  # pragma: no cover - non-TPU build
        return {}
