"""Shared Pallas plumbing (TPU compiler params with interpret fallback)."""
from __future__ import annotations


def tpu_params(dimension_semantics: tuple[str, ...], interpret: bool) -> dict:
    """CompilerParams for TPU lowering; empty under interpret mode."""
    if interpret:
        return {}
    try:
        from jax.experimental.pallas import tpu as pltpu

        cp = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams"
        )
        return {"compiler_params": cp(dimension_semantics=dimension_semantics)}
    except Exception:  # pragma: no cover - non-TPU build
        return {}
