"""Pure-jnp oracles for the computation-aware decompression kernels.

These implement exactly the math the Pallas kernels must reproduce
(paper §III-C adapted to the TPU tiered format, DESIGN.md §3):

* ``kpack_scores_ref``   — fused K decompress + q·Kᵀ (paper Fig. 8).
* ``vpack_out_ref``      — fused w·V decompress + matvec (paper Fig. 11).
* ``packed_decode_attention_ref`` — the full single-launch decode attention
  over the compressed region + residual buffer, merged flash-style
  (replaces the paper's atomicAdd partial sums with a log-sum-exp merge).

Metadata folding (the TPU analogue of the paper's "decompress into
registers"): token-wise dequantization is never materialized. With
K_deq[l, c] = q_int[l, c] * scale[l] + zero[l],

  scores[l] = scale[l] * (q · q_int[:, l]) + zero[l] * sum_c(q[c])
  out[c]    = sum_l (w[l] * scale[l]) * q_int[c, l]  +  sum_l w[l] * zero[l]

so the integer matvec runs directly on decoded integers and the per-token
(scale, zero) are folded in as rank-1 corrections.

These oracles consume the DENSE TieredCache layout only. Paged caches
reach them through the page-table gather (``core.cache.gather_paged`` /
``tiered.gather_tiered_pages``), which reassembles the dense layout
bit-identically — so one oracle covers both storage modes, and the paged
Pallas kernels are checked against the gathered dense launch
(tests/test_paged.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.tiered import TieredCache, chan_inverse_perm, unpack_tier

Array = jax.Array

NEG_INF = -1e30


def valid_mask(n: Array, length: int, lead: int) -> Array:
    """``arange(length) < n`` with ``lead`` broadcast axes before the length.

    ``n`` is a valid-token count: scalar (uniform wave) or [B] (per-row
    slot state). Returns [1]*lead + [length] for a scalar, or
    [B] + [1]*(lead-1) + [length] for a vector — broadcastable against
    [B, ..., length] score tensors either way.
    """
    n = jnp.asarray(n)
    ar = jnp.arange(length)
    if n.ndim == 0:
        return (ar < n).reshape((1,) * lead + (length,))
    return ar.reshape((1,) * lead + (length,)) < n.reshape((-1,) + (1,) * lead)


def _grouped_q(q: Array, h_kv: int) -> Array:
    """[B, H, D] -> [B, H_kv, G, D] (GQA grouping)."""
    B, H, D = q.shape
    return q.reshape(B, h_kv, H // h_kv, D)


def kpack_scores_ref(q: Array, kc: TieredCache, sm_scale: float = 1.0) -> Array:
    """Fused K decompress + q·Kᵀ.

    q:  f32 [B, H, D] query in ORIGINAL channel order.
    kc: compressed K, channels-major tier layout, capacity L.
    Returns scores f32 [B, H, L] (no masking — caller masks to n_valid).
    """
    B, H, D = q.shape
    h_kv = kc.scale.shape[-2]
    L = kc.capacity
    qg = _grouped_q(q.astype(jnp.float32), h_kv)  # [B, Hkv, G, D]
    # channel permutation of K is absorbed by permuting q (free).
    qp = jnp.take_along_axis(qg, kc.chan_perm[:, :, None, :], axis=-1)
    # integer matvec per tier
    si = jnp.zeros((B, h_kv, qg.shape[2], L), jnp.float32)
    off = 0
    for t, c in zip(kc.tiers, kc.spec.counts):
        qint = unpack_tier(t, L).astype(jnp.float32)  # [B, Hkv, C_t, L]
        si = si + jnp.einsum("bhgc,bhcl->bhgl", qp[..., off : off + c], qint)
        off += c
    qsum = jnp.sum(qg, axis=-1, keepdims=True)  # [B, Hkv, G, 1]
    scores = si * kc.scale[:, :, None, :] + qsum * kc.zero[:, :, None, :]
    return (scores * sm_scale).reshape(B, H, L)


def vpack_out_ref(w: Array, vc: TieredCache) -> Array:
    """Fused w·V decompress + matvec.

    w:  f32 [B, H, L] attention weights (already softmaxed & masked).
    vc: compressed V. Returns out f32 [B, H, D] in ORIGINAL channel order.
    """
    B, H, L = w.shape
    h_kv = vc.scale.shape[-2]
    wg = w.astype(jnp.float32).reshape(B, h_kv, H // h_kv, L)
    ws = wg * vc.scale[:, :, None, :]  # fold scale into weights
    parts = []
    for t in vc.tiers:
        qint = unpack_tier(t, L).astype(jnp.float32)  # [B, Hkv, C_t, L]
        parts.append(jnp.einsum("bhgl,bhcl->bhgc", ws, qint))
    out = jnp.concatenate(parts, axis=-1)  # tier channel order
    zterm = jnp.einsum("bhgl,bhl->bhg", wg, vc.zero)[..., None]
    out = out + zterm
    inv = chan_inverse_perm(vc.chan_perm)  # undo channel permutation
    out = jnp.take_along_axis(out, inv[:, :, None, :], axis=-1)
    return out.reshape(B, H, -1)


def packed_decode_attention_ref(
    q: Array,
    kc: TieredCache,
    vc: TieredCache,
    resid_k: Array,
    resid_v: Array,
    n_comp: Array,
    n_resid: Array,
    sm_scale: float,
) -> Array:
    """Full decode attention: softmax over [compressed | residual] regions.

    q: [B, H, D]; resid_k/v: [B, H_kv, R, D] full precision.
    n_comp/n_resid: scalar or per-row [B] valid-token counts.
    Returns attention output [B, H, D].
    """
    B, H, D = q.shape
    h_kv = resid_k.shape[1]
    L = kc.capacity
    R = resid_k.shape[2]

    s_comp = kpack_scores_ref(q, kc, sm_scale)  # [B, H, L]
    mask_c = valid_mask(n_comp, L, lead=2)
    s_comp = jnp.where(mask_c, s_comp, NEG_INF)

    qg = _grouped_q(q.astype(jnp.float32), h_kv)
    s_res = jnp.einsum(
        "bhgd,bhrd->bhgr", qg, resid_k.astype(jnp.float32)
    ).reshape(B, H, R) * sm_scale
    mask_r = valid_mask(n_resid, R, lead=2)
    s_res = jnp.where(mask_r, s_res, NEG_INF)

    m = jnp.maximum(jnp.max(s_comp, -1, keepdims=True), jnp.max(s_res, -1, keepdims=True))
    w_comp = jnp.exp(s_comp - m)
    w_res = jnp.exp(s_res - m)
    # zero out masked lanes exactly (exp(NEG_INF - m) underflows anyway)
    w_comp = jnp.where(mask_c, w_comp, 0.0)
    w_res = jnp.where(mask_r, w_res, 0.0)
    denom = jnp.sum(w_comp, -1, keepdims=True) + jnp.sum(w_res, -1, keepdims=True)

    o_comp = vpack_out_ref(w_comp, vc)  # [B, H, D] (unnormalized)
    wg = w_res.reshape(B, h_kv, H // h_kv, R)
    o_res = jnp.einsum("bhgr,bhrd->bhgd", wg, resid_v.astype(jnp.float32)).reshape(B, H, D)
    return (o_comp + o_res) / jnp.maximum(denom, 1e-30)


def dense_decode_attention_ref(
    q: Array,
    raw_k: Array,
    raw_v: Array,
    resid_k: Array,
    resid_v: Array,
    n_comp: Array,
    n_resid: Array,
    sm_scale: float,
) -> Array:
    """Uncompressed-cache decode attention (the cuBLAS-equivalent baseline).

    raw_k/v: [B, H_kv, L, D] bf16. n_comp/n_resid: scalar or per-row [B].
    """
    B, H, D = q.shape
    h_kv = raw_k.shape[1]
    L, R = raw_k.shape[2], resid_k.shape[2]
    qg = _grouped_q(q.astype(jnp.float32), h_kv)
    s_c = jnp.einsum("bhgd,bhld->bhgl", qg, raw_k.astype(jnp.float32)) * sm_scale
    s_r = jnp.einsum("bhgd,bhrd->bhgr", qg, resid_k.astype(jnp.float32)) * sm_scale
    mask_c = valid_mask(n_comp, L, lead=3)
    mask_r = valid_mask(n_resid, R, lead=3)
    s_c = jnp.where(mask_c, s_c, NEG_INF)
    s_r = jnp.where(mask_r, s_r, NEG_INF)
    m = jnp.maximum(s_c.max(-1, keepdims=True), s_r.max(-1, keepdims=True))
    w_c = jnp.where(mask_c, jnp.exp(s_c - m), 0.0)
    w_r = jnp.where(mask_r, jnp.exp(s_r - m), 0.0)
    denom = w_c.sum(-1, keepdims=True) + w_r.sum(-1, keepdims=True)
    o = jnp.einsum("bhgl,bhld->bhgd", w_c, raw_v.astype(jnp.float32)) + jnp.einsum(
        "bhgr,bhrd->bhgd", w_r, resid_v.astype(jnp.float32)
    )
    return (o / jnp.maximum(denom, 1e-30)).reshape(B, H, D)
