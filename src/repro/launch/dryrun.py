import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating real data:
  * compiled.memory_analysis()  — per-device bytes (proves it fits)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the post-SPMD HLO text
and writes one JSON per cell under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import json
import re
import time
import traceback

import jax

from ..configs import ARCHS, ASSIGNED, SHAPES, shape_applicable
from .mesh import make_production_mesh
from .specs import build_cell

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:\w+\[[\d,]*\][^ )]*(?:,\s*)?)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in post-SPMD HLO."""
    per_op: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(2)
        n = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt not in _DTYPE_BYTES:
                continue
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            n += size * _DTYPE_BYTES[dt]
        per_op[op] = per_op.get(op, 0) + n
    per_op["total"] = sum(per_op.values())
    return per_op


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             policy: str = "packkv") -> dict:
    arch = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size, "policy": policy,
    }
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, policy=policy)
    with mesh:
        from ..distributed.sharding import set_active_mesh

        set_active_mesh(mesh)
        try:
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            try:
                ma = compiled.memory_analysis()
                rec["memory"] = {
                    k: int(getattr(ma, k))
                    for k in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                    )
                    if hasattr(ma, k)
                }
                print(f"[{cell.name}] memory_analysis: {rec['memory']}")
            except Exception as e:  # CPU backend may not implement it
                rec["memory"] = {"error": str(e)}
            try:
                ca = compiled.cost_analysis()
                ca = ca[0] if isinstance(ca, (list, tuple)) else ca
                rec["cost"] = {
                    "flops": float(ca.get("flops", -1)),
                    "bytes_accessed": float(ca.get("bytes accessed", -1)),
                    "optimal_seconds": float(ca.get("optimal_seconds", -1)),
                }
                print(f"[{cell.name}] cost_analysis: {rec['cost']}")
            except Exception as e:
                rec["cost"] = {"error": str(e)}
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_lines"] = hlo.count("\n")
            # loop-aware cost model (scan bodies × trip counts) — the
            # numbers §Roofline actually uses (XLA's cost_analysis counts
            # while bodies once; see benchmarks/hlo_cost.py)
            try:
                import sys

                sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                                "../../.."))
                from benchmarks.hlo_cost import analyze

                rec["loop_cost"] = analyze(hlo)
                print(f"[{cell.name}] loop-aware: "
                      f"flops={rec['loop_cost']['flops']:.3e} "
                      f"bytes={rec['loop_cost']['bytes']:.3e} "
                      f"coll={rec['loop_cost']['collectives']['total']:.3e}")
            except Exception as e:
                rec["loop_cost"] = {"error": str(e)}
            rec["status"] = "ok"
        except Exception as e:
            rec["status"] = "fail"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-3000:]
        finally:
            set_active_mesh(None)
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--policy", default="packkv",
                    choices=["packkv", "none", "kivi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = []
    arch_list = ASSIGNED if args.all or args.arch is None else [args.arch]
    shape_list = list(SHAPES) if args.all or args.shape is None else [args.shape]
    for a in arch_list:
        for s in shape_list:
            ok, why = shape_applicable(ARCHS[a], SHAPES[s])
            if ok:
                cells.append((a, s))
            else:
                print(f"SKIP {a}×{s}: {why}")

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for a, s in cells:
        for mp in meshes:
            tag = f"{a}_{s}_{'multi' if mp else 'single'}_{args.policy}"
            rec = run_cell(a, s, mp, args.policy)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"].upper()
            if status != "OK":
                n_fail += 1
                print(f"{status} {tag}: {rec.get('error')}")
            else:
                print(
                    f"OK {tag}: lower {rec['lower_s']}s compile {rec['compile_s']}s "
                    f"flops={rec['cost'].get('flops'):.3e} "
                    f"coll={rec['collectives']['total']:.3e}B"
                )
    print(f"dry-run finished: {len(cells) * len(meshes) - n_fail} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
