"""Training launcher: data stream -> jitted train_step -> checkpoints.

Runs real steps on CPU with smoke/small configs; on a TPU fleet the same
script runs under the production mesh (--mesh prod). Fault tolerance:
  * atomic checkpoints every --ckpt-every steps (AsyncCheckpointer)
  * --resume restores the latest COMMITted checkpoint + data-stream state
  * StragglerMonitor flags slow steps; after `patience` consecutive flags
    it requests an elastic downscale plan (logged; the surrounding fleet
    controller would enact it and re-launch with --resume).

Example (quickstart-scale):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 20 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore
from ..configs import get_arch
from ..data import ShardedTokenStream
from ..distributed import StragglerMonitor, downscale_plan
from ..distributed import sharding as shd
from ..models import get_model
from ..training import OptConfig, init_opt_state
from ..training.train import make_train_step
from .mesh import make_debug_mesh, make_production_mesh


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "constant"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="debug", choices=["debug", "prod", "prod-multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch}: train launcher supports token archs; "
                         "see examples/ for frames/patches training")
    api = get_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, schedule=args.schedule,
                        total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))

    mesh = {
        "debug": lambda: make_debug_mesh(),
        "prod": lambda: make_production_mesh(),
        "prod-multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()

    stream = ShardedTokenStream(
        vocab=cfg.vocab, batch_per_host=args.batch, seq=args.seq, seed=args.seed
    )

    key = jax.random.PRNGKey(args.seed)
    params = api.init(key, cfg)
    opt_state = init_opt_state(params)
    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), extra = restore(
                args.ckpt_dir, last, (params, opt_state)
            )
            stream.restore(extra["stream"])
            start_step = last
            print(f"resumed from step {last}")

    step_fn = jax.jit(make_train_step(api, cfg, opt_cfg, args.grad_accum),
                      donate_argnums=(0, 1))
    monitor = StragglerMonitor()
    shd.set_active_mesh(mesh if mesh.size > 1 else None)

    with mesh:
        for step in range(start_step, args.steps):
            batch = stream.next_batch()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            monitor.start()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            verdict = monitor.stop()
            if verdict == "exclude":
                plan = downscale_plan(tuple(mesh.devices.shape), "exclude-straggler")
                print(f"straggler exclusion requested: {plan}")
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} [{verdict}]")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.submit(step + 1, (params, opt_state),
                            {"stream": stream.state()})
    if ckpt:
        ckpt.close()
    shd.set_active_mesh(None)
    print("training done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
