"""Serving launcher: calibrated PackKV engine + slot-scheduled requests.

Every family (transformer, rwkv6, hybrid_rglru) serves through the one
chunk-interleaved ``SlotServer`` engine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --requests 12 --max-new 32 --policy packkv
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get_arch
from ..core.cache import PackKVConfig
from ..models import get_model
from ..serving import Engine, EngineConfig, Request, SlotServer
from ..utils import tree_bytes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=192)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=1024)
    ap.add_argument("--policy", default="packkv", choices=["packkv", "none", "kivi"])
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--prefill-chunk-pages", type=int, default=1,
                    help="admission chunk budget in pages per scheduler "
                    "step; decode never stalls more than one chunk "
                    "(0 = legacy monolithic prefill; docs/serving.md)")
    ap.add_argument("--paged", action="store_true",
                    help="paged compressed region: shared page pool + "
                    "page-reservation admission (docs/architecture.md)")
    ap.add_argument("--page-size", type=int, default=256)
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="pool size in pages; < batch*capacity/page_size "
                    "oversubscribes (admission blocks on reservations)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix page cache: refcounted page reuse "
                    "across requests + suffix-only prefill (requires "
                    "--paged; docs/serving.md)")
    ap.add_argument("--prefix-cache-pages", type=int, default=None,
                    help="max pool pages the prefix index may pin "
                    "(default unbounded; pool pressure still evicts LRU)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decode: host n-gram drafting + "
                    "batched k-token verify launches; greedy outputs stay "
                    "bit-identical (docs/serving.md)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max drafted tokens per verify launch "
                    "(window = k + 1)")
    ap.add_argument("--preempt", action="store_true",
                    help="priority preemption: a blocked higher-class "
                    "admission swaps a lower-class victim's compressed "
                    "pages to host RAM; the victim resumes bit-identically "
                    "later (docs/serving.md)")
    ap.add_argument("--session-cache", action="store_true",
                    help="multi-turn session cache: retiring slots park "
                    "their compressed pages host-side; a returning session "
                    "restores them and prefills only its new suffix "
                    "(docs/serving.md)")
    ap.add_argument("--session-cache-mb", type=int, default=256,
                    help="host-RAM budget for parked sessions in MB "
                    "(LRU-by-bytes beyond it: spill to --session-disk-dir "
                    "or drop)")
    ap.add_argument("--session-ttl-s", type=float, default=None,
                    help="idle parked sessions expire after this many "
                    "seconds (default: never)")
    ap.add_argument("--session-disk-dir", default=None, metavar="DIR",
                    help="disk spill tier for LRU host-tier victims "
                    "(savable-dtype mini serializers; default: drop)")
    ap.add_argument("--priority-every", type=int, default=0, metavar="N",
                    help="demo traffic shaping: every Nth request is "
                    "class 0 (highest), the rest class 1 (0 = all class 0)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expired requests retire "
                    "with their partial output at the next scheduler step")
    ap.add_argument("--aging-steps", type=int, default=32,
                    help="scheduler steps per one class promotion of "
                    "queued work (0 = strict priority, may starve)")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="dump final SlotStats (incl. drafted/accepted "
                    "counts and acceptance rate) as JSON to PATH")
    ap.add_argument("--mesh", default="1,1", metavar="DP,KV",
                    help="serving mesh shape 'dp,kv': shard pool payloads "
                    "by KV head over kv devices and partition attention "
                    "rows over dp (1,1 = single-device; outputs are "
                    "bit-identical either way; docs/serving.md). Needs "
                    "dp*kv visible devices — on CPU set "
                    "XLA_FORCE_HOST_PLATFORM_DEVICE_COUNT first")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    try:
        mesh_shape = tuple(int(x) for x in args.mesh.split(","))
        assert len(mesh_shape) == 2
    except (ValueError, AssertionError):
        raise SystemExit(f"--mesh takes 'dp,kv' (e.g. 1,2), got {args.mesh!r}")

    cfg = get_arch(args.arch, smoke=args.smoke)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only; nothing to serve")
    api = get_model(cfg)
    if args.session_cache and api.evacuate_slot is None:
        raise SystemExit(
            f"{args.arch} (family {cfg.family!r}) cannot serve "
            "--session-cache: its recurrent slot state has no "
            "evacuate/restore ops to park through — drop --session-cache")
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key, cfg)

    pack = PackKVConfig(policy=args.policy)
    ecfg = EngineConfig(capacity=args.capacity, max_batch=args.batch,
                        backend=args.backend, paged=args.paged,
                        page_size=args.page_size, pool_pages=args.pool_pages,
                        prefix_cache=args.prefix_cache,
                        prefix_cache_pages=args.prefix_cache_pages,
                        prefill_chunk_pages=args.prefill_chunk_pages,
                        spec_decode=args.spec_decode, spec_k=args.spec_k,
                        preempt=args.preempt, aging_steps=args.aging_steps,
                        session_cache=args.session_cache,
                        session_cache_mb=args.session_cache_mb,
                        session_ttl_s=args.session_ttl_s,
                        session_disk_dir=args.session_disk_dir,
                        mesh_shape=mesh_shape)
    t0 = time.time()
    engine = Engine(cfg, params, pack, ecfg)
    print(f"engine built in {time.time() - t0:.1f}s; policy={args.policy}")
    if engine.mesh is not None:
        print(f"serving mesh dp={mesh_shape[0]} x kv={mesh_shape[1]} over "
              f"{mesh_shape[0] * mesh_shape[1]} devices: pool payloads "
              f"sharded by KV head ({cfg.n_kv_heads} -> "
              f"{cfg.n_kv_heads // mesh_shape[1]}/shard), page ledger "
              "replicated")
    ks, vs = engine.pack_cfg.k_spec_static, engine.pack_cfg.v_spec_static
    if args.policy == "packkv" and ks is not None:  # recurrent: no KV tiers
        print(f"calibrated K tiers {ks.widths}×{ks.counts}; "
              f"V tiers {vs.widths}×{vs.counts}")

    if cfg.input_mode != "tokens":
        raise SystemExit(
            f"{args.arch} takes input_mode {cfg.input_mode!r}; the request "
            "queue carries token prompts only — batch such inputs through "
            "Engine.generate instead")
    server = SlotServer(engine)
    rng = np.random.default_rng(args.seed)
    # --prefix-cache demo traffic: every request opens with the same
    # two-page "system prompt" so later admissions hit the index
    sys_prompt = (rng.integers(0, cfg.vocab, 2 * args.page_size)
                  if args.prefix_cache else np.zeros(0, np.int64))
    for rid in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        toks = np.concatenate([sys_prompt, rng.integers(0, cfg.vocab, plen)])
        n = args.priority_every
        prio = 0 if (n <= 0 or rid % n == 0) else 1
        server.submit(Request(rid=rid, max_new=args.max_new, tokens=toks,
                              priority=prio, deadline_ms=args.deadline_ms))
    t0 = time.time()
    done = server.run()
    n_tok = sum(len(r.output) for r in done)
    dt = time.time() - t0
    print(f"{args.requests} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s on CPU)")
    if args.session_cache:
        # returning-session demo: every request comes back with a short
        # follow-up on its full first-turn trace -> served from the park
        t1 = time.time()
        for rid in range(args.requests):
            r = server.done[rid]
            trace = np.concatenate([np.asarray(r.tokens),
                                    np.asarray(r.output)])
            ext = rng.integers(0, cfg.vocab, 8)
            server.submit(Request(rid=args.requests + rid,
                                  max_new=args.max_new,
                                  tokens=np.concatenate([trace, ext])))
        n2 = sum(len(r.output) for r in server.run())
        print(f"{args.requests} returning sessions, {n2} tokens in "
              f"{time.time() - t1:.1f}s")
    s = server.stats
    print(f"slot scheduler: {s.decode_steps} decode steps, "
          f"occupancy {s.occupancy:.2f}, {s.slot_reuses} slot reuses, "
          f"{s.admitted} admitted / {s.completed} completed, "
          f"{s.prefill_chunks} prefill chunks")
    if args.paged:
        print(f"paged pool: {engine.pack_cfg.pool_pages} pages of "
              f"{args.page_size} tokens, peak reserved "
              f"{s.pages_reserved_peak}, {s.admission_blocks} "
              f"admission blocks")
    if args.prefix_cache:
        print(f"prefix cache: {s.prefix_hits}/{s.prefix_lookups} hits "
              f"(rate {s.prefix_hit_rate:.2f}), "
              f"{s.prefix_pages_shared} pages shared by reference, "
              f"{s.prefix_evictions} evictions")
    if args.spec_decode:
        print(f"speculative decode: {s.spec_launches} verify launches, "
              f"{s.spec_accepted}/{s.spec_drafted} drafts accepted "
              f"(rate {s.acceptance_rate:.2f})"
              + (f", {s.degraded_steps} degraded steps (spec disabled by "
                 "the straggler watchdog)" if s.degraded_steps else ""))
    if args.preempt:
        print(f"preemption: {s.preemptions} swap-outs "
              f"({s.swapped_pages} pages out / {s.restored_pages} back)")
    if args.session_cache:
        st = server._sessions
        print(f"session cache: {s.session_parks} parks, "
              f"{s.session_hits}/{s.session_lookups} hits "
              f"(rate {s.session_hit_rate:.2f}), "
              f"{s.session_restored_pages} pages restored, "
              f"{s.session_evictions} evicted/expired; host "
              f"{st.nbytes / 1e6:.1f} MB resident "
              f"(peak {st.peak_bytes / 1e6:.1f}), "
              f"{st.spills} disk spills / {st.loads} loads")
    if s.cancelled or s.expired:
        print(f"retired early: {s.cancelled} cancelled, "
              f"{s.expired} past deadline")
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump(s.to_json(), f, indent=2, default=float)
        print(f"wrote {args.stats_json}")

    # cache memory report (the paper's deliverable). Byte counts are
    # static-shape-determined, so the allocated slot cache suffices — and
    # unlike a whole-batch prefill it is valid for oversubscribed pools.
    cap = args.capacity
    comp_bytes = tree_bytes(engine.alloc_slot_cache())
    raw = (cfg.n_layers * 2 * args.batch * cfg.n_kv_heads * cap * cfg.hd * 2)
    print(f"cache pytree bytes (capacity {cap}): {comp_bytes:,} "
          f"vs raw bf16 {raw:,} -> {raw / comp_bytes:.2f}x smaller")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
