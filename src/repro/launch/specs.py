"""Per-cell step functions + ShapeDtypeStruct input specs + shardings.

``build_cell(arch, shape, mesh)`` returns everything the dry-run needs to
``jit(...).lower(...).compile()`` one (architecture × input-shape × mesh)
cell WITHOUT allocating any real data: abstract params/opt/cache via
jax.eval_shape, abstract batches via ShapeDtypeStruct, and PartitionSpecs
from the divisibility-aware rule engine.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeCfg, shape_applicable
from ..core.cache import PackKVConfig
from ..core.tiered import TierSpec
from ..distributed import sharding as shd
from ..models import get_model
from ..models import transformer as tfm
from ..training.optimizer import OptConfig, init_opt_state
from ..training.train import make_train_step


def default_pack_cfg(arch: ArchConfig, policy: str = "packkv") -> PackKVConfig:
    """Static dry-run compression config (calibration picks specs at real
    engine build; the dry-run uses the default 2/4/8 tier split)."""
    hd = arch.hd
    return PackKVConfig(
        policy=policy,
        k_spec_static=TierSpec.for_head_dim(hd) if policy == "packkv" else None,
        v_spec_static=TierSpec.for_head_dim(hd) if policy == "packkv" else None,
    )


def batch_struct(arch: ArchConfig, shape: ShapeCfg, *, with_labels: bool) -> dict:
    B, S = shape.global_batch, shape.seq_len
    d: dict[str, Any] = {}
    if arch.input_mode == "tokens":
        d["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif arch.input_mode == "frames":
        d["frames"] = jax.ShapeDtypeStruct((B, S, arch.d_model), jnp.bfloat16)
    else:  # tokens_patches — patches are part of the context budget
        d["tokens"] = jax.ShapeDtypeStruct((B, S - arch.n_patches), jnp.int32)
        d["patches"] = jax.ShapeDtypeStruct(
            (B, arch.n_patches, arch.d_model), jnp.bfloat16
        )
    if with_labels:
        n_lab = S - (arch.n_patches if arch.input_mode == "tokens_patches" else 0)
        d["labels"] = jax.ShapeDtypeStruct((B, n_lab), jnp.int32)
    return d


@dataclasses.dataclass
class Cell:
    name: str
    step_fn: Any
    args: tuple  # abstract (ShapeDtypeStruct pytrees)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def build_cell(arch: ArchConfig, shape: ShapeCfg, mesh, *,
               policy: str = "packkv", backend: str = "xla",
               grad_accum: int = 0) -> Cell:
    ok, why = shape_applicable(arch, shape)
    if not ok:
        raise ValueError(f"{arch.name} × {shape.name} skipped: {why}")
    api = get_model(arch)
    key = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(lambda k: api.init(k, arch), key)
    p_specs = shd.param_specs(params_abs, mesh)
    dp = shd.dp_axes(mesh)
    pack_cfg = default_pack_cfg(arch, policy)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(lambda: init_opt_state(params_abs))
        o_specs = shd.opt_state_specs(params_abs, mesh)
        batch = batch_struct(arch, shape, with_labels=True)
        b_specs = shd.batch_specs(batch, mesh)
        if grad_accum == 0:  # auto: deeper microbatching for >10B models
            grad_accum = 8 if arch.param_count() > 1e10 else 4
        step = make_train_step(
            api, arch, OptConfig(), grad_accum=grad_accum,
            param_pspecs=p_specs, accum_pspecs=o_specs.mu,
        )
        metric_specs = {"loss": P(), "grad_norm": P(), "step": P()}
        return Cell(
            name=f"{arch.name}×{shape.name}",
            step_fn=step,
            args=(params_abs, opt_abs, batch),
            in_shardings=(_named(p_specs, mesh), _named(o_specs, mesh),
                          _named(b_specs, mesh)),
            out_shardings=(_named(p_specs, mesh), _named(o_specs, mesh),
                           _named(metric_specs, mesh)),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        batch = batch_struct(arch, shape, with_labels=False)
        b_specs = shd.batch_specs(batch, mesh)
        if arch.family == "encoder":
            step = partial(tfm.encode, cfg=arch)
            out_spec = shd.spec_with_fallback(
                (shape.global_batch, shape.seq_len, arch.d_model),
                [dp, "model", None], mesh,
            )
            return Cell(
                name=f"{arch.name}×{shape.name}",
                step_fn=lambda params, batch: step(params, batch=batch),
                args=(params_abs, batch),
                in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh)),
                out_shardings=NamedSharding(mesh, out_spec),
            )
        capacity = _capacity(arch, shape)
        step = lambda params, batch: api.prefill(
            params, arch, pack_cfg, capacity, batch
        )
        cache_abs = jax.eval_shape(
            lambda: api.alloc_cache(arch, pack_cfg, shape.global_batch, capacity)
        )
        c_specs = shd.cache_specs(cache_abs, mesh)
        logits_spec = shd.spec_with_fallback(
            (shape.global_batch, arch.vocab), [dp, "model"], mesh
        )
        return Cell(
            name=f"{arch.name}×{shape.name}",
            step_fn=step,
            args=(params_abs, batch),
            in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh)),
            out_shardings=(NamedSharding(mesh, logits_spec), _named(c_specs, mesh)),
        )

    # decode
    capacity = _capacity(arch, shape)
    cache_abs = jax.eval_shape(
        lambda: api.alloc_cache(arch, pack_cfg, shape.global_batch, capacity)
    )
    c_specs = shd.cache_specs(cache_abs, mesh)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_spec = shd.spec_with_fallback(token.shape, [dp, None], mesh)
    logits_spec = shd.spec_with_fallback(
        (shape.global_batch, arch.vocab), [dp, "model"], mesh
    )
    step = lambda params, cache, token: api.decode_step(
        params, arch, cache, token, backend=backend
    )
    return Cell(
        name=f"{arch.name}×{shape.name}",
        step_fn=step,
        args=(params_abs, cache_abs, token),
        in_shardings=(_named(p_specs, mesh), _named(c_specs, mesh),
                      NamedSharding(mesh, t_spec)),
        out_shardings=(NamedSharding(mesh, logits_spec), _named(c_specs, mesh)),
        donate_argnums=(1,),
    )


def _capacity(arch: ArchConfig, shape: ShapeCfg) -> int:
    """Compressed-region capacity for serving cells."""
    if arch.family == "hybrid_rglru":
        return arch.window  # windowed cache; RG-LRU state is O(1)
    if arch.family == "rwkv6":
        return 64  # unused (state-based)
    return shape.seq_len
