"""§Perf H1 correctness: context-parallel decode == single-device decode.

Runs in a subprocess with 8 fake host devices (the 512-device override is
reserved for dryrun.py; tests keep the main process at 1 device).
"""
import json
import subprocess
import sys

import pytest

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.core.tiered import TierSpec
from repro.models import get_model
from repro.distributed.sharding import set_active_mesh

cfg = SMOKES["llama2-7b"]
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)
pack = PackKVConfig(
    residual=96,
    k_spec_static=TierSpec.for_head_dim(cfg.hd),
    v_spec_static=TierSpec.for_head_dim(cfg.hd),
)
rng = np.random.default_rng(0)
B, S, cap = 1, 446, 512  # 512/8 = 64 per shard = one block; resid 62 after prefill
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
toks = [jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        for _ in range(40)]  # crosses a flush boundary (resid 96 -> block 64)

def run(mesh):
    set_active_mesh(mesh)
    try:
        lg, cache = api.prefill(params, cfg, pack, cap, batch)
        outs = [np.asarray(lg)]
        for t in toks:
            lg, cache = api.decode_step(params, cfg, cache, t)
            outs.append(np.asarray(lg))
        return np.stack(outs)
    finally:
        set_active_mesh(None)

base = run(None)  # single-device plain path
mesh = jax.make_mesh((1, 8), ("data", "model"))
with mesh:
    cp = run(mesh)  # context-parallel path (8 context shards)
scale = float(np.max(np.abs(base)))
rel_early = float(np.max(np.abs(base[:2] - cp[:2]))) / scale
rel_all = float(np.max(np.abs(base - cp))) / scale
print("RESULT " + json.dumps({"rel_early": rel_early, "rel_all": rel_all}))
# prefill + first decode step: identical cache contents -> must match to
# fp noise. From step 2 on, the LSE-merge's different reduction order
# rounds k/v casts to the NEIGHBOURING bf16 ulp (measured delta exactly
# 2^-7), which the lossy codec then amplifies chaotically — only coarse
# trajectory agreement is meaningful there.
assert rel_early < 1e-3, rel_early
assert rel_all < 5e-2, rel_all
"""


@pytest.mark.slow
def test_context_parallel_decode_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd=".", timeout=900,
    )
    lines = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    assert lines, f"child failed:\n{r.stderr[-2000:]}"
    res = json.loads(lines[0][7:])
    assert res["rel_early"] < 1e-3 and res["rel_all"] < 5e-2, res
