"""Invariant 8: every Pallas kernel matches ref.py across shape/dtype sweeps
(interpret mode on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    calibrate_specs,
    prefill_cache,
)
from repro.data import synthetic_kv
from repro.kernels import ops
from repro.kernels.ref import (
    kpack_scores_ref,
    packed_decode_attention_ref,
    vpack_out_ref,
)


def _make_cache(rng, B, Hkv, D, L, n_tokens, k_rel=0.1, v_rel=0.2,
                calibrated=True):
    k = jnp.asarray(synthetic_kv(rng, B, Hkv, n_tokens, D))
    v = jnp.asarray(synthetic_kv(rng, B, Hkv, n_tokens, D))
    cfg = PackKVConfig(k_rel_scale=k_rel, v_rel_scale=v_rel)
    if calibrated:
        cfg = calibrate_specs(k, v, cfg)
    cache = alloc_layer_cache(cfg, batch=B, h_kv=Hkv, head_dim=D, capacity=L)
    return prefill_cache(cache, k, v), k, v


CASES = [
    # (B, Hkv, G, D, L, tile)
    (1, 1, 1, 32, 128, 32),
    (2, 2, 4, 64, 256, 128),
    (1, 3, 2, 128, 256, 64),
    (2, 1, 8, 64, 512, 256),
]


@pytest.mark.parametrize("B,Hkv,G,D,L,tile", CASES)
def test_kpack_scores_matches_ref(rng, B, Hkv, G, D, L, tile):
    cache, _, _ = _make_cache(rng, B, Hkv, D, L, L - 64)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    ref = ops.packed_qk_scores(q, cache.k, 0.125, backend="xla")
    got = ops.packed_qk_scores(q, cache.k, 0.125, backend="pallas", tile_l=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,Hkv,G,D,L,tile", CASES)
def test_vpack_out_matches_ref(rng, B, Hkv, G, D, L, tile):
    cache, _, _ = _make_cache(rng, B, Hkv, D, L, L - 64)
    w = jax.nn.softmax(
        jnp.asarray(rng.normal(size=(B, Hkv * G, L)).astype(np.float32)), axis=-1
    )
    ref = ops.packed_weighted_v(w, cache.v, backend="xla")
    got = ops.packed_weighted_v(w, cache.v, backend="pallas", tile_l=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,Hkv,G,D,L,tile", CASES)
def test_fused_attention_matches_ref(rng, B, Hkv, G, D, L, tile):
    cache, _, _ = _make_cache(rng, B, Hkv, D, L, L - 40)  # non-block-aligned
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    sm = 1.0 / np.sqrt(D)
    args = (q, cache.k, cache.v, cache.resid_k, cache.resid_v,
            cache.n_comp, cache.n_resid, sm)
    ref = ops.packed_decode_attention(*args, backend="xla")
    got = ops.packed_decode_attention(*args, backend="pallas", tile_l=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_fused_attention_empty_compressed_region(rng):
    """n_comp == 0: all mass on the residual buffer; no NaNs."""
    B, Hkv, G, D, L = 1, 2, 2, 64, 128
    cache, _, _ = _make_cache(rng, B, Hkv, D, L, 40)  # only residual
    assert int(cache.n_comp[0]) == 0
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    args = (q, cache.k, cache.v, cache.resid_k, cache.resid_v,
            cache.n_comp, cache.n_resid, 0.125)
    ref = ops.packed_decode_attention(*args, backend="xla")
    got = ops.packed_decode_attention(*args, backend="pallas", tile_l=32)
    assert not bool(jnp.isnan(got).any())
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_fused_attention_per_row_lengths(rng):
    """Slot-table shape: rows with DIFFERENT n_comp/n_resid — the pallas
    fused kernel masks each grid row to its own count and matches the
    per-row xla oracle."""
    from repro.core.cache import insert_prefill

    B, Hkv, G, D, L = 3, 2, 2, 64, 256
    k = jnp.asarray(synthetic_kv(rng, B, Hkv, 192, D))
    v = jnp.asarray(synthetic_kv(rng, B, Hkv, 192, D))
    cfg = calibrate_specs(k, v, PackKVConfig())
    cache = alloc_layer_cache(cfg, batch=B, h_kv=Hkv, head_dim=D, capacity=L)
    # row 0: 192 tokens, row 1: 72 tokens, row 2: left empty (dead slot)
    cache = insert_prefill(cache, 0, k[0], v[0])
    cache = insert_prefill(cache, 1, k[1, :, :72], v[1, :, :72])
    assert [int(x) for x in cache.n_comp] == [192, 64, 0]
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    args = (q, cache.k, cache.v, cache.resid_k, cache.resid_v,
            cache.n_comp, cache.n_resid, 0.125)
    ref = ops.packed_decode_attention(*args, backend="xla")
    got = ops.packed_decode_attention(*args, backend="pallas", tile_l=64)
    assert not bool(jnp.isnan(got).any())
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)
    # dead row contributes nothing
    np.testing.assert_array_equal(np.asarray(got[2]), 0.0)


def test_tier_matvec_per_row_n_valid(rng):
    """kpack/vpack kernels' in-kernel n_valid masking == masking outside."""
    B, Hkv, G, D, L = 2, 2, 2, 64, 256
    cache, _, _ = _make_cache(rng, B, Hkv, D, L, 192)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    n_valid = jnp.asarray([192, 64], jnp.int32)
    s = ops.packed_qk_scores(q, cache.k, 0.125, n_valid=n_valid,
                             backend="pallas", tile_l=64)
    s_ref = ops.packed_qk_scores(q, cache.k, 0.125, n_valid=n_valid,
                                 backend="xla")
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5,
                               atol=1e-4)
    # columns past each row's n_valid are zeroed
    assert np.abs(np.asarray(s[1, :, 64:])).max() == 0.0
    w = jax.nn.softmax(
        jnp.asarray(rng.normal(size=(B, Hkv * G, L)).astype(np.float32)), -1
    )
    o = ops.packed_weighted_v(w, cache.v, n_valid=n_valid, backend="pallas",
                              tile_l=64)
    o_ref = ops.packed_weighted_v(w, cache.v, n_valid=n_valid, backend="xla")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=1e-5,
                               atol=1e-4)


def test_uncalibrated_spec_still_matches_ref(rng):
    """Shift-packs active (default spec, gaussian data): pallas == xla even
    under lossy shifts."""
    r = np.random.default_rng(7)
    B, Hkv, G, D, L = 1, 2, 2, 64, 128
    k = jnp.asarray(r.normal(size=(B, Hkv, 128, D)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(B, Hkv, 128, D)).astype(np.float32))
    cfg = PackKVConfig()
    cache = alloc_layer_cache(cfg, batch=B, h_kv=Hkv, head_dim=D, capacity=L)
    cache = prefill_cache(cache, k, v)
    q = jnp.asarray(r.normal(size=(B, Hkv * G, D)).astype(np.float32))
    s_ref = ops.packed_qk_scores(q, cache.k, 1.0, backend="xla")
    s_got = ops.packed_qk_scores(q, cache.k, 1.0, backend="pallas", tile_l=64)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref), rtol=1e-5,
                               atol=1e-3)


def test_compressed_attention_error_bounded(rng):
    """End-to-end: compressed attention stays close to full precision on
    realistic (calibrated) KV data."""
    B, Hkv, G, D, L = 2, 2, 4, 128, 256
    cache, k, v = _make_cache(rng, B, Hkv, D, L, 192)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    sm = 1.0 / np.sqrt(D)
    got = ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, sm, backend="xla",
    )
    from repro.kernels.ref import dense_decode_attention_ref

    pad = jnp.zeros((B, Hkv, L - 192, D))
    ke = jnp.concatenate([k, pad], 2)
    ve = jnp.concatenate([v, pad], 2)
    exact = dense_decode_attention_ref(
        q, ke, ve, cache.resid_k * 0, cache.resid_v * 0,
        jnp.int32(192), jnp.int32(0), sm,
    )
    rel = float(jnp.max(jnp.abs(got - exact)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.25, rel


def test_pack16_fused_attention_matches_ref(rng):
    """Paper Fig 13's other optimum: pack_size=16 through the full stack."""
    from repro.core.tiered import TierSpec

    B, Hkv, G, D, L = 1, 2, 2, 64, 256
    spec = TierSpec(widths=(4, 8), counts=(48, 16), pack_size=16)
    cfg = PackKVConfig(pack_size=16, k_spec_static=spec, v_spec_static=spec)
    k = jnp.asarray(synthetic_kv(rng, B, Hkv, 192, D))
    v = jnp.asarray(synthetic_kv(rng, B, Hkv, 192, D))
    cache = prefill_cache(alloc_layer_cache(cfg, B, Hkv, D, L), k, v)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    args = (q, cache.k, cache.v, cache.resid_k, cache.resid_v,
            cache.n_comp, cache.n_resid, 0.125)
    ref = ops.packed_decode_attention(*args, backend="xla")
    got = ops.packed_decode_attention(*args, backend="pallas", tile_l=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)
