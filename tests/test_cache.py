"""Runtime cache lifecycle: prefill, decode appends, flush, ring mode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    append_token,
    calibrate_specs,
    prefill_cache,
)
from repro.data import synthetic_kv
from repro.kernels import ops
from repro.kernels.ref import dense_decode_attention_ref


def test_prefill_bookkeeping(rng):
    cfg = PackKVConfig()
    cache = alloc_layer_cache(cfg, 1, 2, 128, 256)
    k = jnp.asarray(synthetic_kv(rng, 1, 2, 130, 128))
    cache = prefill_cache(cache, k, k)
    assert int(cache.n_comp[0]) == 128 and int(cache.n_resid[0]) == 2


def test_append_until_flush(rng):
    cfg = PackKVConfig(residual=96)
    cache = alloc_layer_cache(cfg, 1, 1, 32, 256)
    k1 = jnp.asarray(synthetic_kv(rng, 1, 1, 64, 32))
    cache = prefill_cache(cache, k1, k1)
    assert int(cache.n_comp[0]) == 64 and int(cache.n_resid[0]) == 0
    step = jax.jit(append_token)
    for i in range(97):
        t = jnp.asarray(synthetic_kv(rng, 1, 1, 1, 32))
        cache = step(cache, t, t)
    # residual filled to 96 after the 96th append; the 97th flushes a block
    assert int(cache.n_comp[0]) == 128
    assert int(cache.n_resid[0]) == 96 - 64 + 1


@pytest.mark.slow
def test_decode_attention_after_appends_matches_dense(rng):
    """Rebuild the exact token set; compressed decode ≈ dense decode."""
    cfg = PackKVConfig(residual=96, k_rel_scale=0.02, v_rel_scale=0.02)
    B, H, D, cap = 1, 2, 64, 256
    n0, n_steps = 64, 40
    k0 = jnp.asarray(synthetic_kv(rng, B, H, n0, D))
    v0 = jnp.asarray(synthetic_kv(rng, B, H, n0, D))
    cfg = calibrate_specs(k0, v0, cfg, slack=1)
    cache = alloc_layer_cache(cfg, B, H, D, cap)
    cache = prefill_cache(cache, k0, v0)
    ks, vs = [k0], [v0]
    for i in range(n_steps):
        kt = jnp.asarray(synthetic_kv(rng, B, H, 1, D))
        vt = jnp.asarray(synthetic_kv(rng, B, H, 1, D))
        ks.append(kt)
        vs.append(vt)
        cache = append_token(cache, kt, vt)
    q = jnp.asarray(rng.normal(size=(B, H * 2, D)).astype(np.float32))
    sm = 1.0 / np.sqrt(D)
    got = ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, sm,
    )
    K = jnp.concatenate(ks, axis=2)
    V = jnp.concatenate(vs, axis=2)
    pad = jnp.zeros((B, H, cap - K.shape[2], D))
    want = dense_decode_attention_ref(
        q, jnp.concatenate([K, pad], 2), jnp.concatenate([V, pad], 2),
        cache.resid_k * 0, cache.resid_v * 0,
        jnp.int32(K.shape[2]), jnp.int32(0), sm,
    )
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel < 0.15, rel


def test_ring_append_overwrites_oldest(rng):
    cfg = PackKVConfig(residual=96, repack="none")
    W = 128  # window capacity (2 blocks)
    cache = alloc_layer_cache(cfg, 1, 1, 32, W)
    k0 = jnp.asarray(synthetic_kv(rng, 1, 1, W, 32))
    cache = prefill_cache(cache, k0, k0)
    assert int(cache.n_comp[0]) == W
    step = jax.jit(lambda c, k, v: append_token(c, k, v, ring=True))
    for i in range(97):  # trigger one ring flush (residual fills at 96)
        t = jnp.asarray(synthetic_kv(rng, 1, 1, 1, 32))
        cache = step(cache, t, t)
    assert int(cache.n_comp[0]) == W + 64  # grows; mask uses min(n_comp, W)
    # capacity unchanged — the flush wrapped around
    assert cache.k.capacity == W


def test_policy_none_matches_exact(rng):
    cfg = PackKVConfig(policy="none", residual=96)
    B, H, D, cap = 1, 1, 32, 128
    k = jnp.asarray(synthetic_kv(rng, B, H, 64, D))
    v = jnp.asarray(synthetic_kv(rng, B, H, 64, D))
    cache = alloc_layer_cache(cfg, B, H, D, cap)
    cache = prefill_cache(cache, k, v)
    q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
    got = ops.dense_decode_attention(
        q, cache.raw_k, cache.raw_v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, 0.25,
    )
    pad = jnp.zeros((B, H, cap - 64, D))
    want = dense_decode_attention_ref(
        q, jnp.concatenate([k, pad], 2).astype(jnp.bfloat16),
        jnp.concatenate([v, pad], 2).astype(jnp.bfloat16),
        cache.resid_k, cache.resid_v, jnp.int32(64), jnp.int32(0), 0.25,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3,
                               atol=1e-3)


def test_policy_registry():
    from repro.core.policy import available, get_policy

    assert {"none", "kivi", "packkv"} <= set(available())
    p = get_policy("packkv_tight")
    assert p.k_rel_scale == 0.02
    p2 = get_policy("packkv", residual=64)
    assert p2.residual == 64
    import pytest as _pt

    with _pt.raises(KeyError):
        get_policy("bogus")
