"""Recurrent families on the unified SlotServer (ISSUE 6).

rwkv6 and hybrid_rglru decode through O(1) recurrent state, not a
page-addressable KV cache — but they ride the SAME slot scheduler as the
transformers: per-slot state insert/reset ops, per-row positions, free
rows masked to zero after every ride-along decode.

  * Slot outputs are BIT-IDENTICAL to batch-size-1 ``Engine.generate``:
    admission prefills each prompt alone (B=1 chunks), so a short prompt
    sharing the table with a long one sees NO padding — the left-pad
    pollution the retired wave scheduler's batched prefill suffered from
    (pads run through the recurrence like real tokens) cannot occur.
  * Chunked admission composes the recurrence exactly: scheduler cuts are
    multiples of ``prefill_chunk_pages * page_size`` (16-aligned), where
    the chunked WKV / LRU scans are exact resume points.
  * --prefix-cache and --paged still fail loudly at engine build: there
    are no pages to share in a recurrent state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

FAMILIES = ["rwkv6-1.6b", "recurrentgemma-9b"]


@pytest.fixture(scope="module", params=FAMILIES)
def rec_engine(request):
    cfg = SMOKES[request.param]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    cap = cfg.window if cfg.window else 256
    return Engine(cfg, params, PackKVConfig(policy="none", residual=96),
                  EngineConfig(capacity=cap, max_batch=2, calibrate=False,
                               page_size=64)), cfg


def test_slot_server_matches_b1_generate(rec_engine, rng):
    """Mixed-length requests (several prefill chunks each, co-resident
    decodes, slot reuse) == per-request B=1 generate, bit for bit."""
    eng, cfg = rec_engine
    reqs = [
        Request(rid=0, max_new=6, tokens=rng.integers(0, cfg.vocab, 150)),
        Request(rid=1, max_new=9, tokens=rng.integers(0, cfg.vocab, 70)),
        Request(rid=2, max_new=4, tokens=rng.integers(0, cfg.vocab, 200)),
    ]
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    srv.run()
    assert srv.stats.slot_reuses >= 1
    assert srv.stats.prefill_chunks >= sum(
        -(-len(r.tokens) // eng.chunk_tokens()) for r in reqs)
    for r in reqs:
        want, _ = eng.generate(
            {"tokens": jnp.asarray(r.tokens[None], jnp.int32)}, r.max_new)
        np.testing.assert_array_equal(srv.done[r.rid].output, want[0],
                                      err_msg=f"rid {r.rid}")


def test_no_left_pad_pollution(rec_engine, rng):
    """Regression: a 10-token prompt admitted while a 190-token prompt
    decodes in the other slot. A batched left-padded prefill would push
    180 pad tokens through the short row's recurrence and corrupt it;
    per-slot B=1 admission must reproduce the solo run exactly."""
    eng, cfg = rec_engine
    short = rng.integers(0, cfg.vocab, 10)
    long = rng.integers(0, cfg.vocab, 190)
    srv = SlotServer(eng)
    srv.submit(Request(rid=0, max_new=12, tokens=long))
    srv.submit(Request(rid=1, max_new=12, tokens=short))
    srv.run()
    for rid, toks in ((0, long), (1, short)):
        want, _ = eng.generate(
            {"tokens": jnp.asarray(toks[None], jnp.int32)}, 12)
        np.testing.assert_array_equal(srv.done[rid].output, want[0],
                                      err_msg=f"rid {rid}")


def test_chunked_matches_monolithic(rec_engine, rng):
    """prefill_chunk_pages=1 (64-token cuts, 16-aligned WKV/LRU resume
    points) == the monolithic whole-prompt admission."""
    eng, cfg = rec_engine
    mono = Engine(cfg, eng.params, eng.pack_cfg,
                  dataclasses.replace(eng.ecfg, prefill_chunk_pages=0))
    mk = lambda: [Request(rid=i, max_new=5,
                          tokens=rng.integers(0, cfg.vocab, n))
                  for i, n in enumerate((130, 64, 33))]
    st = rng.bit_generator.state
    a = SlotServer(eng)
    for r in mk():
        a.submit(r)
    a.run()
    rng.bit_generator.state = st
    b = SlotServer(mono)
    for r in mk():
        b.submit(r)
    b.run()
    assert a.stats.prefill_chunks > 0 and b.stats.prefill_chunks == 0
    for rid in a.done:
        np.testing.assert_array_equal(a.done[rid].output, b.done[rid].output)


@pytest.mark.parametrize("name", FAMILIES)
def test_paged_and_prefix_cache_rejected(name):
    """No page-addressable KV -> both --paged and --prefix-cache fail at
    engine build, before params are touched."""
    cfg = SMOKES[name]
    with pytest.raises(ValueError, match="prefix-cache"):
        Engine(cfg, None, PackKVConfig(policy="none"),
               EngineConfig(capacity=256, paged=True, prefix_cache=True))
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, None, PackKVConfig(policy="none"),
               EngineConfig(capacity=256, paged=True))
