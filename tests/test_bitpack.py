"""Invariants 2 & 7: bit-packing is lossless; sizes match analytic model."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # degrade to skips, not collection errors
from hypothesis import given, settings, strategies as st

from repro.core.bitpack import (
    bits_required,
    compression_ratio,
    pack_block,
    packed_total_bits,
    unpack_block,
)


def test_roundtrip_exact(rng):
    q = rng.integers(0, 11, size=(64, 128))
    blk = pack_block(q, 8)
    assert (unpack_block(blk) == q).all()


@given(
    seed=st.integers(0, 2**16),
    pack=st.sampled_from([2, 4, 8, 16]),
    hi=st.integers(1, 255),
)
@settings(max_examples=30, deadline=None)
def test_roundtrip_property(seed, pack, hi):
    r = np.random.default_rng(seed)
    n = pack * r.integers(1, 6)
    q = r.integers(0, hi + 1, size=(n, 16))
    blk = pack_block(q, pack)
    assert (unpack_block(blk) == q).all()


def test_bits_required():
    assert (bits_required(np.array([0, 1, 2, 3, 4, 7, 8, 255]))
            == np.array([0, 1, 2, 2, 3, 3, 4, 8])).all()


def test_payload_matches_analytic(rng):
    q = rng.integers(0, 11, size=(64, 32))
    blk = pack_block(q, 8)
    # stored payload words cover exactly payload_bits (invariant 7)
    assert blk.payload_bits <= len(blk.payload) * 32 < blk.payload_bits + 32 + 32
    assert blk.total_bits() == packed_total_bits(
        q, 8, axis=0, n_token_meta=0
    )


def test_constant_block_compresses_maximally(rng):
    q = np.full((64, 32), 7)
    blk = pack_block(q, 8)
    assert blk.payload_bits == 0  # width-0 packs: only metadata remains
    assert (unpack_block(blk) == q).all()


def test_cr_improves_with_low_entropy(rng):
    lo = rng.integers(0, 2, size=(64, 32))  # 1-bit data
    hi = rng.integers(0, 256, size=(64, 32))  # 8-bit data
    assert compression_ratio(lo, 8) > compression_ratio(hi, 8) * 2
