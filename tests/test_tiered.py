"""Compute-tier format: invariants 5, 6, 7 (append equiv, shift-bounded
error, no silent padding) + calibration guarantees."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # degrade to skips, not collection errors
from hypothesis import given, settings, strategies as st

from repro.core.tiered import (
    TierSpec,
    alloc_tiered,
    append_block,
    assign_channel_tiers,
    chan_inverse_perm,
    choose_tier_spec,
    dequantize_tiered,
    pack_tier,
    pack_tiered,
    pack_words,
    required_channel_widths,
    unpack_tier,
    unpack_words,
)


@given(width=st.sampled_from([1, 2, 4, 8, 16]), seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_pack_words_roundtrip(width, seed):
    r = np.random.default_rng(seed)
    L = (32 // width) * r.integers(1, 5)
    vals = jnp.asarray(r.integers(0, 2**width, size=(3, L)), jnp.int32)
    w = pack_words(vals, width)
    out = unpack_words(w, width, L)
    assert (np.asarray(out) == np.asarray(vals)).all()


def test_tier_roundtrip_exact_when_width_sufficient(rng):
    q = jnp.asarray(rng.integers(0, 11, size=(2, 8, 64)), jnp.int32)  # 4 bits
    buf = pack_tier(q, width=4)
    out = unpack_tier(buf, 64)
    assert (np.asarray(out) == np.asarray(q)).all()


def test_tier_shift_bounded_error(rng):
    """Invariant 6: error <= 2^shift with shift <= 3 (mid-rise halves it).

    Data needing 7 bits in a 4-bit tier -> shift 3 drops the low 3 bits;
    mid-rise reconstruction bounds |err| by 2^(shift-1) = 4. (Data beyond
    width+MAX_SHIFT bits saturates instead — calibration with slack<=3
    guarantees that case never occurs; see choose_tier_spec.)"""
    q = jnp.asarray(rng.integers(0, 128, size=(2, 8, 64)), jnp.int32)  # 7 bits
    buf = pack_tier(q, width=4)
    out = unpack_tier(buf, 64)
    err = np.abs(np.asarray(out) - np.asarray(q))
    assert err.max() <= 2 ** 2  # 2^(shift-1)


def test_choose_tier_spec_no_shift_on_calibration_data(rng):
    q = jnp.asarray(rng.integers(0, 11, size=(4, 128, 64)), jnp.int32)
    w = required_channel_widths(q)
    spec = choose_tier_spec(w)
    assert spec.head_dim == 128
    perm = assign_channel_tiers(w, spec)
    qp = jnp.take_along_axis(q, perm[..., None], axis=-2)
    # per-tier widths must cover assigned channels' needs
    off = 0
    for width, count in zip(spec.widths, spec.counts):
        wt = required_channel_widths(qp[:, off : off + count, :])
        assert int(wt.max()) <= width
        off += count


def test_pack_tiered_dequant_roundtrip(rng):
    B, H, D, L = 1, 2, 64, 128
    q = jnp.asarray(rng.integers(0, 11, size=(B, H, D, L)), jnp.int32)
    w = required_channel_widths(q)
    spec = choose_tier_spec(w)
    perm = assign_channel_tiers(w, spec)
    scale = jnp.ones((B, H, L)) * 0.5
    zero = jnp.zeros((B, H, L)) - 1.0
    tc = pack_tiered(q, perm, scale, zero, spec)
    deq = dequantize_tiered(tc)
    want = np.asarray(q, np.float32) * 0.5 - 1.0
    np.testing.assert_allclose(np.asarray(deq), want, atol=1e-6)


def test_append_block_equals_concat(rng):
    """Invariant 5: decode(append(A,B)) == concat(decode(A), decode(B))."""
    B, H, D, Lb = 1, 1, 32, 64
    spec = TierSpec(widths=(4,), counts=(32,))
    cache = alloc_tiered(B, H, 2 * Lb, spec)
    perm = cache.chan_perm
    qs = []
    for i in range(2):
        q = jnp.asarray(rng.integers(0, 11, size=(B, H, D, Lb)), jnp.int32)
        qs.append(q)
        blk = pack_tiered(q, perm, jnp.ones((B, H, Lb)), jnp.zeros((B, H, Lb)), spec)
        cache = append_block(cache, blk, jnp.int32(i * Lb))
    out = dequantize_tiered(cache)
    want = np.concatenate([np.asarray(q, np.float32) for q in qs], axis=-1)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-6)


def test_no_silent_padding(rng):
    """Invariant 7: buffer sizes match the analytic layout exactly."""
    spec = TierSpec(widths=(2, 4, 8), counts=(32, 64, 32))
    cache = alloc_tiered(2, 4, 256, spec)
    for t, (w, c) in zip(cache.tiers, zip(spec.widths, spec.counts)):
        assert t.payload.shape == (2, 4, c, 256 * w // 32)
        assert t.mins.shape == (2, 4, c, 256 // 8)
        assert t.shifts.shape == (2, 4, c, 256 // 8 // 4)


def test_chan_inverse_perm(rng):
    perm = jnp.asarray(np.stack([rng.permutation(16) for _ in range(3)]))
    inv = chan_inverse_perm(perm)
    eye = jnp.take_along_axis(perm, inv, axis=-1)
    assert (np.asarray(eye) == np.arange(16)).all()


def test_tier_spec_validation():
    with pytest.raises(AssertionError):
        TierSpec(widths=(3,), counts=(8,))  # 3 doesn't divide 32
    with pytest.raises(AssertionError):
        TierSpec(widths=(4, 2), counts=(8, 8))  # not ascending
