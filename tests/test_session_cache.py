"""Multi-turn session cache (ISSUE 9): park/resume exactness against the
REAL engine.

The core claim: a returning session served from a parked entry is
bit-identical to the SAME conversation decoded without interruption,
across {xla, pallas} x {packkv, none} x {dense, paged, prefix} — with
ZERO forward passes over the restored context. The argument mirrors
preemption exactness (placement independence: parked bytes are the row's
exact compressed pages + residual + counters + calibration) plus
teacher-forced suffix ingestion: the new turn's unseen tokens stream
through ordinary decode launches whose argmax is overridden by the
already-known next prompt token, so the cache the suffix builds is the
one an uninterrupted decode would have built.

The control is a manual drive on a session-off engine of the same
calibrated config: prefill turn 1, greedy-decode it, teacher-force the
extension, greedy-decode turn 2. NOTE the control must prefill through
the SAME path as the server (``insert_request_prefix`` when the prefix
cache is on): the prefix and plain prefill paths calibrate channel
permutations differently, which is cross-path behavior under test
elsewhere, not a park/resume property.

Also here: the disk spill tier (LRU victims survive a host-capacity
squeeze byte-exactly via the savable-dtype mini serializers), TTL expiry
degrading to a cold admission, a 3-resume conversation chain, parked
shared-prefix pages, and the loud rejections (sliding-window attention,
recurrent families).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig, SessionStore
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

PAGE = 128


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, policy, backend, mode, **kw):
    paged = mode != "dense"
    return Engine(
        cfg, params, PackKVConfig(policy=policy),
        EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                     decode_chunk=4, bucketed=True, bucket_unit=64,
                     backend=backend, paged=paged, page_size=PAGE,
                     prefix_cache=(mode == "prefix"),
                     debug_invariants=paged, prefill_chunk_pages=1,
                     session_cache=True, **kw))


def _control_chain(src: Engine, prompt, turns):
    """Uninterrupted manual drive of a whole conversation on a session-off
    engine of the same calibrated config: ``turns`` is ``[(ext, max_new),
    ...]`` with ``ext is None`` for turn 1. Returns one output list per
    turn."""
    base = Engine(src.cfg, src.params, src.pack_cfg,
                  dataclasses.replace(src.ecfg, max_batch=1,
                                      session_cache=False, preempt=False,
                                      calibrate=False, spec_decode=False))
    cache = base.alloc_slot_cache()
    if base.ecfg.prefix_cache:
        logits, cache = base.insert_request_prefix(cache, 0, prompt, [], None)
    else:
        logits, cache = base.insert_request(cache, 0, prompt)
    t = int(jnp.argmax(logits))
    outs = []
    for ext, max_new in turns:
        if ext is not None:
            # teacher-force the extension: the previous turn's last token
            # seeds the first launch, the extension's last token seeds the
            # new turn's first real argmax
            for f in [outs[-1][-1]] + [int(x) for x in ext[:-1]]:
                _, cache = base.decode(cache, jnp.asarray([[f]]), None)
            lg, cache = base.decode(cache, jnp.asarray([[int(ext[-1])]]),
                                    None)
            t = int(jnp.argmax(lg, -1)[0])
        out = [t]
        for _ in range(max_new - 1):
            lg, cache = base.decode(cache, jnp.asarray([[t]]), None)
            t = int(jnp.argmax(lg, -1)[0])
            out.append(t)
        outs.append(out)
    return outs


MODES = ("dense", "paged", "prefix")
MATRIX = [(p, b, m) for p in ("packkv", "none") for b in ("xla", "pallas")
          for m in MODES]


@pytest.mark.parametrize("policy,backend,mode", MATRIX)
def test_session_hit_bit_identical(smoke_setup, policy, backend, mode):
    cfg, params = smoke_setup
    eng = _engine(cfg, params, policy, backend, mode)
    srv = SlotServer(eng)
    r = np.random.default_rng(5)
    prompt = r.integers(0, cfg.vocab, 200)
    srv.submit(Request(rid=0, max_new=8, tokens=prompt))
    srv.run()
    assert srv.stats.session_parks == 1, "retirement never parked"
    out1 = list(srv.done[0].output)

    ext = r.integers(0, cfg.vocab, 5)
    chunks_before = srv.stats.prefill_chunks
    srv.submit(Request(rid=1, max_new=6, tokens=np.concatenate(
        [prompt, np.asarray(out1), ext])))
    srv.run()
    assert srv.stats.session_hits == 1, "returning session missed"
    # zero forward passes over the restored context: the hit admits via
    # one restore scatter, never a prefill chunk
    assert srv.stats.prefill_chunks == chunks_before
    if mode != "dense":
        assert srv.stats.session_restored_pages > 0
    out2 = list(srv.done[1].output)

    c1, c2 = _control_chain(eng, prompt, [(None, 8), (ext, 6)])
    assert out1 == c1, f"turn 1 diverged: {out1} != {c1}"
    assert out2 == c2, f"session hit diverged: {out2} != {c2}"


def test_session_three_resume_chain(smoke_setup):
    """A 4-turn conversation resumes 3 times, each turn bit-identical to
    the uninterrupted chain (the re-park after each turn snapshots the
    grown trace, so every resume extends the previous one)."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "packkv", "xla", "paged")
    srv = SlotServer(eng)
    r = np.random.default_rng(7)
    prompt = r.integers(0, cfg.vocab, 150)
    plan = [(None, 6), (r.integers(0, cfg.vocab, 4), 4),
            (r.integers(0, cfg.vocab, 1), 5), (r.integers(0, cfg.vocab, 3), 4)]
    outs = []
    toks = prompt
    for rid, (ext, max_new) in enumerate(plan):
        if ext is not None:
            toks = np.concatenate([toks, np.asarray(outs[-1]), ext])
        srv.submit(Request(rid=rid, max_new=max_new, tokens=toks))
        srv.run()
        outs.append(list(srv.done[rid].output))
    assert srv.stats.session_parks == 4 and srv.stats.session_hits == 3
    assert srv.stats.session_hit_rate == 0.75
    ctl = _control_chain(eng, prompt, plan)
    for k, (got, want) in enumerate(zip(outs, ctl)):
        assert got == want, f"turn {k} diverged: {got} != {want}"


def test_session_disk_tier_roundtrip(smoke_setup, tmp_path):
    """A 1-byte host tier forces the park straight to disk through the
    savable-dtype mini serializers; the returning session promotes it back
    and is still bit-identical — the spill is byte-exact."""
    cfg, params = smoke_setup
    store = SessionStore(capacity_bytes=1, disk_dir=str(tmp_path))
    eng = _engine(cfg, params, "packkv", "xla", "paged")
    srv = SlotServer(eng, session_store=store)
    r = np.random.default_rng(9)
    prompt = r.integers(0, cfg.vocab, 180)
    srv.submit(Request(rid=0, max_new=8, tokens=prompt))
    srv.run()
    assert store.spills == 1 and len(store._host) == 0
    assert len(store._disk) == 1, "park never spilled to disk"
    out1 = list(srv.done[0].output)
    ext = r.integers(0, cfg.vocab, 4)
    srv.submit(Request(rid=1, max_new=6, tokens=np.concatenate(
        [prompt, np.asarray(out1), ext])))
    srv.run()
    assert store.loads == 1, "hit never promoted from disk"
    assert srv.stats.session_hits == 1
    out2 = list(srv.done[1].output)
    c1, c2 = _control_chain(eng, prompt, [(None, 8), (ext, 6)])
    assert (out1, out2) == (c1, c2)


def test_session_ttl_expiry_degrades_to_cold(smoke_setup):
    """An expired park is a MISS, never a crash: the returning session
    re-prefills cold and (losslessly, policy=none) still matches the
    uninterrupted chain."""
    cfg, params = smoke_setup
    now = [0.0]
    store = SessionStore(ttl_s=10.0, clock=lambda: now[0])
    eng = _engine(cfg, params, "none", "xla", "dense")
    srv = SlotServer(eng, session_store=store)
    r = np.random.default_rng(3)
    prompt = r.integers(0, cfg.vocab, 150)
    srv.submit(Request(rid=0, max_new=6, tokens=prompt))
    srv.run()
    assert len(store) == 1
    out1 = list(srv.done[0].output)
    now[0] = 11.0  # the park is now stale
    ext = r.integers(0, cfg.vocab, 4)
    srv.submit(Request(rid=1, max_new=5, tokens=np.concatenate(
        [prompt, np.asarray(out1), ext])))
    srv.run()
    assert store.expired == 1 and srv.stats.session_hits == 0
    assert srv.stats.session_evictions == 1
    assert srv.done[1].status == "done"
    c1, c2 = _control_chain(eng, prompt, [(None, 6), (ext, 5)])
    assert list(srv.done[0].output) == c1
    assert list(srv.done[1].output) == c2  # lossless: cold == chain


def test_session_shared_prefix_park(smoke_setup):
    """A parked session whose prefix pages live in the trie re-maps them
    by REFERENCE on return: the parked meta pins ``n_shared`` pages, the
    restore streams back only the owned ones, and the resumed output is
    exact."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "packkv", "xla", "prefix")
    srv = SlotServer(eng)
    r = np.random.default_rng(11)
    sys_p = r.integers(0, cfg.vocab, 2 * PAGE)
    a = np.concatenate([sys_p, r.integers(0, cfg.vocab, 40)])
    b = np.concatenate([sys_p, r.integers(0, cfg.vocab, 53)])
    srv.submit(Request(rid=0, max_new=6, tokens=a))
    srv.run()
    srv.submit(Request(rid=1, max_new=6, tokens=b))  # B shares A's prefix
    srv.run()
    assert srv.stats.session_parks == 2
    assert srv.stats.prefix_hits == 1, "B never shared A's prefix pages"
    out_b = list(srv.done[1].output)
    trace_b = np.concatenate([b, np.asarray(out_b)])
    key = srv._sessions.match(trace_b)
    assert key is not None
    meta = srv._sessions.meta(key)
    assert meta["n_shared"] >= 2, "parked meta lost the shared-prefix pin"
    ext = r.integers(0, cfg.vocab, 4)
    restored_before = srv.stats.session_restored_pages
    srv.submit(Request(rid=2, max_new=5,
                       tokens=np.concatenate([trace_b, ext])))
    srv.run()
    assert srv.stats.session_hits == 1
    # only the OWNED pages streamed back; the shared ones re-mapped free
    assert (srv.stats.session_restored_pages - restored_before
            == meta["n_pages"] - meta["n_shared"])
    assert srv.done[2].status == "done" and len(srv.done[2].output) == 5


_SHARDED_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

cfg = SMOKES["llama2-7b"]
params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
PAGE = 128


def build(mesh_shape, session):
    return Engine(cfg, params, PackKVConfig(policy="packkv"),
                  EngineConfig(capacity=512, max_batch=1, calib_tokens=128,
                               bucket_unit=64, paged=True, page_size=PAGE,
                               session_cache=session, mesh_shape=mesh_shape))


r = np.random.default_rng(5)
prompt = r.integers(0, cfg.vocab, 200)
ext = r.integers(0, cfg.vocab, 5)

# the park/hit drive on the kv-sharded engine
srv = SlotServer(build((1, 2), session=True))
srv.submit(Request(rid=0, max_new=8, tokens=prompt))
srv.run()
out1 = list(map(int, srv.done[0].output))
srv.submit(Request(rid=1, max_new=6, tokens=np.concatenate(
    [prompt, np.asarray(out1), ext])))
srv.run()
hits = srv.stats.session_hits
out2 = list(map(int, srv.done[1].output))

# the cold control: same mesh, session cache OFF, manual uninterrupted
# drive of the whole conversation (parked bytes vs recompute must agree)
base = build((1, 2), session=False)
cache = base.alloc_slot_cache()
logits, cache = base.insert_request(cache, 0, prompt)
t = int(jnp.argmax(logits))
c1 = [t]
for _ in range(7):
    lg, cache = base.decode(cache, jnp.asarray([[t]]), None)
    t = int(jnp.argmax(lg, -1)[0])
    c1.append(t)
for f in [c1[-1]] + [int(x) for x in ext[:-1]]:
    _, cache = base.decode(cache, jnp.asarray([[f]]), None)
lg, cache = base.decode(cache, jnp.asarray([[int(ext[-1])]]), None)
t = int(jnp.argmax(lg, -1)[0])
c2 = [t]
for _ in range(5):
    lg, cache = base.decode(cache, jnp.asarray([[t]]), None)
    t = int(jnp.argmax(lg, -1)[0])
    c2.append(t)
print("RESULT " + json.dumps({"hits": hits, "out1": out1, "out2": out2,
                              "c1": c1, "c2": c2}))
"""


@pytest.mark.slow
def test_session_hit_matches_cold_on_mesh():
    """ISSUE 10: park/resume on a kv-sharded mesh. The parked mini gathers
    shard-local payloads into the same dense full-head format as
    single-device parks, and the restore re-shards through the lane
    in_specs — so a session HIT on the mesh must equal the uninterrupted
    cold drive on the same mesh, bit for bit."""
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_CHILD], capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd=".", timeout=900,
    )
    lines = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    assert lines, f"child failed:\n{r.stderr[-2000:]}"
    res = json.loads(lines[0][7:])
    assert res["hits"] == 1, "returning session missed on the mesh"
    assert res["out1"] == res["c1"], "turn 1 diverged on the mesh"
    assert res["out2"] == res["c2"], "sharded session hit != cold drive"


def test_session_rejects_sliding_window(smoke_setup):
    _, params = smoke_setup
    cfg = SMOKES["recurrentgemma-9b"]  # window=128
    with pytest.raises(ValueError, match="sliding-window"):
        _engine(cfg, params, "none", "xla", "dense")


def test_session_rejects_recurrent_family(smoke_setup):
    _, params = smoke_setup
    cfg = SMOKES["rwkv6-1.6b"]  # pure recurrent: no evacuate/restore ops
    with pytest.raises(ValueError, match="session-cache"):
        _engine(cfg, params, "none", "xla", "dense")
