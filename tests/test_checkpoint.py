"""Invariant 9 + fault tolerance: atomic checkpoints, corruption safety,
async writer, restore-with-shardings."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, gc_old, latest_step, restore, save
from repro.training.optimizer import OptState


def _tree(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
        "b16": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16),
        "opt": OptState(
            mu={"w": jnp.zeros((8, 16))}, nu={"w": jnp.ones((8, 16))},
            step=jnp.int32(7),
        ),
    }


def test_roundtrip(tmp_path, rng):
    t = _tree(rng)
    save(str(tmp_path), 5, t, {"stream": {"step": 5, "seed": 0}})
    assert latest_step(str(tmp_path)) == 5
    got, extra = restore(str(tmp_path), 5, t)
    assert extra["stream"]["step"] == 5
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype  # bf16 preserved


def test_uncommitted_checkpoint_invisible(tmp_path, rng):
    t = _tree(rng)
    save(str(tmp_path), 5, t)
    # simulate a preempted save: directory without COMMIT
    d = os.path.join(str(tmp_path), "step_00000009")
    os.makedirs(d)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        f.write("{}")
    assert latest_step(str(tmp_path)) == 5  # ignores the torn write


def test_structure_mismatch_rejected(tmp_path, rng):
    t = _tree(rng)
    save(str(tmp_path), 1, t)
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"different": t["w"]})


def test_gc_keeps_latest(tmp_path, rng):
    t = _tree(rng)
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, t)
    gc_old(str(tmp_path), keep=2)
    assert latest_step(str(tmp_path)) == 4
    assert not os.path.exists(os.path.join(str(tmp_path), "step_00000001"))


def test_async_checkpointer(tmp_path, rng):
    t = _tree(rng)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.submit(s, t, {"s": s})
    ck.close()
    assert latest_step(str(tmp_path)) == 30
    got, extra = restore(str(tmp_path), 30, t)
    assert extra["s"] == 30


def test_restore_with_shardings(tmp_path, rng):
    """Elastic restore: device_put onto explicit (single-device) shardings."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(None, None))
    got, _ = restore(str(tmp_path), 1, t, shardings={"w": sh})
    assert got["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
