"""Property-based scheduler test (ISSUE 6): the REAL ``SlotServer`` driven
over a stub engine so thousands of admission/decode/retire schedules run in
milliseconds, checked against a pure-Python oracle.

Invariants (asserted after EVERY scheduler step, for random traffic across
paged/dense × chunked/monolithic configurations):

  * FIFO admission — requests enter slots in exactly submit order, even
    when page-count admission blocks the head.
  * Reservation conservation — reservations never exceed the admissible
    pool (``pool - watermark``), every claimed slot holds a reservation,
    and a row never pops more pages than its reservation promised.
  * Refcount conservation — the stub pool's free count plus every live
    row's held pages equals the pool size at all times, and the free list
    never over-pops (the scheduler's reservations are the only thing
    standing between the in-graph free-list and underflow).
  * Bounded stall — while any slot is occupied, every scheduler step runs
    EXACTLY one decode launch and at most one bounded prefill chunk: no
    decoding request ever waits for a whole prompt. A speculative verify
    launch counts as the step's one decode launch.
  * Speculation (ISSUE 7) — per verify launch, accepted <= drafted; every
    request still finishes with EXACTLY ``max_new`` tokens (multi-token
    emission never overshoots or double-counts), and every emitted token
    equals the stub's greedy pick for its slot.

The deterministic seeded sweep always runs; the hypothesis variant widens
the search when hypothesis is installed (CI: requirements-dev.txt).
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serving import EngineConfig, Request, SlotServer
from repro.utils import cdiv

BLOCK, VOCAB = 64, 97


class _StubEngine:
    """Host-only engine exposing exactly the surface SlotServer touches.

    The ``cache`` is a dict: per-row held page counts + prompt token
    counts + a scalar free-page counter standing in for the device
    free-list. Page pops mirror the real engine's schedule: the
    block-aligned prompt pops at insert, decode pops one page whenever a
    row's block-aligned token count crosses a page multiple (capped at
    capacity). Every call is logged for the oracle.
    """

    def __init__(self, ecfg, pool_pages):
        self.cfg = SimpleNamespace(input_mode="tokens", family="dense")
        self.ecfg = ecfg
        self.pack_cfg = SimpleNamespace(
            pool_pages=pool_pages, block=BLOCK, residual=96, policy="none",
            page_size=ecfg.page_size)
        self._decode_multi = None
        self.log = []  # ("insert", rid) | ("chunk", rid) | ("decode",)

    # -- pool bookkeeping ---------------------------------------------------
    def _pages_for(self, n_tokens):
        lb = min(self.ecfg.capacity, (n_tokens // BLOCK) * BLOCK)
        return cdiv(lb, self.ecfg.page_size) if self.ecfg.paged else 0

    def _pop(self, cache, slot, n):
        if n:
            assert cache["free"] >= n, \
                f"free-list underflow: slot {slot} pops {n} of {cache['free']}"
            cache["free"] -= n
            cache["rows"][slot] += n

    def alloc_slot_cache(self):
        return {"free": self.pack_cfg.pool_pages,
                "rows": [0] * self.ecfg.max_batch,
                "toks": [0] * self.ecfg.max_batch}

    def free_slot(self, cache, slot):
        cache["free"] += cache["rows"][slot]
        cache["rows"][slot] = 0
        cache["toks"][slot] = 0
        return cache

    def mask_free(self, cache, active):
        return cache

    def bucket_for(self, n_max):
        return None

    # -- admission ----------------------------------------------------------
    def _insert_row(self, cache, slot, n_tokens, rid):
        self._pop(cache, slot, self._pages_for(n_tokens))
        cache["toks"][slot] = n_tokens
        self.log.append(("insert", rid))

    def insert_request(self, cache, slot, tokens):
        self._insert_row(cache, slot, len(tokens), int(tokens[0]))
        return np.zeros((1, VOCAB), np.float32), cache

    def chunk_tokens(self):
        return self.ecfg.prefill_chunk_pages * self.ecfg.page_size

    def chunk_init(self, prompt_len):
        return {"len": prompt_len, "seen": 0}

    def chunk_step(self, scratch, tokens, n_ctx):
        assert n_ctx == scratch["seen"], "chunks resumed out of order"
        scratch["seen"] += len(tokens)
        self.log.append(("chunk", int(tokens[0]) if n_ctx == 0 else None))
        return np.zeros((1, VOCAB), np.float32), scratch

    def chunk_insert(self, cache, slot, scratch):
        assert scratch["seen"] == scratch["len"], "insert before last chunk"
        self._insert_row(cache, slot, scratch["len"], None)
        return cache

    def chunk_final(self, cache, slot, scratch, tokens, n_ctx):
        # fused last chunk: one dispatch = chunk_step + chunk_insert
        logits, scratch = self.chunk_step(scratch, tokens, n_ctx)
        cache = self.chunk_insert(cache, slot, scratch)
        return logits, cache

    # -- decode -------------------------------------------------------------
    def decode(self, cache, tok, n_bucket=None):
        self.log.append(("decode", None))
        for i in range(self.ecfg.max_batch):
            if cache["toks"][i]:
                before = self._pages_for(cache["toks"][i])
                cache["toks"][i] += 1
                self._pop(cache, i, self._pages_for(cache["toks"][i]) - before)
        # greedy argmax of row i picks (i + 1) % VOCAB
        logits = np.zeros((self.ecfg.max_batch, VOCAB), np.float32)
        for i in range(self.ecfg.max_batch):
            logits[i, (i + 1) % VOCAB] = 1.0
        return logits, cache

    def decode_verify(self, cache, tokens, lens, active, n_bucket=None):
        """Stub verify launch: the greedy pick of row i is the constant
        (i + 1) % VOCAB at every window position, so a draft is accepted
        iff it proposes exactly that — the same acceptance rule as
        ``models.transformer.verify_steps``. Committing seed + accepted
        advances the row's token count (and page pops) all at once."""
        self.log.append(("decode", None))
        B = self.ecfg.max_batch
        hat = np.zeros((B, tokens.shape[1]), np.int32)
        n_accept = np.zeros((B,), np.int32)
        for i in range(B):
            if not active[i] or not cache["toks"][i]:
                continue
            c = (i + 1) % VOCAB
            hat[i, :] = c
            m = 0
            for j in range(int(lens[i]) - 1):
                if int(tokens[i, 1 + j]) != c:
                    break
                m += 1
            n_accept[i] = m
            self.log.append(("verify", int(lens[i]) - 1, m))
            before = self._pages_for(cache["toks"][i])
            cache["toks"][i] += 1 + m
            self._pop(cache, i, self._pages_for(cache["toks"][i]) - before)
        return hat, n_accept, cache


def _drive(rng, *, paged, chunk_pages, spec=False):
    """Run random traffic through SlotServer + stub; assert invariants
    after every step against the pure-Python oracle. Returns the number of
    verify launches (speculation cases assert the path was exercised)."""
    page = int(rng.choice([64, 128]))
    n_slots = int(rng.integers(1, 5))
    capacity = page * int(rng.integers(2, 5))
    pool = (n_slots * capacity // page if not rng.integers(0, 2)
            else max(2, int(rng.integers(2, n_slots * capacity // page + 1))))
    ecfg = EngineConfig(capacity=capacity, max_batch=n_slots, paged=paged,
                        page_size=page, pool_pages=pool, calibrate=False,
                        prefill_chunk_pages=chunk_pages, decode_chunk=1,
                        spec_decode=spec, spec_k=int(rng.integers(1, 5)),
                        spec_backoff=int(rng.choice([0, 1, 32])))
    eng = _StubEngine(ecfg, pool)
    srv = SlotServer(eng)

    n_req = int(rng.integers(1, 12))
    reqs = []
    for rid in range(n_req):
        plen = int(rng.integers(1, capacity))
        max_new = int(rng.integers(1, capacity + 96 - plen + 1))
        if paged and cdiv(min(capacity, plen + max_new), page) > pool:
            max_new = 1  # keep it admissible; rejection has its own test
            plen = min(plen, (pool * page) - 1)
        # first prompt token carries the rid so the stub can log FIFO order
        toks = np.full((plen,), rid, np.int64)
        reqs.append(Request(rid=rid, max_new=max_new, tokens=toks))

    while reqs or srv.queue or srv.n_occupied or srv._task is not None:
        # interleave submits with steps at random
        while reqs and rng.integers(0, 2):
            srv.submit(reqs.pop(0))
        if not (srv.queue or srv.n_occupied or srv._task is not None):
            srv.submit(reqs.pop(0))  # idle server: force progress
        occ_before = srv.n_occupied
        decodes, chunks = (sum(e[0] == "decode" for e in eng.log),
                           sum(e[0] == "chunk" for e in eng.log))
        srv.step()
        d_dec = sum(e[0] == "decode" for e in eng.log) - decodes
        d_chk = sum(e[0] == "chunk" for e in eng.log) - chunks
        # bounded stall: an occupied table always decodes, and waits for
        # at most one bounded chunk first (monolithic mode may admit a
        # whole prompt per slot, which is exactly the stall being fixed)
        if occ_before:
            assert d_dec == 1, "occupied step skipped decode"
            if chunk_pages:
                assert d_chk <= 1, "decode stalled behind >1 prefill chunk"
        # reservation conservation
        if paged:
            assert sum(srv._reserved.values()) <= pool - ecfg.page_watermark
            for slot, held in enumerate(srv.cache["rows"] if srv.cache
                                        else []):
                if held:
                    assert slot in srv._reserved, \
                        f"slot {slot} holds pages with no reservation"
                    assert held <= srv._reserved[slot], \
                        f"slot {slot} popped {held} > reserved"
        # refcount conservation: free + held == pool, never negative
        if srv.cache is not None:
            assert srv.cache["free"] + sum(srv.cache["rows"]) == pool
            assert srv.cache["free"] >= 0

    # every submitted request completed with exactly max_new tokens —
    # multi-token speculative emission must not overshoot or double-count —
    # and every token is the slot's constant greedy pick
    assert len(srv.done) == n_req
    for rid in range(n_req):
        out = srv.done[rid].output
        assert len(out) == srv.done[rid].max_new
        # token 0 is the prefill argmax (zero logits); every decoded token
        # is the slot's constant greedy pick
        assert len(set(out[1:])) <= 1, f"rid {rid} mixed tokens: {out}"
    # speculation oracle: accepted <= drafted per verify launch, and the
    # stats roll-up matches the launch log
    verifies = [e for e in eng.log if e[0] == "verify"]
    for _, drafted, accepted in verifies:
        assert 0 <= accepted <= drafted
    assert srv.stats.spec_drafted == sum(e[1] for e in verifies)
    assert srv.stats.spec_accepted == sum(e[2] for e in verifies)
    if not spec:
        assert not verifies and srv.stats.spec_launches == 0
    # FIFO: rows were inserted in submit order. Chunked tasks log their
    # rid on the FIRST chunk (n_ctx == 0); monolithic inserts log theirs.
    order = [e[1] for e in eng.log
             if e[0] in ("insert", "chunk") and e[1] is not None]
    assert order == sorted(order), f"admission violated FIFO: {order}"
    assert order == list(range(n_req))
    return len(verifies)


CASES = [(False, 0, False), (False, 1, False), (True, 0, False),
         (True, 1, False), (True, 2, False),
         (False, 1, True), (True, 1, True), (True, 2, True)]


@pytest.mark.parametrize("paged,chunk_pages,spec", CASES)
def test_scheduler_invariants_seeded(paged, chunk_pages, spec):
    """Deterministic sweep — runs everywhere, no hypothesis needed."""
    n_verify = 0
    for seed in range(25):
        n_verify += _drive(np.random.default_rng(seed), paged=paged,
                           chunk_pages=chunk_pages, spec=spec)
    if spec:  # the sweep must actually hit the verify path
        assert n_verify > 0


def test_scheduler_invariants_hypothesis():
    """Adversarial widening of the same property when hypothesis is
    available (CI installs requirements-dev.txt)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=120, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(seed=st.integers(0, 2**31 - 1), paged=st.booleans(),
               chunk_pages=st.integers(0, 3), spec=st.booleans())
    def prop(seed, paged, chunk_pages, spec):
        _drive(np.random.default_rng(seed), paged=paged,
               chunk_pages=chunk_pages, spec=spec)

    prop()
