"""Property-based scheduler test (ISSUE 6): the REAL ``SlotServer`` driven
over a stub engine so thousands of admission/decode/retire schedules run in
milliseconds, checked against a pure-Python oracle.

Invariants (asserted after EVERY scheduler step, for random traffic across
paged/dense × chunked/monolithic configurations):

  * FIFO admission — requests enter slots in exactly submit order, even
    when page-count admission blocks the head.
  * Reservation conservation — reservations never exceed the admissible
    pool (``pool - watermark``), every claimed slot holds a reservation,
    and a row never pops more pages than its reservation promised.
  * Refcount conservation — the stub pool's free count plus every live
    row's held pages equals the pool size at all times, and the free list
    never over-pops (the scheduler's reservations are the only thing
    standing between the in-graph free-list and underflow).
  * Bounded stall — while any slot is occupied, every scheduler step runs
    EXACTLY one decode launch and at most one bounded prefill chunk: no
    decoding request ever waits for a whole prompt. A speculative verify
    launch counts as the step's one decode launch.
  * Speculation (ISSUE 7) — per verify launch, accepted <= drafted; every
    request still finishes with EXACTLY ``max_new`` tokens (multi-token
    emission never overshoots or double-counts), and every emitted token
    equals the stub's greedy pick for its slot.
  * Priority + preemption (ISSUE 8) — admission is per-class FIFO (each
    class's admissions happen in submit order even across preemptions and
    chunk aborts); page conservation holds through swap-out/swap-in (an
    evacuated row's pages return to the pool, a restored row re-pops within
    its reservation); the SwapStore drains by the time the queue does; and
    ``preemptions``/``cancelled``/``expired`` stats match the event log and
    terminal statuses exactly.
  * Fault storms (deterministic ``FaultPlan`` schedules) — pool squeezes,
    cancel/deadline storms, chunk-boundary aborts and straggler bursts all
    act through the same seams real traffic does, and every invariant
    above must survive them after EVERY step.
  * Session cache (ISSUE 9) — every retirement-park shows up in the
    evacuation log (``evacuations == preemptions + session_parks``);
    parked entries hold host BYTES, never pool pages, so page
    conservation is unchanged; fault-fabricated returning sessions
    (``resume`` events) admit as hits (restore, no insert) or fall back
    cold without disturbing per-class FIFO of first admissions; expiry
    racing a resume degrades to a cold admission, never a crash or leak.
  * Replicated ledger (ISSUE 10) — on a ``(dp, kv)`` serving mesh the
    page ledger is REPLICATED: the stub applies every ledger op to one
    independent replica per mesh device and asserts the replicas stay
    identical, so the scheduler can never feed an op device-dependent
    state; the same seeded traffic at (1,1), (1,2) and (2,2) must
    produce identical event logs and stats.

The deterministic seeded sweep always runs; the hypothesis variant widens
the search when hypothesis is installed (CI: requirements-dev.txt;
``HYPOTHESIS_MAX_EXAMPLES`` raises the example count on the nightly lane).
"""
import copy
import os
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.cache import SessionStore
from repro.distributed.fault import FaultEvent, FaultPlan, StragglerMonitor
from repro.serving import EngineConfig, Request, SlotServer
from repro.utils import cdiv

BLOCK, VOCAB = 64, 97


class _StubEngine:
    """Host-only engine exposing exactly the surface SlotServer touches.

    The ``cache`` is a dict: per-row held page counts + prompt token
    counts + a scalar free-page counter standing in for the device
    free-list. Page pops mirror the real engine's schedule: the
    block-aligned prompt pops at insert, decode pops one page whenever a
    row's block-aligned token count crosses a page multiple (capped at
    capacity). Every call is logged for the oracle.
    """

    def __init__(self, ecfg, pool_pages):
        self.cfg = SimpleNamespace(input_mode="tokens", family="dense")
        self.ecfg = ecfg
        self.pack_cfg = SimpleNamespace(
            pool_pages=pool_pages, block=BLOCK, residual=96, policy="none",
            page_size=ecfg.page_size)
        self._decode_multi = None
        self.log = []  # ("insert", rid) | ("chunk", rid) | ("decode",)

    # -- pool bookkeeping ---------------------------------------------------
    def _pages_for(self, n_tokens):
        lb = min(self.ecfg.capacity, (n_tokens // BLOCK) * BLOCK)
        return cdiv(lb, self.ecfg.page_size) if self.ecfg.paged else 0

    def _pop(self, cache, slot, n):
        if n:
            assert cache["free"] >= n, \
                f"free-list underflow: slot {slot} pops {n} of {cache['free']}"
            cache["free"] -= n
            cache["rows"][slot] += n

    def alloc_slot_cache(self):
        return {"free": self.pack_cfg.pool_pages,
                "rows": [0] * self.ecfg.max_batch,
                "toks": [0] * self.ecfg.max_batch}

    def free_slot(self, cache, slot):
        cache["free"] += cache["rows"][slot]
        cache["rows"][slot] = 0
        cache["toks"][slot] = 0
        return cache

    def mask_free(self, cache, active):
        return cache

    def bucket_for(self, n_max):
        return None

    # -- admission ----------------------------------------------------------
    def _insert_row(self, cache, slot, n_tokens, rid):
        self._pop(cache, slot, self._pages_for(n_tokens))
        cache["toks"][slot] = n_tokens
        self.log.append(("insert", rid))

    def insert_request(self, cache, slot, tokens):
        self._insert_row(cache, slot, len(tokens), int(tokens[0]))
        return np.zeros((1, VOCAB), np.float32), cache

    def chunk_tokens(self):
        return self.ecfg.prefill_chunk_pages * self.ecfg.page_size

    def chunk_init(self, prompt_len):
        return {"len": prompt_len, "seen": 0}

    def chunk_step(self, scratch, tokens, n_ctx):
        assert n_ctx == scratch["seen"], "chunks resumed out of order"
        scratch["seen"] += len(tokens)
        self.log.append(("chunk", int(tokens[0]) if n_ctx == 0 else None))
        return np.zeros((1, VOCAB), np.float32), scratch

    def chunk_insert(self, cache, slot, scratch):
        assert scratch["seen"] == scratch["len"], "insert before last chunk"
        self._insert_row(cache, slot, scratch["len"], None)
        return cache

    def chunk_final(self, cache, slot, scratch, tokens, n_ctx):
        # fused last chunk: one dispatch = chunk_step + chunk_insert
        logits, scratch = self.chunk_step(scratch, tokens, n_ctx)
        cache = self.chunk_insert(cache, slot, scratch)
        return logits, cache

    # -- decode -------------------------------------------------------------
    def decode(self, cache, tok, n_bucket=None):
        self.log.append(("decode", None))
        for i in range(self.ecfg.max_batch):
            if cache["toks"][i]:
                before = self._pages_for(cache["toks"][i])
                cache["toks"][i] += 1
                self._pop(cache, i, self._pages_for(cache["toks"][i]) - before)
        # greedy argmax of row i picks (i + 1) % VOCAB
        logits = np.zeros((self.ecfg.max_batch, VOCAB), np.float32)
        for i in range(self.ecfg.max_batch):
            logits[i, (i + 1) % VOCAB] = 1.0
        return logits, cache

    def decode_verify(self, cache, tokens, lens, active, n_bucket=None):
        """Stub verify launch: the greedy pick of row i is the constant
        (i + 1) % VOCAB at every window position, so a draft is accepted
        iff it proposes exactly that — the same acceptance rule as
        ``models.transformer.verify_steps``. Committing seed + accepted
        advances the row's token count (and page pops) all at once."""
        self.log.append(("decode", None))
        B = self.ecfg.max_batch
        hat = np.zeros((B, tokens.shape[1]), np.int32)
        n_accept = np.zeros((B,), np.int32)
        for i in range(B):
            if not active[i] or not cache["toks"][i]:
                continue
            c = (i + 1) % VOCAB
            hat[i, :] = c
            m = 0
            for j in range(int(lens[i]) - 1):
                if int(tokens[i, 1 + j]) != c:
                    break
                m += 1
            n_accept[i] = m
            self.log.append(("verify", int(lens[i]) - 1, m))
            before = self._pages_for(cache["toks"][i])
            cache["toks"][i] += 1 + m
            self._pop(cache, i, self._pages_for(cache["toks"][i]) - before)
        return hat, n_accept, cache

    # -- preemption (ISSUE 8) ------------------------------------------------
    def evacuate(self, cache, slot, n_pages, n_shared=0):
        """Swap-out: the row's pages go back to the free list, its token
        count rides out in the mini. The scheduler's ``n_pages`` hint is
        residual-aware (the REAL engine's flush model); the stub keeps its
        own simpler block-aligned model, so it ignores the hint — both
        models stay internally consistent and both are reservation-bounded."""
        mini = {"toks": cache["toks"][slot]}
        cache["free"] += cache["rows"][slot]
        cache["rows"][slot] = 0
        cache["toks"][slot] = 0
        self.log.append(("evacuate", slot))
        return cache, mini

    def restore(self, cache, slot, mini, shared_phys=(), n_pages=0,
                n_shared=0):
        self._pop(cache, slot, self._pages_for(mini["toks"]))
        cache["toks"][slot] = mini["toks"]
        self.log.append(("restore", slot))
        return cache


def _tree_eq(a, b):
    if isinstance(a, np.ndarray):
        return isinstance(b, np.ndarray) and np.array_equal(a, b)
    if isinstance(a, dict):
        return a.keys() == b.keys() and all(_tree_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(map(_tree_eq, a, b))
    return a == b


class _MeshStubEngine(_StubEngine):
    """The stub on a ``(dp, kv)`` mesh: the REAL sharded engine keeps its
    page ledger REPLICATED across every device (only pool payloads shard,
    by KV head), so every ledger transition must be a pure function of
    scheduler-visible state. Enforced by replay: each op runs once per
    mesh device on an independent deep copy of its inputs and the replica
    results must be identical — any device-dependent input the scheduler
    smuggled in would diverge them."""

    _REPLAYED = ("insert_request", "free_slot", "mask_free", "chunk_step",
                 "chunk_insert", "chunk_final", "decode", "decode_verify",
                 "evacuate", "restore")

    def __init__(self, ecfg, pool_pages, mesh_shape=(1, 1)):
        super().__init__(ecfg, pool_pages)
        self.n_dev = mesh_shape[0] * mesh_shape[1]

    def __getattribute__(self, name):
        if name in _MeshStubEngine._REPLAYED:
            base = getattr(_StubEngine, name)

            def replayed(*args, **kw):
                mark = len(self.log)
                first = base(self, *copy.deepcopy(args),
                             **copy.deepcopy(kw))
                for _ in range(self.n_dev - 1):
                    del self.log[mark:]  # replicas log once, not n_dev times
                    rep = base(self, *copy.deepcopy(args), **copy.deepcopy(kw))
                    assert _tree_eq(first, rep), \
                        f"ledger replica diverged in {name}"
                return first

            return replayed
        return super().__getattribute__(name)


def _drive(rng, *, paged, chunk_pages, spec=False, prio=False, preempt=False,
           session=False, fault_factory=None, straggler=None,
           mesh_shape=(1, 1), log_sink=None):
    """Run random traffic through SlotServer + stub; assert invariants
    after every step against the pure-Python oracle. Returns the run's
    ``SlotStats`` so sweeps can assert a path was actually exercised.

    ``prio`` draws per-request priority classes 0-2 (aging on);
    ``preempt`` turns on swap-out preemption; ``session`` turns on the
    voluntary session cache (every natural retirement parks);
    ``fault_factory`` builds a fresh deterministic ``FaultPlan`` per run;
    ``straggler`` builds a decode-launch watchdog to inject."""
    page = int(rng.choice([64, 128]))
    n_slots = int(rng.integers(1, 5))
    capacity = page * int(rng.integers(2, 5))
    pool = (n_slots * capacity // page if not rng.integers(0, 2)
            else max(2, int(rng.integers(2, n_slots * capacity // page + 1))))
    ecfg = EngineConfig(capacity=capacity, max_batch=n_slots, paged=paged,
                        page_size=page, pool_pages=pool, calibrate=False,
                        prefill_chunk_pages=chunk_pages, decode_chunk=1,
                        spec_decode=spec, spec_k=int(rng.integers(1, 5)),
                        spec_backoff=int(rng.choice([0, 1, 32])),
                        preempt=preempt, session_cache=session,
                        aging_steps=8 if prio else 32,
                        mesh_shape=mesh_shape)
    eng = _MeshStubEngine(ecfg, pool, mesh_shape)
    plan = fault_factory() if fault_factory is not None else None
    srv = SlotServer(eng, fault_plan=plan,
                     straggler=straggler() if straggler is not None else None)
    faulty = plan is not None

    n_req = int(rng.integers(1, 12))
    reqs = []
    prio_of = {}
    for rid in range(n_req):
        plen = int(rng.integers(1, capacity))
        max_new = int(rng.integers(1, capacity + 96 - plen + 1))
        if paged and cdiv(min(capacity, plen + max_new), page) > pool:
            max_new = 1  # keep it admissible; rejection has its own test
            plen = min(plen, (pool * page) - 1)
        # first prompt token carries the rid so the stub can log FIFO order
        toks = np.full((plen,), rid, np.int64)
        prio_of[rid] = int(rng.integers(0, 3)) if prio else 0
        reqs.append(Request(rid=rid, max_new=max_new, tokens=toks,
                            priority=prio_of[rid]))

    while reqs or srv.queue or srv.n_occupied or srv._task is not None:
        # interleave submits with steps at random
        while reqs and rng.integers(0, 2):
            srv.submit(reqs.pop(0))
        if not (srv.queue or srv.n_occupied or srv._task is not None):
            srv.submit(reqs.pop(0))  # idle server: force progress
        occ_before = srv.n_occupied
        decodes, chunks = (sum(e[0] == "decode" for e in eng.log),
                           sum(e[0] == "chunk" for e in eng.log))
        srv.step()
        d_dec = sum(e[0] == "decode" for e in eng.log) - decodes
        d_chk = sum(e[0] == "chunk" for e in eng.log) - chunks
        # bounded stall: an occupied table always decodes, and waits for
        # at most one bounded chunk first (monolithic mode may admit a
        # whole prompt per slot, which is exactly the stall being fixed).
        # A reap can empty the table mid-step, so gate on occupancy at the
        # decode point when requests can die.
        if occ_before and (srv.n_occupied or not (faulty or preempt)):
            assert d_dec == 1, "occupied step skipped decode"
            if chunk_pages:
                assert d_chk <= 1, "decode stalled behind >1 prefill chunk"
        # reservation conservation
        if paged:
            assert sum(srv._reserved.values()) <= pool - ecfg.page_watermark
            for slot, held in enumerate(srv.cache["rows"] if srv.cache
                                        else []):
                if held:
                    assert slot in srv._reserved, \
                        f"slot {slot} holds pages with no reservation"
                    assert held <= srv._reserved[slot], \
                        f"slot {slot} popped {held} > reserved"
        # page conservation: free + held == pool, never negative — evacuated
        # rows' pages are back in the pool, restores re-pop within their
        # reservation, so this holds THROUGH preemption and fault storms
        if srv.cache is not None:
            assert srv.cache["free"] + sum(srv.cache["rows"]) == pool
            assert srv.cache["free"] >= 0

    # every submitted request reached a terminal status; completed ones hold
    # EXACTLY max_new tokens (multi-token speculative emission never
    # overshoots or double-counts), dead ones at most their partial output
    # (fault-fabricated returning sessions add done entries past n_req)
    assert len(srv.done) >= n_req if session else len(srv.done) == n_req
    statuses = {}
    for rid in range(n_req):
        req = srv.done[rid]
        statuses[rid] = req.status
        out = req.output
        if req.status == "done":
            assert len(out) == req.max_new
        else:
            assert req.status in ("cancelled", "expired", "parked")
            assert len(out) <= req.max_new
        # token 0 is the prefill argmax (zero logits); every decoded token
        # is the slot's constant greedy pick. A preempted request may
        # resume in a DIFFERENT slot, so its constant may change once per
        # preemption but never more often. (A session HIT emits only slot
        # constants — no prefill argmax — so the bound still holds.)
        assert len(set(out[1:])) <= 1 + req.n_preempts, \
            f"rid {rid} mixed tokens: {out}"
    all_done = list(srv.done.values())
    n_parked = sum(r.status == "parked" for r in all_done)
    assert srv.stats.completed == sum(r.status == "done" for r in all_done)
    assert srv.stats.cancelled == sum(
        r.status == "cancelled" for r in all_done)
    assert srv.stats.expired == sum(r.status == "expired" for r in all_done)
    assert srv.stats.completed + srv.stats.cancelled + srv.stats.expired \
        + n_parked == len(all_done)
    if not session:
        assert n_parked == 0
    # preemption + session oracle: every evacuation in the stub's log is a
    # swap-out or a retirement park, every swapped row either streamed
    # back or died with its request (SwapStore drains; parked entries may
    # legitimately outlive the run — they hold host bytes, not pages)
    evacs = sum(e[0] == "evacuate" for e in eng.log)
    restores = sum(e[0] == "restore" for e in eng.log)
    assert srv.stats.preemptions + srv.stats.session_parks == evacs
    assert restores <= evacs
    if srv._swap is not None:
        assert len(srv._swap) == 0, "SwapStore leaked evacuated rows"
    if srv._sessions is not None:
        # store counters are self-consistent: everything parked was served
        # back, evicted/expired, or still resident
        st = srv._sessions
        assert st.parks == st.hits + st.evictions + st.expired + len(st)
        assert srv.stats.session_parks == st.parks
        assert srv.stats.session_hits == st.hits
    if not (preempt or session):
        assert evacs == 0
    # the pool is whole again once everything retired
    if srv.cache is not None:
        assert srv.cache["free"] == pool
    # speculation oracle: accepted <= drafted per verify launch, and the
    # stats roll-up matches the launch log
    verifies = [e for e in eng.log if e[0] == "verify"]
    for _, drafted, accepted in verifies:
        assert 0 <= accepted <= drafted
    assert srv.stats.spec_drafted == sum(e[1] for e in verifies)
    assert srv.stats.spec_accepted == sum(e[2] for e in verifies)
    if not spec:
        assert not verifies and srv.stats.spec_launches == 0
    # PER-CLASS FIFO: each class's rows were inserted in submit order, even
    # across preemptions and chunk aborts (requeues keep original submit
    # order within the class). Chunked tasks log their rid on the FIRST
    # chunk (n_ctx == 0); monolithic inserts log theirs; restores re-enter
    # without a fresh insert, so re-admissions never reorder the log.
    order = [e[1] for e in eng.log
             if e[0] in ("insert", "chunk") and e[1] is not None]
    if session:
        # a fault-fabricated resume that MISSES re-prefills cold and logs
        # the original rid again (the stub keys the log on tokens[0], and
        # a fabricated session's trace starts with the original prompt).
        # First admissions must still be per-class FIFO; re-walks may
        # interleave anywhere.
        seen: set = set()
        order = [rid for rid in order
                 if not (rid in seen or seen.add(rid))]
    for c in set(prio_of.values()):
        sub = [rid for rid in order if prio_of[rid] == c]
        assert sub == sorted(sub), \
            f"class {c} admission violated FIFO: {order}"
    if not (prio or faulty):
        assert order == sorted(order), f"admission violated FIFO: {order}"
        assert order == list(range(n_req))
    if log_sink is not None:
        log_sink.extend(eng.log)
    return srv.stats


CASES = [(False, 0, False), (False, 1, False), (True, 0, False),
         (True, 1, False), (True, 2, False),
         (False, 1, True), (True, 1, True), (True, 2, True)]


@pytest.mark.parametrize("paged,chunk_pages,spec", CASES)
def test_scheduler_invariants_seeded(paged, chunk_pages, spec):
    """Deterministic sweep — runs everywhere, no hypothesis needed."""
    n_verify = 0
    for seed in range(25):
        n_verify += _drive(np.random.default_rng(seed), paged=paged,
                           chunk_pages=chunk_pages, spec=spec).spec_launches
    if spec:  # the sweep must actually hit the verify path
        assert n_verify > 0


PREEMPT_CASES = [(False, 0), (False, 1), (True, 0), (True, 1), (True, 2)]


@pytest.mark.parametrize("paged,chunk_pages", PREEMPT_CASES)
def test_scheduler_priority_preempt_seeded(paged, chunk_pages):
    """Priority classes + swap-out preemption under random traffic:
    per-class FIFO admission, page conservation through evacuate/restore,
    SwapStore drainage and stats/log agreement (all inside ``_drive``)."""
    preempts = 0
    for seed in range(25):
        preempts += _drive(np.random.default_rng(seed), paged=paged,
                           chunk_pages=chunk_pages, prio=True,
                           preempt=True).preemptions
    assert preempts > 0, "sweep never exercised the swap-out path"


MESH_SHAPES = ((1, 1), (1, 2), (2, 2))


@pytest.mark.parametrize("paged,chunk_pages", [(True, 1), (True, 0),
                                               (False, 1)])
def test_scheduler_ledger_device_count_independent(paged, chunk_pages):
    """ISSUE 10: the scheduler's ledger decisions may not depend on the
    mesh shape. Same seeded traffic (with speculation, priorities,
    preemption and session parks all on) at (1,1), (1,2) and (2,2):
    identical per-op replica ledgers (asserted inside the stub), identical
    event logs, identical stats roll-ups."""
    fields = ("completed", "cancelled", "expired", "decode_steps",
              "prefill_chunks", "admitted", "preemptions", "session_parks",
              "session_hits", "spec_drafted", "spec_accepted",
              "pages_reserved_peak", "admission_blocks")
    for seed in range(8):
        runs = []
        for ms in MESH_SHAPES:
            log = []
            stats = _drive(np.random.default_rng(seed), paged=paged,
                           chunk_pages=chunk_pages, spec=True, prio=True,
                           preempt=True, session=True, mesh_shape=ms,
                           log_sink=log)
            runs.append((log, {f: getattr(stats, f) for f in fields}))
        for ms, (log, st) in zip(MESH_SHAPES[1:], runs[1:]):
            assert log == runs[0][0], \
                f"seed {seed}: event log at mesh {ms} != (1,1)"
            assert st == runs[0][1], \
                f"seed {seed}: stats at mesh {ms} != (1,1): {st}"


def _squeeze_plan():
    # squeeze the whole pool for a few steps, then release: admission must
    # block (not underflow) and resume afterwards
    return FaultPlan([FaultEvent(step=2, kind="pool_squeeze", arg=10**6),
                      FaultEvent(step=9, kind="pool_squeeze", arg=0)])


FAULT_CASES = [
    ("cancel_storm",
     lambda: FaultPlan.storm("cancel", start=3, count=4, every=2)),
    ("deadline_storm",
     lambda: FaultPlan.storm("deadline", start=4, count=3, every=3, arg=2)),
    ("pool_squeeze", _squeeze_plan),
    ("chunk_abort",
     lambda: FaultPlan.storm("chunk_abort", start=2, count=5, every=2)),
    ("mixed",
     lambda: FaultPlan.storm("cancel", start=3, count=3, every=4)
     + _squeeze_plan()
     + FaultPlan.storm("chunk_abort", start=5, count=3, every=3)),
]


@pytest.mark.parametrize("name,factory", FAULT_CASES,
                         ids=[c[0] for c in FAULT_CASES])
def test_scheduler_fault_storms(name, factory):
    """Deterministic fault schedules against the full priority+preemption
    scheduler: every conservation invariant must hold after every step of
    every storm, and every request must still reach a terminal status."""
    died = 0
    for seed in range(15):
        for paged, chunk_pages in ((True, 1), (True, 2), (False, 1),
                                   (True, 0)):
            stats = _drive(np.random.default_rng(seed), paged=paged,
                           chunk_pages=chunk_pages, prio=True, preempt=True,
                           fault_factory=factory)
            died += stats.cancelled + stats.expired
    if name in ("cancel_storm", "deadline_storm", "mixed"):
        assert died > 0, "storm never killed a request"


SESSION_CASES = [
    # voluntary mid-flight parks: rows retire as "parked", their bytes move
    # host-side, and the pool is whole after every step
    ("park_storm",
     lambda: FaultPlan.storm("park", start=2, count=5, every=2)),
    # parked sessions come back: fabricated returning requests must admit
    # as session hits (restore, no insert) or fall back to a cold prefill
    ("park_resume",
     lambda: FaultPlan.storm("park", start=2, count=4, every=3)
     + FaultPlan.storm("resume", start=4, count=4, every=3)),
    # a returning session under a squeezed pool must block, not underflow,
    # and stream back once the squeeze lifts
    ("resume_pressure",
     lambda: FaultPlan.storm("park", start=2, count=3, every=2)
     + FaultPlan.storm("resume", start=5, count=3, every=2)
     + FaultPlan([FaultEvent(step=6, kind="pool_squeeze", arg=10**6),
                  FaultEvent(step=12, kind="pool_squeeze", arg=0)])),
    # expiry racing a resume: the store may expire an entry the very step a
    # returning session arrives — it must degrade to a cold admission
    ("expiry_race",
     lambda: FaultPlan.storm("park", start=2, count=4, every=2)
     + FaultPlan.storm("session_expire", start=5, count=4, every=2)
     + FaultPlan.storm("resume", start=5, count=4, every=2)),
]


@pytest.mark.parametrize("name,factory", SESSION_CASES,
                         ids=[c[0] for c in SESSION_CASES])
def test_scheduler_session_storms(name, factory):
    """Deterministic park/resume/expire schedules against the session-cache
    scheduler: free+held == pool after every step, evacuations reconcile
    with parks+preemptions, and the session store's own counters balance
    (parks == hits + evictions + expired + resident) — across paged/dense,
    chunked/monolithic prefill, and preemption on/off."""
    parks = hits = 0
    for seed in range(15):
        for paged, chunk_pages, preempt in ((True, 1, True), (True, 2, False),
                                            (False, 1, True), (True, 0, False)):
            stats = _drive(np.random.default_rng(seed), paged=paged,
                           chunk_pages=chunk_pages, prio=True,
                           preempt=preempt, session=True,
                           fault_factory=factory)
            parks += stats.session_parks
            hits += stats.session_hits
    assert parks > 0, "storm never parked a session"
    if name == "park_resume":
        assert hits > 0, "resume storm never produced a session hit"


def test_straggler_watchdog_degrades_spec():
    """A straggler burst on the decode-launch watchdog auto-disables
    speculative decode — graceful degradation: outputs stay exact, and the
    mode switch is surfaced in ``SlotStats.degraded_steps``."""
    ecfg = EngineConfig(capacity=256, max_batch=2, paged=True, page_size=64,
                        pool_pages=8, calibrate=False, prefill_chunk_pages=1,
                        decode_chunk=1, spec_decode=True, spec_k=2)
    eng = _StubEngine(ecfg, 8)
    plan = FaultPlan.storm("straggler", start=8, count=3, every=1, arg=1e3)
    srv = SlotServer(eng, fault_plan=plan,
                     straggler=StragglerMonitor(patience=1))
    for rid in range(3):
        srv.submit(Request(rid=rid, max_new=40,
                           tokens=np.full((65,), rid, np.int64)))
    srv.run()
    assert srv._spec_degraded, "watchdog never excluded the straggler"
    assert srv.stats.degraded_steps > 0
    assert len(srv.done) == 3
    for r in srv.done.values():
        assert r.status == "done" and len(r.output) == r.max_new


def _flood(aging: int) -> Request:
    """One-slot server, endless class-0 flood, one class-2 request."""
    ecfg = EngineConfig(capacity=128, max_batch=1, paged=False,
                        calibrate=False, prefill_chunk_pages=0,
                        decode_chunk=1, aging_steps=aging)
    eng = _StubEngine(ecfg, 4)
    srv = SlotServer(eng)
    srv.submit(Request(rid=0, max_new=2, tokens=np.full((3,), 0, np.int64)))
    low = Request(rid=999, max_new=2, tokens=np.full((3,), 96, np.int64),
                  priority=2)
    srv.submit(low)
    for rid in range(1, 41):
        # keep the class-0 queue non-empty: one fresh flood request a step
        srv.submit(Request(rid=rid, max_new=2,
                           tokens=np.full((3,), rid % 97, np.int64)))
        srv.step()
    return low


def test_priority_aging_no_starvation():
    """Aging promotes a waiting class-2 head one class per ``aging_steps``
    steps; once promoted to class 0 its earlier submit order beats every
    later flood arrival — delayed, never starved."""
    assert _flood(aging=2).status == "done"


def test_strict_priority_starves_without_aging():
    """The control: ``aging_steps = 0`` is strict priority, and the same
    flood starves the class-2 request indefinitely."""
    assert _flood(aging=0).status == "queued"


def test_scheduler_invariants_hypothesis():
    """Adversarial widening of the same property when hypothesis is
    available (CI installs requirements-dev.txt)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(
        max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "120")),
        deadline=None, suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(seed=st.integers(0, 2**31 - 1), paged=st.booleans(),
               chunk_pages=st.integers(0, 3), spec=st.booleans(),
               prio=st.booleans(), preempt=st.booleans(),
               session=st.booleans())
    def prop(seed, paged, chunk_pages, spec, prio, preempt, session):
        _drive(np.random.default_rng(seed), paged=paged,
               chunk_pages=chunk_pages, spec=spec, prio=prio,
               preempt=preempt, session=session)

    prop()
