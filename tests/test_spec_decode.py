"""Speculative verify window (ISSUE 7): bit-identity vs stepwise decode.

``verify_steps`` runs ONE batched forward over a q_len=w draft window and
must reproduce, per row and per valid position, exactly the argmax the
stepwise ``decode_step`` loop produces when fed the same tokens — and the
COMMITTED cache (seed + accepted prefix) must be BYTE-identical to the
stepwise cache state, because accepted drafts' K/V bytes feed every later
launch. This byte check is the regression guard for the batched-attention
pitfall: vmapping the per-position attention over the window axis changes
the floating-point reduction order at ULP level, which corrupts deeper
layers' cached K/V for accepted drafts and flips a LATER launch's argmax
(outputs match for dozens of tokens, then diverge) — so the window
attention stays unrolled over the exact per-token kernels (see
``models.transformer.verify_steps``).

End-to-end: speculative serving produces bit-identical outputs to the
non-speculative engine across {xla, pallas} × {packkv, none} ×
{dense, paged, paged+prefix-cache}, under full, partial and zero
acceptance.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

B, CAP, R = 3, 256, 96
PLENS = (191, 131, 156)  # post-prefill residuals 63 / 3 / 28
N_WARM = 33  # pushes row 0 to n_resid == R: the verify SEED append flushes
PAGE = 128


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# model-level: verify window vs stepwise, ragged lens, flush-adjacent row
# ---------------------------------------------------------------------------


def _warm(cfg, params, api, pack, step, rng):
    """Ragged slot cache advanced N_WARM greedy steps; returns
    (cache, last-token [B])."""
    cache = api.alloc_cache(cfg, pack, B, CAP)
    last = np.zeros((B,), np.int32)
    for i, plen in enumerate(PLENS):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, plen)), jnp.int32)
        lg, cache = api.prefill_into_slot(
            params, cfg, pack, CAP, cache, i, {"tokens": toks})
        last[i] = int(np.argmax(np.asarray(lg[0])))
    for _ in range(N_WARM):
        lg, cache = step(params, cache=cache, token=jnp.asarray(last[:, None]))
        last = np.argmax(np.asarray(lg), axis=-1).astype(np.int32)
    return cache, last


def _assert_row_equal(got, want, i):
    """Row ``i`` of two stacked caches byte-equal over all LIVE state:
    counters, compressed region (drafts never touch it), and the residual
    buffer up to ``n_resid`` (rejected drafts die as dead bytes past it —
    the stepwise reference never wrote those offsets, so they are excluded
    rather than zeroed)."""
    np.testing.assert_array_equal(got.n_comp[:, i], want.n_comp[:, i])
    np.testing.assert_array_equal(got.n_resid[:, i], want.n_resid[:, i])
    for name in ("k", "v", "raw_k", "raw_v"):
        a, b = getattr(got, name), getattr(want, name)
        if a is not None:
            jax.tree.map(lambda x, y: np.testing.assert_array_equal(
                x[:, i], y[:, i], err_msg=name), a, b)
    r = int(got.n_resid[0, i])
    np.testing.assert_array_equal(got.resid_k[:, i, :, :r],
                                  want.resid_k[:, i, :, :r])
    np.testing.assert_array_equal(got.resid_v[:, i, :, :r],
                                  want.resid_v[:, i, :, :r])


@pytest.mark.parametrize("policy", ["packkv", "none"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("w", [5, 2])
def test_verify_window_matches_stepwise(rng, smoke_setup, policy, backend, w):
    """Ragged window (full / k=1-or-none / partial acceptance per row, row 0
    flushing at the seed): hat and the committed cache match the stepwise
    decode_step loop fed the same tokens, bit for bit."""
    cfg, params = smoke_setup
    api = get_model(cfg)
    pack = PackKVConfig(policy=policy, residual=R)
    step = jax.jit(partial(api.decode_step, cfg=cfg, backend=backend))
    verify = jax.jit(partial(api.decode_verify, cfg=cfg, backend=backend),
                     static_argnames=("n_bucket",))
    cache, seed = _warm(cfg, params, api, pack, step, rng)

    # greedy chain from the warm state: chain[j] = argmax after j+1 steps
    c, t, chain = cache, seed, []
    for _ in range(w):
        lg, c = step(params, cache=c, token=jnp.asarray(t[:, None]))
        t = np.argmax(np.asarray(lg), axis=-1).astype(np.int32)
        chain.append(t)
    wrong = (np.stack(chain, 1) + 1) % cfg.vocab  # never the greedy pick

    # row 0: every draft correct; row 1: first draft wrong (k=1 when w=2);
    # row 2: one correct then wrong (w=2: seed-only, the k=0 ride-along)
    toks = np.zeros((B, w), np.int32)
    toks[:, 0] = seed
    for j in range(w - 1):
        toks[0, 1 + j] = chain[j][0]
        toks[1, 1 + j] = wrong[1, j]
        toks[2, 1 + j] = chain[j][2] if j == 0 else wrong[2, j]
    lens = np.array([w, 2, min(4, w) if w > 2 else 1], np.int32)
    want_accept = np.array([w - 1, 0, 1 if w > 2 else 0], np.int32)

    # stepwise reference fed the SAME window tokens, snapshotting each step
    ref_hat, snaps, c = np.zeros((B, w), np.int32), [], cache
    for j in range(w):
        lg, c = step(params, cache=c, token=jnp.asarray(toks[:, j:j + 1]))
        ref_hat[:, j] = np.argmax(np.asarray(lg), axis=-1)
        snaps.append(c)

    hat, n_accept, committed = verify(
        params, cache=cache, tokens=jnp.asarray(toks),
        lens=jnp.asarray(lens), active=jnp.ones((B,), bool), n_bucket=None)
    hat, n_accept = np.asarray(hat), np.asarray(n_accept)
    np.testing.assert_array_equal(n_accept, want_accept)
    for i in range(B):
        np.testing.assert_array_equal(hat[i, :lens[i]], ref_hat[i, :lens[i]],
                                      err_msg=f"row {i}")
        _assert_row_equal(committed, snaps[int(n_accept[i])], i)


# ---------------------------------------------------------------------------
# engine-level: speculative outputs == plain outputs, whole matrix
# ---------------------------------------------------------------------------


class _CorruptReplay:
    """Test drafter: replays the plain run's outputs but corrupts every 3rd
    proposal, so verify launches deterministically exercise full accepts,
    partial accepts, corrections and full rejections. Legitimate because
    draft content only ever moves the acceptance rate (``NGramDrafter``)."""

    def __init__(self, ref: dict, vocab: int):
        self._ref = ref  # {tuple(prompt): plain-run output tokens}
        self._vocab = vocab
        self._pos: dict[int, list] = {}

    def seed(self, slot, tokens):
        toks = [int(t) for t in tokens]
        self._pos[slot] = [self._ref.get(tuple(toks[:-1]), []), 1]

    def extend(self, slot, tokens):
        self._pos[slot][1] += len(tuple(tokens))

    def drop(self, slot):
        self._pos.pop(slot, None)

    def draft(self, slot, k):
        stream, cur = self._pos[slot]
        return [(t + 1) % self._vocab if (cur + j) % 3 == 0 else int(t)
                for j, t in enumerate(stream[cur:cur + k])]


def _reqs(vocab):
    r = np.random.default_rng(5)
    shared = r.integers(0, vocab, PAGE)  # one full page for the prefix index
    mk = lambda rid, n, mn: Request(
        rid=rid, max_new=mn,
        tokens=np.concatenate([shared, r.integers(0, vocab, n)]))
    return [mk(0, 70, 10), mk(1, 40, 8), mk(2, 100, 12)]


def _serve(eng, reqs, drafter=None):
    srv = SlotServer(eng, drafter=drafter)
    for r in reqs:
        srv.submit(r)
    srv.run()
    return srv


MATRIX = [(p, b, m) for p in ("packkv", "none") for b in ("xla", "pallas")
          for m in ("dense", "paged", "prefix")]


@pytest.mark.parametrize("policy,backend,mode", MATRIX)
def test_spec_outputs_match_plain(smoke_setup, policy, backend, mode):
    cfg, params = smoke_setup
    paged = mode != "dense"
    ecfg = EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                        decode_chunk=4, bucketed=True, bucket_unit=64,
                        backend=backend, paged=paged, page_size=PAGE,
                        prefix_cache=(mode == "prefix"),
                        debug_invariants=paged)
    plain = Engine(cfg, params, PackKVConfig(policy=policy), ecfg)
    spec = Engine(cfg, params, plain.pack_cfg,
                  dataclasses.replace(ecfg, calibrate=False, spec_decode=True,
                                      spec_k=3, spec_backoff=0))
    a = _serve(plain, _reqs(cfg.vocab))
    assert a.stats.spec_launches == 0  # flag off: exactly the PR-6 path
    ref = {tuple(int(t) for t in r.tokens): a.done[r.rid].output
           for r in _reqs(cfg.vocab)}
    b = _serve(spec, _reqs(cfg.vocab),
               drafter=_CorruptReplay(ref, cfg.vocab))
    assert b.stats.spec_launches > 0 and b.stats.spec_drafted > 0
    assert 0 < b.stats.spec_accepted <= b.stats.spec_drafted
    for rid in a.done:
        np.testing.assert_array_equal(a.done[rid].output, b.done[rid].output,
                                      err_msg=f"rid {rid}")


def test_spec_rejected_for_recurrent_families(smoke_setup):
    """Families without page-addressable KV decode one token per state
    update; the engine refuses --spec-decode for them up front."""
    cfg = SMOKES["rwkv6-1.6b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="spec"):
        Engine(cfg, params, PackKVConfig(policy="none"),
               EngineConfig(capacity=256, max_batch=2, calibrate=False,
                            spec_decode=True))
