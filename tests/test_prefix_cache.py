"""Shared-prefix page cache (ISSUE 5): refcounted copy-on-write pages, the
scheduler's prefix index, and suffix-only prefill.

Invariants under test:
  * Refcount conservation — ``free ⇔ ref == 0`` in both directions — holds
    through interleaved pop/share/acquire/release/COW/reset traffic, both
    deterministically and under adversarial (hypothesis) op sequences.
  * NO ALIASED MUTATION: a page's bytes never change while ``ref > 1``.
    ``append_token``'s flush copy-on-writes a private replacement, and the
    mutating row stays bit-identical to a dense twin driven identically.
  * A prefix-cache-hit admission is BIT-IDENTICAL to a cold run of the same
    prompt (both backends, both policies), reserves only its unshared
    suffix, and under pool pressure the scheduler evicts cold cached
    prefixes instead of blocking admission.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import (
    PackKVConfig,
    acquire_pages,
    alloc_layer_cache,
    alloc_page_pool,
    append_token,
    insert_prefill,
    pool_pop_prefix,
    pool_release_row,
    release_pages,
    reset_slot,
    share_pages,
    slice_compressed,
)
from repro.data import synthetic_kv
from repro.kernels import ops
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

B, H, G, D = 3, 2, 2, 64
CAP, PAGE, R = 1024, 256, 96
SM = 0.125


def _kv(rng, n, b=1):
    return (jnp.asarray(synthetic_kv(rng, b, H, n, D)),
            jnp.asarray(synthetic_kv(rng, b, H, n, D)))


def _pair(policy="packkv", pool_pages=None):
    dense = alloc_layer_cache(PackKVConfig(policy=policy, residual=R),
                              B, H, D, CAP)
    paged = alloc_layer_cache(
        PackKVConfig(policy=policy, residual=R, paged=True, page_size=PAGE,
                     pool_pages=pool_pages),
        B, H, D, CAP,
    )
    return dense, paged


def _attend(cache, q, backend="xla"):
    cfg = cache.cfg
    if cfg.policy == "none":
        c = slice_compressed(cache, None)
        return ops.dense_decode_attention(
            q, c.raw_k, c.raw_v, c.resid_k, c.resid_v, c.n_comp, c.n_resid, SM)
    if cache.pages is not None:
        return ops.paged_decode_attention(q, cache, SM, backend=backend,
                                          tile_l=64)
    return ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v, cache.n_comp,
        cache.n_resid, SM, backend=backend, tile_l=64)


from conftest import ref_conserved as _conserved  # free ⇔ ref == 0


# ---------------------------------------------------------------------------
# pool-level: share / acquire / release refcounting
# ---------------------------------------------------------------------------


def test_share_release_refcounts(rng):
    _, cache = _pair()
    k0, v0 = _kv(rng, 2 * PAGE)  # exactly two full pages, empty residual
    cache = insert_prefill(cache, 0, k0, v0)
    pool = cache.pages
    phys = jnp.asarray(np.asarray(pool.page_table)[0, :2])
    _conserved(pool)

    # the index pins both pages: ref 1 -> 2, stack untouched
    cache = acquire_pages(cache, phys)
    assert (np.asarray(cache.pages.ref)[np.asarray(phys)] == 2).all()
    _conserved(cache.pages)

    # a recipient slot maps them by reference: ref 3, no pops
    nf = int(cache.pages.n_free)
    cache = share_pages(cache, 2, phys)
    assert int(cache.pages.n_free) == nf
    assert (np.asarray(cache.pages.ref)[np.asarray(phys)] == 3).all()
    np.testing.assert_array_equal(
        np.asarray(cache.pages.page_table)[2, :2], np.asarray(phys))
    _conserved(cache.pages)

    # donor retires: pages stay allocated (index + recipient still hold)
    cache = reset_slot(cache, 0)
    assert (np.asarray(cache.pages.ref)[np.asarray(phys)] == 2).all()
    assert int(cache.pages.n_free) == nf
    _conserved(cache.pages)

    # recipient's references released; index eviction frees the pages
    cache = dataclasses.replace(
        cache, pages=pool_release_row(cache.pages, 2, jnp.int32(2)))
    assert (np.asarray(cache.pages.ref)[np.asarray(phys)] == 1).all()
    cache = release_pages(cache, phys)
    assert (np.asarray(cache.pages.ref)[np.asarray(phys)] == 0).all()
    assert int(cache.pages.n_free) == nf + 2
    _conserved(cache.pages)

    # sentinel-padded ids are ignored (the engine's fixed-width jit calls)
    P = cache.pages.n_pool_pages
    before = np.asarray(cache.pages.ref).copy()
    cache = acquire_pages(cache, jnp.asarray([P, P + 3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(cache.pages.ref), before)


def test_shared_page_reads_alias(rng):
    """A recipient row reading mapped pages sees the donor's exact bytes
    (policy 'none': counters set manually; attention must match row 0)."""
    _, cache = _pair("none")
    k0, v0 = _kv(rng, 2 * PAGE)
    cache = insert_prefill(cache, 0, k0, v0)
    phys = jnp.asarray(np.asarray(cache.pages.page_table)[0, :2])
    cache = share_pages(cache, 1, phys)
    cache = dataclasses.replace(
        cache,
        n_comp=cache.n_comp.at[1].set(2 * PAGE),
        resid_k=cache.resid_k.at[1].set(cache.resid_k[0]),
        resid_v=cache.resid_v.at[1].set(cache.resid_v[0]),
        n_resid=cache.n_resid.at[1].set(cache.n_resid[0]),
    )
    q1 = jnp.asarray(rng.normal(size=(1, H * G, D)).astype(np.float32))
    q = jnp.concatenate([q1, q1, jnp.zeros_like(q1)], axis=0)
    out = np.asarray(_attend(cache, q))
    np.testing.assert_array_equal(out[0], out[1])


# ---------------------------------------------------------------------------
# copy-on-write: shared bytes immutable, mutating row stays exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_cow_preserves_shared_bytes(rng, policy):
    """Drive a row into a mid-page flush while its partial page is pinned
    (ref 2). The flush must pop a private replacement: the pinned page's
    bytes stay frozen, refcounts stay conserved, and the row's attention
    stays bit-identical to a dense twin driven identically."""
    dense, paged = _pair(policy)
    L = PAGE + 128  # page 0 full, page 1 half full (128 of 256 tokens)
    k0, v0 = _kv(rng, L)
    dense = insert_prefill(dense, 0, k0, v0)
    paged = insert_prefill(paged, 0, k0, v0)
    old_phys = int(np.asarray(paged.pages.page_table)[0, 1])
    paged = acquire_pages(paged, jnp.asarray([old_phys], jnp.int32))
    assert int(paged.pages.ref[old_phys]) == 2

    def page_bytes(c):
        leaf = c.raw_k if c.cfg.policy == "none" else c.k.scale
        return np.asarray(leaf[:, old_phys]).copy()

    frozen = page_bytes(paged)
    step = jax.jit(append_token)
    for _ in range(R + 8):  # forces a flush into page 1 at offset 128
        kt, vt = _kv(rng, 1, b=B)
        dense = step(dense, kt, vt)
        paged = step(paged, kt, vt)
    assert int(np.asarray(paged.n_comp)[0]) > L - L % 64  # flush happened
    # the pinned page never mutated, the row moved to a private copy
    np.testing.assert_array_equal(page_bytes(paged), frozen)
    new_phys = int(np.asarray(paged.pages.page_table)[0, 1])
    assert new_phys != old_phys
    assert int(paged.pages.ref[old_phys]) == 1  # row's reference dropped
    assert int(paged.pages.ref[new_phys]) == 1
    _conserved(paged.pages)
    # ... and the COW row still reads exactly what the dense twin holds
    q = jnp.asarray(rng.normal(size=(B, H * G, D)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(_attend(paged, q)),
                                  np.asarray(_attend(dense, q)))


def test_exclusive_pages_never_cow(rng):
    """ref == 1 traffic never pops extra pages: PR-4 accounting intact."""
    _, paged = _pair()
    k0, v0 = _kv(rng, PAGE + 128)
    paged = insert_prefill(paged, 0, k0, v0)
    free_before = int(paged.pages.n_free)
    step = jax.jit(append_token)
    for _ in range(R + 8):
        kt, vt = _kv(rng, 1, b=B)
        paged = step(paged, kt, vt)
    # rows 1/2 popped one page each for their own first flush; row 0 only
    # wrote its existing partial page — no COW pop
    used = int(np.sum(np.ceil(np.asarray(paged.n_comp) / PAGE)))
    assert int(paged.pages.n_free) == paged.pages.n_pool_pages - used
    assert free_before - int(paged.pages.n_free) == 2
    _conserved(paged.pages)


# ---------------------------------------------------------------------------
# hypothesis: refcount conservation under adversarial share/evict sequences
# ---------------------------------------------------------------------------


def test_refcount_sequences_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    N_SLOTS, POOL, MAXP = 4, 8, 4

    from repro.core.cache import (
        _pool_release_ids,
        pool_acquire_ids,
        pool_map_prefix,
    )

    @hyp.given(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, N_SLOTS - 1),
                  st.integers(0, MAXP)),
        max_size=40))
    @hyp.settings(deadline=None, max_examples=50)
    def run(ops_seq):
        pool = alloc_page_pool(batch=N_SLOTS, capacity=MAXP * PAGE,
                               page_size=PAGE, pool_pages=POOL)
        held = {s: [] for s in range(N_SLOTS)}  # model: page ids per slot
        pinned: list[int] = []  # model of the index's references
        model_ref = {p: 0 for p in range(POOL)}

        def release_slot(s):
            pool2 = pool_release_row(pool, s, jnp.int32(len(held[s])))
            for p in held[s]:
                model_ref[p] -= 1
            held[s] = []
            return pool2

        for op, slot, n in ops_seq:
            if op == 0:  # evict + insert an n-page request
                pool = release_slot(slot)
                if n > sum(1 for p in range(POOL) if model_ref[p] == 0):
                    continue  # oversubscription is the scheduler's to avoid
                pool, phys = pool_pop_prefix(pool, slot, n)
                held[slot] = [int(p) for p in np.asarray(phys)]
                for p in held[slot]:
                    model_ref[p] += 1
            elif op == 1:  # share another slot's pages by reference
                src = (slot + 1) % N_SLOTS
                k = min(n, len(held[src]))
                if k == 0:
                    continue
                pool = release_slot(slot)
                pool = pool_map_prefix(
                    pool, slot, jnp.asarray(held[src][:k], jnp.int32))
                held[slot] = held[src][:k]
                for p in held[slot]:
                    model_ref[p] += 1
            elif op == 2:  # index pins a held page
                if not held[slot]:
                    continue
                p = held[slot][n % len(held[slot])]
                pool = pool_acquire_ids(pool, jnp.asarray([p], jnp.int32))
                pinned.append(p)
                model_ref[p] += 1
            else:  # index releases its oldest pin
                if not pinned:
                    continue
                p = pinned.pop(0)
                pool = _pool_release_ids(pool, jnp.asarray([p], jnp.int32))
                model_ref[p] -= 1

            ref = np.asarray(pool.ref)
            for p in range(POOL):
                assert int(ref[p]) == model_ref[p], (p, ref, model_ref)
            assert int(pool.n_free) == sum(
                1 for p in range(POOL) if model_ref[p] == 0)
            free = set(np.asarray(pool.free)[: int(pool.n_free)].tolist())
            assert free == {p for p in range(POOL) if model_ref[p] == 0}

    run()


# ---------------------------------------------------------------------------
# scheduler: hit == cold bit-identity, suffix-only reservation, eviction
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, policy, backend, pool_pages=None,
            prefix_cache_pages=None):
    return Engine(
        cfg, params, PackKVConfig(policy=policy),
        EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                     decode_chunk=4, bucketed=True, bucket_unit=64,
                     backend=backend, paged=True, page_size=128,
                     pool_pages=pool_pages, prefix_cache=True,
                     prefix_cache_pages=prefix_cache_pages,
                     debug_invariants=True))


def _serve(eng, reqs):
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    srv.run()
    return srv


SYS = np.random.default_rng(11).integers(0, 512, 300)  # 2 full 128-pages


def _shared_reqs(vocab):
    r = np.random.default_rng(5)
    mk = lambda rid, n, mn: Request(
        rid=rid, max_new=mn,
        tokens=np.concatenate([SYS, r.integers(0, vocab, n)]))
    return [mk(0, 40, 6), mk(1, 60, 5), mk(2, 25, 7)]


@pytest.fixture(scope="module")
def pkx_engine(smoke_setup):
    cfg, params = smoke_setup
    return _engine(cfg, params, "packkv", "xla")


@pytest.mark.parametrize("policy,backend",
                         [("packkv", "xla"), ("packkv", "pallas"),
                          ("none", "xla")])
def test_prefix_hit_bit_identical_to_cold(smoke_setup, pkx_engine, policy,
                                          backend):
    """Requests sharing a 2-page system prompt: later admissions hit the
    index, reserve only their suffix, and every output is bit-identical to
    a cold run of the same request on a fresh server (the index lives in
    the SlotServer, so a fresh server on the same engine IS a cold run)."""
    cfg, params = smoke_setup
    eng = (pkx_engine if (policy, backend) == ("packkv", "xla")
           else _engine(cfg, params, policy, backend))
    warm = _serve(eng, _shared_reqs(cfg.vocab))
    s = warm.stats
    assert s.prefix_lookups == 3 and s.prefix_hits == 2
    assert s.prefix_pages_shared == 4  # 2 pages x 2 hitting requests
    assert 0 < s.prefix_hit_rate < 1
    # suffix-only reservation: a hit reserves need_total - 2 pages
    from repro.utils import cdiv

    reqs = _shared_reqs(cfg.vocab)
    needs = [cdiv(min(512, len(r.tokens) + r.max_new), 128) for r in reqs]
    assert s.pages_reserved_peak <= needs[0] + needs[1] - 2
    for r in reqs:  # cold run of each request alone, fresh server
        cold = _serve(eng, [r])
        np.testing.assert_array_equal(warm.done[r.rid].output,
                                      cold.done[r.rid].output)


def test_identical_prompt_resubmitted(smoke_setup, pkx_engine):
    """An exactly repeated prompt hits (match capped one token short of the
    prompt so the suffix is never empty) and reproduces itself."""
    cfg, params = smoke_setup
    toks = np.random.default_rng(9).integers(0, cfg.vocab, 256)  # 2 pages
    srv = SlotServer(pkx_engine)
    srv.submit(Request(rid=0, max_new=4, tokens=toks))
    srv.run()
    srv.submit(Request(rid=1, max_new=4, tokens=toks))
    srv.run()
    assert srv.stats.prefix_hits == 1
    assert srv.stats.prefix_pages_shared == 1  # capped below the full prompt
    np.testing.assert_array_equal(srv.done[0].output, srv.done[1].output)


def test_eviction_under_pool_pressure(smoke_setup):
    """A tight pool: the index's cold pages are evicted to admit a large
    request instead of blocking, and outputs stay exact."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "packkv", "xla", pool_pages=5)
    srv = SlotServer(eng)
    r = np.random.default_rng(13)
    small = Request(rid=0, max_new=4, tokens=r.integers(0, cfg.vocab, 300))
    srv.submit(small)
    srv.run()
    assert srv._index.n_held == 2  # two full pages registered
    big_toks = r.integers(0, cfg.vocab, 500)
    srv.submit(Request(rid=1, max_new=8, tokens=big_toks))  # needs 4 of 5
    srv.run()
    assert srv.stats.prefix_evictions >= 1
    assert srv.stats.admission_blocks == 0
    cold = _serve(eng, [Request(rid=1, max_new=8, tokens=big_toks)])
    np.testing.assert_array_equal(srv.done[1].output, cold.done[1].output)


def test_index_cap_trims_registration(smoke_setup):
    """prefix_cache_pages bounds the pages the index pins."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "packkv", "xla", prefix_cache_pages=1)
    srv = _serve(eng, _shared_reqs(cfg.vocab))
    assert srv._index.n_held <= 1
    assert srv.stats.prefix_hits >= 1  # page 0 still matches


def test_prefix_cache_requires_paged_and_slots(smoke_setup):
    cfg, params = smoke_setup
    with pytest.raises(ValueError, match="requires --paged"):
        Engine(cfg, params, PackKVConfig(),
               EngineConfig(capacity=512, prefix_cache=True, paged=False))
