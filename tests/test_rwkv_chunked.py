"""§Perf H2: chunked matmul-form WKV == sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.rwkv6 import CHUNK_C, _wkv_chunked


def _wkv_sequential(r, k, v, w, u, S0):
    B, T, H, N = r.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    tm = lambda a: jnp.moveaxis(a, 1, 0)
    S, ys = jax.lax.scan(step, S0, (tm(r), tm(k), tm(v), tm(w)))
    return jnp.moveaxis(ys, 0, 1), S


def test_chunked_matches_sequential(rng):
    B, T, H, N = 2, 4 * CHUNK_C, 3, 16
    r = jnp.asarray(rng.normal(size=(B, T, H, N)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, H, N)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, H, N)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.2, 0.999, size=(B, T, H, N)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, N)).astype(np.float32) * 0.1)
    S0 = jnp.asarray(rng.normal(size=(B, H, N, N)).astype(np.float32) * 0.1)
    y_s, S_s = _wkv_sequential(r, k, v, w, u, S0)
    y_c, S_c = _wkv_chunked(r, k, v, w, u, S0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_s), rtol=2e-4,
                               atol=2e-4)


def test_chunked_strong_decay_stable(rng):
    """Decays near the MIN_LOGW clamp must not produce inf/nan."""
    B, T, H, N = 1, 2 * CHUNK_C, 2, 8
    r = jnp.asarray(rng.normal(size=(B, T, H, N)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, H, N)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, H, N)).astype(np.float32))
    w = jnp.full((B, T, H, N), 1e-6, jnp.float32)  # below the clamp
    u = jnp.zeros((H, N))
    S0 = jnp.zeros((B, H, N, N))
    y, S = _wkv_chunked(r, k, v, w, u, S0)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(S)).all()
