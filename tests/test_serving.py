"""Serving engine: calibration, generate determinism, continuous batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer


@pytest.fixture(scope="module")
def llama_engine():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return Engine(cfg, params, PackKVConfig(),
                  EngineConfig(capacity=256, max_batch=2, calib_tokens=128)), cfg


def test_calibration_sets_static_specs(llama_engine):
    eng, cfg = llama_engine
    assert eng.pack_cfg.k_spec_static is not None
    assert eng.pack_cfg.k_spec_static.head_dim == cfg.hd
    assert eng.pack_cfg.v_spec_static.head_dim == cfg.hd


def test_generate_deterministic(llama_engine, rng):
    eng, cfg = llama_engine
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32)
    a, _ = eng.generate({"tokens": toks}, max_new=6)
    b, _ = eng.generate({"tokens": toks}, max_new=6)
    assert (a == b).all()
    assert a.shape == (2, 6)


def test_exact_policy_agrees_with_tight_compression(rng):
    """At rel_scale→0 the PackKV engine must produce the same greedy tokens
    as the uncompressed engine."""
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 64)), jnp.int32)
    ecfg = EngineConfig(capacity=256, max_batch=1, calib_tokens=128)
    e_none = Engine(cfg, params, PackKVConfig(policy="none"), ecfg)
    e_pack = Engine(
        cfg, params,
        PackKVConfig(k_rel_scale=0.005, v_rel_scale=0.005), ecfg,
    )
    a, _ = e_none.generate({"tokens": toks}, max_new=5)
    b, _ = e_pack.generate({"tokens": toks}, max_new=5)
    assert (a == b).all(), (a, b)


def test_chunked_admission_counts_and_stall_bound(llama_engine, rng):
    """Chunked admission splits a long prompt into page-bounded segments
    and never runs more than one prefill task at a time (bounded decode
    stall); the legacy monolithic path (chunk budget 0) gives the same
    greedy tokens."""
    base, cfg = llama_engine
    eng = Engine(cfg, base.params, base.pack_cfg,
                 dataclasses.replace(base.ecfg, page_size=64,
                                     calibrate=False))
    page = eng.ecfg.page_size
    reqs = lambda: [Request(rid=rid, max_new=4,
                            tokens=rng.integers(0, cfg.vocab, 3 * page + 7))
                    for rid in range(3)]
    rng_state = rng.bit_generator.state
    srv = SlotServer(eng)
    for r in reqs():
        srv.submit(r)
    srv.run()
    # 3*page+7 tokens at a 1-page budget -> 4 segments per request
    assert srv.stats.prefill_chunks == 3 * 4

    rng.bit_generator.state = rng_state
    mono = SlotServer(
        Engine(cfg, eng.params, eng.pack_cfg,
               dataclasses.replace(eng.ecfg, prefill_chunk_pages=0,
                                   calibrate=False)))
    for r in reqs():
        mono.submit(r)
    mono.run()
    assert mono.stats.prefill_chunks == 0
    for rid in srv.done:
        np.testing.assert_array_equal(srv.done[rid].output,
                                      mono.done[rid].output)


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_slot_server_matches_per_request_generate(rng, policy):
    """Heterogeneous prompts/max_new through the continuous scheduler give
    the SAME greedy tokens as Engine.generate run per-request (B=1), and a
    freed slot is reused within the run."""
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, PackKVConfig(policy=policy),
                 EngineConfig(capacity=256, max_batch=2, calib_tokens=128))
    reqs = [
        Request(rid=0, max_new=3, tokens=rng.integers(0, cfg.vocab, 50)),
        Request(rid=1, max_new=8, tokens=rng.integers(0, cfg.vocab, 70)),
        Request(rid=2, max_new=5, tokens=rng.integers(0, cfg.vocab, 50)),
        Request(rid=3, max_new=2, tokens=rng.integers(0, cfg.vocab, 30)),
        Request(rid=4, max_new=1, tokens=rng.integers(0, cfg.vocab, 30)),
    ]
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    finished = srv.run()
    # run() returns every request, including admit-time retirements (max_new=1)
    assert len(finished) == len(reqs) == len(srv.done)
    # more requests than slots completed -> at least one slot was recycled
    assert srv.stats.slot_reuses >= 1
    assert srv.stats.completed == 5
    assert 0.0 < srv.stats.occupancy <= 1.0
    for r in reqs:
        want, _ = eng.generate(
            {"tokens": jnp.asarray(r.tokens[None], jnp.int32)}, r.max_new
        )
        np.testing.assert_array_equal(srv.done[r.rid].output, want[0])


def test_slot_server_rejects_zero_max_new(rng):
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, PackKVConfig(policy="none"),
                 EngineConfig(capacity=256, max_batch=1, calibrate=False))
    srv = SlotServer(eng)
    with pytest.raises(ValueError, match="max_new"):
        srv.submit(Request(rid=0, max_new=0,
                           tokens=rng.integers(0, cfg.vocab, 8)))


def test_slot_server_eos_eviction(rng):
    """A request that emits eos stops early and frees its slot."""
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, PackKVConfig(policy="none"),
                 EngineConfig(capacity=256, max_batch=1, calib_tokens=128))
    toks = rng.integers(0, cfg.vocab, 40)
    probe, _ = eng.generate({"tokens": jnp.asarray(toks[None], jnp.int32)}, 4)
    eos = int(probe[0, 1])  # force eos on the 2nd generated token
    srv = SlotServer(eng, eos_id=eos)
    srv.submit(Request(rid=0, max_new=16, tokens=toks))
    srv.run()
    out = srv.done[0].output
    assert len(out) == 2 and out[-1] == eos
    assert srv.slots == [None]


def test_recurrent_families_reject_prefix_cache():
    """rwkv6 / rglru decode state has no page-addressable KV pages:
    --prefix-cache must fail loudly at engine build (the check fires before
    params are touched), not be silently ignored at admission time."""
    for name in ("rwkv6-1.6b", "recurrentgemma-9b"):
        cfg = SMOKES[name]
        with pytest.raises(ValueError, match="prefix-cache"):
            Engine(cfg, None, PackKVConfig(policy="none"),
                   EngineConfig(capacity=256, paged=True, prefix_cache=True))


def test_rglru_engine_windowed(rng):
    cfg = SMOKES["recurrentgemma-9b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, PackKVConfig(residual=96),
                 EngineConfig(capacity=cfg.window, max_batch=1, calib_tokens=128))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 200)), jnp.int32)  # > window
    out, state = eng.generate({"tokens": toks}, max_new=4)
    assert out.shape == (1, 4)
    assert state.pos.shape == (1,)  # per-row positions (slot recycling)
    assert int(state.pos[0]) == 204
