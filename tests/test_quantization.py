"""Invariant 1: quantization error bound |x - deq(q(x))| <= scale/2."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # degrade to skips, not collection errors
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (
    QuantConfig,
    dequantize,
    quantize,
)
from repro.core.kivi import kivi_cr, kivi_cr_from_rel_scale


@pytest.mark.parametrize("rel", [0.01, 0.05, 0.1, 0.2, 0.5])
@pytest.mark.parametrize("gran", ["token", "channel"])
def test_error_bound(rng, rel, gran):
    x = jnp.asarray(rng.normal(size=(2, 2, 128, 64)).astype(np.float32))
    cfg = QuantConfig(rel_scale=rel, granularity=gran)
    q, s, z = quantize(x, cfg)
    deq = dequantize(q, s, z, cfg)
    # elementwise error <= scale/2 (+fp eps); scale varies per unit
    if gran == "token":
        bound = s / 2
        err = jnp.abs(deq - x)
        assert bool(jnp.all(err <= bound * 1.001 + 1e-6))
    else:
        err = float(jnp.max(jnp.abs(deq - x)))
        assert err <= float(jnp.max(s)) / 2 * 1.001 + 1e-6


@given(
    rel=st.floats(0.02, 0.5),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_error_bound_property(rel, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, r.uniform(0.1, 10), size=(4, 64)).astype(np.float32))
    cfg = QuantConfig(rel_scale=rel)
    q, s, z = quantize(x, cfg)
    deq = dequantize(q, s, z, cfg)
    assert bool(jnp.all(jnp.abs(deq - x) <= s / 2 * 1.001 + 1e-5))


def test_integer_range(rng):
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    cfg = QuantConfig(rel_scale=0.1)
    q, _, _ = quantize(x, cfg)
    assert int(q.min()) >= 0 and int(q.max()) <= cfg.max_q


def test_bits_mode(rng):
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    cfg = QuantConfig(bits=2)
    q, s, z = quantize(x, cfg)
    assert int(q.max()) <= 3
    deq = dequantize(q, s, z, cfg)
    assert bool(jnp.all(jnp.abs(deq - x) <= s / 2 * 1.001 + 1e-6))


def test_constant_input_safe():
    x = jnp.ones((4, 16))
    cfg = QuantConfig(rel_scale=0.1)
    q, s, z = quantize(x, cfg)
    deq = dequantize(q, s, z, cfg)
    assert bool(jnp.all(deq == x))


def test_kivi_cr_paper_numbers():
    """Paper §III-B2: 2-bit/64 -> 6.4x; 3-bit/64 -> 4.57x; 4-bit/64 -> 3.56x."""
    assert abs(kivi_cr(2, 64) - 6.4) < 0.01
    assert abs(kivi_cr(3, 64) - 4.57) < 0.01
    assert abs(kivi_cr(4, 64) - 3.56) < 0.01


def test_kivi_cr_from_rel_scale_monotone():
    crs = [kivi_cr_from_rel_scale(r) for r in (0.02, 0.05, 0.1, 0.3)]
    assert all(a <= b for a, b in zip(crs, crs[1:]))
