"""Preemptive serving (ISSUE 8): swap-out/resume exactness, deadlines,
cancellation, and leak-free aborts — against the REAL engine.

The core claim: a preempted request's resumed greedy output is
bit-identical to an uninterrupted run, across {xla, pallas} × {packkv,
none} × {dense, paged, prefix}. The argument is placement-independence —
evacuation gathers the row's exact bytes (compressed pages, residual,
counters, calibration), restore scatters them into whatever physical pages
the free list hands back, and attention reads the row through its page
table either way. No forward pass runs at restore: the resume seed token
was never cached (``_Active.cached_tokens`` counts prompt + out - 1), so
decode continues exactly where it stopped.

Also here: deadline semantics (already-expired rejected at submit,
in-flight expiry honored within ONE scheduler step), and the regression
that cancelling a request mid-prefill-chunk leaks no pages, refcounts or
reservations (``debug_invariants`` asserts refcount conservation after
every admit/retire throughout).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

PAGE = 128


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, policy, backend, mode, preempt=True, max_batch=2,
            pool_pages=None):
    paged = mode != "dense"
    return Engine(
        cfg, params, PackKVConfig(policy=policy),
        EngineConfig(capacity=512, max_batch=max_batch, calib_tokens=128,
                     decode_chunk=4, bucketed=True, bucket_unit=64,
                     backend=backend, paged=paged, page_size=PAGE,
                     pool_pages=pool_pages, prefix_cache=(mode == "prefix"),
                     debug_invariants=paged, prefill_chunk_pages=1,
                     preempt=preempt))


def _traffic(vocab):
    """Two long class-1 requests (they fill the table and share a 2-page
    prefix, so a prefix-cache victim swaps out holding shared refs) plus
    one short class-0 arrival that must preempt."""
    r = np.random.default_rng(11)
    sys = r.integers(0, vocab, 2 * PAGE)
    lows = [Request(rid=i, max_new=40, priority=1,
                    tokens=np.concatenate(
                        [sys, r.integers(0, vocab, 40 + 13 * i)]))
            for i in range(2)]
    hi = Request(rid=2, max_new=6, priority=0,
                 tokens=r.integers(0, vocab, 100))
    return [*lows, hi]


MODES = ("dense", "paged", "prefix")
MATRIX = [(p, b, m) for p in ("packkv", "none") for b in ("xla", "pallas")
          for m in MODES]


@pytest.mark.parametrize("policy,backend,mode", MATRIX)
def test_preempt_resume_bit_identical(smoke_setup, policy, backend, mode):
    cfg, params = smoke_setup
    pre = _engine(cfg, params, policy, backend, mode, preempt=True)
    reqs = _traffic(cfg.vocab)
    srv = SlotServer(pre)
    srv.submit(reqs[0])
    srv.submit(reqs[1])
    for _ in range(8):  # both lows admitted and several tokens deep
        srv.step()
    srv.submit(reqs[2])  # class-0 arrival: the table is full -> swap-out
    srv.run()
    assert srv.stats.preemptions >= 1, "swap-out path never fired"
    assert srv.stats.completed == 3
    assert sum(r.n_preempts for r in srv.done.values()) \
        == srv.stats.preemptions
    if mode != "dense":
        assert srv.stats.swapped_pages == srv.stats.restored_pages

    # uninterrupted control: same calibrated engine config, preemption off
    base = Engine(cfg, params, pre.pack_cfg,
                  dataclasses.replace(pre.ecfg, preempt=False,
                                      calibrate=False))
    ctl = SlotServer(base)
    for r in _traffic(cfg.vocab):
        ctl.submit(r)
    ctl.run()
    assert ctl.stats.preemptions == 0
    for rid in srv.done:
        np.testing.assert_array_equal(srv.done[rid].output,
                                      ctl.done[rid].output,
                                      err_msg=f"rid {rid}")


def test_preempt_on_page_pressure(smoke_setup):
    """A free SLOT but no reservable pages: the class-0 arrival must swap
    a class-1 victim out for its pages, and the victim's resumed output
    still matches the uninterrupted run."""
    cfg, params = smoke_setup
    pre = _engine(cfg, params, "packkv", "xla", "paged", preempt=True,
                  max_batch=3, pool_pages=6)
    reqs = _traffic(cfg.vocab)  # lows reserve 3 pages each = the whole pool
    srv = SlotServer(pre)
    srv.submit(reqs[0])
    srv.submit(reqs[1])
    for _ in range(8):
        srv.step()
    assert srv.n_occupied == 2  # slot 2 free, zero pages available
    srv.submit(reqs[2])
    srv.run()
    assert srv.stats.preemptions >= 1
    assert srv.stats.completed == 3

    base = Engine(cfg, params, pre.pack_cfg,
                  dataclasses.replace(pre.ecfg, preempt=False,
                                      calibrate=False))
    ctl = SlotServer(base)
    for r in _traffic(cfg.vocab):
        ctl.submit(r)
    ctl.run()
    for rid in srv.done:
        np.testing.assert_array_equal(srv.done[rid].output,
                                      ctl.done[rid].output,
                                      err_msg=f"rid {rid}")


def test_deadline_rejected_at_submit(smoke_setup):
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "none", "xla", "dense", preempt=False)
    srv = SlotServer(eng)
    toks = np.arange(8, dtype=np.int64)
    for bad in (0.0, -5.0):
        with pytest.raises(ValueError, match="deadline_ms"):
            srv.submit(Request(rid=0, max_new=4, tokens=toks,
                               deadline_ms=bad))
    with pytest.raises(ValueError, match="priority"):
        srv.submit(Request(rid=0, max_new=4, tokens=toks, priority=-1))


def test_deadline_expires_within_one_step(smoke_setup):
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "none", "xla", "dense", preempt=False)
    srv = SlotServer(eng)
    req = Request(rid=0, max_new=200, deadline_ms=1e9,
                  tokens=np.random.default_rng(5).integers(0, cfg.vocab, 70))
    srv.submit(req)
    for _ in range(3):
        srv.step()
    assert req.status == "active" and srv.n_occupied == 1
    n_before = len(srv.slots[0].out)
    req.deadline_ms = 1e-6  # now long past: the NEXT step must retire it
    out = srv.step()
    assert out and out[0] is req
    assert req.status == "expired"
    assert srv.n_occupied == 0 and srv._reserved == {}
    # partial output kept, and expiry stopped generation within one step
    # (at most one decode launch of decode_chunk tokens after the reap ran)
    assert n_before <= len(req.output) <= n_before + eng.ecfg.decode_chunk
    assert srv.stats.expired == 1 and srv.stats.completed == 0


def test_cancel_mid_prefill_chunk_leaks_nothing(smoke_setup):
    """Regression for the retirement refactor: a cancel landing between
    prefill chunks must release the claimed slot's reservation and leave
    the pool whole — mid-task state holds no device pages by construction."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "packkv", "xla", "prefix", preempt=True)
    srv = SlotServer(eng)
    long_toks = np.random.default_rng(6).integers(0, cfg.vocab, 3 * PAGE + 50)
    req = Request(rid=0, max_new=4, tokens=long_toks)
    srv.submit(req)
    srv.step()  # task started: first chunk done, more to go
    assert srv._task is not None and not srv._task.done
    assert 0 in srv._reserved
    req.cancel()
    srv.step()  # reap aborts the task through the shared retirement path
    assert srv._task is None
    assert req.status == "cancelled" and srv.stats.cancelled == 1
    assert srv._reserved == {} and srv.n_occupied == 0
    assert len(req.output) == 0
    # pool fully free again (debug_invariants asserted refcounts all along)
    pool = srv.cache.pages
    assert int(pool.n_free[0]) == eng.pack_cfg.pool_pages
    assert int(np.asarray(pool.ref[0]).sum()) == 0
    # and the server still serves: a fresh request completes normally
    nxt = Request(rid=1, max_new=4, tokens=long_toks[: PAGE + 30])
    srv.submit(nxt)
    srv.run()
    assert nxt.status == "done" and len(nxt.output) == 4


def test_cancel_swapped_out_request(smoke_setup):
    """A request cancelled WHILE swapped out retires from the SwapStore
    with its partial output; the store drains and its shared pages unpin."""
    cfg, params = smoke_setup
    pre = _engine(cfg, params, "packkv", "xla", "paged", preempt=True)
    reqs = _traffic(cfg.vocab)
    srv = SlotServer(pre)
    srv.submit(reqs[0])
    srv.submit(reqs[1])
    for _ in range(8):
        srv.step()
    srv.submit(reqs[2])
    # step until the swap-out happens, then cancel the victim in the store
    for _ in range(30):
        srv.step()
        if srv._swap is not None and len(srv._swap) > 0:
            break
    assert len(srv._swap) == 1
    victim = next(r for r in (reqs[0], reqs[1]) if r.rid in srv._swap)
    victim.cancel()
    srv.run()
    assert victim.status == "cancelled"
    assert len(victim.output) > 0  # generated-so-far tokens kept
    assert len(srv._swap) == 0
    assert srv.stats.completed == 2 and srv.stats.cancelled == 1
