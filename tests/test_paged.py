"""Paged tiered KV pool (ISSUE 4): free-list invariants, paged-vs-dense
bit-identity, and page-count admission.

Invariants under test:
  * The free-list allocator hands out unique pages, returns a retired
    slot's pages exactly, and reuses them — under interleaved
    insert/append/reset traffic and under adversarial (hypothesis)
    insert/evict sequences.
  * Every read of a paged cache — page-table gather (xla) or in-kernel
    page indexing (pallas) — is BIT-IDENTICAL to the dense storage mode at
    ragged per-row lengths, including n_comp = 0, lengths straddling a
    page boundary, and a completely full pool.
  * ``SlotServer`` with an oversubscribed pool (pool_pages < max_batch *
    capacity / page_size) blocks admission on page reservations, keeps
    FIFO order, and still serves mixed traffic exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    alloc_page_pool,
    append_token,
    gather_paged,
    insert_prefill,
    live_pages,
    pool_pop_prefix,
    pool_pop_rows,
    pool_release_row,
    prefill_cache,
    reset_slot,
    slice_compressed,
)
from repro.data import synthetic_kv
from repro.kernels import ops
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

B, H, G, D = 3, 2, 2, 64
CAP, PAGE, R = 1024, 256, 96
SM = 0.125


def _kv(rng, n, b=1):
    return (jnp.asarray(synthetic_kv(rng, b, H, n, D)),
            jnp.asarray(synthetic_kv(rng, b, H, n, D)))


def _pair(policy="packkv", pool_pages=None):
    """(dense, paged) cache pair of identical capacity."""
    dense = alloc_layer_cache(PackKVConfig(policy=policy, residual=R),
                              B, H, D, CAP)
    paged = alloc_layer_cache(
        PackKVConfig(policy=policy, residual=R, paged=True, page_size=PAGE,
                     pool_pages=pool_pages),
        B, H, D, CAP,
    )
    return dense, paged


def _attend(cache, q, n_bucket=None, backend="xla"):
    cfg = cache.cfg
    if cfg.policy == "none":
        c = slice_compressed(cache, n_bucket)
        return ops.dense_decode_attention(
            q, c.raw_k, c.raw_v, c.resid_k, c.resid_v, c.n_comp, c.n_resid, SM)
    if cache.pages is not None:
        return ops.paged_decode_attention(q, cache, SM, n_bucket=n_bucket,
                                          backend=backend, tile_l=64)
    c = slice_compressed(cache, n_bucket)
    return ops.packed_decode_attention(
        q, c.k, c.v, c.resid_k, c.resid_v, c.n_comp, c.n_resid, SM,
        backend=backend, tile_l=64)


# ---------------------------------------------------------------------------
# free-list allocator invariants
# ---------------------------------------------------------------------------


def _free_set(pool):
    return set(np.asarray(pool.free[: int(pool.n_free)]).tolist())


from conftest import ref_conserved as _ref_conserved


def test_pool_alloc_free_reuse():
    pool = alloc_page_pool(batch=3, capacity=CAP, page_size=PAGE)  # 12 pages
    assert pool.n_pool_pages == 12 and pool.max_pages == 4
    assert _free_set(pool) == set(range(12))
    _ref_conserved(pool)

    # batched per-row pops are unique, land at ref == 1, shrink the stack
    pool = pool_pop_rows(pool, jnp.array([True, False, True]),
                         jnp.array([0, 0, 0]))
    t = np.asarray(pool.page_table)
    assert int(pool.n_free) == 10 and t[0, 0] != t[2, 0]
    assert {int(t[0, 0]), int(t[2, 0])} & _free_set(pool) == set()
    assert int(pool.ref[t[0, 0]]) == 1 and int(pool.ref[t[2, 0]]) == 1
    _ref_conserved(pool)

    # static prefix pop for a prompt
    pool, phys = pool_pop_prefix(pool, 1, 3)
    assert int(pool.n_free) == 7 and len(set(np.asarray(phys).tolist())) == 3
    np.testing.assert_array_equal(np.asarray(pool.page_table)[1, :3],
                                  np.asarray(phys))
    _ref_conserved(pool)

    # releasing a row restores exactly its pages (ref 1 -> 0 -> stack)
    before = _free_set(pool)
    pool = pool_release_row(pool, 1, jnp.int32(3))
    assert int(pool.n_free) == 10
    assert _free_set(pool) == before | set(np.asarray(phys).tolist())
    _ref_conserved(pool)

    # zero-page release is a no-op
    pool2 = pool_release_row(pool, 0, jnp.int32(0))
    assert int(pool2.n_free) == int(pool.n_free)


def test_live_pages():
    assert int(live_pages(jnp.int32(0), 256)) == 0
    assert int(live_pages(jnp.int32(1), 256)) == 1
    assert int(live_pages(jnp.int32(256), 256)) == 1
    assert int(live_pages(jnp.int32(257), 256)) == 2


def test_pool_accounting_under_slot_traffic(rng):
    """Interleaved insert/append/reset keeps n_free == pool - live pages."""
    _, cache = _pair()
    step = jax.jit(append_token)

    def check(c):
        used = int(np.sum(np.ceil(np.asarray(c.n_comp) / PAGE)))
        assert int(c.pages.n_free) == c.pages.n_pool_pages - used
        _ref_conserved(c.pages)
        # live table prefixes reference distinct physical pages (ref == 1:
        # no sharing in this exclusive-ownership traffic)
        live = [
            np.asarray(c.pages.page_table)[b, : int(np.ceil(n / PAGE))]
            for b, n in enumerate(np.asarray(c.n_comp))
        ]
        flat = np.concatenate(live) if live else np.zeros(0)
        assert len(set(flat.tolist())) == len(flat)
        assert (np.asarray(c.pages.ref)[flat.astype(int)] == 1).all()

    k0, v0 = _kv(rng, 300)
    cache = insert_prefill(cache, 0, k0, v0)
    check(cache)
    k1, v1 = _kv(rng, 70)
    cache = insert_prefill(cache, 1, k1, v1)
    check(cache)
    for _ in range(120):  # pushes row 0 across a page boundary
        kt, vt = _kv(rng, 1, b=B)
        cache = step(cache, kt, vt)
    check(cache)
    cache = reset_slot(cache, 0)
    assert int(cache.n_comp[0]) == 0
    check(cache)
    # recycled slot reuses returned pages
    k2, v2 = _kv(rng, 500)
    cache = insert_prefill(cache, 0, k2, v2)
    check(cache)


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_slot_ops_match_dense(rng, policy):
    """The paged cache reproduces the dense cache's attention bit-for-bit
    through interleaved insert/append/reset traffic (the dense path is
    itself bit-identical to B=1 references, tests/test_slot_cache.py)."""
    dense, paged = _pair(policy)
    step = jax.jit(append_token)
    q = jnp.asarray(rng.normal(size=(B, H * G, D)).astype(np.float32))

    k0, v0 = _kv(rng, 300)
    k1, v1 = _kv(rng, 70)
    for slot, (k, v) in ((0, (k0, v0)), (1, (k1, v1))):
        dense = insert_prefill(dense, slot, k, v)
        paged = insert_prefill(paged, slot, k, v)
    for _ in range(100):
        kt, vt = _kv(rng, 1, b=B)
        dense = step(dense, kt, vt)
        paged = step(paged, kt, vt)
    np.testing.assert_array_equal(np.asarray(dense.n_comp),
                                  np.asarray(paged.n_comp))
    np.testing.assert_array_equal(np.asarray(_attend(dense, q)),
                                  np.asarray(_attend(paged, q)))

    dense, paged = reset_slot(dense, 0), reset_slot(paged, 0)
    k2, v2 = _kv(rng, 200)
    dense = insert_prefill(dense, 0, k2, v2)
    paged = insert_prefill(paged, 0, k2, v2)
    for _ in range(40):
        kt, vt = _kv(rng, 1, b=B)
        dense = step(dense, kt, vt)
        paged = step(paged, kt, vt)
    np.testing.assert_array_equal(np.asarray(_attend(dense, q)),
                                  np.asarray(_attend(paged, q)))


# ---------------------------------------------------------------------------
# kernel-level bit-identity at ragged lengths (both backends)
# ---------------------------------------------------------------------------


def _ragged_pair(rng, lengths, policy="packkv"):
    dense, paged = _pair(policy)
    for b, n in enumerate(lengths):
        if n:
            k, v = _kv(rng, n)
            dense = insert_prefill(dense, b, k, v)
            paged = insert_prefill(paged, b, k, v)
    return dense, paged


# dead row, page-boundary straddle (300 -> 256 + 44 resid), exactly one page,
# and (256, 320, 260) pushing multiple rows past page 1
@pytest.mark.parametrize("lengths", [(0, 300, 256), (256, 320, 260)])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_paged_attention_bit_identical(rng, lengths, backend):
    dense, paged = _ragged_pair(rng, lengths)
    q = jnp.asarray(rng.normal(size=(B, H * G, D)).astype(np.float32))
    for n_bucket in (None, 512):
        want = _attend(dense, q, n_bucket, backend)
        got = _attend(paged, q, n_bucket, backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_flush_capped_at_capacity(rng):
    """A row driven past capacity stops flushing: n_comp never exceeds
    capacity and no page beyond the row's reservation is ever popped (the
    invariant behind the scheduler's reservation ledger)."""
    cfg = PackKVConfig(paged=True, page_size=PAGE, residual=R)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    k0, v0 = _kv(rng, CAP)  # slot 0 starts at capacity
    cache = insert_prefill(cache, 0, k0, v0)
    free_before = int(cache.pages.n_free)
    step = jax.jit(append_token)
    for _ in range(R + 8):  # would cross the capacity boundary unguarded
        kt, vt = _kv(rng, 1, b=B)
        cache = step(cache, kt, vt)
    assert int(cache.n_comp[0]) == CAP  # clamped, not grown
    # rows 1/2 legitimately popped one page each for their own appends;
    # row 0 (at capacity) popped NOTHING beyond its reservation
    others = int(np.sum(np.ceil(np.asarray(cache.n_comp)[1:] / PAGE)))
    assert int(cache.pages.n_free) == free_before - others
    q = jnp.asarray(rng.normal(size=(B, H * G, D)).astype(np.float32))
    assert np.isfinite(np.asarray(_attend(cache, q))).all()


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_paged_attention_full_pool(rng, backend):
    """Every pool page allocated (all rows at capacity): still exact."""
    dense, paged = _ragged_pair(rng, (CAP, CAP, CAP))
    assert int(paged.pages.n_free) == 0
    q = jnp.asarray(rng.normal(size=(B, H * G, D)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(_attend(paged, q, None, backend)),
        np.asarray(_attend(dense, q, None, backend)))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_paged_tier_matvecs_bit_identical(rng, backend):
    """kpack scores / vpack out through the page table == the dense launch
    on the gathered view (tile skipping included)."""
    dense, paged = _ragged_pair(rng, (300, 70, 0))
    nv = paged.n_comp
    n_tokens = 512
    view = gather_paged(paged, n_tokens)
    q = jnp.asarray(rng.normal(size=(B, H * G, D)).astype(np.float32))
    s_paged = ops.packed_qk_scores_paged(
        q, paged.k, paged.pages, n_tokens, SM, n_valid=nv, backend=backend,
        tile_l=64)
    s_dense = ops.packed_qk_scores(q, view.k, SM, n_valid=nv, backend=backend,
                                   tile_l=64)
    np.testing.assert_array_equal(np.asarray(s_paged), np.asarray(s_dense))
    w = jax.nn.softmax(jnp.asarray(
        rng.normal(size=(B, H * G, n_tokens)).astype(np.float32)), -1)
    o_paged = ops.packed_weighted_v_paged(
        w, paged.v, paged.pages, n_valid=nv, backend=backend, tile_l=64)
    o_dense = ops.packed_weighted_v(w, view.v, n_valid=nv, backend=backend,
                                    tile_l=64)
    np.testing.assert_array_equal(np.asarray(o_paged), np.asarray(o_dense))


def test_gather_matches_prefix_slice(rng):
    """gather_paged == slice_compressed contract: a paged cache sliced to a
    bucket exposes exactly the dense cache's sliced buffers (live bytes)."""
    dense, paged = _ragged_pair(rng, (300, 70, 0))
    for n_bucket in (256, 512, None):
        dv = slice_compressed(dense, n_bucket)
        pv = slice_compressed(paged, n_bucket)  # gathers
        assert pv.pages is None and pv.k.capacity == dv.k.capacity
        for b, n in enumerate(np.asarray(dense.n_comp)):
            n = int(min(n, n_bucket or CAP))
            np.testing.assert_array_equal(
                np.asarray(pv.k.scale)[b, :, :n], np.asarray(dv.k.scale)[b, :, :n])
            for tp, td in zip(pv.k.tiers, dv.k.tiers):
                w = tp.width
                np.testing.assert_array_equal(
                    np.asarray(tp.payload)[b, ..., : n * w // 32],
                    np.asarray(td.payload)[b, ..., : n * w // 32])


# ---------------------------------------------------------------------------
# scheduler: paged serving exact + oversubscribed admission blocking
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, policy, backend, paged, pool_pages=None, reqs=None):
    eng = Engine(cfg, params, PackKVConfig(policy=policy),
                 EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                              decode_chunk=4, bucketed=True, bucket_unit=64,
                              backend=backend, paged=paged, page_size=128,
                              pool_pages=pool_pages))
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    srv.run()
    return srv


def _mixed_reqs(vocab, seed=3):
    r = np.random.default_rng(seed)
    return [
        Request(rid=0, max_new=6, tokens=r.integers(0, vocab, 70)),
        Request(rid=1, max_new=3, tokens=r.integers(0, vocab, 40)),
        Request(rid=2, max_new=9, tokens=r.integers(0, vocab, 100)),
        Request(rid=3, max_new=4, tokens=r.integers(0, vocab, 30)),
    ]


@pytest.mark.parametrize("policy,backend",
                         [("packkv", "xla"), ("packkv", "pallas"),
                          ("none", "xla")])
def test_paged_serving_exact(smoke_setup, policy, backend):
    cfg, params = smoke_setup
    d = _serve(cfg, params, policy, backend, False,
               reqs=_mixed_reqs(cfg.vocab))
    p = _serve(cfg, params, policy, backend, True,
               reqs=_mixed_reqs(cfg.vocab))
    assert set(d.done) == set(p.done)
    for rid in d.done:
        np.testing.assert_array_equal(d.done[rid].output, p.done[rid].output)
    assert p.stats.pages_reserved_peak > 0


def test_oversubscribed_admission_blocks(smoke_setup):
    """pool_pages=3 < max_batch * capacity/page (8): big requests (2 pages
    each) serialize through the pool, admission blocks, outputs exact."""
    cfg, params = smoke_setup
    reqs = lambda: [Request(rid=i, max_new=8,
                            tokens=r2.integers(0, cfg.vocab, 200))
                    for i in range(3)]
    r2 = np.random.default_rng(5)
    d = _serve(cfg, params, "packkv", "xla", False, reqs=reqs())
    r2 = np.random.default_rng(5)
    p = _serve(cfg, params, "packkv", "xla", True, pool_pages=3, reqs=reqs())
    for rid in d.done:
        np.testing.assert_array_equal(d.done[rid].output, p.done[rid].output)
    assert p.stats.admission_blocks > 0
    assert p.stats.pages_reserved_peak <= 3
    # a request that can never fit the pool is rejected at submit
    eng = Engine(cfg, params, PackKVConfig(),
                 EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                              paged=True, page_size=128, pool_pages=2))
    srv = SlotServer(eng)
    with pytest.raises(ValueError, match="pages"):
        srv.submit(Request(rid=9, max_new=100,
                           tokens=np.zeros(400, np.int64)))
    # ... and so is one beyond the capacity + residual contract (its row
    # would stop flushing at capacity and degrade its own residual)
    with pytest.raises(ValueError, match="capacity"):
        srv.submit(Request(rid=10, max_new=300,
                           tokens=np.zeros(400, np.int64)))
    # ... and so is a prompt whose block-aligned length alone exceeds
    # capacity (prefill would pop more pages than a table row holds, even
    # though prompt + max_new fits capacity + residual)
    with pytest.raises(ValueError, match="block-aligned"):
        srv.submit(Request(rid=11, max_new=1,
                           tokens=np.zeros(576, np.int64)))


# ---------------------------------------------------------------------------
# hypothesis: free-list under adversarial insert/evict sequences
# ---------------------------------------------------------------------------


def test_free_list_sequences_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    N_SLOTS, POOL, MAXP = 4, 8, 4

    @hyp.given(st.lists(
        st.tuples(st.integers(0, N_SLOTS - 1), st.integers(0, MAXP)),
        max_size=30))
    @hyp.settings(deadline=None, max_examples=50)
    def run(ops_seq):
        pool = alloc_page_pool(batch=N_SLOTS, capacity=MAXP * PAGE,
                               page_size=PAGE, pool_pages=POOL)
        held = {s: 0 for s in range(N_SLOTS)}  # model: pages per slot
        for slot, n in ops_seq:
            # evict whatever the slot holds, then insert an n-page request
            # (skipped when it would oversubscribe — the scheduler's job)
            pool = pool_release_row(pool, slot, jnp.int32(held[slot]))
            held[slot] = 0
            if sum(held.values()) + n > POOL:
                continue
            pool, phys = pool_pop_prefix(pool, slot, n)
            held[slot] = n
            assert len(set(np.asarray(phys).tolist())) == n
        # accounting: stack height mirrors the model exactly, and live
        # pages across slots are disjoint
        assert int(pool.n_free) == POOL - sum(held.values())
        live = [np.asarray(pool.page_table)[s, :n] for s, n in held.items()]
        flat = np.concatenate(live) if live else np.zeros(0)
        assert len(set(flat.tolist())) == len(flat)
        assert set(flat.tolist()) | _free_set(pool) == set(range(POOL))

    run()
