"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward/train step on CPU, output shapes + no NaNs; plus
prefill→decode teacher-forcing consistency for the exact-cache policy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES, ARCHS, SHAPES, cells, shape_applicable
from repro.core.cache import PackKVConfig
from repro.models import get_model

PACK = PackKVConfig(residual=96)


def _batch(cfg, rng, B=2, S=128, labels=True):
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        n_lab = S
    elif cfg.input_mode == "frames":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        )
        n_lab = S
    else:
        Tt = S - cfg.n_patches
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, Tt)), jnp.int32)
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)).astype(np.float32)
        )
        n_lab = Tt
    if labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, n_lab)), jnp.int32)
    return batch


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_forward_and_loss(name, rng):
    cfg = SMOKES[name]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, aux = api.forward_train(params, cfg, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert not bool(jnp.isnan(logits).any())
    loss = api.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize(
    "name", [n for n, c in sorted(SMOKES.items()) if c.has_decode]
)
def test_prefill_decode(name, rng):
    cfg = SMOKES[name]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng, labels=False)
    logits, cache = api.prefill(params, cfg, PACK, 256, batch)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(params, cfg, cache, tok)
        assert logits.shape == (2, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("name", ["llama2-7b", "qwen3-32b", "internvl2-2b"])
def test_decode_matches_teacher_forcing_exact_cache(name, rng):
    """policy='none' decode must reproduce train-forward logits exactly
    (same math, different code path) — validates the serving stack."""
    cfg = SMOKES[name]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1), cfg)
    S = 70  # non-block-aligned on purpose
    batch = _batch(cfg, rng, B=1, S=S, labels=False)
    full_logits, _ = api.forward_train(params, cfg, batch)

    pack_none = PackKVConfig(policy="none", residual=96)
    # prefill with all but the last token, then decode it
    pre = {k: (v[:, :-1] if k == "tokens" else v) for k, v in batch.items()}
    lg, cache = api.prefill(params, cfg, pack_none, 128, pre)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, -2]), rtol=2e-2, atol=2e-2
    )
    tok = batch["tokens"][:, -1:]
    lg2, cache = api.decode_step(params, cfg, cache, tok)
    np.testing.assert_allclose(
        np.asarray(lg2), np.asarray(full_logits[:, -1]), rtol=2e-2, atol=2e-2
    )


def test_cell_grid_counts():
    """DESIGN.md §4: 31 runnable cells, 9 skips, with recorded reasons."""
    assigned = {k: v for k, v in ARCHS.items() if k != "llama2-7b"}
    run, skip = cells(assigned)
    assert len(run) + len(skip) == 40
    assert len(run) == 31
    skip_names = {(a, s) for a, s, _ in skip}
    assert ("hubert-xlarge", "decode_32k") in skip_names
    assert ("hubert-xlarge", "long_500k") in skip_names
    assert ("qwen3-32b", "long_500k") in skip_names
    assert ("rwkv6-1.6b", "long_500k") not in skip_names
    assert ("recurrentgemma-9b", "long_500k") not in skip_names


def test_param_counts_plausible():
    """Full configs should land near their nameplate sizes."""
    approx = {
        "minitron-4b": (4.0e9, 0.4),
        "smollm-135m": (135e6, 0.3),
        "qwen3-32b": (32e9, 0.25),
        "llama2-7b": (6.7e9, 0.15),
    }
    for name, (want, tol) in approx.items():
        got = ARCHS[name].param_count()
        assert abs(got - want) / want < tol, (name, got)
    # MoE: active far below total (the assignment's 48L/64e/1408 moonshot
    # config computes to ~29B total — the assignment dims are authoritative,
    # not the marketing name)
    m = ARCHS["moonshot-v1-16b-a3b"]
    assert m.active_param_count() < 0.25 * m.param_count()
