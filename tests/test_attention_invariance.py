"""Invariant 4 (the paper's theoretical core): Att(q, PK, PV) == Att(q,K,V)
for decode; and the flash oracle matches naive attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # degrade to skips, not collection errors
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import dense_decode_attention_ref
from repro.models.layers import flash_attention


@given(seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_decode_permutation_invariance(seed):
    r = np.random.default_rng(seed)
    B, H, L, D = 1, 2, 32, 16
    q = jnp.asarray(r.normal(size=(B, H, D)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(B, H, L, D)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(B, H, L, D)).astype(np.float32))
    zr = jnp.zeros((B, H, 4, D))
    base = dense_decode_attention_ref(
        q, k, v, zr, zr, jnp.int32(L), jnp.int32(0), 0.25
    )
    perm = r.permutation(L)
    out = dense_decode_attention_ref(
        q, k[:, :, perm], v[:, :, perm], zr, zr, jnp.int32(L), jnp.int32(0), 0.25
    )
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), rtol=2e-5, atol=2e-5)


def _naive_attention(q, k, v, causal, window, sm_scale):
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * sm_scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, D)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 32)])
@pytest.mark.parametrize("gqa", [1, 3])
def test_flash_matches_naive(rng, causal, window, gqa):
    B, Hkv, S, D = 2, 2, 128, 16
    q = jnp.asarray(rng.normal(size=(B, Hkv * gqa, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    sm = 1.0 / np.sqrt(D)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=32, kv_chunk=64, sm_scale=sm)
    want = _naive_attention(q, k, v, causal, window, sm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_chunk_sizes_agree(rng):
    B, H, S, D = 1, 2, 64, 8
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    a = flash_attention(q, k, v, q_chunk=8, kv_chunk=16)
    b = flash_attention(q, k, v, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
