"""Chunked prefill/decode interleaving (ISSUE 6): bit-identity matrix.

The chunk-interleaved admission path must give GREEDY OUTPUTS bit-identical
to the monolithic PR-5 admission (``prefill_chunk_pages=0``) across
{xla, pallas} × {packkv, none} × {prefix-cache on/off} — chunk boundaries
are exact attention resume points at the mask level
(``models.layers.resume_attention``; compression is deferred to the final
insert), and greedy argmax absorbs the ≤1-ULP logit wobble that XLA's
M-dependent gemm blocking and the chunks' live-prefix attention slicing
introduce between chunked and whole-prompt reduction shapes.

Also covered here: a chunk budget spanning multiple pages (a chunk
boundary STRADDLING a page boundary), and the 1-token-suffix admission an
exact prompt resubmission produces under the prefix cache.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

PAGE = 128


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, policy, backend, prefix, chunk_pages):
    return Engine(
        cfg, params, PackKVConfig(policy=policy),
        EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                     decode_chunk=4, bucketed=True, bucket_unit=64,
                     backend=backend, paged=prefix, page_size=PAGE,
                     prefix_cache=prefix, debug_invariants=prefix,
                     prefill_chunk_pages=chunk_pages))


def _reqs(vocab):
    r = np.random.default_rng(3)
    sys = r.integers(0, vocab, 2 * PAGE)  # shared 2-page prefix
    mk = lambda rid, n, mn: Request(
        rid=rid, max_new=mn, tokens=np.concatenate([sys, r.integers(0, vocab, n)]))
    # suffix lengths straddle block (64) and page (128) boundaries
    return [mk(0, 40, 6), mk(1, 130, 5), mk(2, 65, 4)]


def _serve(eng, reqs):
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    srv.run()
    return srv


MATRIX = [(p, b, x) for p in ("packkv", "none") for b in ("xla", "pallas")
          for x in (False, True)]


@pytest.mark.parametrize("policy,backend,prefix", MATRIX)
def test_chunked_bit_identical_to_monolithic(smoke_setup, policy, backend,
                                             prefix):
    cfg, params = smoke_setup
    chunked = _engine(cfg, params, policy, backend, prefix, chunk_pages=1)
    mono = Engine(cfg, params, chunked.pack_cfg,
                  dataclasses.replace(chunked.ecfg, prefill_chunk_pages=0,
                                      calibrate=False))
    a = _serve(chunked, _reqs(cfg.vocab))
    b = _serve(mono, _reqs(cfg.vocab))
    assert a.stats.prefill_chunks > 0 and b.stats.prefill_chunks == 0
    if prefix:  # index behaviour unchanged by chunking
        assert (a.stats.prefix_hits, a.stats.prefix_pages_shared) \
            == (b.stats.prefix_hits, b.stats.prefix_pages_shared) == (2, 4)
    for rid in a.done:
        np.testing.assert_array_equal(a.done[rid].output, b.done[rid].output,
                                      err_msg=f"rid {rid}")


def test_chunk_straddles_page_boundary(smoke_setup):
    """A 2-page chunk budget cuts the prompt at 256-token marks, so every
    chunk interior crosses a 128-token page boundary; outputs still match
    the monolithic path, and admission takes half the segments."""
    cfg, params = smoke_setup
    two = _engine(cfg, params, "packkv", "xla", prefix=False, chunk_pages=2)
    one = Engine(cfg, params, two.pack_cfg,
                 dataclasses.replace(two.ecfg, prefill_chunk_pages=1,
                                     calibrate=False))
    r = np.random.default_rng(7)
    reqs = lambda: [Request(rid=0, max_new=6,
                            tokens=r.integers(0, cfg.vocab, 3 * PAGE + 37))]
    st = r.bit_generator.state
    a = _serve(two, reqs())
    r.bit_generator.state = st
    b = _serve(one, reqs())
    assert a.stats.prefill_chunks == 2  # ceil(421 / 256)
    assert b.stats.prefill_chunks == 4  # ceil(421 / 128)
    np.testing.assert_array_equal(a.done[0].output, b.done[0].output)


def test_one_token_suffix_admission(smoke_setup):
    """An exactly-repeated prompt matches all full pages but is capped one
    token short (decode needs last-token logits), leaving a single-token
    suffix segment for the chunked prefix path; the repeat reproduces the
    original bit-for-bit."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, "packkv", "xla", prefix=True, chunk_pages=1)
    toks = np.random.default_rng(9).integers(0, cfg.vocab, 2 * PAGE)
    srv = SlotServer(eng)
    srv.submit(Request(rid=0, max_new=4, tokens=toks))
    srv.run()
    srv.submit(Request(rid=1, max_new=4, tokens=toks))
    srv.run()
    assert srv.stats.prefix_hits == 1
    assert srv.stats.prefix_pages_shared == 1  # capped below the full prompt
    np.testing.assert_array_equal(srv.done[0].output, srv.done[1].output)
