"""Sharding rule engine (divisibility fallback), gradient compression
(+error feedback), straggler/elastic logic."""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.fault import StragglerMonitor, downscale_plan
from repro.distributed.grad_compress import (
    GradCompressConfig,
    compression_ratio,
    init_residuals,
    roundtrip_grads,
    wire_bits,
)
from repro.distributed.sharding import spec_with_fallback


class FakeMesh:
    """Duck-typed mesh for pure spec logic (CPU has 1 real device)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_basic():
    assert spec_with_fallback((256, 5120), ["data", "model"], MESH) == P("data", "model")


def test_spec_divisibility_fallback():
    # 60 experts don't divide 16 -> replicated
    assert spec_with_fallback((60, 2048), ["model", None], MESH) == P(None, None)
    # odd vocab falls back
    assert spec_with_fallback((122753,), ["model"], MESH) == P(None)


def test_spec_axis_used_once():
    s = spec_with_fallback((64, 64), ["model", "model"], MESH)
    assert s == P("model", None)


def test_spec_tuple_axes():
    s = spec_with_fallback((256, 16), [("pod", "data"), "model"], MESH3)
    assert s == P(("pod", "data"), "model")
    # batch 1 can't shard over 32
    assert spec_with_fallback((1, 16), [("pod", "data"), "model"], MESH3)[0] is None


def test_param_specs_shapes():
    """Rule engine on a real (tiny) param tree with a fake big mesh."""
    from repro.configs import SMOKES
    from repro.distributed.sharding import param_specs
    from repro.models import get_model

    cfg = SMOKES["qwen2-moe-a2.7b"]
    api = get_model(cfg)
    params = jax.eval_shape(lambda k: api.init(k, cfg), jax.random.PRNGKey(0))
    mesh = FakeMesh({"data": 2, "model": 4})
    specs = param_specs(params, mesh)
    # embed [V, D] with V=512: model axis on dim0
    assert specs["embed"] == P("model", None)
    # stacked moe expert w_gate [L, E, D, Fe] = [2, 8, 128, 128]: experts on model
    assert specs["layers"]["mlp"]["w_gate"][-3] == "model"
    # norms replicated
    assert all(a is None for a in specs["final_ln"])


def test_grad_compress_roundtrip_bound(rng):
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    cfg = GradCompressConfig(bits=8, row=64)
    out, _ = roundtrip_grads(g, cfg, None)
    rngs = np.asarray(g["w"]).reshape(-1, 64)
    bound = (rngs.max(1) - rngs.min(1)).max() / (2**8 - 1)
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= bound * 0.51 + 1e-6


def test_grad_compress_error_feedback_reduces_bias(rng):
    """With error feedback the accumulated compressed sum tracks the true
    sum much better than without."""
    cfg = GradCompressConfig(bits=2, row=256)
    g = {"w": jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))}
    true_sum = np.zeros(1024)
    ef_sum = np.zeros(1024)
    nf_sum = np.zeros(1024)
    resid = init_residuals(g, cfg)
    for i in range(20):
        true_sum += np.asarray(g["w"])
        out_ef, resid = roundtrip_grads(g, cfg, resid)
        ef_sum += np.asarray(out_ef["w"])
        out_nf, _ = roundtrip_grads(g, cfg, None)
        nf_sum += np.asarray(out_nf["w"])
    err_ef = np.abs(ef_sum - true_sum).mean()
    err_nf = np.abs(nf_sum - true_sum).mean()
    assert err_ef < err_nf * 0.5, (err_ef, err_nf)


def test_wire_bits_accounting():
    g = {"w": jnp.zeros((1000,))}
    cfg = GradCompressConfig(bits=4, row=100)
    assert wire_bits(g, cfg) == 1000 * 4 + 10 * 64
    assert compression_ratio(g, cfg) > 6


def test_straggler_monitor():
    m = StragglerMonitor(threshold=3.0, patience=2)
    verdicts = [m.observe(1.0) for _ in range(8)]
    assert set(verdicts) == {"ok"}
    assert m.observe(10.0) == "straggler"
    assert m.observe(10.0) == "exclude"
    assert m.observe(1.0) == "ok"  # recovers


def test_downscale_plan():
    p = downscale_plan((2, 16, 16), "node-failure")
    assert p.new_shape == (2, 8, 16)
    assert p.new_device_count == 256


def test_compressed_psum_mean_shardmap():
    """Explicit compressed DP all-reduce on a 1-device 'data' axis."""
    from functools import partial

    from repro.distributed.grad_compress import compressed_psum_mean

    from repro.utils import shard_map_compat

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.arange(64.0)}
    f = shard_map_compat(
        partial(compressed_psum_mean, cfg=GradCompressConfig(bits=8, row=64)),
        mesh=mesh, in_specs=(P(),), out_specs=P(),
    )
    out = f(g)
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) < 0.3
