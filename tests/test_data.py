"""Data pipeline: determinism, restart reproducibility, host sharding."""
import numpy as np

from repro.data import ShardedTokenStream, synthetic_kv, zipf_token_batch


def test_zipf_deterministic():
    r1 = np.random.default_rng(0)
    r2 = np.random.default_rng(0)
    a = zipf_token_batch(r1, 4, 32, 1000)
    b = zipf_token_batch(r2, 4, 32, 1000)
    assert (a == b).all()
    assert a.min() >= 0 and a.max() < 1000


def test_zipf_is_skewed():
    r = np.random.default_rng(0)
    t = zipf_token_batch(r, 64, 256, 5000, alpha=1.2)
    # rank-0 token should dominate
    assert (t == 0).mean() > 10 * (t == 100).mean()


def test_stream_restart_reproduces():
    s1 = ShardedTokenStream(vocab=100, batch_per_host=2, seq=16, seed=3)
    batches = [s1.next_batch() for _ in range(5)]
    state = s1.state()
    nxt = s1.next_batch()

    s2 = ShardedTokenStream(vocab=100, batch_per_host=2, seq=16, seed=3)
    s2.restore(state)
    nxt2 = s2.next_batch()
    assert (nxt["tokens"] == nxt2["tokens"]).all()


def test_hosts_disjoint():
    a = ShardedTokenStream(vocab=1000, batch_per_host=2, seq=64, host_id=0,
                           n_hosts=2).next_batch()
    b = ShardedTokenStream(vocab=1000, batch_per_host=2, seq=64, host_id=1,
                           n_hosts=2).next_batch()
    assert not (a["tokens"] == b["tokens"]).all()


def test_labels_shifted():
    s = ShardedTokenStream(vocab=50, batch_per_host=1, seq=8)
    b = s.next_batch()
    assert b["tokens"].shape == (1, 8) and b["labels"].shape == (1, 8)


def test_synthetic_kv_structure():
    r = np.random.default_rng(0)
    x = synthetic_kv(r, 2, 3, 64, 32)
    assert x.shape == (2, 3, 64, 32)
    # channel means dominate token variation (paper Fig. 4 structure)
    ch_spread = x.mean(axis=2).std()
    tok_spread = x.std(axis=2).mean()
    assert ch_spread > 2 * tok_spread
