"""Sharded paged serving (ISSUE 10): mesh == single-device BIT-IDENTICAL.

The engine on a ``(dp, kv)`` mesh shards pool payloads by KV head over
``kv`` and partitions attention rows over ``dp`` while the page ledger
stays replicated (``kernels/sharded.py``). Because head sharding splits
attention into disjoint head blocks — never the softmax reduction — and
the dp merge only zeroes-and-psums rows each shard fully owns, every
float op runs in the same order on the same values as the single-device
engine. So the bar is exact equality, not tolerance: the same traffic at
``mesh_shape=(1, 1)`` and any sharded shape must emit the same tokens,
through prefill, decode, chunked admission, prefix reuse, speculative
verify, preemption swap-out/resume and session park/resume.

Multi-device cases run in a subprocess with 8 fake host devices (the
main test process stays at 1 device); quick rejection/feature-off checks
run in-process. Replaces the retired context-parallel test: the old
LSE-merge path changed reduction order and could only bound drift, the
lane path is exact.
"""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig

_CHILD = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.configs import SMOKES
from repro.core.cache import PackKVConfig
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer

scn = json.loads(sys.argv[1])
cfg = SMOKES["llama2-7b"]
params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
PAGE = 128


def build(mesh, policy, backend, mode, **kw):
    return Engine(cfg, params, PackKVConfig(policy=policy),
                  EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                               bucket_unit=64, backend=backend,
                               paged=(mode != "dense"), page_size=PAGE,
                               prefix_cache=(mode == "prefix"),
                               mesh_shape=tuple(mesh), **kw))


def drive_plain(mesh, policy, backend, mode, spec):
    srv = SlotServer(build(mesh, policy, backend, mode, spec_decode=spec))
    r = np.random.default_rng(0)
    sys_p = (r.integers(0, cfg.vocab, 2 * PAGE) if mode == "prefix"
             else np.zeros(0, np.int64))
    for rid in range(3):
        toks = np.concatenate([sys_p, r.integers(0, cfg.vocab, 100 + rid * 30)])
        srv.submit(Request(rid=rid, max_new=6, tokens=toks))
    srv.run()
    return [list(map(int, srv.done[i].output)) for i in sorted(srv.done)]


def drive_preempt(mesh):
    # class-0 arrival against a full table forces a swap-out (test_preempt)
    srv = SlotServer(build(mesh, "packkv", "xla", "paged", preempt=True,
                           decode_chunk=4, prefill_chunk_pages=1))
    r = np.random.default_rng(11)
    sys_p = r.integers(0, cfg.vocab, 2 * PAGE)
    for rid in range(2):
        srv.submit(Request(rid=rid, max_new=40, priority=1,
                           tokens=np.concatenate(
                               [sys_p, r.integers(0, cfg.vocab, 40 + 13 * rid)])))
    for _ in range(8):
        srv.step()
    srv.submit(Request(rid=2, max_new=6, priority=0,
                       tokens=r.integers(0, cfg.vocab, 100)))
    srv.run()
    assert srv.stats.preemptions >= 1, "swap-out path never fired"
    return [list(map(int, srv.done[i].output)) for i in sorted(srv.done)]


def drive_session(mesh):
    srv = SlotServer(build(mesh, "packkv", "xla", "paged", session_cache=True))
    r = np.random.default_rng(0)
    for rid in range(2):
        srv.submit(Request(rid=rid, max_new=6,
                           tokens=r.integers(0, cfg.vocab, 150 + rid * 40)))
    srv.run()
    outs = [list(map(int, srv.done[i].output)) for i in range(2)]
    for rid in range(2):
        d = srv.done[rid]
        trace = np.concatenate([np.asarray(d.tokens), np.asarray(d.output),
                                r.integers(0, cfg.vocab, 8)])
        srv.submit(Request(rid=10 + rid, max_new=6, tokens=trace))
    srv.run()
    assert srv.stats.session_hits == 2, "returning sessions missed"
    return outs + [list(map(int, srv.done[10 + i].output)) for i in range(2)]


def drive(mesh):
    kind = scn["kind"]
    if kind == "preempt":
        return drive_preempt(mesh)
    if kind == "session":
        return drive_session(mesh)
    return drive_plain(mesh, scn["policy"], scn["backend"], scn["mode"],
                       scn.get("spec", False))


ref = drive((1, 1))
diverged = [list(ms) for ms in scn["meshes"] if drive(ms) != ref]
print("RESULT " + json.dumps({"diverged": diverged, "ref": ref}))
"""


def _run_child(scenario):
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, json.dumps(scenario)],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd=".", timeout=900,
    )
    lines = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    assert lines, f"child failed:\n{r.stderr[-2000:]}"
    res = json.loads(lines[0][7:])
    assert not res["diverged"], \
        f"sharded output != single-device at meshes {res['diverged']}"
    assert res["ref"], "child produced no outputs"


@pytest.mark.slow
def test_sharded_paged_exact_all_mesh_shapes():
    """The tentpole case — packkv paged serving — over every supported
    shard count: kv in {2, 4} (head-sharded pool), dp=2 alone (row
    partition only) and the 2x2 composition."""
    _run_child({"kind": "plain", "policy": "packkv", "backend": "xla",
                "mode": "paged",
                "meshes": [[1, 2], [1, 4], [2, 1], [2, 2]]})


MATRIX = [
    # pallas paged kernels run inside the lane on local head slices
    {"kind": "plain", "policy": "packkv", "backend": "pallas",
     "mode": "paged", "meshes": [[1, 2]]},
    # uncompressed paged pool shards the same way
    {"kind": "plain", "policy": "none", "backend": "xla",
     "mode": "paged", "meshes": [[2, 2]]},
    # dense (non-paged) slot caches shard by head too
    {"kind": "plain", "policy": "packkv", "backend": "xla",
     "mode": "dense", "meshes": [[1, 2]]},
    # prefix-cache admission seeds per-slot perms through the lane
    {"kind": "plain", "policy": "packkv", "backend": "xla",
     "mode": "prefix", "meshes": [[2, 2]]},
    # speculative verify launches batch q_len=k+1 through the same lane
    {"kind": "plain", "policy": "packkv", "backend": "xla",
     "mode": "paged", "spec": True, "meshes": [[2, 2]]},
]


@pytest.mark.slow
@pytest.mark.parametrize(
    "scenario", MATRIX,
    ids=[f"{s['policy']}-{s['backend']}-{s['mode']}"
         + ("-spec" if s.get("spec") else "") for s in MATRIX])
def test_sharded_matrix_exact(scenario):
    _run_child(scenario)


@pytest.mark.slow
def test_sharded_preempt_resume_exact():
    """Swap-out gathers shard-local payloads into the same dense mini
    format as single-device, so the victim resumes bit-identically on the
    mesh."""
    _run_child({"kind": "preempt", "meshes": [[1, 2], [2, 2]]})


@pytest.mark.slow
def test_sharded_session_park_resume_exact():
    """Parked sessions cross the host boundary as full-head minis; the
    restore re-shards through the lane in_specs — hits stay exact."""
    _run_child({"kind": "session", "meshes": [[1, 2], [2, 2]]})


# -- in-process rejection / feature-off checks (single device) --------------

@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ecfg(mesh_shape):
    return EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                        bucket_unit=64, paged=True, page_size=128,
                        mesh_shape=mesh_shape)


def test_mesh_off_is_plain_engine(smoke_setup):
    cfg, params = smoke_setup
    eng = Engine(cfg, params, PackKVConfig(policy="packkv"), _ecfg((1, 1)))
    assert eng.mesh is None


def test_mesh_rejects_recurrent_family(smoke_setup):
    _, params = smoke_setup
    for arch in ("rwkv6-1.6b", "recurrentgemma-9b"):
        with pytest.raises(ValueError, match="--mesh"):
            Engine(SMOKES[arch], params, PackKVConfig(policy="none"),
                   EngineConfig(capacity=512, max_batch=2, calib_tokens=128,
                                mesh_shape=(1, 2)))


def test_mesh_rejects_indivisible_kv_heads(smoke_setup):
    cfg, params = smoke_setup  # n_kv_heads = 4
    with pytest.raises(ValueError, match="divisible"):
        Engine(cfg, params, PackKVConfig(policy="packkv"), _ecfg((1, 3)))


def test_mesh_rejects_nonpositive_shape(smoke_setup):
    cfg, params = smoke_setup
    with pytest.raises(ValueError, match="positive"):
        Engine(cfg, params, PackKVConfig(policy="packkv"), _ecfg((0, 2)))


def test_mesh_rejects_missing_devices(smoke_setup):
    cfg, params = smoke_setup  # 64x4 outsizes any test host's device count
    with pytest.raises(ValueError, match="devices"):
        Engine(cfg, params, PackKVConfig(policy="packkv"), _ecfg((64, 4)))
