"""Training stack: loss decreases, grad-accum equivalence, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.data import ShardedTokenStream
from repro.models import get_model
from repro.training import OptConfig, init_opt_state, make_schedule
from repro.training.train import make_train_step


def test_loss_decreases():
    cfg = SMOKES["smollm-135m"]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(api, cfg, OptConfig(lr=1e-3, warmup_steps=2,
                                                       total_steps=30)))
    stream = ShardedTokenStream(vocab=cfg.vocab, batch_per_host=8, seq=64)
    losses = []
    for _ in range(15):
        b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_accum_equivalence(rng):
    cfg = SMOKES["llama2-7b"]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
    }
    p1, _, m1 = make_train_step(api, cfg, oc, grad_accum=1)(
        params, init_opt_state(params), b
    )
    p2, _, m2 = make_train_step(api, cfg, oc, grad_accum=2)(
        params, init_opt_state(params), b
    )
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = jax.tree_util.tree_map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        p1, p2,
    )
    assert max(jax.tree_util.tree_leaves(d)) < 5e-3


def test_wsd_schedule_shape():
    oc = OptConfig(lr=1.0, schedule="wsd", warmup_steps=10, total_steps=100,
                   decay_frac=0.2)
    s = make_schedule(oc)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6  # end of warmup
    assert abs(float(s(50)) - 1.0) < 1e-6  # stable phase
    assert float(s(90)) < 0.6  # decaying
    assert float(s(100)) <= 0.05


def test_cosine_schedule_shape():
    oc = OptConfig(lr=2.0, schedule="cosine", warmup_steps=10, total_steps=100)
    s = make_schedule(oc)
    assert float(s(5)) == 1.0  # mid-warmup
    assert abs(float(s(10)) - 2.0) < 1e-5
    assert float(s(100)) < 1e-5


def test_moe_trains():
    cfg = SMOKES["qwen2-moe-a2.7b"]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(api, cfg, OptConfig(lr=1e-3, warmup_steps=1,
                                                       total_steps=20)))
    stream = ShardedTokenStream(vocab=cfg.vocab, batch_per_host=4, seq=64)
    losses = []
    for _ in range(8):
        b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]
