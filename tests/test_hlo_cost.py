"""The roofline instrument itself: HLO cost parser with loop multiplication."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.hlo_cost import HloAnalyzer, analyze  # noqa: E402


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    r = analyze(c.as_text())
    want = 2 * 64 * 128 * 32
    assert abs(r["flops"] - want) / want < 0.05, r["flops"]


def test_scan_multiplies_flops():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compile(f, a)
    r = analyze(c.as_text())
    want = 10 * 2 * 64 * 64 * 64
    assert abs(r["flops"] - want) / want < 0.15, r["flops"]
    assert not r["warnings"]


def test_nested_scan_multiplies():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None

            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = _compile(f, a)
    r = analyze(c.as_text())
    want = 4 * 5 * 2 * 32**3
    assert abs(r["flops"] - want) / want < 0.2, r["flops"]


def test_bytes_nonzero_and_scaled():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda x: (x * 2 + 1).sum(), a)
    r = analyze(c.as_text())
    assert r["bytes"] >= 1024 * 1024 * 4  # at least one read of the input


def test_flops_scale_with_layers():
    """The motivating bug: XLA cost_analysis is depth-blind; ours isn't."""
    import dataclasses

    from repro.configs import SMOKES
    from repro.models import get_model

    base = SMOKES["llama2-7b"]
    outs = {}
    for L in (2, 4):
        cfg = dataclasses.replace(base, n_layers=L)
        api = get_model(cfg)
        params = jax.eval_shape(lambda k: api.init(k, cfg), jax.random.PRNGKey(0))
        batch = {"tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32)}
        c = jax.jit(
            lambda p, b: api.forward_train(p, cfg, b)[0]
        ).lower(params, batch).compile()
        outs[L] = analyze(c.as_text())["flops"]
    assert outs[4] > outs[2] * 1.5, outs
