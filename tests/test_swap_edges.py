"""SwapStore / evacuation edge cases (ISSUE 9 satellite).

Host-only unit tests over the scheduler stub from the property suite —
the seams exercised here are exactly the ones the session cache leans on:

  * duplicate-rid put is a hard error (a rid can be swapped out at most
    once; re-swapping after a resume is legal);
  * pages pinned only through a swapped-out row's metadata
    (``_live_shared``) survive prefix-index eviction until the row dies,
    then become reclaimable;
  * a victim evacuated by a chunked admission stays host-side across the
    whole multi-step prefill window (page conservation holds while the
    SwapStore and an in-flight chunk task overlap) and resumes exactly;
  * a parked session restores into a reservation SMALLER than its
    original turn's (turn 2 may promise far fewer new tokens) — the
    restore pops only the parked pages, never the stale worst case.
"""
import numpy as np
import pytest

from test_scheduler_property import _StubEngine

from repro.core.cache import SwapStore
from repro.serving import EngineConfig, Request, SlotServer
from repro.serving.engine import PrefixIndex


def test_swapstore_duplicate_rid():
    """One resident entry per rid: a duplicate put asserts instead of
    silently clobbering a live evacuated row; pop -> put (a second
    preemption after a resume) is legal; drop is idempotent."""
    st = SwapStore()
    mini = {"pages": np.zeros((4, 8), np.uint8)}
    st.put(7, mini, {"shared": ()})
    with pytest.raises(AssertionError):
        st.put(7, mini, {"shared": ()})
    got, meta = st.pop(7)
    assert 7 not in st and meta == {"shared": ()}
    assert np.array_equal(got["pages"], mini["pages"])
    st.put(7, mini, {"shared": ()})  # re-swap after resume
    st.drop(7)
    st.drop(7)  # already gone: no-op
    assert len(st) == 0
    assert st.swapped_out == 2 and st.swapped_in == 1
    assert st.nbytes == 0 and st.peak_bytes == 32


class _IndexStubEngine(_StubEngine):
    """Stub + the index-release seam ``_evict_to_fit`` calls."""

    def index_release(self, cache, ids):
        cache["free"] += len(ids)
        for p in ids:
            self.released.append(int(p))
        return cache

    def __init__(self, ecfg, pool_pages):
        super().__init__(ecfg, pool_pages)
        self.released = []


def test_live_shared_pin_survives_index_eviction():
    """A swapped-out row's shared pages are pinned by metadata alone (its
    slot released the device refs at evacuation). Index eviction must
    never reclaim them while the row is host-side; once the row dies the
    pin lifts and the same page is reclaimable."""
    ecfg = EngineConfig(capacity=256, max_batch=2, paged=True, page_size=64,
                        pool_pages=4, page_watermark=0, calibrate=False,
                        prefill_chunk_pages=0, decode_chunk=1, preempt=True)
    eng = _IndexStubEngine(ecfg, 4)
    srv = SlotServer(eng)
    srv.cache = eng.alloc_slot_cache()
    # hand-build the host state: the index holds refs on pages 3 and 5,
    # page 5 is also the shared prefix of a swapped-out request
    srv._index = PrefixIndex(ecfg.page_size)
    chunks = srv._index.chunks(np.arange(2 * ecfg.page_size))
    srv._index.insert(None, chunks[0], 5)
    srv._index.insert(None, chunks[1], 3)
    srv.cache["free"] -= 2  # the refs the index notionally holds
    srv._swap.put(9, {"toks": 64},
                  {"shared": (5,), "n_pages": 1, "n_shared": 1,
                   "out": [1], "last_tok": 1, "forced": [],
                   "base": (64, 0, 64, 1)})
    assert srv._live_shared() == {5}
    # avail = 4 - 2 held = 2; asking for 3 forces ONE eviction — it must
    # be page 3 (page 5 is pinned), and asking for 4 must then block
    assert srv._evict_to_fit(3, set())
    assert eng.released == [3] and srv._index.pages == {5}
    assert not srv._evict_to_fit(4, set()), "evicted a pinned shared page"
    assert srv._index.pages == {5}
    # the swapped row dies -> pin lifts -> page 5 is reclaimable
    srv._swap.drop(9)
    assert srv._live_shared() == set()
    assert srv._evict_to_fit(4, set())
    assert eng.released == [3, 5] and srv._index.n_held == 0
    assert srv.stats.prefix_evictions == 2
    assert srv.cache["free"] == 4


def test_evacuation_overlaps_chunked_prefill():
    """A chunked admission that evacuates a victim at task start leaves the
    victim host-side for the WHOLE multi-step prefill window: conservation
    holds while the SwapStore and the in-flight task overlap, and the
    victim resumes to exactly ``max_new`` tokens."""
    page, pool = 64, 5
    ecfg = EngineConfig(capacity=192, max_batch=2, paged=True, page_size=page,
                        pool_pages=pool, page_watermark=0, calibrate=False,
                        prefill_chunk_pages=1, decode_chunk=1, preempt=True,
                        aging_steps=0)
    eng = _StubEngine(ecfg, pool)
    srv = SlotServer(eng)
    # A: low class, single-chunk prompt (fused insert), 3-page reservation
    srv.submit(Request(rid=0, max_new=100, tokens=np.zeros((64,), np.int64),
                       priority=2))
    srv.step()
    assert srv.n_occupied == 1 and not srv._swap
    # B: high class, 3-chunk prompt; needs 3 pages > 2 avail -> evacuates A
    srv.submit(Request(rid=1, max_new=62,
                       tokens=np.ones((130,), np.int64), priority=0))
    overlap = 0
    while srv.queue or srv.n_occupied or srv._task is not None:
        srv.step()
        if srv._task is not None and len(srv._swap) > 0:
            overlap += 1
        assert srv.cache["free"] + sum(srv.cache["rows"]) == pool
        assert sum(srv._reserved.values()) <= pool
    assert overlap >= 2, "victim never sat host-side across chunk steps"
    assert srv.stats.preemptions == 1 and len(srv._swap) == 0
    a, b = srv.done[0], srv.done[1]
    assert a.status == "done" and len(a.output) == 100 and a.n_preempts == 1
    assert b.status == "done" and len(b.output) == 62
    assert srv.cache["free"] == pool


def test_session_restore_into_smaller_reservation():
    """Turn 1 parks under a WORST-CASE reservation (large ``max_new``);
    the returning turn promises one token, so its reservation is smaller
    than the original. The restore must pop only the parked pages and stay
    within the smaller bound — it may not assume turn 1's worst case."""
    page, pool = 64, 6
    ecfg = EngineConfig(capacity=256, max_batch=1, paged=True, page_size=page,
                        pool_pages=pool, page_watermark=0, calibrate=False,
                        prefill_chunk_pages=0, decode_chunk=1,
                        session_cache=True)
    eng = _StubEngine(ecfg, pool)
    # slot 0's greedy constant is 1 -> eos_id=1 ends turn 1 after two
    # tokens, far short of its 100-token worst case
    srv = SlotServer(eng, eos_id=1)
    prompt = np.zeros((70,), np.int64)
    srv.submit(Request(rid=0, max_new=100, tokens=prompt))
    srv.run()
    out1 = list(srv.done[0].output)
    assert len(out1) == 2, "turn 1 should have stopped at eos"
    assert srv.stats.session_parks == 1 and srv.cache["free"] == pool
    orig_reservation = -(-min(256, 70 + 100) // page)
    assert orig_reservation == 3  # sanity: worst case of turn 1
    # turn 2: trace + one fresh token, ONE new token promised -> the new
    # reservation is STRICTLY smaller than turn 1's worst case
    trace = np.concatenate([prompt, np.asarray(out1, np.int64)])
    srv.submit(Request(rid=0, max_new=1,
                       tokens=np.concatenate([trace, [5]])))
    srv.step()
    assert srv.stats.session_hits == 1
    new_res = srv._reserved.get(0)
    assert new_res is not None and new_res < orig_reservation
    held = srv.cache["rows"][0]
    assert held <= new_res, f"restore popped {held} > reserved {new_res}"
    while srv.queue or srv.n_occupied or srv._task is not None:
        srv.step()
        assert srv.cache["free"] + sum(srv.cache["rows"]) == pool
    assert srv.done[0].status == "done" and len(srv.done[0].output) == 1
    assert srv.cache["free"] == pool
