"""Per-slot cache lifecycle: interleaved insert_prefill / append_token /
reset_slot across rows with different lengths must reproduce, row by row,
exactly what an independent batch-size-1 cache would hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ref_conserved

from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    append_token,
    append_window,
    commit_window,
    insert_prefill,
    prefill_cache,
    reset_slot,
)
from repro.data import synthetic_kv
from repro.kernels import ops

B, H, D, CAP, R = 3, 2, 64, 256, 96
SM = 1.0 / np.sqrt(D)


def _kv(rng, n):
    return (jnp.asarray(synthetic_kv(rng, 1, H, n, D)),
            jnp.asarray(synthetic_kv(rng, 1, H, n, D)))


def _attend(cfg, cache, q):
    if cfg.policy == "none":
        return ops.dense_decode_attention(
            q, cache.raw_k, cache.raw_v, cache.resid_k, cache.resid_v,
            cache.n_comp, cache.n_resid, SM)
    return ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, SM)


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_slot_ops_match_single_row_reference(rng, policy):
    cfg = PackKVConfig(policy=policy, residual=R)
    step = jax.jit(append_token)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    refs = {}  # slot -> independently grown B=1 cache

    def ref_alloc():
        return alloc_layer_cache(cfg, 1, H, D, CAP)

    # phase 1: admit rows 0/1 at different lengths (row 2 stays free)
    k0, v0 = _kv(rng, 130)  # 2 blocks + 2 residual
    k1, v1 = _kv(rng, 70)  # 1 block + 6 residual
    cache = insert_prefill(cache, 0, k0, v0)
    cache = insert_prefill(cache, 1, k1, v1)
    refs[0] = prefill_cache(ref_alloc(), k0, v0)
    refs[1] = prefill_cache(ref_alloc(), k1, v1)

    # phase 2: 100 decode appends -> row 0 flushes earlier than row 1
    for _ in range(100):
        kt, vt = _kv(rng, 1)
        full = jnp.concatenate([kt, kt * 0.5, kt * 2.0], axis=0)
        fullv = jnp.concatenate([vt, vt * 0.5, vt * 2.0], axis=0)
        cache = step(cache, full, fullv)
        refs[0] = step(refs[0], kt, vt)
        refs[1] = step(refs[1], kt * 0.5, vt * 0.5)

    # phase 3: retire row 0, recycle the slot with a fresh request
    cache = reset_slot(cache, 0)
    assert int(cache.n_comp[0]) == 0 and int(cache.n_resid[0]) == 0
    k0b, v0b = _kv(rng, 200)
    cache = insert_prefill(cache, 0, k0b, v0b)
    refs[0] = prefill_cache(ref_alloc(), k0b, v0b)

    # phase 4: more appends across the recycled + surviving rows
    for _ in range(40):
        kt, vt = _kv(rng, 1)
        full = jnp.concatenate([kt, kt * 0.5, kt * 2.0], axis=0)
        fullv = jnp.concatenate([vt, vt * 0.5, vt * 2.0], axis=0)
        cache = step(cache, full, fullv)
        refs[0] = step(refs[0], kt, vt)
        refs[1] = step(refs[1], kt * 0.5, vt * 0.5)

    assert int(cache.n_comp[0]) == int(refs[0].n_comp[0])
    assert int(cache.n_resid[1]) == int(refs[1].n_resid[0])

    # per-row decode attention equals the B=1 reference bit-for-bit
    q = jnp.asarray(rng.normal(size=(B, H * 2, D)).astype(np.float32))
    got = np.asarray(_attend(cfg, cache, q))
    for slot, ref_cache in refs.items():
        want = np.asarray(_attend(cfg, ref_cache, q[slot : slot + 1]))
        np.testing.assert_array_equal(got[slot], want[0])


def test_free_rows_do_not_leak(rng):
    """A never-used row and a reset row contribute nothing: occupied rows'
    outputs are unchanged by junk riding along in dead rows."""
    cfg = PackKVConfig(residual=R)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    k0, v0 = _kv(rng, 100)
    cache = insert_prefill(cache, 1, k0, v0)
    # dead rows 0/2 accumulate appends past a flush boundary
    step = jax.jit(append_token)
    for _ in range(100):
        kt, vt = _kv(rng, 1)
        full = jnp.concatenate([kt * 3.0, kt, kt * -2.0], axis=0)
        fullv = jnp.concatenate([vt * 3.0, vt, vt * -2.0], axis=0)
        cache = step(cache, full, fullv)
    cache = reset_slot(cache, 0)
    cache = reset_slot(cache, 2)

    q = jnp.asarray(rng.normal(size=(B, H * 2, D)).astype(np.float32))
    got = np.asarray(ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, SM))
    assert np.isfinite(got).all()
    # reset rows have zero valid tokens -> output exactly zero
    assert np.array_equal(got[0], np.zeros_like(got[0]))
    assert np.array_equal(got[2], np.zeros_like(got[2]))


# ---------------------------------------------------------------------------
# speculative verify window (ISSUE 7): append_window / commit_window
# ---------------------------------------------------------------------------

SCALE = (1.0, 0.5, 2.0)  # per-row content so rows can't alias


def _win(rng, w):
    """Batched [B, H, w, D] window + the per-row scaled views."""
    k, v = _kv(rng, w)
    return (jnp.concatenate([k * s for s in SCALE], axis=0),
            jnp.concatenate([v * s for s in SCALE], axis=0), k, v)


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_verify_window_commit_matches_reference(rng, policy):
    """Ragged window + partial commit: counters advance by exactly
    1 + n_accept (the seed flush conserves the sum), the residual bytes and
    attention match a B=1 reference that appended ONLY seed + accepted
    tokens, and rejected drafts stay dead through continued decoding."""
    cfg = PackKVConfig(policy=policy, residual=R)
    step = jax.jit(append_token)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    refs = {}
    for i, n in enumerate((191, 131, 156)):  # residuals 63 / 3 / 28
        k, v = _kv(rng, n)
        cache = insert_prefill(cache, i, k, v)
        refs[i] = prefill_cache(alloc_layer_cache(cfg, 1, H, D, CAP), k, v)
    for _ in range(33):  # row 0 hits n_resid == R: the SEED append flushes
        kt, vt = _kv(rng, 1)
        cache = step(cache, jnp.concatenate([kt * s for s in SCALE], axis=0),
                     jnp.concatenate([vt * s for s in SCALE], axis=0))
        for i, s in enumerate(SCALE):
            refs[i] = step(refs[i], kt * s, vt * s)

    kw, vw, k1, v1 = _win(rng, 4)
    lens = jnp.asarray([4, 1, 3])
    n_accept = np.array([3, 0, 1])
    c0 = np.asarray(cache.n_comp) + np.asarray(cache.n_resid)
    cache = commit_window(append_window(cache, kw, vw, lens),
                          jnp.asarray(n_accept))
    c1 = np.asarray(cache.n_comp) + np.asarray(cache.n_resid)
    np.testing.assert_array_equal(c1 - c0, 1 + n_accept)
    for i, s in enumerate(SCALE):
        for j in range(1 + n_accept[i]):
            # eager, like append_window's internal seed append (a jitted
            # flush could fuse differently at ULP level)
            refs[i] = append_token(refs[i], k1[:, :, j:j + 1] * s,
                                   v1[:, :, j:j + 1] * s)
        assert int(cache.n_comp[i]) == int(refs[i].n_comp[0])
        assert int(cache.n_resid[i]) == int(refs[i].n_resid[0])
        r = int(cache.n_resid[i])
        np.testing.assert_array_equal(cache.resid_k[i, :, :r],
                                      refs[i].resid_k[0, :, :r])

    # continued decode overwrites / keeps masking the rejected-draft bytes
    for _ in range(40):
        kt, vt = _kv(rng, 1)
        cache = step(cache, jnp.concatenate([kt * s for s in SCALE], axis=0),
                     jnp.concatenate([vt * s for s in SCALE], axis=0))
        for i, s in enumerate(SCALE):
            refs[i] = step(refs[i], kt * s, vt * s)
    q = jnp.asarray(rng.normal(size=(B, H * 2, D)).astype(np.float32))
    got = np.asarray(_attend(cfg, cache, q))
    for i in refs:
        want = np.asarray(_attend(cfg, refs[i], q[i:i + 1]))
        np.testing.assert_array_equal(got[i], want[0])


def test_verify_window_paged_refcounts(rng):
    """Drafts never touch the page ledger: the pool state after a full
    window equals the state after the seed append alone, and the commit
    conserves every refcount and ``n_comp``."""
    cfg = PackKVConfig(policy="packkv", residual=R, paged=True, page_size=64)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    k, v = _kv(rng, 96)
    cache = prefill_cache(
        cache, jnp.concatenate([k * s for s in SCALE], axis=0),
        jnp.concatenate([v * s for s in SCALE], axis=0))
    step = jax.jit(append_token)
    for _ in range(64):  # push every row to n_resid == R
        kt, vt = _kv(rng, 1)
        cache = step(cache, jnp.concatenate([kt * s for s in SCALE], axis=0),
                     jnp.concatenate([vt * s for s in SCALE], axis=0))
    assert (np.asarray(cache.n_resid) == R).all()

    kw, vw, _, _ = _win(rng, 4)
    seeded = append_token(cache, kw[..., :1, :], vw[..., :1, :])
    windowed = append_window(cache, kw, vw, jnp.asarray([4, 1, 3]))
    # the seed flush crossed a page boundary (non-trivial ledger traffic)
    assert int(seeded.pages.n_free) < int(cache.pages.n_free)
    for f in ("page_table", "free", "n_free", "ref"):
        np.testing.assert_array_equal(getattr(windowed.pages, f),
                                      getattr(seeded.pages, f), err_msg=f)

    committed = commit_window(windowed, jnp.asarray([3, 0, 1]))
    np.testing.assert_array_equal(committed.n_comp, windowed.n_comp)
    np.testing.assert_array_equal(
        np.asarray(committed.n_resid) - np.asarray(windowed.n_resid),
        [3, 0, 1])
    for f in ("page_table", "free", "n_free", "ref"):
        np.testing.assert_array_equal(getattr(committed.pages, f),
                                      getattr(windowed.pages, f), err_msg=f)
    ref_conserved(committed.pages)
