"""Per-slot cache lifecycle: interleaved insert_prefill / append_token /
reset_slot across rows with different lengths must reproduce, row by row,
exactly what an independent batch-size-1 cache would hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    append_token,
    insert_prefill,
    prefill_cache,
    reset_slot,
)
from repro.data import synthetic_kv
from repro.kernels import ops

B, H, D, CAP, R = 3, 2, 64, 256, 96
SM = 1.0 / np.sqrt(D)


def _kv(rng, n):
    return (jnp.asarray(synthetic_kv(rng, 1, H, n, D)),
            jnp.asarray(synthetic_kv(rng, 1, H, n, D)))


def _attend(cfg, cache, q):
    if cfg.policy == "none":
        return ops.dense_decode_attention(
            q, cache.raw_k, cache.raw_v, cache.resid_k, cache.resid_v,
            cache.n_comp, cache.n_resid, SM)
    return ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, SM)


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_slot_ops_match_single_row_reference(rng, policy):
    cfg = PackKVConfig(policy=policy, residual=R)
    step = jax.jit(append_token)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    refs = {}  # slot -> independently grown B=1 cache

    def ref_alloc():
        return alloc_layer_cache(cfg, 1, H, D, CAP)

    # phase 1: admit rows 0/1 at different lengths (row 2 stays free)
    k0, v0 = _kv(rng, 130)  # 2 blocks + 2 residual
    k1, v1 = _kv(rng, 70)  # 1 block + 6 residual
    cache = insert_prefill(cache, 0, k0, v0)
    cache = insert_prefill(cache, 1, k1, v1)
    refs[0] = prefill_cache(ref_alloc(), k0, v0)
    refs[1] = prefill_cache(ref_alloc(), k1, v1)

    # phase 2: 100 decode appends -> row 0 flushes earlier than row 1
    for _ in range(100):
        kt, vt = _kv(rng, 1)
        full = jnp.concatenate([kt, kt * 0.5, kt * 2.0], axis=0)
        fullv = jnp.concatenate([vt, vt * 0.5, vt * 2.0], axis=0)
        cache = step(cache, full, fullv)
        refs[0] = step(refs[0], kt, vt)
        refs[1] = step(refs[1], kt * 0.5, vt * 0.5)

    # phase 3: retire row 0, recycle the slot with a fresh request
    cache = reset_slot(cache, 0)
    assert int(cache.n_comp[0]) == 0 and int(cache.n_resid[0]) == 0
    k0b, v0b = _kv(rng, 200)
    cache = insert_prefill(cache, 0, k0b, v0b)
    refs[0] = prefill_cache(ref_alloc(), k0b, v0b)

    # phase 4: more appends across the recycled + surviving rows
    for _ in range(40):
        kt, vt = _kv(rng, 1)
        full = jnp.concatenate([kt, kt * 0.5, kt * 2.0], axis=0)
        fullv = jnp.concatenate([vt, vt * 0.5, vt * 2.0], axis=0)
        cache = step(cache, full, fullv)
        refs[0] = step(refs[0], kt, vt)
        refs[1] = step(refs[1], kt * 0.5, vt * 0.5)

    assert int(cache.n_comp[0]) == int(refs[0].n_comp[0])
    assert int(cache.n_resid[1]) == int(refs[1].n_resid[0])

    # per-row decode attention equals the B=1 reference bit-for-bit
    q = jnp.asarray(rng.normal(size=(B, H * 2, D)).astype(np.float32))
    got = np.asarray(_attend(cfg, cache, q))
    for slot, ref_cache in refs.items():
        want = np.asarray(_attend(cfg, ref_cache, q[slot : slot + 1]))
        np.testing.assert_array_equal(got[slot], want[0])


def test_free_rows_do_not_leak(rng):
    """A never-used row and a reset row contribute nothing: occupied rows'
    outputs are unchanged by junk riding along in dead rows."""
    cfg = PackKVConfig(residual=R)
    cache = alloc_layer_cache(cfg, B, H, D, CAP)
    k0, v0 = _kv(rng, 100)
    cache = insert_prefill(cache, 1, k0, v0)
    # dead rows 0/2 accumulate appends past a flush boundary
    step = jax.jit(append_token)
    for _ in range(100):
        kt, vt = _kv(rng, 1)
        full = jnp.concatenate([kt * 3.0, kt, kt * -2.0], axis=0)
        fullv = jnp.concatenate([vt * 3.0, vt, vt * -2.0], axis=0)
        cache = step(cache, full, fullv)
    cache = reset_slot(cache, 0)
    cache = reset_slot(cache, 2)

    q = jnp.asarray(rng.normal(size=(B, H * 2, D)).astype(np.float32))
    got = np.asarray(ops.packed_decode_attention(
        q, cache.k, cache.v, cache.resid_k, cache.resid_v,
        cache.n_comp, cache.n_resid, SM))
    assert np.isfinite(got).all()
    # reset rows have zero valid tokens -> output exactly zero
    assert np.array_equal(got[0], np.zeros_like(got[0]))
    assert np.array_equal(got[2], np.zeros_like(got[2]))
