"""Length-aware launches: bucketed prefix slicing, in-kernel tile skipping,
and the donated multi-step scan decode (ISSUE 3).

Invariants:
  * Attention over a bucket-sliced compressed region is BIT-IDENTICAL to the
    full-capacity launch at ragged per-row lengths, including the edges
    n_comp=0, n_comp=capacity, and lengths straddling a bucket boundary
    (dead tiles are exact flash no-ops: alpha=1, p=0).
  * Multi-step scan decode emits the same tokens as step-at-a-time decode.
  * The decode compile count is bounded by the bucket set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.cache import (
    PackKVConfig,
    alloc_layer_cache,
    bucket_length,
    bucket_set,
    calibrate_specs,
    insert_prefill,
    slice_compressed,
)
from repro.data import synthetic_kv
from repro.kernels import ops
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, SlotServer


# ---------------------------------------------------------------------------
# bucket helpers
# ---------------------------------------------------------------------------


def test_bucket_length_properties():
    cap, unit = 4096, 256
    for n in (0, 1, 255, 256, 257, 511, 512, 1000, 4095, 4096, 5000):
        b = bucket_length(n, cap, unit)
        assert b >= min(n, cap)  # covers the live prefix
        assert b <= cap
        assert b == cap or (b % unit == 0 and (b // unit) & (b // unit - 1) == 0)
    assert bucket_length(0, cap, unit) == unit
    assert bucket_length(cap, cap, unit) == cap
    # capacity <= unit: single full-capacity bucket
    assert bucket_length(10, 128, 256) == 128
    assert bucket_set(4096, 256) == (256, 512, 1024, 2048, 4096)
    assert len(bucket_set(4096, 256)) == 5  # log2(4096/256) + 1
    assert bucket_set(384, 256) == (256, 384)


# ---------------------------------------------------------------------------
# kernel-level: sliced == full capacity, bit-identical
# ---------------------------------------------------------------------------


def _ragged_cache(rng, lengths, B, Hkv, D, L):
    """Slot-table cache with per-row live lengths (0 = dead row)."""
    n_src = max(max(lengths), 64)
    k = jnp.asarray(synthetic_kv(rng, B, Hkv, n_src, D))
    v = jnp.asarray(synthetic_kv(rng, B, Hkv, n_src, D))
    cfg = calibrate_specs(k, v, PackKVConfig())
    cache = alloc_layer_cache(cfg, batch=B, h_kv=Hkv, head_dim=D, capacity=L)
    for b, n in enumerate(lengths):
        if n:
            cache = insert_prefill(cache, b, k[b, :, :n], v[b, :, :n])
    return cache


# per-row lengths chosen to hit: dead row, exactly-one-tile, straddling the
# 128-bucket boundary (65 -> n_comp 64, resid 1), and full capacity
@pytest.mark.parametrize("lengths", [(0, 64, 130), (256, 65, 0), (256, 256, 256)])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_bucketed_attention_bit_identical(rng, lengths, backend):
    B, Hkv, G, D, L = 3, 2, 2, 64, 256
    cache = _ragged_cache(rng, lengths, B, Hkv, D, L)
    n_max = int(jnp.max(cache.n_comp))
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    args = lambda c: (q, c.k, c.v, c.resid_k, c.resid_v, c.n_comp, c.n_resid,
                      0.125)
    full = ops.packed_decode_attention(*args(cache), backend=backend, tile_l=64)
    for unit in (64, 128):
        n_bucket = bucket_length(n_max, L, unit)
        sliced = slice_compressed(cache, n_bucket)
        assert sliced.k.capacity == n_bucket
        got = ops.packed_decode_attention(*args(sliced), backend=backend,
                                          tile_l=64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(full))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_bucketed_tier_matvecs_bit_identical(rng, backend):
    """kpack scores / vpack out over a sliced prefix == the full launch's
    live columns (tile skipping inside the last bucket included)."""
    B, Hkv, G, D, L = 2, 2, 2, 64, 512
    cache = _ragged_cache(rng, (200, 70), B, Hkv, D, L)
    nv = cache.n_comp  # [192, 64]
    n_bucket = bucket_length(int(jnp.max(nv)), L, 64)  # 192 live -> 256 bucket
    assert n_bucket < L
    sliced = slice_compressed(cache, n_bucket)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    s_full = ops.packed_qk_scores(q, cache.k, 0.125, n_valid=nv,
                                  backend=backend, tile_l=64)
    s_slice = ops.packed_qk_scores(q, sliced.k, 0.125, n_valid=nv,
                                   backend=backend, tile_l=64)
    np.testing.assert_array_equal(np.asarray(s_slice),
                                  np.asarray(s_full[..., :n_bucket]))
    assert np.abs(np.asarray(s_full[..., n_bucket:])).max() == 0.0
    w = jax.nn.softmax(
        jnp.asarray(rng.normal(size=(B, Hkv * G, L)).astype(np.float32)), -1
    )
    o_full = ops.packed_weighted_v(w, cache.v, n_valid=nv, backend=backend,
                                   tile_l=64)
    o_slice = ops.packed_weighted_v(w[..., :n_bucket], sliced.v, n_valid=nv,
                                    backend=backend, tile_l=64)
    np.testing.assert_array_equal(np.asarray(o_slice), np.asarray(o_full))


def test_pallas_tile_clamps_to_sliced_capacity(rng):
    """A bucket below the kernels' default tile_l (256) must lower as one
    smaller tile, not trip the L % tile_l assert (pallas backend)."""
    B, Hkv, G, D, L = 2, 2, 2, 64, 512
    cache = _ragged_cache(rng, (100, 70), B, Hkv, D, L)
    sliced = slice_compressed(cache, 128)  # < default tile_l
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    args = lambda c: (q, c.k, c.v, c.resid_k, c.resid_v, c.n_comp, c.n_resid,
                      0.125)
    full = ops.packed_decode_attention(*args(cache), backend="pallas")
    got = ops.packed_decode_attention(*args(sliced), backend="pallas")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full))


def test_slice_compressed_policy_none(rng):
    cfg = PackKVConfig(policy="none")
    cache = alloc_layer_cache(cfg, batch=2, h_kv=2, head_dim=32, capacity=256)
    sliced = slice_compressed(cache, 128)
    assert sliced.raw_k.shape[-2] == 128 and sliced.raw_v.shape[-2] == 128
    assert sliced.resid_k.shape == cache.resid_k.shape
    assert slice_compressed(cache, None) is cache
    assert slice_compressed(cache, 256) is cache


# ---------------------------------------------------------------------------
# engine-level: scan decode, bucket equivalence, compile counts
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = SMOKES["llama2-7b"]
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(eng, reqs):
    srv = SlotServer(eng)
    for r in reqs:
        srv.submit(r)
    srv.run()
    return srv


@pytest.mark.parametrize("policy", ["packkv", "none"])
def test_scan_decode_matches_stepwise(rng, smoke_setup, policy):
    """decode_chunk=4 (donated while-loop) and decode_chunk=1 (per-token
    dispatch) produce identical outputs, bucketed and not."""
    cfg, params = smoke_setup
    reqs = lambda: [
        Request(rid=0, max_new=6, tokens=rng2.integers(0, cfg.vocab, 70)),
        Request(rid=1, max_new=3, tokens=rng2.integers(0, cfg.vocab, 40)),
        Request(rid=2, max_new=9, tokens=rng2.integers(0, cfg.vocab, 100)),
    ]
    outs = []
    for chunk, bucketed in ((1, False), (4, True), (4, False)):
        rng2 = np.random.default_rng(3)
        eng = Engine(cfg, params, PackKVConfig(policy=policy),
                     EngineConfig(capacity=256, max_batch=2, calib_tokens=128,
                                  decode_chunk=chunk, bucketed=bucketed,
                                  bucket_unit=64))
        srv = _serve(eng, reqs())
        outs.append({rid: r.output for rid, r in srv.done.items()})
        if chunk > 1:
            assert srv.stats.chunk_launches < srv.stats.decode_steps
    for other in outs[1:]:
        assert set(other) == set(outs[0])
        for rid in outs[0]:
            np.testing.assert_array_equal(other[rid], outs[0][rid])


def test_scan_decode_eos_early_exit(rng, smoke_setup):
    """EOS mid-chunk: output truncated at EOS, slot freed, and the in-graph
    loop early-exits (fewer decode steps than the full budget)."""
    cfg, params = smoke_setup
    eng = Engine(cfg, params, PackKVConfig(policy="none"),
                 EngineConfig(capacity=256, max_batch=1, calib_tokens=128,
                              decode_chunk=8, bucket_unit=64))
    toks = rng.integers(0, cfg.vocab, 40)
    probe, _ = eng.generate({"tokens": jnp.asarray(toks[None], jnp.int32)}, 4)
    eos = int(probe[0, 1])
    srv = SlotServer(eng, eos_id=eos)
    srv.submit(Request(rid=0, max_new=16, tokens=toks))
    srv.run()
    out = srv.done[0].output
    assert len(out) == 2 and out[-1] == eos
    assert srv.slots == [None]
    assert srv.stats.decode_steps < 15  # early exit, not the full budget


def test_decode_compile_count_bounded_by_bucket_set(rng, smoke_setup):
    """One compile per launch bucket: the jit cache of the chunked decode
    holds at most |bucket_set| executables however many chunks ran."""
    cfg, params = smoke_setup
    eng = Engine(cfg, params, PackKVConfig(policy="none"),
                 EngineConfig(capacity=256, max_batch=2, calib_tokens=128,
                              decode_chunk=4, bucket_unit=64))
    buckets = bucket_set(256, 64)
    assert buckets == (64, 128, 256)
    reqs = [Request(rid=i, max_new=6, tokens=rng.integers(0, cfg.vocab, p))
            for i, p in enumerate((30, 40, 70, 100, 130, 200))]
    srv = _serve(eng, reqs)
    assert srv.stats.completed == len(reqs)
    assert eng._decode_multi._cache_size() <= len(buckets)
