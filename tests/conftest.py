"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device (the 512-device override belongs to dryrun.py only).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def ref_conserved(pool):
    """Shared page-pool refcount invariant: free ⇔ ref == 0, both ways
    (used by tests/test_paged.py and tests/test_prefix_cache.py)."""
    ref = np.asarray(pool.ref)
    nf = int(pool.n_free)
    assert int((ref == 0).sum()) == nf, (ref, nf)
    assert int((ref > 0).sum()) + nf == pool.n_pool_pages
    assert (ref[np.asarray(pool.free)[:nf]] == 0).all()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
