"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device (the 512-device override belongs to dryrun.py only).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
