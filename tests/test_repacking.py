"""Invariant 3: repacking emits a permutation; greedy reduces pack cost."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # degrade to skips, not collection errors
from hypothesis import given, settings, strategies as st

from repro.core.bitpack import packed_payload_bits
from repro.core.repacking import (
    greedy_repack,
    median_repack,
    median_repack_jnp,
    repack,
)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_greedy_is_permutation(seed):
    r = np.random.default_rng(seed)
    q = r.integers(0, 16, size=(32, 8))
    perm = greedy_repack(q, 8)
    assert sorted(perm.tolist()) == list(range(32))


def test_median_is_permutation(rng):
    q = rng.integers(0, 16, size=(64, 8))
    perm = median_repack(q, 8)
    assert sorted(perm.tolist()) == list(range(64))


def test_median_jnp_matches_numpy(rng):
    q = rng.integers(0, 16, size=(64, 9))
    a = median_repack(q, 8)
    b = np.asarray(median_repack_jnp(jnp.asarray(q)))
    # same median ordering (ties may differ only among equal medians)
    med = np.median(q, axis=1)
    assert (med[a] == med[b]).all()


def test_greedy_never_hurts_payload(rng):
    """Greedy repacking should not increase the bit-packed payload."""
    for _ in range(5):
        q = rng.integers(0, 11, size=(32, 16))
        base = packed_payload_bits(q, 8)
        perm = greedy_repack(q, 8)
        packed = packed_payload_bits(q[perm], 8)
        assert packed <= base


def test_greedy_wins_on_clustered_data(rng):
    """Two interleaved clusters: greedy must (nearly) separate them."""
    a = rng.integers(0, 2, size=(16, 16))
    b = rng.integers(8, 10, size=(16, 16))
    q = np.empty((32, 16), dtype=np.int64)
    q[0::2], q[1::2] = a, b  # worst-case interleaving
    base = packed_payload_bits(q, 8)
    perm = greedy_repack(q, 8)
    packed = packed_payload_bits(q[perm], 8)
    assert packed < base * 0.7


def test_repack_modes_dispatch(rng):
    qk = rng.integers(0, 11, size=(16, 8))
    qv = rng.integers(0, 11, size=(16, 8))
    for mode in ("none", "greedy_k", "greedy_v", "greedy_joint", "median_v"):
        perm = repack(qk, qv, 8, mode)
        assert sorted(perm.tolist()) == list(range(16))
    with pytest.raises(ValueError):
        repack(qk, qv, 8, "bogus")
