"""Storage tier: block-independent stream, seamless append, CR accounting."""
import numpy as np
import pytest

from repro.core.block_format import CompressedKVStream
from repro.data import synthetic_kv


def _stream_with_blocks(rng, n_blocks=3, mode="greedy_joint"):
    s = CompressedKVStream(repack_mode=mode)
    kv = synthetic_kv(rng, 1, 1, 64 * n_blocks, 64)[0, 0]
    vv = synthetic_kv(rng, 1, 1, 64 * n_blocks, 64)[0, 0]
    for b in range(n_blocks):
        s.append(kv[b * 64 : (b + 1) * 64], vv[b * 64 : (b + 1) * 64],
                 head=0, token_start=b * 64)
    return s, kv, vv


def test_append_decode_roundtrip_within_error_bound(rng):
    s, kv, vv = _stream_with_blocks(rng)
    k, v = s.decode_head(0, restore_order=True)
    # lossless after quantization: error <= scale/2 (token-wise)
    rngs = kv.max(1) - kv.min(1)
    bound = (0.1 * rngs / 2)[:, None] + 1e-6
    assert (np.abs(k - kv) <= bound).all()
    rngs_v = vv.max(1) - vv.min(1)
    assert (np.abs(v - vv) <= (0.2 * rngs_v / 2)[:, None] + 1e-6).all()


def test_block_independence(rng):
    """Decoding block i never touches other blocks (seamless appending)."""
    s, kv, vv = _stream_with_blocks(rng)
    k1, _ = s.decode_block(1, restore_order=True)
    s2 = CompressedKVStream(repack_mode="greedy_joint")
    s2.entries = [s.entries[1]]
    k1b, _ = s2.decode_block(0, restore_order=True)
    assert (k1 == k1b).all()


def test_serialize_directory(rng):
    s, _, _ = _stream_with_blocks(rng)
    flat, directory = s.serialize()
    assert len(directory) == 3
    assert directory[0]["offset_words"] == 0
    total = sum(d["k_words"] + d["v_words"] for d in directory)
    assert len(flat) == total


def test_cr_beats_kivi_on_structured_data(rng):
    """The headline: PackKV CR > quantization-only CR on KV-like data."""
    s, _, _ = _stream_with_blocks(rng, n_blocks=4)
    cr = s.compression_ratio()
    from repro.core.kivi import kivi_cr_from_rel_scale

    kivi = kivi_cr_from_rel_scale(0.1)
    assert cr > kivi, (cr, kivi)


def test_repacking_modes_cr_ordering():
    crs = {}
    for mode in ("none", "greedy_joint", "median_v"):
        s, _, _ = _stream_with_blocks(np.random.default_rng(42), mode=mode)
        crs[mode] = s.compression_ratio()
    assert crs["greedy_joint"] >= crs["none"] * 0.99
